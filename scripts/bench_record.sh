#!/usr/bin/env bash
# Regenerate the checked-in perf baselines: BENCH_decode.json and
# BENCH_sas.json from the two bench binaries' --json mode, and
# BENCH_serve.json from a `bench-serve` open-loop saturation sweep.
#
# Run it from the rust/ crate root on a quiet machine (no other load),
# e.g. in CI: bash ../scripts/bench_record.sh
#
# The JSONs record which kernel backend produced the numbers
# ("kernel_backend") plus the dispatched-vs-scalar-arm microkernel
# speedups, so a baseline recorded on an AVX2 host is distinguishable
# from one recorded on NEON or on the scalar fallback. Pass a backend
# name to pin the arm explicitly:
#
#   bash ../scripts/bench_record.sh            # auto-detected arm
#   bash ../scripts/bench_record.sh scalar     # scalar baseline
set -euo pipefail

BACKEND=${1:-auto}

[ -f Cargo.toml ] || {
  echo "bench_record: run from the rust/ crate root" >&2
  exit 1
}

cargo bench --bench decode_bench -- --json --kernel-backend "$BACKEND"
cargo bench --bench sas_bench -- --json --kernel-backend "$BACKEND"

# Serving saturation sweep: open-loop arrivals through the real TCP wire
# protocol, small enough to finish in a couple of minutes on one core
# but wide enough to cross the knee. --check validates the report
# (no transport errors, p50 <= p99 per histogram) before we keep it.
cargo run --release --quiet -- bench-serve \
  --mode open --rates 2,4,8,16,32 --requests 64 --mix longtail \
  --shared-prefix-ratio 0.3 --cancel-prob 0.05 --sparse-ratio 0.25 \
  --transport tcp --seed 7 --out BENCH_serve.json --check

for f in BENCH_decode.json BENCH_sas.json BENCH_serve.json; do
  [ -s "$f" ] || { echo "bench_record: $f was not written" >&2; exit 1; }
done
echo "bench_record: wrote BENCH_decode.json, BENCH_sas.json and BENCH_serve.json"
