#!/usr/bin/env bash
# Regenerate the checked-in perf baselines BENCH_decode.json and
# BENCH_sas.json from the two bench binaries' --json mode.
#
# Run it from the rust/ crate root on a quiet machine (no other load),
# e.g. in CI: bash ../scripts/bench_record.sh
#
# The JSONs record which kernel backend produced the numbers
# ("kernel_backend") plus the dispatched-vs-scalar-arm microkernel
# speedups, so a baseline recorded on an AVX2 host is distinguishable
# from one recorded on NEON or on the scalar fallback. Pass a backend
# name to pin the arm explicitly:
#
#   bash ../scripts/bench_record.sh            # auto-detected arm
#   bash ../scripts/bench_record.sh scalar     # scalar baseline
set -euo pipefail

BACKEND=${1:-auto}

[ -f Cargo.toml ] || {
  echo "bench_record: run from the rust/ crate root" >&2
  exit 1
}

cargo bench --bench decode_bench -- --json --kernel-backend "$BACKEND"
cargo bench --bench sas_bench -- --json --kernel-backend "$BACKEND"

for f in BENCH_decode.json BENCH_sas.json; do
  [ -s "$f" ] || { echo "bench_record: $f was not written" >&2; exit 1; }
done
echo "bench_record: wrote BENCH_decode.json and BENCH_sas.json"
