#!/usr/bin/env bash
# No-artifact streaming smoke: start `turboattn serve --path turbo-cpu`,
# drive the wire protocol over bash's /dev/tcp, and assert
#   1. at least one TOK line arrives before DONE (token streaming),
#   2. CANCEL <id> ends the request with a `cancelled` DONE,
#   3. STATS reports the cancellation,
# then shut the server down cleanly.
#
# Usage: scripts/stream_smoke.sh [path-to-turboattn] [port]
# (run from the rust/ crate root, e.g. in CI: bash ../scripts/stream_smoke.sh)
set -euo pipefail

BIN=${1:-target/release/turboattn}
PORT=${2:-7163}

"$BIN" serve --path turbo-cpu --port "$PORT" --quiet &
SRV=$!
trap 'kill "$SRV" 2>/dev/null || true; wait "$SRV" 2>/dev/null || true' EXIT

fail() { echo "stream_smoke: FAIL: $*" >&2; exit 1; }

# Wait for the listener; the whole loop's stderr is silenced because a
# refused /dev/tcp connect reports through the shell, not a command.
connected=0
for _ in $(seq 1 100); do
  if exec 3<>"/dev/tcp/127.0.0.1/$PORT"; then
    connected=1
    break
  fi
  sleep 0.2
done 2>/dev/null
[ "$connected" = 1 ] || fail "server did not come up on port $PORT"

# --- 1. streaming: TOK lines precede DONE -------------------------------
printf 'GEN 24 the stream smoke test\n' >&3
read -r ack <&3
case "$ack" in ACK\ *) ;; *) fail "expected ACK, got: $ack";; esac
toks=0 done_line=""
while read -r line <&3; do
  case "$line" in
    TOK\ *) toks=$((toks + 1)) ;;
    DONE\ *) done_line="$line"; break ;;
    *) fail "unexpected line: $line" ;;
  esac
done
[ "$toks" -ge 1 ] || fail "no TOK line before DONE"
[ "$(echo "$done_line" | awk '{print $3}')" = max_tokens ] \
  || fail "unexpected finish reason: $done_line"
echo "stream_smoke: streaming OK ($toks TOK lines before DONE)"

# --- 2. cancellation: DONE reports cancelled ----------------------------
printf 'GEN 200 cancel this long request\n' >&3
read -r ack <&3
case "$ack" in ACK\ *) ;; *) fail "expected ACK, got: $ack";; esac
id=${ack#ACK }
printf 'CANCEL %s\n' "$id" >&3
done_line=""
while read -r line <&3; do
  case "$line" in
    DONE\ *) done_line="$line"; break ;;
    TOK\ *) ;;
    *) fail "unexpected line: $line" ;;
  esac
done
[ "$(echo "$done_line" | awk '{print $3}')" = cancelled ] \
  || fail "CANCEL did not yield a cancelled DONE: $done_line"
echo "stream_smoke: cancellation OK ($done_line)"

# --- 3. STATS surfaces the cancel ---------------------------------------
printf 'STATS\n' >&3
read -r stats <&3
case "$stats" in
  STATS\ *cancelled=1*) ;;
  *) fail "STATS missing cancelled=1: $stats" ;;
esac
echo "stream_smoke: stats OK"

# --- 3b. STATS JSON: machine-readable form of the same scrape -----------
printf 'STATS JSON\n' >&3
read -r stats_json <&3
case "$stats_json" in
  STATS\ {*\"cancelled\":1*}) ;;
  *) fail "STATS JSON missing \"cancelled\":1: $stats_json" ;;
esac
echo "stream_smoke: stats json OK"

printf 'QUIT\n' >&3
read -r bye <&3
[ "$bye" = BYE ] || fail "expected BYE, got: $bye"

kill "$SRV"
wait "$SRV" 2>/dev/null || true
trap - EXIT
echo "stream_smoke: PASS"
