"""Build-time training of the tiny serving model on a synthetic corpus.

The paper evaluates on LLaMA3-8B/Qwen2-7B/Phi3 checkpoints, which are not
available in this sandbox (see DESIGN.md §2). The substitute is a small
byte-level LM trained here, at build time, on a deterministic synthetic
grammar — enough structure that next-token agreement between the exact and
quantized attention paths is a meaningful accuracy signal.

Runs once from ``make artifacts`` (aot.py calls :func:`get_params`, which
caches trained weights in artifacts/params.npz).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_lib

# Deterministic synthetic grammar: subject verb object adverb sentences.
_SUBJECTS = ["the router", "a worker", "the scheduler", "one shard",
             "the cache", "a batch", "the kernel", "this head"]
_VERBS = ["routes", "quantizes", "merges", "streams", "evicts", "scores",
          "packs", "flushes"]
_OBJECTS = ["the tokens", "eight pages", "a tile", "the buffer",
            "low bits", "two heads", "the scales", "old blocks"]
_ADVERBS = ["quickly", "in order", "without loss", "per layer", "at once",
            "lazily", "again", "safely"]


def gen_corpus(n_sentences: int = 4000, seed: int = 7) -> bytes:
    """Deterministic corpus of templated sentences (byte-level)."""
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(n_sentences):
        s = (
            f"{_SUBJECTS[rng.integers(8)]} {_VERBS[rng.integers(8)]} "
            f"{_OBJECTS[rng.integers(8)]} {_ADVERBS[rng.integers(8)]}. "
        )
        parts.append(s)
    return "".join(parts).encode("ascii")


def _batches(data: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(data) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        x = np.stack([data[i : i + seq] for i in idx])
        y = np.stack([data[i + 1 : i + seq + 1] for i in idx])
        yield jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)


def loss_fn(params, x, y, cfg):
    logits = model_lib.forward_batch(params, x, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train(
    cfg: model_lib.ModelConfig,
    steps: int = 300,
    batch: int = 16,
    seq: int = 96,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 50,
) -> model_lib.Params:
    """Adam training loop; returns trained params."""
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(key, cfg)
    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]

    @jax.jit
    def step(params, m, v, x, y, t):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg)
        gflat, _ = jax.tree_util.tree_flatten(grads)
        pflat, tree_ = jax.tree_util.tree_flatten(params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(pflat, gflat, m, v):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**t)
            vhat = vi / (1 - b2**t)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return jax.tree_util.tree_unflatten(tree_, new_p), new_m, new_v, loss

    data = np.frombuffer(gen_corpus(), dtype=np.uint8).astype(np.int32)
    t0 = time.time()
    for i, (x, y) in enumerate(_batches(data, batch, seq, steps, seed)):
        params, m, v, loss = step(params, m, v, x, y, jnp.float32(i + 1))
        if (i + 1) % log_every == 0 or i == 0:
            print(
                f"[train] step {i+1:4d}/{steps} loss={float(loss):.4f} "
                f"({time.time()-t0:.1f}s)",
                flush=True,
            )
    return params


def _flatten_with_paths(params) -> dict[str, np.ndarray]:
    out = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, val in node.items():
                walk(f"{prefix}/{k}" if prefix else k, val)
        elif isinstance(node, list):
            for i, val in enumerate(node):
                walk(f"{prefix}/{i}", val)
        else:
            out[prefix] = np.asarray(node)

    walk("", params)
    return out


def _unflatten_with_paths(flat: dict[str, np.ndarray], cfg) -> model_lib.Params:
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)

    def walk(prefix, node):
        if isinstance(node, dict):
            return {
                k: walk(f"{prefix}/{k}" if prefix else k, val)
                for k, val in node.items()
            }
        if isinstance(node, list):
            return [walk(f"{prefix}/{i}", val) for i, val in enumerate(node)]
        return jnp.asarray(flat[prefix])

    return walk("", params)


def get_params(
    cfg: model_lib.ModelConfig,
    cache_path: str = "../artifacts/params.npz",
    steps: int = 300,
) -> model_lib.Params:
    """Trained params, cached on disk so `make artifacts` trains once."""
    if os.path.exists(cache_path):
        flat = dict(np.load(cache_path))
        print(f"[train] loaded cached params from {cache_path}")
        return _unflatten_with_paths(flat, cfg)
    params = train(cfg, steps=steps)
    os.makedirs(os.path.dirname(cache_path), exist_ok=True)
    np.savez(cache_path, **_flatten_with_paths(params))
    print(f"[train] saved params to {cache_path}")
    return params


if __name__ == "__main__":
    train(model_lib.ModelConfig(), steps=100)
