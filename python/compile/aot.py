"""AOT compile path: lower every serving entrypoint to HLO **text**.

HLO text — not ``lowered.compile().serialize()`` and not a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
Rust ``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Model weights are baked into the HLO as constants (closure over trained
params), so the Rust binary is fully self-contained once artifacts exist.

Run: ``cd python && python -m compile.aot --out ../artifacts``
(idempotent; `make artifacts` wires the dependency tracking).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import train as train_lib
from .kernels import flash as flash_k
from .kernels import ref as ref_k
from .kernels import sas as sas_k
from .kernels import turbo as turbo_k

# Microbench kernel shapes (standalone attention artifacts for Rust golden
# tests and benches — independent of the model config).
MICRO_H, MICRO_N, MICRO_D = 4, 128, 32
MICRO_BLOCK = 32
SAS_ROWS, SAS_COLS = 128, 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (gen_hlo.py recipe).

    `as_hlo_text(True)` = print_large_constants: the trained weights are
    baked into the HLO as constants and the default printer elides
    anything big as `constant({...})`, which would silently destroy the
    model on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_of(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def build_entrypoints(params, cfg: model_lib.ModelConfig):
    """(name, fn, arg_specs) for every artifact."""
    c = cfg.max_ctx
    l, h, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    nb = cfg.n_cache_blocks
    i32, f32, i8 = jnp.int32, jnp.float32, jnp.int8

    prefill_turbo = functools.partial(model_lib.prefill_turbo, params, cfg)
    prefill_flash = functools.partial(model_lib.prefill_flash, params, cfg)
    decode_turbo = functools.partial(model_lib.decode_turbo, params, cfg)
    decode_flash = functools.partial(model_lib.decode_flash, params, cfg)

    def attn_turbo_micro(q, k, v):
        return (
            turbo_k.turbo_attention(
                q, k, v, br=MICRO_BLOCK, bc=MICRO_BLOCK, causal=True
            ),
        )

    def attn_flash_micro(q, k, v):
        return (
            flash_k.flash_attention(
                q, k, v, br=MICRO_BLOCK, bc=MICRO_BLOCK, causal=True
            ),
        )

    def sas_micro(x):
        return (sas_k.sas_softmax(x, block=MICRO_BLOCK),)

    return [
        (
            "prefill_turbo",
            prefill_turbo,
            [_spec((c,), i32), _spec((), i32)],
        ),
        (
            "prefill_flash",
            prefill_flash,
            [_spec((c,), i32), _spec((), i32)],
        ),
        (
            "decode_turbo",
            decode_turbo,
            [
                _spec((), i32),
                _spec((), i32),
                _spec((l, h, c, dh), i8),
                _spec((l, h, c, dh), i8),
                _spec((l, h, nb), f32),
                _spec((l, h, nb), f32),
                _spec((), i32),
            ],
        ),
        (
            "decode_flash",
            decode_flash,
            [
                _spec((), i32),
                _spec((), i32),
                _spec((l, h, c, dh), f32),
                _spec((l, h, c, dh), f32),
                _spec((), i32),
            ],
        ),
        (
            "attn_turbo_micro",
            attn_turbo_micro,
            [_spec((MICRO_H, MICRO_N, MICRO_D), f32)] * 3,
        ),
        (
            "attn_flash_micro",
            attn_flash_micro,
            [_spec((MICRO_H, MICRO_N, MICRO_D), f32)] * 3,
        ),
        ("sas_micro", sas_micro, [_spec((SAS_ROWS, SAS_COLS), f32)]),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=300)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model_lib.ModelConfig()
    params = train_lib.get_params(
        cfg, cache_path=os.path.join(args.out, "params.npz"),
        steps=args.train_steps,
    )

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_head": cfg.d_head,
            "d_ff": cfg.d_ff,
            "max_ctx": cfg.max_ctx,
            "block": cfg.block,
            "n_r": cfg.n_r,
            "int8_qmax": ref_k.INT8_QMAX,
            "sas_poly": list(ref_k.SAS_POLY),
        },
        "micro": {
            "heads": MICRO_H,
            "seq": MICRO_N,
            "d_head": MICRO_D,
            "block": MICRO_BLOCK,
            "sas_rows": SAS_ROWS,
            "sas_cols": SAS_COLS,
        },
        "artifacts": [],
    }

    for name, fn, specs in build_entrypoints(params, cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        out_list = list(outs) if isinstance(outs, (tuple, list)) else [outs]
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [_shape_of(s) for s in specs],
                "outputs": [_shape_of(s) for s in jax.tree_util.tree_leaves(out_list)],
            }
        )
        print(f"[aot] wrote {fname} ({len(text)/1e6:.2f} MB)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
