"""Layer-1 fused TurboAttention Pallas kernels (paper Algorithms 1 and 2).

Prefill: grid over (head, q-block); each grid step quantizes its Q tile to
INT8 symmetric, then streams K/V tiles through INT8 quantization, an
INT8xINT8->INT32 score matmul, SAS online softmax, INT8 P quantization and
an INT8 PV matmul, maintaining FlashAttention's running (m, l, acc) state.

Decode: grid over heads; the K/V cache arrives already at q1 level (INT8 +
per-block FP scales) — the Rust side performs the integer q2->q1
decompression (paper decode Step 2) before invoking this kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the kv loop here is
a `fori_loop` over dynamic slices of a whole-head VMEM block; on a real TPU
it becomes a third grid dimension with (m, l, acc) in VMEM scratch, and the
INT8 dots target the MXU via preferred_element_type=int32. interpret=True
throughout: CPU PJRT cannot run Mosaic custom-calls.

NOTE on jit: these wrappers are deliberately *not* jitted at definition.
When the whole wrapper is jitted with a **constant** nk_valid, XLA CPU's
constant folding of the interpret-mode kernel produces wrong masking for
padded tails (jax 0.8.2; adding a debug print makes it vanish). The AOT
artifacts always pass nq_valid/nk_valid as *traced* runtime scalars, which
compiles correctly — test_attention_kernels.py has a regression test
pinning the traced-jit == eager behaviour.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .sas import NEG_BIG, sas_exp_inline

INTERPRET = True


def _quant_tile(x):
    """Symmetric INT8 tile quantization, kernel-inline (Algorithm 1)."""
    amax = jnp.max(jnp.abs(x))
    s = jnp.maximum(amax / ref.INT8_QMAX, 1e-8)
    q = jnp.clip(jnp.round(x / s), -127.0, 127.0).astype(jnp.int8)
    return q, s


def _idot(a, b):
    """INT8 x INT8 -> INT32 dot (MXU path on TPU; numpy under interpret)."""
    return jax.lax.dot(
        a.astype(jnp.int32),
        b.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def _turbo_prefill_kernel(
    bc: int, n_r: float, causal: bool,
    q_ref, k_ref, v_ref, lut_ref, nvalid_ref, o_ref,
):
    i = pl.program_id(1)
    q = q_ref[0]  # [br, d]
    br, d = q.shape
    k_all = k_ref[0]  # [nk_pad, d]
    v_all = v_ref[0]
    lut = lut_ref[...]
    nq_valid = nvalid_ref[0]
    nk_valid = nvalid_ref[1]
    nk_pad = k_all.shape[0]
    tc = nk_pad // bc
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    q8, sq = _quant_tile(q)
    q8i = q8.astype(jnp.int32)
    qpos = i * br + jax.lax.iota(jnp.int32, br)  # absolute q row index

    def body(j, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice(k_all, (j * bc, 0), (bc, d))
        vb = jax.lax.dynamic_slice(v_all, (j * bc, 0), (bc, d))
        k8, sk = _quant_tile(kb)
        v8, sv = _quant_tile(vb)
        s_ij = (
            _idot(q8i, k8.astype(jnp.int32).T).astype(jnp.float32)
            * (sq * sk * scale)
        )
        kpos = j * bc + jax.lax.iota(jnp.int32, bc)
        mask = kpos[None, :] < nk_valid
        if causal:
            # q row r is absolute position (nk_valid - nq_valid + qpos[r]).
            apos = qpos[:, None] + (nk_valid - nq_valid)
            mask = jnp.logical_and(mask, kpos[None, :] <= apos)
        s_ij = jnp.where(mask, s_ij, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
        p = sas_exp_inline(s_ij - m_new[:, None], lut, n_r)
        alpha = sas_exp_inline(m - m_new, lut, n_r)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        p8, sp = _quant_tile(p)
        pv = (
            _idot(p8.astype(jnp.int32), v8.astype(jnp.int32)).astype(
                jnp.float32
            )
            * (sp * sv)
        )
        acc_new = alpha[:, None] * acc + pv
        # Blocks entirely past the valid length must not touch the state
        # (the SAS rescale of a no-op block is 0.9996, not exactly 1).
        live = (j * bc) < nk_valid
        m = jnp.where(live, m_new, m)
        l = jnp.where(live, l_new, l)
        acc = jnp.where(live, acc_new, acc)
        return m, l, acc

    m0 = jnp.full((br,), NEG_BIG, jnp.float32)
    l0 = jnp.zeros((br,), jnp.float32)
    a0 = jnp.zeros((br, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, tc, body, (m0, l0, a0))
    o_ref[0] = acc / jnp.maximum(l, 1e-20)[:, None]


def turbo_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    nq_valid: jax.Array | None = None,
    nk_valid: jax.Array | None = None,
    *,
    br: int = ref.DEFAULT_BR,
    bc: int = ref.DEFAULT_BC,
    n_r: float = ref.SAS_NR,
    causal: bool = False,
) -> jax.Array:
    """Multi-head fused TurboAttention prefill over [H, Nq, d] / [H, Nk, d].

    Pads sequence dims to tile multiples internally; returns [H, Nq, d].
    ``nq_valid``/``nk_valid`` may be traced i32 scalars so one compiled
    executable serves every sequence length up to the padded shape.
    """
    h, nq, d = q.shape
    nk = k.shape[1]
    nq_pad = -(-nq // br) * br
    nk_pad = -(-nk // bc) * bc
    qp = jnp.pad(q, ((0, 0), (0, nq_pad - nq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk_pad - nk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk_pad - nk), (0, 0)))
    lut = ref.sas_lut(n_r)
    if nq_valid is None:
        nq_valid = jnp.int32(nq)
    if nk_valid is None:
        nk_valid = jnp.int32(nk)
    nvalid = jnp.stack(
        [jnp.asarray(nq_valid, jnp.int32), jnp.asarray(nk_valid, jnp.int32)]
    )
    out = pl.pallas_call(
        functools.partial(_turbo_prefill_kernel, bc, n_r, causal),
        grid=(h, nq_pad // br),
        in_specs=[
            pl.BlockSpec((1, br, d), lambda hh, ii: (hh, ii, 0)),
            pl.BlockSpec((1, nk_pad, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((1, nk_pad, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((lut.shape[0],), lambda hh, ii: (0,)),
            pl.BlockSpec((2,), lambda hh, ii: (0,)),
        ],
        out_specs=[pl.BlockSpec((1, br, d), lambda hh, ii: (hh, ii, 0))],
        out_shape=[jax.ShapeDtypeStruct((h, nq_pad, d), jnp.float32)],
        interpret=INTERPRET,
    )(qp, kp, vp, lut, nvalid)[0]
    return out[:, :nq]


def _turbo_decode_kernel(
    bc: int, n_r: float,
    q_ref, k8_ref, v8_ref, sk_ref, sv_ref, lut_ref, nvalid_ref,
    o_ref, m_ref, l_ref,
):
    q = q_ref[0]  # [d]
    d = q.shape[0]
    k8 = k8_ref[0]  # [nk_pad, d] int8 (q1 level)
    v8 = v8_ref[0]
    sk = sk_ref[0]  # [tc] per-block fp scales
    sv = sv_ref[0]
    lut = lut_ref[...]
    nk_valid = nvalid_ref[0]
    nk_pad = k8.shape[0]
    tc = nk_pad // bc
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    q8, sq = _quant_tile(q)
    q8i = q8.astype(jnp.int32)[None, :]  # [1, d]

    def body(j, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice(k8, (j * bc, 0), (bc, d)).astype(jnp.int32)
        vb = jax.lax.dynamic_slice(v8, (j * bc, 0), (bc, d)).astype(jnp.int32)
        s_j = (
            _idot(q8i, kb.T).astype(jnp.float32)[0] * (sq * sk[j] * scale)
        )
        kpos = j * bc + jax.lax.iota(jnp.int32, bc)
        s_j = jnp.where(kpos < nk_valid, s_j, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(s_j))
        p = sas_exp_inline(s_j - m_new, lut, n_r)
        alpha = sas_exp_inline(m - m_new, lut, n_r)
        l_new = alpha * l + jnp.sum(p)
        p8, sp = _quant_tile(p)
        pv = (
            _idot(p8.astype(jnp.int32)[None, :], vb).astype(jnp.float32)[0]
            * (sp * sv[j])
        )
        acc_new = alpha * acc + pv
        live = (j * bc) < nk_valid
        m = jnp.where(live, m_new, m)
        l = jnp.where(live, l_new, l)
        acc = jnp.where(live, acc_new, acc)
        return m, l, acc

    m0 = jnp.float32(NEG_BIG)
    l0 = jnp.float32(0.0)
    a0 = jnp.zeros((d,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, tc, body, (m0, l0, a0))
    o_ref[0] = acc / jnp.maximum(l, 1e-20)
    m_ref[0] = m
    l_ref[0] = l


def turbo_decode(
    q: jax.Array,
    k8: jax.Array,
    v8: jax.Array,
    sk: jax.Array,
    sv: jax.Array,
    nk_valid: jax.Array,
    *,
    bc: int = ref.DEFAULT_BC,
    n_r: float = ref.SAS_NR,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Multi-head TurboAttention decode step (Algorithm 2).

    q [H, d] float; k8/v8 [H, nk_pad, d] int8 (q1 cache, page-aligned);
    sk/sv [H, tc] per-block scales; nk_valid traced scalar — the same
    compiled executable serves every context length up to nk_pad.

    Returns (out [H, d], m [H], l [H]): the un-merged online-softmax state
    so the caller can fold in tokens that are not yet in the INT8 cache
    (the model's current token — see model.py decode path).
    """
    h, nk_pad, d = k8.shape
    tc = nk_pad // bc
    lut = ref.sas_lut(n_r)
    nvalid = jnp.reshape(nk_valid.astype(jnp.int32), (1,))
    out, m, l = pl.pallas_call(
        functools.partial(_turbo_decode_kernel, bc, n_r),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda hh: (hh, 0)),
            pl.BlockSpec((1, nk_pad, d), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((1, nk_pad, d), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((1, tc), lambda hh: (hh, 0)),
            pl.BlockSpec((1, tc), lambda hh: (hh, 0)),
            pl.BlockSpec((lut.shape[0],), lambda hh: (0,)),
            pl.BlockSpec((1,), lambda hh: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda hh: (hh, 0)),
            pl.BlockSpec((1,), lambda hh: (hh,)),
            pl.BlockSpec((1,), lambda hh: (hh,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, d), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k8, v8, sk, sv, lut, nvalid)
    return out, m, l
