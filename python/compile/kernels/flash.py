"""Layer-1 FP32 FlashAttention baseline Pallas kernel.

Exact-exp tiled online-softmax attention — the paper's "FlashAttention
FP16/32" comparator. Structure mirrors turbo.py so the two kernels differ
only in what TurboAttention changes: tile quantization and SAS.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .sas import NEG_BIG

INTERPRET = True


def _flash_kernel(bc: int, causal: bool, q_ref, k_ref, v_ref, nvalid_ref, o_ref):
    i = pl.program_id(1)
    q = q_ref[0]
    br, d = q.shape
    k_all = k_ref[0]
    v_all = v_ref[0]
    nq_valid = nvalid_ref[0]
    nk_valid = nvalid_ref[1]
    nk_pad = k_all.shape[0]
    tc = nk_pad // bc
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qpos = i * br + jax.lax.iota(jnp.int32, br)

    def body(j, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice(k_all, (j * bc, 0), (bc, d))
        vb = jax.lax.dynamic_slice(v_all, (j * bc, 0), (bc, d))
        s_ij = jax.lax.dot(q, kb.T, preferred_element_type=jnp.float32) * scale
        kpos = j * bc + jax.lax.iota(jnp.int32, bc)
        mask = kpos[None, :] < nk_valid
        if causal:
            apos = qpos[:, None] + (nk_valid - nq_valid)
            mask = jnp.logical_and(mask, kpos[None, :] <= apos)
        s_ij = jnp.where(mask, s_ij, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
        p = jnp.exp(jnp.maximum(s_ij - m_new[:, None], NEG_BIG))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(jnp.maximum(m - m_new, NEG_BIG))
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[:, None] * acc + jax.lax.dot(
            p, vb, preferred_element_type=jnp.float32
        )
        live = (j * bc) < nk_valid
        m = jnp.where(live, m_new, m)
        l = jnp.where(live, l_new, l)
        acc = jnp.where(live, acc_new, acc)
        return m, l, acc

    m0 = jnp.full((br,), NEG_BIG, jnp.float32)
    l0 = jnp.zeros((br,), jnp.float32)
    a0 = jnp.zeros((br, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, tc, body, (m0, l0, a0))
    o_ref[0] = acc / jnp.maximum(l, 1e-20)[:, None]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    nq_valid: jax.Array | None = None,
    nk_valid: jax.Array | None = None,
    *,
    br: int = ref.DEFAULT_BR,
    bc: int = ref.DEFAULT_BC,
    causal: bool = False,
) -> jax.Array:
    """Multi-head exact tiled attention over [H, Nq, d] / [H, Nk, d]."""
    h, nq, d = q.shape
    nk = k.shape[1]
    nq_pad = -(-nq // br) * br
    nk_pad = -(-nk // bc) * bc
    qp = jnp.pad(q, ((0, 0), (0, nq_pad - nq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk_pad - nk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk_pad - nk), (0, 0)))
    if nq_valid is None:
        nq_valid = jnp.int32(nq)
    if nk_valid is None:
        nk_valid = jnp.int32(nk)
    nvalid = jnp.stack(
        [jnp.asarray(nq_valid, jnp.int32), jnp.asarray(nk_valid, jnp.int32)]
    )
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bc, causal),
        grid=(h, nq_pad // br),
        in_specs=[
            pl.BlockSpec((1, br, d), lambda hh, ii: (hh, ii, 0)),
            pl.BlockSpec((1, nk_pad, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((1, nk_pad, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((2,), lambda hh, ii: (0,)),
        ],
        out_specs=[pl.BlockSpec((1, br, d), lambda hh, ii: (hh, ii, 0))],
        out_shape=[jax.ShapeDtypeStruct((h, nq_pad, d), jnp.float32)],
        interpret=INTERPRET,
    )(qp, kp, vp, nvalid)[0]
    return out[:, :nq]
