"""Pure-jnp reference oracles for every TurboAttention kernel.

These are the CORE correctness signal: each Pallas kernel in this package
is validated against the matching function here by pytest (with hypothesis
shape sweeps), and the Rust CPU engine is validated against the same math
via golden vectors.

Numerics follow the paper exactly:
  * INT8 symmetric blockwise quantization with scale = max|x| / 119
    (TurboAttention Algorithm 1; 119 leaves headroom below 127 so the
    running-rescale in online softmax cannot overflow int8).
  * Progressive asymmetric INT4/INT2 channelwise-group compression of the
    INT8 tensors, with INT8 integer scale/zero-point (paper Eq. 7/8 and
    Algorithm 1 write-back step).
  * SAS: e^{-t} = LUT(t_int) * POLY(t_dec), cubic least-squares POLY on
    [0,1) (paper Eq. 15), sparsity threshold n_r (paper Eq. 14).
  * Algorithm 1 (prefill) / Algorithm 2 (decode) fused dataflow with
    online softmax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Paper constants
# --------------------------------------------------------------------------

#: Symmetric INT8 range used by TurboAttention (max|x| maps to 119).
INT8_QMAX = 119.0

#: SAS cubic polynomial coefficients for e^{-x} on [0, 1) — paper Eq. 15.
SAS_POLY = (-0.1025, 0.4626, -0.9922, 0.9996)

#: SAS sparsity threshold: scores below n_r (after max-subtraction) -> 0.
SAS_NR = -6.0

#: Default FlashAttention tile sizes (B_r, B_c) — paper §5.2 uses 64.
DEFAULT_BR = 64
DEFAULT_BC = 64


# --------------------------------------------------------------------------
# Quantization primitives
# --------------------------------------------------------------------------


def quant_sym_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric INT8 quantization: q = round(x/s), s = max|x|/119.

    Returns (q int8, s f32 scalar). The caller decides block granularity by
    what it passes in (a FlashAttention tile in Algorithm 1).
    """
    amax = jnp.max(jnp.abs(x))
    s = jnp.maximum(amax / INT8_QMAX, 1e-8).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s), -127.0, 127.0).astype(jnp.int8)
    return q, s


def dequant_sym_int8(q: jax.Array, s: jax.Array) -> jax.Array:
    """Inverse of :func:`quant_sym_int8`."""
    return q.astype(jnp.float32) * s


def quant_asym_int(
    q1: jax.Array, bits: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Progressive step: asymmetric INT4/INT2 compression of an INT8 tensor.

    Channelwise (axis 0 = tokens, axis 1 = channels): each channel of the
    q1 (int8) block gets an integer scale and zero point, themselves
    representable in INT8 (paper Eq. 7/8).

        s_int = max(1, ceil((max - min) / (2^bits - 1)))   (int)
        z_int = floor(min / s_int)                          (int)
        q2    = clip(round(q1 / s_int) - z_int, 0, 2^bits-1)

    Dequantization (pure integer, the decode hot path):

        q1' = (q2 + z_int) * s_int

    Returns (q2 int8-held codes in [0, 2^bits-1], s_int int32 per channel,
    z_int int32 per channel).
    """
    assert bits in (2, 3, 4), bits
    levels = (1 << bits) - 1
    q1i = q1.astype(jnp.int32)
    cmin = jnp.min(q1i, axis=0)
    cmax = jnp.max(q1i, axis=0)
    s_int = jnp.maximum((cmax - cmin + levels - 1) // levels, 1)
    z_int = jnp.floor_divide(cmin, s_int)
    # Round-to-nearest in integer arithmetic, valid for signed q1:
    # floor((2*q1 + s) / (2*s)).
    rounded = jnp.floor_divide(2 * q1i + s_int, 2 * s_int)
    q2 = jnp.clip(rounded - z_int, 0, levels)
    return q2.astype(jnp.int8), s_int.astype(jnp.int32), z_int.astype(jnp.int32)


def dequant_asym_int(
    q2: jax.Array, s_int: jax.Array, z_int: jax.Array
) -> jax.Array:
    """Integer q2 -> q1 dequantization (paper Algorithm 2, Step 2)."""
    q1 = (q2.astype(jnp.int32) + z_int) * s_int
    return jnp.clip(q1, -127, 127).astype(jnp.int8)


def progressive_quant(
    x: jax.Array, bits: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Full BPQ pipeline float -> (q2, s_int, z_int, s_fp)."""
    q1, s_fp = quant_sym_int8(x)
    q2, s_int, z_int = quant_asym_int(q1, bits)
    return q2, s_int, z_int, s_fp


def progressive_dequant(
    q2: jax.Array, s_int: jax.Array, z_int: jax.Array, s_fp: jax.Array
) -> jax.Array:
    """Full inverse of :func:`progressive_quant` (to float, for oracles)."""
    return dequant_asym_int(q2, s_int, z_int).astype(jnp.float32) * s_fp


def quant_asym_float_grouped(
    x: jax.Array, bits: int, group: int, axis: int
) -> jax.Array:
    """KIVI-style fake-quant: asymmetric float-scale group quantization.

    Used by the KIVI/GEAR baselines. ``axis`` is the dimension along which
    groups of size ``group`` share a scale (0 = per-channel groups down the
    token axis, 1 = per-token groups across channels). Returns the
    dequantized tensor (fake quant) — baselines decompress to float before
    attention, which is exactly the overhead TurboAttention removes.
    """
    assert x.ndim == 2
    levels = (1 << bits) - 1
    moved = jnp.moveaxis(x, axis, 0)  # group axis first
    n = moved.shape[0]
    pad = (-n) % group
    padded = jnp.pad(moved, ((0, pad), (0, 0)), constant_values=0.0)
    g = padded.reshape(-1, group, padded.shape[1])
    gmin = jnp.min(g, axis=1, keepdims=True)
    gmax = jnp.max(g, axis=1, keepdims=True)
    scale = jnp.maximum((gmax - gmin) / levels, 1e-8)
    q = jnp.clip(jnp.round((g - gmin) / scale), 0, levels)
    deq = q * scale + gmin
    deq = deq.reshape(padded.shape)[:n]
    return jnp.moveaxis(deq, 0, axis)


# --------------------------------------------------------------------------
# SAS: Sparse Activated Softmax
# --------------------------------------------------------------------------


def sas_lut(n_r: float = SAS_NR) -> jax.Array:
    """Lookup table LUT[i] = e^{-i} for i = 0..|n_r|, with a trailing 0."""
    depth = int(-n_r)
    idx = jnp.arange(depth + 2, dtype=jnp.float32)
    lut = jnp.exp(-idx)
    return lut.at[depth + 1].set(0.0)


def sas_poly(t: jax.Array) -> jax.Array:
    """Cubic approximation of e^{-t} for t in [0, 1) — paper Eq. 15."""
    c3, c2, c1, c0 = SAS_POLY
    return ((c3 * t + c2) * t + c1) * t + c0


def sas_exp(x: jax.Array, n_r: float = SAS_NR) -> jax.Array:
    """SAS approximation of e^{x} for x <= 0 (paper Eq. 13/14).

    Scores below the sparsity threshold n_r return exactly 0.
    """
    t = -x  # t >= 0
    depth = int(-n_r)
    t_int = jnp.floor(t)
    t_dec = t - t_int
    lut = sas_lut(n_r)
    idx = jnp.clip(t_int, 0, depth + 1).astype(jnp.int32)
    val = lut[idx] * sas_poly(t_dec)
    return jnp.where(x < n_r, 0.0, val)


def sas_softmax(x: jax.Array, n_r: float = SAS_NR) -> jax.Array:
    """Row-wise SAS softmax (paper Algorithm 3)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = sas_exp(x - m, n_r)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-20)


# --------------------------------------------------------------------------
# Attention references
# --------------------------------------------------------------------------


def attention_exact(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = False
) -> jax.Array:
    """Exact softmax attention over a single head: [Nq,d],[Nk,d],[Nk,d]."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        nq, nk = s.shape
        # Row i of q corresponds to absolute position (nk - nq + i).
        qpos = jnp.arange(nq)[:, None] + (nk - nq)
        kpos = jnp.arange(nk)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v


def _blocks(n: int, b: int) -> int:
    return (n + b - 1) // b


def turbo_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    br: int = DEFAULT_BR,
    bc: int = DEFAULT_BC,
    n_r: float = SAS_NR,
    causal: bool = False,
    kv_bits: int | None = None,
) -> jax.Array:
    """Reference implementation of TurboAttention prefill (Algorithm 1).

    Single head. Blocked online softmax where every matmul runs over
    INT8-quantized tiles and every exponentiation goes through SAS.
    If ``kv_bits`` is 2/3/4, K and V tiles are additionally round-tripped
    through progressive quantization before use, so tests can measure the
    full-pipeline (q2-cache) error that decode sees.
    """
    nq, d = q.shape
    nk = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    tr, tc = _blocks(nq, br), _blocks(nk, bc)
    out = jnp.zeros((nq, d), jnp.float32)
    for i in range(tr):
        q_blk = q[i * br : (i + 1) * br]
        rb = q_blk.shape[0]
        q8, sq = quant_sym_int8(q_blk)
        m = jnp.full((rb,), -jnp.inf, jnp.float32)
        l = jnp.zeros((rb,), jnp.float32)
        acc = jnp.zeros((rb, d), jnp.float32)
        for j in range(tc):
            k_blk = k[j * bc : (j + 1) * bc]
            v_blk = v[j * bc : (j + 1) * bc]
            if kv_bits is not None:
                k_blk = progressive_dequant(*progressive_quant(k_blk, kv_bits))
                v_blk = progressive_dequant(*progressive_quant(v_blk, kv_bits))
            k8, sk = quant_sym_int8(k_blk)
            v8, sv = quant_sym_int8(v_blk)
            s_ij = (
                jnp.dot(q8.astype(jnp.int32), k8.astype(jnp.int32).T).astype(
                    jnp.float32
                )
                * sq
                * sk
                * scale
            )
            if causal:
                qpos = jnp.arange(i * br, i * br + rb)[:, None] + (nk - nq)
                kpos = jnp.arange(j * bc, j * bc + k_blk.shape[0])[None, :]
                s_ij = jnp.where(kpos <= qpos, s_ij, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
            # Guard fully-masked rows: keep m finite for the SAS argument.
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = sas_exp(
                jnp.where(jnp.isfinite(s_ij), s_ij - m_safe[:, None], -jnp.inf),
                n_r,
            )
            alpha = sas_exp(
                jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf), n_r
            )
            l = alpha * l + jnp.sum(p, axis=-1)
            p8, sp = quant_sym_int8(p)
            pv = (
                jnp.dot(p8.astype(jnp.int32), v8.astype(jnp.int32)).astype(
                    jnp.float32
                )
                * sp
                * sv
            )
            acc = alpha[:, None] * acc + pv
            m = m_new
        out = out.at[i * br : i * br + rb].set(
            acc / jnp.maximum(l, 1e-20)[:, None]
        )
    return out


def turbo_decode_ref(
    q: jax.Array,
    k8: jax.Array,
    v8: jax.Array,
    sk: jax.Array,
    sv: jax.Array,
    *,
    bc: int = DEFAULT_BC,
    n_r: float = SAS_NR,
) -> jax.Array:
    """Reference TurboAttention decode (Algorithm 2), single head.

    ``k8``/``v8`` are the INT8 (q1-level) cache produced by the Rust side's
    q2->q1 integer dequantization; ``sk``/``sv`` are the per-block FP scales
    from the original symmetric step, shape [n_blocks].
    """
    (d,) = q.shape
    nk = k8.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    tc = _blocks(nk, bc)
    q8, sq = quant_sym_int8(q)
    m = jnp.float32(-jnp.inf)
    l = jnp.float32(0.0)
    acc = jnp.zeros((d,), jnp.float32)
    for j in range(tc):
        kb = k8[j * bc : (j + 1) * bc].astype(jnp.int32)
        vb = v8[j * bc : (j + 1) * bc].astype(jnp.int32)
        s_j = (
            jnp.dot(q8.astype(jnp.int32), kb.T).astype(jnp.float32)
            * sq
            * sk[j]
            * scale
        )
        m_new = jnp.maximum(m, jnp.max(s_j))
        p = sas_exp(s_j - m_new, n_r)
        alpha = sas_exp(jnp.where(jnp.isfinite(m), m - m_new, -jnp.inf), n_r)
        l = alpha * l + jnp.sum(p)
        p8, sp = quant_sym_int8(p)
        pv = jnp.dot(p8.astype(jnp.int32), vb).astype(jnp.float32) * sp * sv[j]
        acc = alpha * acc + pv
        m = m_new
    return acc / jnp.maximum(l, 1e-20)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    br: int = DEFAULT_BR,
    bc: int = DEFAULT_BC,
    causal: bool = False,
) -> jax.Array:
    """FP32 tiled FlashAttention (exact exp) — the paper's baseline."""
    nq, d = q.shape
    nk = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    tr, tc = _blocks(nq, br), _blocks(nk, bc)
    out = jnp.zeros((nq, d), jnp.float32)
    for i in range(tr):
        q_blk = q[i * br : (i + 1) * br]
        rb = q_blk.shape[0]
        m = jnp.full((rb,), -jnp.inf, jnp.float32)
        l = jnp.zeros((rb,), jnp.float32)
        acc = jnp.zeros((rb, d), jnp.float32)
        for j in range(tc):
            k_blk = k[j * bc : (j + 1) * bc]
            v_blk = v[j * bc : (j + 1) * bc]
            s_ij = (q_blk @ k_blk.T) * scale
            if causal:
                qpos = jnp.arange(i * br, i * br + rb)[:, None] + (nk - nq)
                kpos = jnp.arange(j * bc, j * bc + k_blk.shape[0])[None, :]
                s_ij = jnp.where(kpos <= qpos, s_ij, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(
                jnp.isfinite(s_ij), jnp.exp(s_ij - m_safe[:, None]), 0.0
            )
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = alpha[:, None] * acc + p @ v_blk
            m = m_new
        out = out.at[i * br : i * br + rb].set(
            acc / jnp.maximum(l, 1e-20)[:, None]
        )
    return out


# --------------------------------------------------------------------------
# Headwise mixed precision (paper §3.2)
# --------------------------------------------------------------------------


def head_priority(kv: jax.Array) -> jax.Array:
    """priority^(h) = gap^(h) * std^(h) over a [H, N, d] K (or V) tensor.

    gap  = max-min range across all channels of the head,
    std  = standard deviation of the per-channel gaps.
    """
    cmax = jnp.max(kv, axis=1)  # [H, d]
    cmin = jnp.min(kv, axis=1)
    gaps = cmax - cmin  # per-channel gap, [H, d]
    gap = jnp.max(cmax, axis=-1) - jnp.min(cmin, axis=-1)  # [H]
    std = jnp.std(gaps, axis=-1)
    return gap * std


def select_2bit_heads(priority: jax.Array, n_h: int) -> jax.Array:
    """Boolean mask of heads assigned 2-bit (the n_h lowest-priority)."""
    order = jnp.argsort(priority)
    mask = jnp.zeros(priority.shape, bool)
    return mask.at[order[:n_h]].set(True)
