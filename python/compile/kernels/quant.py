"""Layer-1 Pallas quantization kernels (FlashQ building blocks).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that both the
pytest oracle checks and the Rust runtime can execute. Block shapes are
still chosen as if targeting TPU VMEM (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True  # CPU PJRT: Mosaic lowering unavailable (see DESIGN.md)


def _quant_sym_kernel(x_ref, q_ref, s_ref):
    """Per-grid-block symmetric INT8 quantization (paper Eq. 9)."""
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x))
    s = jnp.maximum(amax / ref.INT8_QMAX, 1e-8)
    q_ref[...] = jnp.clip(jnp.round(x / s), -127.0, 127.0).astype(jnp.int8)
    s_ref[0] = s


def quant_sym_int8_blocked(
    x: jax.Array, block: int = ref.DEFAULT_BC
) -> tuple[jax.Array, jax.Array]:
    """Quantize [n, d] to INT8 with one symmetric scale per row-block.

    Returns (q int8 [n, d], scales f32 [n_blocks]). ``n`` must be a
    multiple of ``block`` (the caller pads; the KV cache is page-aligned).
    """
    n, d = x.shape
    assert n % block == 0, (n, block)
    nb = n // block
    q, s = pl.pallas_call(
        _quant_sym_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x)
    return q, s


def _quant_asym_kernel(levels: int, q1_ref, q2_ref, s_ref, z_ref):
    """Channelwise asymmetric INT-k compression of an INT8 block (Eq. 10)."""
    q1 = q1_ref[...].astype(jnp.int32)
    cmin = jnp.min(q1, axis=0)
    cmax = jnp.max(q1, axis=0)
    s_int = jnp.maximum((cmax - cmin + levels - 1) // levels, 1)
    z_int = jnp.floor_divide(cmin, s_int)
    rounded = jnp.floor_divide(2 * q1 + s_int, 2 * s_int)
    q2_ref[...] = jnp.clip(rounded - z_int, 0, levels).astype(jnp.int8)
    s_ref[...] = s_int.astype(jnp.int32)[None, :]
    z_ref[...] = z_int.astype(jnp.int32)[None, :]


def quant_asym_blocked(
    q1: jax.Array, bits: int, block: int = ref.DEFAULT_BC
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Progressive q1->q2 compression, per row-block, channelwise.

    Returns (q2 codes int8 [n, d], s_int int32 [nb, d], z_int int32 [nb, d]).
    """
    n, d = q1.shape
    assert n % block == 0
    nb = n // block
    levels = (1 << bits) - 1
    return pl.pallas_call(
        functools.partial(_quant_asym_kernel, levels),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((nb, d), jnp.int32),
            jax.ShapeDtypeStruct((nb, d), jnp.int32),
        ],
        interpret=INTERPRET,
    )(q1)


def _dequant_asym_kernel(q2_ref, s_ref, z_ref, q1_ref):
    """Integer q2 -> q1 decompression (decode Step 2)."""
    q1 = (q2_ref[...].astype(jnp.int32) + z_ref[...]) * s_ref[...]
    q1_ref[...] = jnp.clip(q1, -127, 127).astype(jnp.int8)


def dequant_asym_blocked(
    q2: jax.Array,
    s_int: jax.Array,
    z_int: jax.Array,
    block: int = ref.DEFAULT_BC,
) -> jax.Array:
    """Inverse of :func:`quant_asym_blocked` back to INT8 (never float)."""
    n, d = q2.shape
    nb = n // block
    return pl.pallas_call(
        _dequant_asym_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.int8)],
        interpret=INTERPRET,
    )(q2, s_int, z_int)[0]
