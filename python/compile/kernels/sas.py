"""Layer-1 Pallas kernel for SAS (Sparse Activated Softmax).

Implements paper Algorithm 3: rowwise max-subtraction, sparsity threshold
n_r, then e^{-t} = LUT(t_int) * POLY(t_dec) with the cubic from Eq. 15,
and rowwise renormalization. The LUT is tiny (|n_r|+2 entries) because the
sparsity threshold bounds the integer part — that is the "sparse" in SAS.

On TPU this evaluates entirely in the VPU in low precision with no
transcendental-unit round trip; interpret=True here for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True

#: Finite stand-in for -inf inside kernels (avoids inf arithmetic).
NEG_BIG = -1e9


def sas_exp_inline(x: jax.Array, lut: jax.Array, n_r: float) -> jax.Array:
    """SAS e^{x} for x <= 0, usable inside a Pallas kernel body.

    ``lut`` must be :func:`ref.sas_lut`(n_r) passed in as a kernel operand
    (on TPU it lives in SMEM; the poly runs vectorized in the VPU).
    """
    depth = int(-n_r)
    t = -x
    t_int = jnp.floor(t)
    t_dec = t - t_int
    idx = jnp.clip(t_int, 0.0, float(depth + 1)).astype(jnp.int32)
    val = lut[idx] * ref.sas_poly(t_dec)
    return jnp.where(x < n_r, 0.0, val)


def _sas_softmax_kernel(n_r: float, x_ref, lut_ref, o_ref):
    x = x_ref[...]
    lut = lut_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = sas_exp_inline(x - m, lut, n_r)
    o_ref[...] = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-20)


def sas_softmax(
    x: jax.Array, block: int = ref.DEFAULT_BR, n_r: float = ref.SAS_NR
) -> jax.Array:
    """Row-blocked SAS softmax over a [n, m] score matrix."""
    n, mdim = x.shape
    assert n % block == 0, (n, block)
    lut = ref.sas_lut(n_r)
    return pl.pallas_call(
        functools.partial(_sas_softmax_kernel, n_r),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, mdim), lambda i: (i, 0)),
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((block, mdim), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, mdim), jnp.float32)],
        interpret=INTERPRET,
    )(x, lut)[0]
