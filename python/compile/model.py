"""Layer-2 JAX model: a byte-level transformer LM whose attention runs
through the Layer-1 TurboAttention kernels.

Three attention paths share the same weights:
  * ``exact``  — plain jnp softmax attention (training + oracle).
  * ``flash``  — FP32 tiled FlashAttention Pallas kernel (paper baseline).
  * ``turbo``  — fused quantized TurboAttention Pallas kernel.

The decode path mirrors the paper's serving split: the Rust coordinator
owns the quantized (q2) KV store and the enhanced INT8 buffer; this module
consumes the q1-level cache (INT8 + per-block scales) the coordinator
reconstructs, and returns the new token's float K/V for the coordinator to
quantize into the buffer. The current token participates in attention via
an online-softmax merge with the kernel's (m, l) state, so it never needs
to round-trip through the cache within a step.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import flash as flash_k
from .kernels import ref as ref_k
from .kernels import turbo as turbo_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + tiling for the tiny serving model."""

    vocab: int = 256  # byte-level
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    max_ctx: int = 288  # prefill pad + decode headroom
    block: int = 32  # B_r = B_c (paper §5.2 uses 64; scaled to model)
    n_r: float = ref_k.SAS_NR

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_cache_blocks(self) -> int:
        assert self.max_ctx % self.block == 0
        return self.max_ctx // self.block


Params = dict[str, Any]


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """Initialize LM parameters (scaled-normal, GPT-2-style)."""
    keys = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))

    def norm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    d, h, f = cfg.d_model, cfg.n_heads, cfg.d_ff
    params: Params = {
        "tok_emb": norm(next(keys), (cfg.vocab, d), 0.02),
        "pos_emb": norm(next(keys), (cfg.max_ctx, d), 0.02),
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "head": norm(next(keys), (d, cfg.vocab), 0.02),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wq": norm(next(keys), (d, d), 0.02),
                "wk": norm(next(keys), (d, d), 0.02),
                "wv": norm(next(keys), (d, d), 0.02),
                "wo": norm(next(keys), (d, d), 0.02 / (2 * cfg.n_layers) ** 0.5),
                "w1": norm(next(keys), (d, f), 0.02),
                "b1": jnp.zeros((f,)),
                "w2": norm(next(keys), (f, d), 0.02 / (2 * cfg.n_layers) ** 0.5),
                "b2": jnp.zeros((d,)),
            }
        )
    return params


def layer_norm(x: jax.Array, p: Params) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["g"] + p["b"]


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[S, d_model] -> [H, S, d_head]."""
    s = x.shape[0]
    return x.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)


def _merge_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[H, S, d_head] -> [S, d_model]."""
    return x.transpose(1, 0, 2).reshape(x.shape[1], cfg.d_model)


def _attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: ModelConfig,
    mode: str,
    nvalid: jax.Array | None,
) -> jax.Array:
    """Dispatch [H, S, dh] attention to the selected path (causal)."""
    if mode == "exact":
        outs = jax.vmap(
            lambda qq, kk, vv: ref_k.attention_exact(qq, kk, vv, causal=True)
        )(q, k, v)
        return outs
    if mode == "flash":
        return flash_k.flash_attention(
            q, k, v, nvalid, nvalid, br=cfg.block, bc=cfg.block, causal=True
        )
    if mode == "turbo":
        return turbo_k.turbo_attention(
            q, k, v, nvalid, nvalid,
            br=cfg.block, bc=cfg.block, n_r=cfg.n_r, causal=True,
        )
    raise ValueError(mode)


def forward(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str = "exact",
    nvalid: jax.Array | None = None,
    return_kv: bool = False,
):
    """Full-sequence forward pass. tokens [S] int32 -> logits [S, vocab].

    With ``return_kv``, also returns per-layer float K/V [L, H, S, dh]
    (the prefill cache before quantization).
    """
    s = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    kvs = []
    for lp in params["layers"]:
        h_in = layer_norm(x, lp["ln1"])
        q = _split_heads(h_in @ lp["wq"], cfg)
        k = _split_heads(h_in @ lp["wk"], cfg)
        v = _split_heads(h_in @ lp["wv"], cfg)
        attn = _attention(q, k, v, cfg, mode, nvalid)
        x = x + _merge_heads(attn, cfg) @ lp["wo"]
        h2 = layer_norm(x, lp["ln2"])
        x = x + (jax.nn.gelu(h2 @ lp["w1"] + lp["b1"])) @ lp["w2"] + lp["b2"]
        if return_kv:
            kvs.append((k, v))
    logits = layer_norm(x, params["ln_f"]) @ params["head"]
    if return_kv:
        ks = jnp.stack([k for k, _ in kvs])  # [L, H, S, dh]
        vs = jnp.stack([v for _, v in kvs])
        return logits, ks, vs
    return logits


def forward_batch(params: Params, tokens: jax.Array, cfg: ModelConfig):
    """Training helper: [B, S] -> [B, S, vocab] with exact attention."""
    return jax.vmap(lambda t: forward(params, t, cfg, mode="exact"))(tokens)


# --------------------------------------------------------------------------
# AOT entrypoints
# --------------------------------------------------------------------------


def _quant_cache_blocked(kv: jax.Array, block: int):
    """Quantize a [L, H, S, dh] float cache to q1: int8 + per-block scales.

    Returns (q8 [L,H,S,dh] i8, scales [L,H,S/block] f32). Matches paper
    Algorithm 1's symmetric per-tile step; the further q2 compression is
    the Rust coordinator's job (per-head mixed precision lives there).
    """
    l, h, s, dh = kv.shape
    nb = s // block
    blocks = kv.reshape(l, h, nb, block, dh)
    amax = jnp.max(jnp.abs(blocks), axis=(3, 4))
    scales = jnp.maximum(amax / ref_k.INT8_QMAX, 1e-8)
    q = jnp.clip(
        jnp.round(blocks / scales[..., None, None]), -127.0, 127.0
    ).astype(jnp.int8)
    return q.reshape(l, h, s, dh), scales.astype(jnp.float32)


def prefill_turbo(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  nvalid: jax.Array):
    """AOT prefill (turbo path): tokens [max_ctx] i32, nvalid i32 scalar.

    Returns (logits [max_ctx, vocab], k8, v8 [L,H,max_ctx,dh] i8,
    sk, sv [L,H,max_ctx/block] f32).
    """
    logits, ks, vs = forward(
        params, tokens, cfg, mode="turbo", nvalid=nvalid, return_kv=True
    )
    k8, sk = _quant_cache_blocked(ks, cfg.block)
    v8, sv = _quant_cache_blocked(vs, cfg.block)
    return logits, k8, v8, sk, sv


def prefill_flash(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  nvalid: jax.Array):
    """AOT prefill (exact baseline): float K/V cache out."""
    logits, ks, vs = forward(
        params, tokens, cfg, mode="flash", nvalid=nvalid, return_kv=True
    )
    return logits, ks, vs


def _sas_merge_token(out, m, l, s_new, v_new, n_r):
    """Online-softmax merge of one extra (current-token) score column.

    out/m/l: [H, dh], [H], [H] from turbo_decode; s_new [H]; v_new [H, dh].
    """
    m_tot = jnp.maximum(m, s_new)
    alpha = ref_k.sas_exp(m - m_tot, n_r)  # rescale cached part
    p_new = ref_k.sas_exp(s_new - m_tot, n_r)
    l_tot = alpha * l + p_new
    merged = (alpha * l)[:, None] * out + p_new[:, None] * v_new
    return merged / jnp.maximum(l_tot, 1e-20)[:, None]


def decode_turbo(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,      # i32 scalar — token to embed
    pos: jax.Array,        # i32 scalar — its absolute position
    k8: jax.Array,         # [L, H, max_ctx, dh] i8 (q1 cache from Rust)
    v8: jax.Array,
    sk: jax.Array,         # [L, H, max_ctx/block] f32
    sv: jax.Array,
    nk_valid: jax.Array,   # i32 scalar — tokens already in cache
):
    """AOT decode step (turbo): one token through all layers.

    Returns (logits [vocab], k_new [L, H, dh], v_new [L, H, dh]).
    The new token attends to the INT8 cache via Algorithm 2 plus a float
    merge of its own K/V (which the Rust side then folds into the buffer).
    """
    x = params["tok_emb"][token] + params["pos_emb"][pos]
    k_news, v_news = [], []
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))
    for li, lp in enumerate(params["layers"]):
        h_in = layer_norm(x, lp["ln1"])
        q = (h_in @ lp["wq"]).reshape(cfg.n_heads, cfg.d_head)
        k_t = (h_in @ lp["wk"]).reshape(cfg.n_heads, cfg.d_head)
        v_t = (h_in @ lp["wv"]).reshape(cfg.n_heads, cfg.d_head)
        out, m, l = turbo_k.turbo_decode(
            q, k8[li], v8[li], sk[li], sv[li], nk_valid,
            bc=cfg.block, n_r=cfg.n_r,
        )
        s_new = jnp.sum(q * k_t, axis=-1) * scale  # [H]
        attn = _sas_merge_token(out, m, l, s_new, v_t, cfg.n_r)
        x = x + attn.reshape(cfg.d_model) @ lp["wo"]
        h2 = layer_norm(x, lp["ln2"])
        x = x + (jax.nn.gelu(h2 @ lp["w1"] + lp["b1"])) @ lp["w2"] + lp["b2"]
        k_news.append(k_t)
        v_news.append(v_t)
    logits = layer_norm(x, params["ln_f"]) @ params["head"]
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def decode_flash(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,
    pos: jax.Array,
    kf: jax.Array,        # [L, H, max_ctx, dh] f32 exact cache
    vf: jax.Array,
    nk_valid: jax.Array,
):
    """AOT decode step (exact float-cache baseline, FlashAttention math)."""
    x = params["tok_emb"][token] + params["pos_emb"][pos]
    k_news, v_news = [], []
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))
    max_ctx = kf.shape[2]
    for li, lp in enumerate(params["layers"]):
        h_in = layer_norm(x, lp["ln1"])
        q = (h_in @ lp["wq"]).reshape(cfg.n_heads, cfg.d_head)
        k_t = (h_in @ lp["wk"]).reshape(cfg.n_heads, cfg.d_head)
        v_t = (h_in @ lp["wv"]).reshape(cfg.n_heads, cfg.d_head)
        # Exact masked attention over cache + current token.
        s_cache = jnp.einsum("hd,hnd->hn", q, kf[li]) * scale
        mask = jnp.arange(max_ctx)[None, :] < nk_valid
        s_cache = jnp.where(mask, s_cache, -jnp.inf)
        s_new = jnp.sum(q * k_t, axis=-1, keepdims=True) * scale
        s_all = jnp.concatenate([s_cache, s_new], axis=1)
        p = jax.nn.softmax(s_all, axis=-1)
        attn = jnp.einsum("hn,hnd->hd", p[:, :max_ctx], vf[li]) + p[
            :, max_ctx:
        ] * v_t
        x = x + attn.reshape(cfg.d_model) @ lp["wo"]
        h2 = layer_norm(x, lp["ln2"])
        x = x + (jax.nn.gelu(h2 @ lp["w1"] + lp["b1"])) @ lp["w2"] + lp["b2"]
        k_news.append(k_t)
        v_news.append(v_t)
    logits = layer_norm(x, params["ln_f"]) @ params["head"]
    return logits, jnp.stack(k_news), jnp.stack(v_news)
