"""Quantization kernels vs ref oracles, with hypothesis shape/param sweeps."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import quant, ref

COMMON = dict(deadline=None, max_examples=15)


def _rand(rng, shape, scale=3.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestSymmetricInt8:
    @settings(**COMMON)
    @given(
        n=st.integers(1, 6),
        d=st.integers(1, 48),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**31),
    )
    def test_roundtrip_error_bound(self, n, d, scale, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (n, d), scale)
        q, s = ref.quant_sym_int8(x)
        err = np.max(np.abs(np.asarray(ref.dequant_sym_int8(q, s)) - np.asarray(x)))
        assert err <= float(s) * 0.5 + 1e-6

    @settings(**COMMON)
    @given(seed=st.integers(0, 2**31))
    def test_scale_is_amax_over_119(self, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (8, 8))
        _, s = ref.quant_sym_int8(x)
        assert np.isclose(float(s), max(np.max(np.abs(np.asarray(x))) / 119.0, 1e-8), rtol=1e-6)

    def test_zero_input(self):
        q, s = ref.quant_sym_int8(jnp.zeros((4, 4)))
        assert np.all(np.asarray(q) == 0) and float(s) > 0

    @settings(**COMMON)
    @given(
        nb=st.integers(1, 4), block=st.sampled_from([8, 16]),
        d=st.integers(4, 32), seed=st.integers(0, 2**31),
    )
    def test_pallas_blocked_matches_ref(self, nb, block, d, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (nb * block, d))
        q, s = quant.quant_sym_int8_blocked(x, block=block)
        for b in range(nb):
            qr, sr = ref.quant_sym_int8(x[b * block : (b + 1) * block])
            np.testing.assert_array_equal(
                np.asarray(q[b * block : (b + 1) * block]), np.asarray(qr)
            )
            assert np.isclose(float(s[b]), float(sr), rtol=1e-6)


class TestProgressive:
    @settings(**COMMON)
    @given(
        bits=st.sampled_from([2, 3, 4]),
        n=st.integers(2, 40),
        d=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_codes_in_range(self, bits, n, d, seed):
        rng = np.random.default_rng(seed)
        q1, _ = ref.quant_sym_int8(_rand(rng, (n, d)))
        q2, s_int, z_int = ref.quant_asym_int(q1, bits)
        assert np.all(np.asarray(q2) >= 0)
        assert np.all(np.asarray(q2) <= (1 << bits) - 1)
        assert np.all(np.asarray(s_int) >= 1)
        assert np.all(np.abs(np.asarray(s_int)) <= 255)

    @settings(**COMMON)
    @given(
        bits=st.sampled_from([2, 3, 4]),
        n=st.integers(2, 40),
        d=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_integer_roundtrip_error_bounded_by_scale(self, bits, n, d, seed):
        """|q1' - q1| <= 1.5 * s_int per channel (round + clip slack)."""
        rng = np.random.default_rng(seed)
        q1, _ = ref.quant_sym_int8(_rand(rng, (n, d)))
        q2, s_int, z_int = ref.quant_asym_int(q1, bits)
        back = ref.dequant_asym_int(q2, s_int, z_int)
        err = np.abs(np.asarray(back, np.int32) - np.asarray(q1, np.int32))
        bound = 1.5 * np.asarray(s_int)[None, :] + 1
        assert np.all(err <= bound), (err.max(), np.asarray(s_int).max())

    def test_4bit_tighter_than_2bit(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (64, 32))
        errs = {}
        for bits in (2, 4):
            deq = ref.progressive_dequant(*ref.progressive_quant(x, bits))
            errs[bits] = float(jnp.mean((deq - x) ** 2))
        assert errs[4] < errs[2]

    @settings(**COMMON)
    @given(
        bits=st.sampled_from([2, 4]),
        nb=st.integers(1, 3),
        seed=st.integers(0, 2**31),
    )
    def test_pallas_asym_matches_ref(self, bits, nb, seed):
        rng = np.random.default_rng(seed)
        block, d = 16, 24
        q1, _ = ref.quant_sym_int8(_rand(rng, (nb * block, d)))
        q2, si, zi = quant.quant_asym_blocked(q1, bits, block=block)
        for b in range(nb):
            sl = slice(b * block, (b + 1) * block)
            q2r, sir, zir = ref.quant_asym_int(q1[sl], bits)
            np.testing.assert_array_equal(np.asarray(q2[sl]), np.asarray(q2r))
            np.testing.assert_array_equal(np.asarray(si[b]), np.asarray(sir))
            np.testing.assert_array_equal(np.asarray(zi[b]), np.asarray(zir))

    @settings(**COMMON)
    @given(bits=st.sampled_from([2, 4]), seed=st.integers(0, 2**31))
    def test_pallas_dequant_matches_ref(self, bits, seed):
        rng = np.random.default_rng(seed)
        block, d, nb = 16, 8, 2
        q1, _ = ref.quant_sym_int8(_rand(rng, (nb * block, d)))
        q2, si, zi = quant.quant_asym_blocked(q1, bits, block=block)
        back = quant.dequant_asym_blocked(q2, si, zi, block=block)
        for b in range(nb):
            sl = slice(b * block, (b + 1) * block)
            np.testing.assert_array_equal(
                np.asarray(back[sl]),
                np.asarray(ref.dequant_asym_int(q2[sl], si[b], zi[b])),
            )


class TestChannelVsTokenwise:
    def test_channelwise_beats_tokenwise_with_channel_outliers(self):
        """Fig 10: with channel outliers, channelwise group quant wins."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(128, 32)).astype(np.float32)
        x[:, 3] *= 12.0  # persistent channel outlier (Fig 4 pattern)
        x[:, 17] *= 8.0
        xj = jnp.asarray(x)
        err_chan = float(jnp.mean(
            (ref.quant_asym_float_grouped(xj, 4, 32, axis=0) - xj) ** 2))
        err_tok = float(jnp.mean(
            (ref.quant_asym_float_grouped(xj, 4, 32, axis=1) - xj) ** 2))
        assert err_chan < err_tok


class TestHeadwise:
    def test_priority_ranks_outlier_heads_higher(self):
        rng = np.random.default_rng(2)
        kv = rng.normal(size=(4, 64, 16)).astype(np.float32)
        kv[2, :, 5] *= 20.0  # head 2 gets a big channel outlier
        pr = np.asarray(ref.head_priority(jnp.asarray(kv)))
        assert np.argmax(pr) == 2

    def test_select_2bit_heads_picks_lowest(self):
        pr = jnp.asarray([3.0, 1.0, 2.0, 10.0])
        mask = np.asarray(ref.select_2bit_heads(pr, 2))
        assert list(mask) == [False, True, True, False]

    @settings(**COMMON)
    @given(h=st.integers(1, 8), n_h=st.integers(0, 8), seed=st.integers(0, 2**31))
    def test_select_count(self, h, n_h, seed):
        hypothesis.assume(n_h <= h)
        rng = np.random.default_rng(seed)
        pr = jnp.asarray(rng.random(h).astype(np.float32))
        mask = np.asarray(ref.select_2bit_heads(pr, n_h))
        assert mask.sum() == n_h
