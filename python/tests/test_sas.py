"""SAS (Sparse Activated Softmax) kernel and oracle tests."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import ref, sas

COMMON = dict(deadline=None, max_examples=15)


class TestPoly:
    def test_coefficients_match_paper(self):
        assert ref.SAS_POLY == (-0.1025, 0.4626, -0.9922, 0.9996)

    def test_poly_error_on_unit_interval(self):
        """Fig 5: cubic fit of e^{-x} on [0,1] — max error well under 1e-3."""
        t = jnp.linspace(0.0, 1.0, 1001)
        err = np.max(np.abs(np.asarray(ref.sas_poly(t) - jnp.exp(-t))))
        assert err < 5e-4, err


class TestSasExp:
    @settings(**COMMON)
    @given(seed=st.integers(0, 2**31))
    def test_matches_exp_above_threshold(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(-rng.random(256) * 6.0, jnp.float32)  # in (-6, 0]
        approx = np.asarray(ref.sas_exp(x))
        exact = np.asarray(jnp.exp(x))
        assert np.max(np.abs(approx - exact)) < 1e-3

    def test_sparsity_below_threshold(self):
        x = jnp.asarray([-6.001, -7.5, -100.0, -1e9], jnp.float32)
        assert np.all(np.asarray(ref.sas_exp(x)) == 0.0)

    def test_zero_maps_to_poly_constant(self):
        assert np.isclose(float(ref.sas_exp(jnp.float32(0.0))), 0.9996)

    def test_lut_contents(self):
        lut = np.asarray(ref.sas_lut())
        np.testing.assert_allclose(lut[:7], np.exp(-np.arange(7)), rtol=1e-6)
        assert lut[7] == 0.0

    def test_monotone_nonincreasing(self):
        x = jnp.linspace(-8.0, 0.0, 4001)
        y = np.asarray(ref.sas_exp(x))
        assert np.all(np.diff(y) >= -1e-6)


class TestSasSoftmax:
    @settings(**COMMON)
    @given(
        n=st.integers(1, 8), m=st.integers(2, 64), seed=st.integers(0, 2**31)
    )
    def test_close_to_exact_softmax(self, n, m, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, m)) * 2.5, jnp.float32)
        approx = np.asarray(ref.sas_softmax(x))
        exact = np.asarray(jax.nn.softmax(x, axis=-1))
        # Elementwise error dominated by dropped tail mass below n_r.
        assert np.max(np.abs(approx - exact)) < 2e-2

    @settings(**COMMON)
    @given(n=st.integers(1, 6), seed=st.integers(0, 2**31))
    def test_rows_sum_to_one(self, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, 16)) * 5, jnp.float32)
        rows = np.asarray(jnp.sum(ref.sas_softmax(x), axis=-1))
        np.testing.assert_allclose(rows, 1.0, atol=1e-5)

    def test_extreme_scores_sparsified(self):
        x = jnp.asarray([[0.0, -20.0, -20.0, -20.0]], jnp.float32)
        out = np.asarray(ref.sas_softmax(x))[0]
        np.testing.assert_allclose(out, [1.0, 0.0, 0.0, 0.0], atol=1e-6)

    @settings(**COMMON)
    @given(
        nb=st.integers(1, 3),
        block=st.sampled_from([8, 16]),
        m=st.integers(2, 48),
        seed=st.integers(0, 2**31),
    )
    def test_pallas_kernel_matches_ref(self, nb, block, m, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(nb * block, m)) * 3, jnp.float32)
        out_k = np.asarray(sas.sas_softmax(x, block=block))
        out_r = np.asarray(ref.sas_softmax(x))
        np.testing.assert_allclose(out_k, out_r, atol=1e-6)
