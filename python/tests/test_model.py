"""Layer-2 model tests: shapes, path agreement, prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.kernels import ref

CFG = model_lib.ModelConfig(
    d_model=32, n_layers=2, n_heads=2, d_ff=64, max_ctx=64, block=16
)


@pytest.fixture(scope="module")
def params():
    return model_lib.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(32, 127, size=(CFG.max_ctx,)), jnp.int32)


class TestForward:
    def test_logits_shape(self, params, tokens):
        logits = model_lib.forward(params, tokens, CFG)
        assert logits.shape == (CFG.max_ctx, CFG.vocab)

    def test_flash_path_matches_exact(self, params, tokens):
        a = model_lib.forward(params, tokens, CFG, mode="exact")
        b = model_lib.forward(params, tokens, CFG, mode="flash")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)

    def test_turbo_path_close_to_exact(self, params, tokens):
        a = model_lib.forward(params, tokens, CFG, mode="exact")
        b = model_lib.forward(params, tokens, CFG, mode="turbo")
        # Quantized path: logits drift bounded; argmax agreement high.
        agree = np.mean(
            np.argmax(np.asarray(a), -1) == np.argmax(np.asarray(b), -1)
        )
        assert agree > 0.9, agree

    def test_return_kv_shapes(self, params, tokens):
        _, ks, vs = model_lib.forward(params, tokens, CFG, return_kv=True)
        want = (CFG.n_layers, CFG.n_heads, CFG.max_ctx, CFG.d_head)
        assert ks.shape == want and vs.shape == want

    def test_causality(self, params, tokens):
        """Changing a future token must not affect earlier logits."""
        logits1 = model_lib.forward(params, tokens, CFG)
        t2 = tokens.at[40].set((tokens[40] + 1) % 127)
        logits2 = model_lib.forward(params, t2, CFG)
        np.testing.assert_allclose(
            np.asarray(logits1[:40]), np.asarray(logits2[:40]), atol=1e-5
        )


class TestPrefillDecodeConsistency:
    def test_flash_decode_reproduces_forward(self, params, tokens):
        """Prefill n tokens, decode the rest one-by-one == full forward."""
        n, total = 24, 32
        full = model_lib.forward(params, tokens[:total], CFG, mode="exact")
        logits, kf, vf = model_lib.prefill_flash(
            params, CFG, tokens, jnp.int32(n)
        )
        np.testing.assert_allclose(
            np.asarray(logits[:n]),
            np.asarray(full[:n]),
            atol=1e-3,
        )
        kf = np.array(kf)
        vf = np.array(vf)
        for t in range(n, total):
            step_logits, k_new, v_new = model_lib.decode_flash(
                params, CFG, tokens[t], jnp.int32(t),
                jnp.asarray(kf), jnp.asarray(vf), jnp.int32(t),
            )
            np.testing.assert_allclose(
                np.asarray(step_logits), np.asarray(full[t]), atol=2e-3
            )
            kf[:, :, t] = np.asarray(k_new)
            vf[:, :, t] = np.asarray(v_new)

    def test_turbo_prefill_outputs(self, params, tokens):
        logits, k8, v8, sk, sv = model_lib.prefill_turbo(
            params, CFG, tokens, jnp.int32(48)
        )
        assert logits.shape == (CFG.max_ctx, CFG.vocab)
        assert k8.dtype == jnp.int8 and v8.dtype == jnp.int8
        assert sk.shape == (
            CFG.n_layers, CFG.n_heads, CFG.max_ctx // CFG.block
        )
        assert np.all(np.asarray(sk) > 0)

    def test_turbo_decode_agreement_with_flash(self, params, tokens):
        """Quantized decode tracks the exact path's next-token choices."""
        n = 32
        _, kf, vf = model_lib.prefill_flash(params, CFG, tokens, jnp.int32(n))
        _, k8, v8, sk, sv = model_lib.prefill_turbo(
            params, CFG, tokens, jnp.int32(n)
        )
        lf, _, _ = model_lib.decode_flash(
            params, CFG, tokens[n], jnp.int32(n), kf, vf, jnp.int32(n)
        )
        lt, k_new, v_new = model_lib.decode_turbo(
            params, CFG, tokens[n], jnp.int32(n), k8, v8, sk, sv, jnp.int32(n)
        )
        assert k_new.shape == (CFG.n_layers, CFG.n_heads, CFG.d_head)
        # Tiny random-init model: top-1 often matches, top-5 must overlap.
        top_f = set(np.argsort(np.asarray(lf))[-5:])
        top_t = set(np.argsort(np.asarray(lt))[-5:])
        assert top_f & top_t, (top_f, top_t)


class TestQuantCacheBlocked:
    def test_matches_per_block_ref(self):
        rng = np.random.default_rng(3)
        kv = jnp.asarray(rng.normal(size=(2, 2, 32, 8)), jnp.float32)
        q8, s = model_lib._quant_cache_blocked(kv, 16)
        q_ref, s_ref = ref.quant_sym_int8(kv[1, 0, 16:32])
        np.testing.assert_array_equal(
            np.asarray(q8[1, 0, 16:32]), np.asarray(q_ref)
        )
        assert np.isclose(float(s[1, 0, 1]), float(s_ref), rtol=1e-6)
