"""Fused attention Pallas kernels vs references (the core L1 signal)."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import flash, quant, ref, turbo

COMMON = dict(deadline=None, max_examples=8)


def _qkv(seed, h, nq, nk, d, scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(h, nq, d)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(h, nk, d)) * scale, jnp.float32)
    v = jnp.asarray(rng.normal(size=(h, nk, d)) * scale, jnp.float32)
    return q, k, v


class TestFlashKernel:
    @settings(**COMMON)
    @given(
        h=st.integers(1, 3),
        nq=st.integers(1, 70),
        d=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_matches_exact_attention(self, h, nq, d, causal, seed):
        nk = nq  # self-attention shape
        q, k, v = _qkv(seed, h, nq, nk, d)
        out = flash.flash_attention(q, k, v, br=16, bc=16, causal=causal)
        exact = jnp.stack(
            [ref.attention_exact(q[i], k[i], v[i], causal) for i in range(h)]
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exact), atol=2e-5
        )

    def test_cross_attention_rectangular(self):
        q, k, v = _qkv(3, 2, 24, 56, 16)
        out = flash.flash_attention(q, k, v, br=16, bc=16, causal=False)
        exact = jnp.stack(
            [ref.attention_exact(q[i], k[i], v[i]) for i in range(2)]
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(exact), atol=2e-5)

    def test_traced_nvalid_masks_padding(self):
        """Same executable must serve shorter sequences via nvalid."""
        q, k, v = _qkv(5, 1, 32, 32, 16)
        n = 20
        out_full = flash.flash_attention(
            q, k, v, jnp.int32(n), jnp.int32(n), br=16, bc=16, causal=True
        )
        exact = ref.attention_exact(q[0, :n], k[0, :n], v[0, :n], True)
        np.testing.assert_allclose(
            np.asarray(out_full[0, :n]), np.asarray(exact), atol=2e-5
        )


class TestTurboPrefillKernel:
    @settings(**COMMON)
    @given(
        h=st.integers(1, 2),
        nq=st.integers(1, 70),
        d=st.sampled_from([8, 16]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_matches_turbo_ref(self, h, nq, d, causal, seed):
        q, k, v = _qkv(seed, h, nq, nq, d)
        out = turbo.turbo_attention(q, k, v, br=16, bc=16, causal=causal)
        want = jnp.stack(
            [
                ref.turbo_attention_ref(
                    q[i], k[i], v[i], br=16, bc=16, causal=causal
                )
                for i in range(h)
            ]
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2.5e-2)

    @settings(**COMMON)
    @given(seed=st.integers(0, 2**31))
    def test_close_to_exact_attention(self, seed):
        """End-to-end quantization error stays small (paper: near-lossless)."""
        q, k, v = _qkv(seed, 2, 48, 48, 16)
        out = turbo.turbo_attention(q, k, v, br=16, bc=16, causal=True)
        exact = jnp.stack(
            [ref.attention_exact(q[i], k[i], v[i], True) for i in range(2)]
        )
        rel = np.linalg.norm(np.asarray(out - exact)) / np.linalg.norm(
            np.asarray(exact)
        )
        assert rel < 0.05, rel

    def test_traced_nvalid(self):
        q, k, v = _qkv(11, 1, 32, 32, 16)
        n = 19
        out = turbo.turbo_attention(
            q, k, v, jnp.int32(n), jnp.int32(n), br=16, bc=16, causal=True
        )
        want = ref.turbo_attention_ref(
            q[0, :n], k[0, :n], v[0, :n], br=16, bc=16, causal=True
        )
        np.testing.assert_allclose(
            np.asarray(out[0, :n]), np.asarray(want), atol=2.5e-2
        )


class TestTurboDecodeKernel:
    @settings(**COMMON)
    @given(
        h=st.integers(1, 3),
        nk=st.integers(1, 60),
        d=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_decode_ref(self, h, nk, d, seed):
        bc = 16
        nk_pad = -(-nk // bc) * bc
        rng = np.random.default_rng(seed)
        kf = rng.normal(size=(h, nk_pad, d)).astype(np.float32)
        vf = rng.normal(size=(h, nk_pad, d)).astype(np.float32)
        qv = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
        k8 = jnp.stack([quant.quant_sym_int8_blocked(jnp.asarray(kf[i]), block=bc)[0] for i in range(h)])
        sk = jnp.stack([quant.quant_sym_int8_blocked(jnp.asarray(kf[i]), block=bc)[1] for i in range(h)])
        v8 = jnp.stack([quant.quant_sym_int8_blocked(jnp.asarray(vf[i]), block=bc)[0] for i in range(h)])
        sv = jnp.stack([quant.quant_sym_int8_blocked(jnp.asarray(vf[i]), block=bc)[1] for i in range(h)])
        out, m, l = turbo.turbo_decode(qv, k8, v8, sk, sv, jnp.int32(nk), bc=bc)
        for i in range(h):
            want = ref.turbo_decode_ref(
                qv[i], k8[i][:nk], v8[i][:nk], sk[i], sv[i], bc=bc
            )
            np.testing.assert_allclose(
                np.asarray(out[i]), np.asarray(want), atol=2.5e-2
            )
        assert np.all(np.asarray(l) > 0)

    def test_online_state_allows_external_merge(self):
        """(m, l) outputs let the model merge the current token exactly."""
        h, nk, d, bc = 2, 32, 16, 16
        rng = np.random.default_rng(4)
        kf = jnp.asarray(rng.normal(size=(h, nk, d)), jnp.float32)
        vf = jnp.asarray(rng.normal(size=(h, nk, d)), jnp.float32)
        qv = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
        k_t = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
        v_t = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
        k8 = jnp.stack([quant.quant_sym_int8_blocked(kf[i], block=bc)[0] for i in range(h)])
        sk = jnp.stack([quant.quant_sym_int8_blocked(kf[i], block=bc)[1] for i in range(h)])
        v8 = jnp.stack([quant.quant_sym_int8_blocked(vf[i], block=bc)[0] for i in range(h)])
        sv = jnp.stack([quant.quant_sym_int8_blocked(vf[i], block=bc)[1] for i in range(h)])
        out, m, l = turbo.turbo_decode(qv, k8, v8, sk, sv, jnp.int32(nk), bc=bc)
        scale = 1.0 / np.sqrt(d)
        s_new = jnp.sum(qv * k_t, axis=-1) * scale
        m_tot = jnp.maximum(m, s_new)
        alpha = ref.sas_exp(m - m_tot)
        p_new = ref.sas_exp(s_new - m_tot)
        l_tot = alpha * l + p_new
        merged = ((alpha * l)[:, None] * out + p_new[:, None] * v_t) / l_tot[:, None]
        # Compare against decode over the extended int8 cache + float merge
        # done by the reference path on identical inputs.
        for i in range(h):
            base = ref.turbo_decode_ref(qv[i], k8[i], v8[i], sk[i], sv[i], bc=bc)
            m_i = np.maximum(np.asarray(m[i]), np.asarray(s_new[i]))
            a_i = float(ref.sas_exp(m[i] - m_i))
            p_i = float(ref.sas_exp(s_new[i] - m_i))
            l_i = a_i * float(l[i]) + p_i
            want = (a_i * float(l[i]) * np.asarray(base) + p_i * np.asarray(v_t[i])) / l_i
            np.testing.assert_allclose(np.asarray(merged[i]), want, atol=1e-4)


class TestJitTracedNvalidRegression:
    """Regression for the XLA-CPU constant-folding Heisenbug (see turbo.py).

    The AOT artifact path jits the kernels with *traced* nq/nk_valid; that
    configuration must match the (known-good) eager execution exactly.
    """

    def test_turbo_traced_jit_matches_eager(self):
        import jax

        q, k, v = _qkv(0, 1, 2, 2, 8)
        eager = turbo.turbo_attention(q, k, v, br=16, bc=16, causal=False)
        jitted = jax.jit(
            lambda a, b, c, nq, nk: turbo.turbo_attention(
                a, b, c, nq, nk, br=16, bc=16, causal=False
            )
        )(q, k, v, jnp.int32(2), jnp.int32(2))
        np.testing.assert_allclose(
            np.asarray(eager), np.asarray(jitted), atol=1e-6
        )

    def test_flash_traced_jit_matches_eager(self):
        import jax

        q, k, v = _qkv(1, 1, 5, 5, 8)
        eager = flash.flash_attention(q, k, v, br=16, bc=16, causal=True)
        jitted = jax.jit(
            lambda a, b, c, nq, nk: flash.flash_attention(
                a, b, c, nq, nk, br=16, bc=16, causal=True
            )
        )(q, k, v, jnp.int32(5), jnp.int32(5))
        np.testing.assert_allclose(
            np.asarray(eager), np.asarray(jitted), atol=1e-6
        )
