//! Parallel-decode determinism/parity suite — the contract behind
//! `EngineConfig.decode_threads`.
//!
//! The headline property: for random model geometries, precision maps,
//! prompts, and decode traces, running the turbo decode path with
//! `decode_threads ∈ {1, 2, 4, 7}` must produce **byte-identical**
//! results — attention outputs and (m, l) merge states compared with
//! `f32::to_bits` (no tolerance), and `CacheStats` compared exactly.
//! Parallelism is purely a throughput knob; a single flipped bit here is
//! a scheduling bug, not noise.
//!
//! The contract covers **both** turbo backends: the library-level slab
//! sync + `turbo_decode_streams` trace (the `Turbo` path's CPU
//! substrate), and — since the third backend landed — a full serving
//! trace through the `TurboCpu` `DynBackend` (prefill + greedy decode +
//! fold, attention on the integer kernels), compared logits-bits-exact
//! across `decode_threads`.
//!
//! Plus the pool soundness corners the decode loop relies on: worker
//! panics surface as `Err` without poisoning later steps, zero-head and
//! heads-smaller-than-pool geometries, and thread-leak-free reuse across
//! 1k decode steps.
//!
//! The whole suite holds under **every kernel backend**: the integer
//! kernels are exact in `i32` (order-independent) and the SIMD SAS arms
//! bit-replicate the scalar arm, so thread-count invariance cannot
//! depend on the dispatched ISA. CI runs this suite once with
//! `TURBO_KERNEL=scalar` and once on the detected SIMD arm;
//! `backend_is_pinned_and_reported` below records which arm a given run
//! actually validated.

use std::sync::Arc;

use turboattention::attention::backend::TurboSession;
use turboattention::attention::{
    backend_for, turbo_decode_streams, DecodeScratch, DynBackend, PathMode,
};
use turboattention::kvcache::{
    CacheStats, KvCache, KvCacheConfig, PrecisionMap,
};
use turboattention::model::{argmax, ModelBundle, TurboSlabs};
use turboattention::pool::WorkerPool;
use turboattention::quant::{quant_sym_int8, Bits};
use turboattention::runtime::{Manifest, Runtime};
use turboattention::testutil::prop::Gen;
use turboattention::testutil::{prop, Rng};

const THREADS: [usize; 4] = [1, 2, 4, 7];

/// Stamp the kernel arm this suite run exercised into the test output,
/// and pin it: the backend is process-sticky, so every parity case in
/// this binary ran the same arm (no scalar-vs-SIMD mixing could mask a
/// divergence between them).
#[test]
fn backend_is_pinned_and_reported() {
    let b = turboattention::kernels::kernel_backend();
    assert!(b.supported());
    assert_eq!(turboattention::kernels::kernel_backend(), b);
    println!("parallel_parity validated kernel backend: {}", b.name());
}

/// One randomized decode trace, fully determined by its fields — the
/// same `Case` replayed at any thread count consumes randomness
/// identically, so any output difference is the scheduler's fault.
#[derive(Debug, Clone, Copy)]
struct Case {
    l_n: usize,
    h_n: usize,
    dh: usize,
    block: usize,
    /// Slab capacity in tokens (page-aligned).
    ctx: usize,
    /// Prompt tokens ingested q1-block-style before decode.
    prefill: usize,
    /// Decode steps (one folded token each).
    steps: usize,
    /// Call `sync_slabs` every this many steps (plus a final sync).
    sync_every: usize,
    /// Heads per layer stored at 2-bit (mixed precision).
    n_2bit: usize,
    seed: u64,
}

impl Case {
    fn gen(g: &mut Gen) -> Case {
        let l_n = g.usize_in(1, 4);
        let h_n = g.usize_in(1, 5);
        let block = 4;
        let ctx = 32;
        let prefill = g.usize_in(0, 12);
        Case {
            l_n,
            h_n,
            dh: g.usize_in(4, 16),
            block,
            ctx,
            prefill,
            steps: g.usize_in(1, ctx - 1 - prefill),
            sync_every: g.usize_in(1, 4),
            n_2bit: g.usize_in(0, h_n + 1).min(h_n),
            seed: g.seed(),
        }
    }
}

/// Everything the decode path produced, bit-exact.
#[derive(Debug, PartialEq)]
struct Trace {
    out_bits: Vec<u32>,
    ml_bits: Vec<(u32, u32)>,
    nk: usize,
    stats: CacheStats,
}

fn run_case(case: &Case, threads: usize) -> Trace {
    let Case { l_n, h_n, dh, block, ctx, .. } = *case;
    let n_streams = l_n * h_n;
    let pool = Arc::new(WorkerPool::new(threads));
    let mut pm = PrecisionMap::uniform(l_n, h_n, Bits::Int4);
    for l in 0..l_n {
        for h in 0..case.n_2bit {
            pm.set(l, h, Bits::Int2);
        }
    }
    let cache = KvCache::new(KvCacheConfig::new(l_n, h_n, dh, block, pm));
    let mut sess = TurboSession::from_parts_pooled(
        cache,
        TurboSlabs::new(l_n, h_n, ctx, dh, block),
        Arc::clone(&pool),
    );
    let mut rng = Rng::new(case.seed);
    // "Prompt": q1 blocks ingested per stream, like `ingest_prefill`.
    if case.prefill > 0 {
        for l in 0..l_n {
            for h in 0..h_n {
                let k = quant_sym_int8(&rng.normal_vec(case.prefill * dh, 1.0));
                sess.cache.k_stream_mut(l, h).ingest_q1_block(
                    &k.codes,
                    k.scale,
                    case.prefill,
                );
                let v = quant_sym_int8(&rng.normal_vec(case.prefill * dh, 1.0));
                sess.cache.v_stream_mut(l, h).ingest_q1_block(
                    &v.codes,
                    v.scale,
                    case.prefill,
                );
            }
        }
    }
    // Decode trace: fold one token per step, sync at intervals (so the
    // incremental paths — partial buffers, flush rewrites — all fire).
    for i in 0..case.steps {
        for l in 0..l_n {
            for h in 0..h_n {
                let k = rng.normal_vec(dh, 1.0);
                let v = rng.normal_vec(dh, 1.0);
                sess.cache.k_stream_mut(l, h).push_token(&k);
                sess.cache.v_stream_mut(l, h).push_token(&v);
            }
        }
        if i % case.sync_every == 0 {
            sess.sync_slabs().expect("mid-trace sync");
        }
    }
    let nk = sess.sync_slabs().expect("final sync");
    // The decode step's attention over every (layer, head) stream.
    let q = rng.normal_vec(n_streams * dh, 1.0);
    let mut scratches = vec![DecodeScratch::new(); threads.max(1)];
    let mut ml = vec![(0.0f32, 0.0f32); n_streams];
    let mut out = vec![0.0f32; n_streams * dh];
    turbo_decode_streams(
        &pool,
        &q,
        &sess.slabs.k8,
        &sess.slabs.v8,
        &sess.slabs.sk,
        &sess.slabs.sv,
        dh,
        nk,
        block,
        -6.0,
        &mut scratches,
        &mut ml,
        &mut out,
    )
    .expect("decode streams");
    Trace {
        out_bits: out.iter().map(|x| x.to_bits()).collect(),
        ml_bits: ml
            .iter()
            .map(|&(m, l)| (m.to_bits(), l.to_bits()))
            .collect(),
        nk,
        stats: sess.cache.stats(),
    }
}

/// The headline test: thread count must never change a bit of decode
/// output or a byte of cache accounting.
#[test]
fn decode_bit_identical_across_thread_counts() {
    prop::run("parallel decode parity", 20, |g| {
        let case = Case::gen(g);
        let want = run_case(&case, 1);
        assert_eq!(want.nk, case.prefill + case.steps, "trace sanity");
        for &threads in &THREADS[1..] {
            let got = run_case(&case, threads);
            assert_eq!(
                got, want,
                "threads={threads} diverged from serial ({case:?})"
            );
        }
    });
}

/// One full serving trace through the `TurboCpu` backend: prefill,
/// `steps` greedy decode steps with K/V folds, all attention on the
/// integer kernels and the worker pool. Fully determined by
/// (prompt, steps, seed) — thread count must not change a bit.
#[derive(Debug, PartialEq)]
struct CpuTrace {
    /// Every logits value the backend produced (prefill + each step),
    /// as bits — `to_bits` equality, no tolerance.
    logits_bits: Vec<u32>,
    /// Greedy token choices (argmax of each step's logits).
    generated: Vec<u8>,
    stats: CacheStats,
}

fn run_cpu_case(prompt: &[u8], steps: usize, threads: usize) -> CpuTrace {
    let info = Manifest::cpu_substrate().model;
    let pool = Arc::new(WorkerPool::new(threads));
    // n_2bit_heads = 1: the mixed-precision q2 path is in the trace too.
    let backend =
        backend_for(PathMode::TurboCpu, Bits::Int4, 1, 7, &info, pool);
    let mut bundle = ModelBundle::new(Runtime::cpu_substrate());
    let (logits, mut state, _reg) =
        backend.prefill(&mut bundle, prompt, None).expect("prefill");
    let mut logits_bits: Vec<u32> =
        logits.iter().map(|x| x.to_bits()).collect();
    let last =
        &logits[(prompt.len() - 1) * info.vocab..prompt.len() * info.vocab];
    let mut token = argmax(last) as u8;
    let mut generated = vec![token];
    for i in 0..steps {
        let pos = prompt.len() + i;
        let out = backend
            .decode_step(&mut bundle, &mut state, token, pos, 0)
            .expect("decode");
        backend
            .fold_new_token(&bundle, &mut state, &out.k_new, &out.v_new, pos);
        logits_bits.extend(out.logits.iter().map(|x| x.to_bits()));
        token = argmax(&out.logits) as u8;
        generated.push(token);
    }
    CpuTrace {
        logits_bits,
        generated,
        stats: backend.cache_stats(&state).expect("turbo-family stats"),
    }
}

/// The TurboCpu arm of the headline property: the serving path built on
/// `turbo_decode_streams` + the integer kernels is logits-bit-identical
/// for every `decode_threads`.
#[test]
fn turbo_cpu_backend_bit_identical_across_thread_counts() {
    // 31-token prompt + 12 steps crosses the 32-token page boundary, so
    // the trace includes a buffer flush (view rewrite) mid-decode.
    let prompt = b"the turbo cpu substrate serves ";
    let want = run_cpu_case(prompt, 12, 1);
    assert_eq!(want.stats.tokens, prompt.len() + 12, "trace sanity");
    assert!(want.stats.slab_bytes > 0, "slab accounting present");
    for &threads in &THREADS[1..] {
        let got = run_cpu_case(prompt, 12, threads);
        assert_eq!(got, want, "threads={threads} diverged from serial");
    }
}

/// One decode trace of a session that may have forked from a shared
/// prompt prefix: (logits bits, greedy bytes) — `CacheStats` is checked
/// separately because the shared/private byte split legitimately
/// differs between the sharing modes.
fn run_cpu_shared_trace(
    prompt: &[u8],
    steps: usize,
    threads: usize,
    share: bool,
) -> (Vec<u32>, Vec<u8>, CacheStats) {
    let info = Manifest::cpu_substrate().model;
    let pool = Arc::new(WorkerPool::new(threads));
    let backend =
        backend_for(PathMode::TurboCpu, Bits::Int4, 1, 7, &info, pool);
    let mut bundle = ModelBundle::new(Runtime::cpu_substrate());
    // Donor session builds (and would register) the prefix pages; it
    // stays alive for the whole trace, like a batched neighbor.
    let (_, _donor, reg) =
        backend.prefill(&mut bundle, prompt, None).expect("donor prefill");
    let shared = if share {
        Some(reg.expect("page-crossing prompt registers a prefix"))
    } else {
        None
    };
    let (logits, mut state, _) = backend
        .prefill(&mut bundle, prompt, shared.as_ref())
        .expect("prefill");
    let mut logits_bits: Vec<u32> =
        logits.iter().map(|x| x.to_bits()).collect();
    let last =
        &logits[(prompt.len() - 1) * info.vocab..prompt.len() * info.vocab];
    let mut token = argmax(last) as u8;
    let mut generated = vec![token];
    for i in 0..steps {
        let pos = prompt.len() + i;
        let out = backend
            .decode_step(&mut bundle, &mut state, token, pos, 0)
            .expect("decode");
        backend
            .fold_new_token(&bundle, &mut state, &out.k_new, &out.v_new, pos);
        logits_bits.extend(out.logits.iter().map(|x| x.to_bits()));
        token = argmax(&out.logits) as u8;
        generated.push(token);
    }
    let stats = backend.cache_stats(&state).expect("turbo-family stats");
    (logits_bits, generated, stats)
}

/// The acceptance property of the shared page pool: a session sharing a
/// page-aligned prompt prefix with a live donor decodes
/// **bit-identically** to a fully private session — across every
/// `decode_threads` — while its stats show the prefix as shared.
#[test]
fn shared_prefix_decode_bit_identical_to_private() {
    // 40 tokens: one full 32-token page (shared) + 8 buffered; 26 steps
    // push past token 64, so a buffer flush (private page creation +
    // view rewrite) happens mid-trace in both sessions.
    let prompt: Vec<u8> = (0..40).map(|i| b'a' + (i % 19) as u8).collect();
    let steps = 26;
    let (want_bits, want_gen, private_stats) =
        run_cpu_shared_trace(&prompt, steps, 1, false);
    assert_eq!(
        private_stats.shared_page_bytes, 0,
        "private session shares nothing"
    );
    for &threads in &THREADS {
        let (bits, gen, stats) =
            run_cpu_shared_trace(&prompt, steps, threads, true);
        assert_eq!(
            bits, want_bits,
            "shared-vs-private logits diverged (threads={threads})"
        );
        assert_eq!(gen, want_gen, "generation diverged (threads={threads})");
        assert!(
            stats.shared_page_bytes > 0,
            "forked session must report shared pages (threads={threads})"
        );
        // Everything except the sharing split matches the private run.
        assert_eq!(stats.tokens, private_stats.tokens);
        assert_eq!(stats.bytes, private_stats.bytes);
        // And the private thread sweep agrees with itself.
        let (pbits, pgen, pstats) =
            run_cpu_shared_trace(&prompt, steps, threads, false);
        assert_eq!(pbits, want_bits, "private sweep (threads={threads})");
        assert_eq!(pgen, want_gen);
        assert_eq!(pstats, private_stats, "private stats exact");
    }
}

/// Repeating the same trace on the same multi-thread pool is also
/// deterministic (no cross-step scheduler state bleeds into results).
#[test]
fn repeated_runs_on_same_thread_count_identical() {
    let g_case = Case {
        l_n: 2,
        h_n: 4,
        dh: 8,
        block: 4,
        ctx: 32,
        prefill: 5,
        steps: 17,
        sync_every: 2,
        n_2bit: 1,
        seed: 0xFEED,
    };
    let a = run_case(&g_case, 4);
    let b = run_case(&g_case, 4);
    assert_eq!(a, b);
}

/// Heads < threads: a 7-thread pool over a single (layer, head) stream
/// still matches serial exactly.
#[test]
fn single_head_with_wide_pool_matches_serial() {
    let case = Case {
        l_n: 1,
        h_n: 1,
        dh: 8,
        block: 4,
        ctx: 32,
        prefill: 3,
        steps: 9,
        sync_every: 1,
        n_2bit: 0,
        seed: 0xBEE,
    };
    assert_eq!(run_case(&case, 1), run_case(&case, 7));
}

/// Zero heads: degenerate geometry must be a clean no-op, not a panic.
#[test]
fn zero_head_geometry_syncs_to_empty() {
    let pm = PrecisionMap::uniform(0, 0, Bits::Int4);
    let cache = KvCache::new(KvCacheConfig::new(0, 0, 8, 4, pm));
    let mut sess = TurboSession::from_parts_pooled(
        cache,
        TurboSlabs::new(0, 0, 32, 8, 4),
        Arc::new(WorkerPool::new(4)),
    );
    assert_eq!(sess.sync_slabs().expect("empty sync"), 0);
    // Decode over zero streams is likewise a no-op.
    let pool = WorkerPool::new(4);
    let mut scratches = vec![DecodeScratch::new(); 4];
    turbo_decode_streams(
        &pool,
        &[],
        &[],
        &[],
        &[],
        &[],
        8,
        0,
        4,
        -6.0,
        &mut scratches,
        &mut [],
        &mut [],
    )
    .expect("zero streams");
}

/// A panicked scope on the session's pool must not poison later decode
/// steps: the same pool keeps serving, and results still match a fresh
/// serial replay of the same trace.
#[test]
fn worker_panic_does_not_poison_later_decode_steps() {
    let case = Case {
        l_n: 2,
        h_n: 3,
        dh: 8,
        block: 4,
        ctx: 32,
        prefill: 4,
        steps: 11,
        sync_every: 3,
        n_2bit: 0,
        seed: 0xD00D,
    };
    let pool = Arc::new(WorkerPool::new(4));
    // Crash a shard-shaped job on the shared pool before any decode.
    let err = pool
        .scope(|s| {
            s.execute(|| panic!("injected shard failure"));
            s.execute(|| {});
        })
        .expect_err("panic must surface");
    assert!(err.first_panic.contains("injected shard failure"));
    // The very same pool now runs a full trace; byte-parity with serial.
    let pm = PrecisionMap::uniform(case.l_n, case.h_n, Bits::Int4);
    let cache = KvCache::new(KvCacheConfig::new(
        case.l_n, case.h_n, case.dh, case.block, pm,
    ));
    let mut sess = TurboSession::from_parts_pooled(
        cache,
        TurboSlabs::new(case.l_n, case.h_n, case.ctx, case.dh, case.block),
        Arc::clone(&pool),
    );
    let mut rng = Rng::new(case.seed);
    for _ in 0..case.steps {
        for l in 0..case.l_n {
            for h in 0..case.h_n {
                let k = rng.normal_vec(case.dh, 1.0);
                let v = rng.normal_vec(case.dh, 1.0);
                sess.cache.k_stream_mut(l, h).push_token(&k);
                sess.cache.v_stream_mut(l, h).push_token(&v);
            }
        }
        sess.sync_slabs().expect("post-panic sync");
    }
    // Oracle: same trace, fresh serial session. Compare the slabs the
    // decode executable would read.
    let pm = PrecisionMap::uniform(case.l_n, case.h_n, Bits::Int4);
    let cache = KvCache::new(KvCacheConfig::new(
        case.l_n, case.h_n, case.dh, case.block, pm,
    ));
    let mut serial = TurboSession::from_parts(
        cache,
        TurboSlabs::new(case.l_n, case.h_n, case.ctx, case.dh, case.block),
    );
    let mut rng = Rng::new(case.seed);
    for _ in 0..case.steps {
        for l in 0..case.l_n {
            for h in 0..case.h_n {
                let k = rng.normal_vec(case.dh, 1.0);
                let v = rng.normal_vec(case.dh, 1.0);
                serial.cache.k_stream_mut(l, h).push_token(&k);
                serial.cache.v_stream_mut(l, h).push_token(&v);
            }
        }
        serial.sync_slabs().expect("serial sync");
    }
    assert_eq!(sess.slabs.k8, serial.slabs.k8);
    assert_eq!(sess.slabs.v8, serial.slabs.v8);
    assert_eq!(sess.slabs.sk, serial.slabs.sk);
    assert_eq!(sess.slabs.sv, serial.slabs.sv);
}

/// 1k decode steps on one pool: the worker set stays exactly fixed (no
/// thread leaks from per-step scopes) and is fully joined on drop.
#[test]
fn thousand_step_decode_loop_leaks_no_threads() {
    let (l_n, h_n, dh, block) = (1usize, 2, 4, 8);
    let steps = 1000usize;
    let ctx = steps + block; // slab headroom, page-aligned
    let pool = Arc::new(WorkerPool::new(2));
    let probe = pool.probe();
    assert_eq!(probe.live(), 2);
    let pm = PrecisionMap::uniform(l_n, h_n, Bits::Int4);
    let cache = KvCache::new(KvCacheConfig::new(l_n, h_n, dh, block, pm));
    let mut sess = TurboSession::from_parts_pooled(
        cache,
        TurboSlabs::new(l_n, h_n, ctx, dh, block),
        Arc::clone(&pool),
    );
    let mut rng = Rng::new(7);
    let mut scratches = vec![DecodeScratch::new(); 2];
    let mut ml = vec![(0.0f32, 0.0f32); l_n * h_n];
    let mut out = vec![0.0f32; l_n * h_n * dh];
    for step in 0..steps {
        for l in 0..l_n {
            for h in 0..h_n {
                let k = rng.normal_vec(dh, 1.0);
                let v = rng.normal_vec(dh, 1.0);
                sess.cache.k_stream_mut(l, h).push_token(&k);
                sess.cache.v_stream_mut(l, h).push_token(&v);
            }
        }
        let nk = sess.sync_slabs().expect("sync");
        assert_eq!(nk, step + 1);
        if step % 100 == 0 {
            let q = rng.normal_vec(l_n * h_n * dh, 1.0);
            turbo_decode_streams(
                &pool,
                &q,
                &sess.slabs.k8,
                &sess.slabs.v8,
                &sess.slabs.sk,
                &sess.slabs.sv,
                dh,
                nk,
                block,
                -6.0,
                &mut scratches,
                &mut ml,
                &mut out,
            )
            .expect("decode");
        }
    }
    assert_eq!(probe.live(), 2, "pool must neither grow nor shrink");
    drop(sess);
    drop(pool);
    assert_eq!(probe.live(), 0, "drop must join every worker");
}
