//! TCP wire-protocol integration: the server streams `TOK` lines before
//! `DONE`, honors `CANCEL`, answers `STATS`, and allocates request ids
//! engine-side (the `ACK`). Runs the artifact-free TurboCpu engine in a
//! background thread — no PJRT, no artifacts.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;

use turboattention::coordinator::{
    Engine, EngineConfig, EngineHandle, PathMode, SamplingParams,
};
use turboattention::model::ModelBundle;
use turboattention::runtime::Runtime;
use turboattention::server;

/// Start engine thread + server thread on an ephemeral port; return the
/// bound address. The threads are detached — they die with the test
/// process (the listener loop has no shutdown path by design).
fn start_server() -> std::net::SocketAddr {
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let cfg = EngineConfig {
            mode: PathMode::TurboCpu,
            decode_threads: 2,
            ..Default::default()
        };
        let engine = Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg);
        let _ = engine.run_loop(rx);
    });
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    std::thread::spawn(move || {
        let _ = server::serve(
            listener,
            EngineHandle::new(tx),
            SamplingParams::default(),
        );
    });
    addr
}

fn connect() -> TcpStream {
    TcpStream::connect(start_server()).expect("connect")
}

#[test]
fn gen_streams_tok_lines_before_done() {
    let sock = connect();
    let mut writer = sock.try_clone().expect("clone");
    let mut reader = BufReader::new(sock).lines();
    let mut read_line =
        || reader.next().expect("line").expect("io");

    writeln!(writer, "GEN 24 the stream smoke test").expect("write");
    let ack = read_line();
    let id: u64 = ack
        .strip_prefix("ACK ")
        .unwrap_or_else(|| panic!("expected ACK, got {ack:?}"))
        .parse()
        .expect("ack id");
    assert!(id >= 1, "engine-allocated id");

    let mut toks = 0usize;
    let done = loop {
        let line = read_line();
        if let Some(rest) = line.strip_prefix("TOK ") {
            let mut f = rest.split(' ');
            assert_eq!(f.next().unwrap().parse::<u64>().unwrap(), id);
            let index: usize = f.next().unwrap().parse().unwrap();
            assert_eq!(index, toks, "dense token indices");
            let byte: u16 = f.next().unwrap().parse().unwrap();
            assert!(byte < 256, "token is one byte");
            toks += 1;
        } else if line.starts_with("DONE ") {
            break line;
        } else {
            panic!("unexpected line {line:?}");
        }
    };
    assert_eq!(toks, 24, "every token streamed before DONE");
    let mut f = done.split(' ');
    assert_eq!(f.next(), Some("DONE"));
    assert_eq!(f.next().unwrap().parse::<u64>().unwrap(), id);
    assert_eq!(f.next(), Some("max_tokens"));

    writeln!(writer, "QUIT").expect("write");
    assert_eq!(read_line(), "BYE");
    // QUIT closes the socket server-side — the stream ends (EOF), it
    // does not linger open.
    assert!(reader.next().is_none(), "expected EOF after BYE");
}

#[test]
fn cancel_yields_cancelled_done_and_stats_counts_it() {
    let addr = start_server();
    let sock = TcpStream::connect(addr).expect("connect");
    let mut writer = sock.try_clone().expect("clone");
    let mut reader = BufReader::new(sock).lines();
    let mut read_line =
        || reader.next().expect("line").expect("io");

    // A long request we abort after the ack: 200 tokens is far more
    // decode work than the cancel round-trip.
    writeln!(writer, "GEN 200 cancel this long request").expect("write");
    let ack = read_line();
    let id: u64 = ack
        .strip_prefix("ACK ")
        .unwrap_or_else(|| panic!("expected ACK, got {ack:?}"))
        .parse()
        .expect("ack id");
    writeln!(writer, "CANCEL {id}").expect("write");
    let (mut toks, done) = {
        let mut toks = 0usize;
        loop {
            let line = read_line();
            if line.starts_with("TOK ") {
                toks += 1;
            } else if line.starts_with("DONE ") {
                break (toks, line);
            } else {
                panic!("unexpected line {line:?}");
            }
        }
    };
    let mut f = done.split(' ');
    assert_eq!(f.next(), Some("DONE"));
    assert_eq!(f.next().unwrap().parse::<u64>().unwrap(), id);
    assert_eq!(f.next(), Some("cancelled"), "finish reason on the wire");
    assert!(toks < 200, "cancel must cut the stream short");

    writeln!(writer, "STATS").expect("write");
    let stats = read_line();
    assert!(stats.starts_with("STATS "), "got {stats:?}");
    assert!(
        stats.contains("cancelled=1"),
        "requests_cancelled surfaced: {stats:?}"
    );

    // Per-request overrides parse end to end (greedy + explicit seed).
    writeln!(writer, "GEN 4 greedy seed=7 short follow-up").expect("write");
    let ack2 = read_line();
    assert!(ack2.starts_with("ACK "), "got {ack2:?}");
    toks = 0;
    loop {
        let line = read_line();
        if line.starts_with("TOK ") {
            toks += 1;
        } else if line.starts_with("DONE ") {
            assert!(line.split(' ').nth(2) == Some("max_tokens"));
            break;
        } else {
            panic!("unexpected line {line:?}");
        }
    }
    assert_eq!(toks, 4);

    // A second connection may not cancel this connection's requests:
    // ids it never ACKed are rejected, not forwarded to the engine.
    let other = TcpStream::connect(addr).expect("connect 2");
    let mut other_writer = other.try_clone().expect("clone");
    let mut other_reader = BufReader::new(other).lines();
    writeln!(other_writer, "CANCEL {id}").expect("write");
    let reply = other_reader.next().expect("line").expect("io");
    assert_eq!(reply, "ERR unknown request id");
    writeln!(other_writer, "QUIT").expect("write");
    assert_eq!(other_reader.next().expect("line").expect("io"), "BYE");

    writeln!(writer, "QUIT").expect("write");
    assert_eq!(read_line(), "BYE");
}
