//! Integration tests over the full serving engine (batcher + runtime +
//! quantized KV cache). The executable-backed paths skip when artifacts
//! are absent; the `TurboCpu` path needs none and always runs.

use turboattention::coordinator::{Engine, EngineConfig, GenRequest, PathMode};
use turboattention::model::ModelBundle;
use turboattention::quant::Bits;
use turboattention::runtime::Runtime;

fn cpu_engine(decode_threads: usize) -> Engine {
    let cfg = EngineConfig {
        mode: PathMode::TurboCpu,
        decode_threads,
        ..Default::default()
    };
    Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg)
}

/// The CPU-substrate serving path end to end through the engine —
/// batcher, prefill, decode rounds, folds, completion — with **no
/// artifacts on disk** (the suite's other paths all skip without them).
#[test]
fn turbo_cpu_engine_serves_without_artifacts() {
    let mut e = cpu_engine(2);
    e.submit(GenRequest::new(1, b"the cpu engine ".to_vec(), 12));
    let done = e.run_to_completion().expect("run");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].generated.len(), 12);
    assert!(done[0].ttft > 0.0 && done[0].total_latency >= done[0].ttft);
    assert!(
        e.metrics.cache_slab_bytes > 0,
        "slab working set aggregated into engine metrics"
    );
    assert!(
        e.metrics.cache_slab_bytes > e.metrics.cache_bytes,
        "slabs ({}) dominate the compressed cache ({})",
        e.metrics.cache_slab_bytes,
        e.metrics.cache_bytes
    );
    assert!(e.metrics.cache_compression > 1.0, "INT8 buffer beats FP16");
}

/// Engine-level arm of the TurboCpu determinism contract: greedy
/// generation is byte-identical for every `decode_threads` (the
/// library-level logits-bit arm lives in `parallel_parity.rs`).
#[test]
fn turbo_cpu_engine_decode_threads_do_not_change_generation() {
    let run = |threads: usize| -> Vec<u8> {
        let mut e = cpu_engine(threads);
        e.submit(GenRequest::new(1, b"the pool shards heads ".to_vec(), 40));
        e.run_to_completion().expect("run")[0].generated.clone()
    };
    let serial = run(1);
    for threads in [2usize, 4, 7] {
        assert_eq!(serial, run(threads), "decode_threads={threads}");
    }
}

/// Multiple interleaved requests complete on the CPU substrate (the
/// continuous batcher drives a real multi-session decode).
#[test]
fn turbo_cpu_engine_interleaves_requests() {
    let mut e = cpu_engine(4);
    for (i, prompt) in
        [b"the cache ".as_slice(), b"one shard ", b"this head "]
            .iter()
            .enumerate()
    {
        e.submit(GenRequest::new(i as u64, prompt.to_vec(), 6 + i * 3));
    }
    let done = e.run_to_completion().expect("run");
    assert_eq!(done.len(), 3);
    let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2]);
    for c in &done {
        assert_eq!(c.generated.len(), 6 + c.id as usize * 3);
    }
    assert_eq!(e.metrics.requests_completed, 3);
}

fn cpu_engine_sharing(decode_threads: usize, share: bool) -> Engine {
    let cfg = EngineConfig {
        mode: PathMode::TurboCpu,
        decode_threads,
        share_prefixes: share,
        ..Default::default()
    };
    Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg)
}

/// Prefix sharing is output-invisible: B identical greedy requests
/// generate the same bytes with sharing on and off (shared pages hold
/// exactly the codes a private prefill would have produced).
#[test]
fn prefix_sharing_does_not_change_generation() {
    let run = |share: bool| -> Vec<Vec<u8>> {
        let mut e = cpu_engine_sharing(2, share);
        // 40 tokens: one shared 32-token page + 8-token tail.
        let prompt: Vec<u8> =
            (0..40).map(|i| b'a' + (i % 11) as u8).collect();
        for id in 0..3u64 {
            e.submit(GenRequest::new(id, prompt.clone(), 10));
        }
        let mut done = e.run_to_completion().expect("run");
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.generated).collect()
    };
    let shared = run(true);
    let private = run(false);
    assert_eq!(shared, private, "sharing changed greedy output");
    assert_eq!(shared.len(), 3);
}

/// The acceptance criterion's metrics arm: B sessions over one common
/// prompt prefix report `shared_page_bytes > 0` and a dedup ratio of
/// exactly (B-1)/B while only the prefix pages exist in the pool.
#[test]
fn prefix_sharing_metrics_report_dedup() {
    let b_sessions = 4u64;
    let mut e = cpu_engine_sharing(2, true);
    // 64 tokens = exactly two 32-token pages, nothing buffered.
    let prompt: Vec<u8> = (0..64).map(|i| b'a' + (i % 13) as u8).collect();
    for id in 0..b_sessions {
        e.submit(GenRequest::new(id, prompt.clone(), 48));
    }
    // 8 iterations: all 4 admitted (1 prefill/step) and decoding, but
    // each has generated < 32 tokens, so no decode buffer has flushed —
    // the pool holds exactly the shared prefix pages.
    for _ in 0..8 {
        e.step().expect("step");
    }
    assert_eq!(e.metrics.prefix_hits, b_sessions - 1, "later requests fork");
    assert_eq!(
        e.metrics.prefix_shared_tokens,
        (b_sessions - 1) * prompt.len() as u64
    );
    assert!(e.metrics.shared_page_bytes > 0, "prefix pages shared");
    assert_eq!(e.metrics.private_page_bytes, 0, "no private pages yet");
    let want = (b_sessions - 1) as f64 / b_sessions as f64;
    assert!(
        (e.metrics.page_dedup_ratio - want).abs() < 1e-9,
        "dedup {} != (B-1)/B = {want}",
        e.metrics.page_dedup_ratio
    );
    // Drain; completions release their refs and the pool empties with
    // the engine's sessions (the index holds no refs of its own).
    let done = e.run_to_completion().expect("drain");
    assert_eq!(done.len(), b_sessions as usize);
}

fn engine(mode: PathMode) -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = Runtime::load("artifacts").expect("runtime");
    let cfg = EngineConfig { mode, ..Default::default() };
    Some(Engine::new(ModelBundle::new(rt), cfg))
}

#[test]
fn single_request_completes() {
    let Some(mut e) = engine(PathMode::Turbo) else { return };
    e.submit(GenRequest::new(1, b"the router ".to_vec(), 12));
    let done = e.run_to_completion().expect("run");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].generated.len(), 12);
    assert!(done[0].ttft > 0.0 && done[0].total_latency >= done[0].ttft);
    assert!(e.metrics.cache_compression > 1.5, "cache must be compressed");
}

#[test]
fn greedy_turbo_matches_flash_baseline() {
    // The paper's near-lossless claim, live on the real artifacts. Greedy
    // decoding compounds any divergence (once one token flips, the
    // suffixes legitimately differ), so the metric is the common-prefix
    // fraction averaged over prompts, not positionwise agreement.
    let Some(mut turbo) = engine(PathMode::Turbo) else { return };
    let Some(mut flash) = engine(PathMode::Flash) else { return };
    let prompts: [&[u8]; 4] = [
        b"the router ",
        b"a worker merges ",
        b"the kernel packs ",
        b"one shard streams ",
    ];
    let mut fractions = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        turbo.submit(GenRequest::new(i as u64, p.to_vec(), 20));
        flash.submit(GenRequest::new(i as u64, p.to_vec(), 20));
    }
    let mut t_out = turbo.run_to_completion().expect("turbo");
    let mut f_out = flash.run_to_completion().expect("flash");
    t_out.sort_by_key(|c| c.id);
    f_out.sort_by_key(|c| c.id);
    for (t, f) in t_out.iter().zip(&f_out) {
        let prefix = t
            .generated
            .iter()
            .zip(&f.generated)
            .take_while(|(a, b)| a == b)
            .count();
        fractions.push(prefix as f64 / t.generated.len() as f64);
    }
    let mean = fractions.iter().sum::<f64>() / fractions.len() as f64;
    assert!(mean >= 0.5, "mean prefix agreement {mean} ({fractions:?})");
    assert!(
        fractions.iter().any(|&f| f >= 0.99),
        "at least one prompt should agree fully: {fractions:?}"
    );
}

#[test]
fn backend_parity_greedy_small_contexts() {
    // Backend-parity property behind the `AttentionBackend` refactor:
    // under greedy decoding on the same seed/prompt, the turbo and flash
    // backends must produce *identical* generations for small contexts —
    // with so few steps the quantization error has no room to flip an
    // argmax, so any divergence here means the paths disagree on session
    // state (cache sync, fold order, position bookkeeping), not accuracy.
    let prompts: [&[u8]; 3] =
        [b"the router ", b"a worker merges ", b"one shard streams "];
    for (i, prompt) in prompts.iter().enumerate() {
        let Some(mut turbo) = engine(PathMode::Turbo) else { return };
        let Some(mut flash) = engine(PathMode::Flash) else { return };
        turbo.submit(GenRequest::new(i as u64, prompt.to_vec(), 4));
        flash.submit(GenRequest::new(i as u64, prompt.to_vec(), 4));
        let t = turbo.run_to_completion().expect("turbo");
        let f = flash.run_to_completion().expect("flash");
        assert_eq!(
            t[0].generated, f[0].generated,
            "greedy divergence on prompt {i}"
        );
    }
}

#[test]
fn cache_metrics_aggregate_over_all_sessions() {
    // The engine reports cache memory summed across live sessions, not an
    // arbitrary single one: two concurrent requests must report more
    // cache bytes mid-flight than one.
    let bytes_with = |n_reqs: usize| -> Option<usize> {
        let mut e = engine(PathMode::Turbo)?;
        for i in 0..n_reqs {
            e.submit(GenRequest::new(i as u64, b"the cache grows ".to_vec(), 48));
        }
        // Step until every request is admitted and has decoded a while,
        // then read the live aggregate.
        for _ in 0..24 {
            e.step().expect("step");
        }
        Some(e.metrics.cache_bytes)
    };
    let Some(one) = bytes_with(1) else { return };
    let two = bytes_with(2).unwrap();
    assert!(
        two > one,
        "2 sessions must report more cache than 1 ({two} vs {one})"
    );
}

#[test]
fn multiple_requests_interleave_and_complete() {
    let Some(mut e) = engine(PathMode::Turbo) else { return };
    for (i, prompt) in
        [b"the cache ".as_slice(), b"one shard ", b"this head "].iter().enumerate()
    {
        e.submit(GenRequest::new(i as u64, prompt.to_vec(), 6 + i * 3));
    }
    let done = e.run_to_completion().expect("run");
    assert_eq!(done.len(), 3);
    let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2]);
    for c in &done {
        assert_eq!(c.generated.len(), 6 + c.id as usize * 3);
    }
    assert_eq!(e.metrics.requests_completed, 3);
}

#[test]
fn stop_byte_terminates_early() {
    let Some(mut e) = engine(PathMode::Turbo) else { return };
    let mut req = GenRequest::new(1, b"the scheduler evicts ".to_vec(), 64);
    req.params.stop_byte = Some(b'.');
    e.submit(req);
    let done = e.run_to_completion().expect("run");
    let gen = &done[0].generated;
    // Trained grammar emits '.' within a sentence length.
    if gen.len() < 64 {
        assert_eq!(*gen.last().unwrap(), b'.');
    }
}

#[test]
fn mixed_precision_engine_still_generates() {
    let Some(rtcheck) = engine(PathMode::Turbo) else { return };
    drop(rtcheck);
    let rt = Runtime::load("artifacts").expect("runtime");
    let cfg = EngineConfig {
        mode: PathMode::Turbo,
        kv_bits: Bits::Int4,
        n_2bit_heads: 2,
        ..Default::default()
    };
    let mut e = Engine::new(ModelBundle::new(rt), cfg);
    // Generate enough tokens that full pages exist (compression comes
    // from the packed q2 pages; the INT8 buffer alone is only ~2x).
    e.submit(GenRequest::new(1, b"eight pages hold the scales ".to_vec(), 72));
    let done = e.run_to_completion().expect("run");
    assert_eq!(done[0].generated.len(), 72);
    assert!(
        e.metrics.cache_compression > 2.0,
        "compression {}",
        e.metrics.cache_compression
    );
}

#[test]
fn decode_threads_do_not_change_generation() {
    // Engine-level arm of the determinism contract (the library-level
    // arm is rust/tests/parallel_parity.rs): the same prompt under
    // greedy decoding must generate identical bytes for every
    // decode_threads, since the pool only reorders disjoint per-stream
    // work. Needs artifacts because engine decode runs the executable.
    let run = |threads: usize| -> Option<Vec<u8>> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let rt = Runtime::load("artifacts").expect("runtime");
        let cfg = EngineConfig {
            mode: PathMode::Turbo,
            decode_threads: threads,
            ..Default::default()
        };
        let mut e = Engine::new(ModelBundle::new(rt), cfg);
        e.submit(GenRequest::new(1, b"the pool shards heads ".to_vec(), 24));
        Some(e.run_to_completion().expect("run")[0].generated.clone())
    };
    let Some(serial) = run(1) else { return };
    for threads in [2usize, 4, 7] {
        let parallel = run(threads).unwrap();
        assert_eq!(
            serial, parallel,
            "decode_threads={threads} changed greedy generation"
        );
    }
}

#[test]
fn deterministic_given_seed() {
    let run = || {
        let mut e = engine(PathMode::Turbo)?;
        e.submit(GenRequest::new(1, b"the kernel ".to_vec(), 16));
        Some(e.run_to_completion().expect("run")[0].generated.clone())
    };
    let Some(a) = run() else { return };
    let b = run().unwrap();
    assert_eq!(a, b, "greedy generation must be deterministic");
}
