//! Cross-layer golden tests: the Rust CPU engines (quant/SAS/turbo) must
//! agree with the Pallas kernels executing through PJRT on identical
//! inputs — the contract that lets accuracy experiments run in pure Rust.
//!
//! Skipped when artifacts are absent.

use turboattention::attention::{turbo_attention, TurboConfig};
use turboattention::runtime::{HostTensor, Runtime};
use turboattention::sas::Sas;
use turboattention::tensor::Mat;
use turboattention::testutil::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime"))
}

#[test]
fn rust_sas_matches_pallas_sas() {
    let Some(mut rt) = runtime() else { return };
    let micro = rt.manifest.micro.clone();
    let mut rng = Rng::new(3);
    let data = rng.normal_vec(micro.sas_rows * micro.sas_cols, 2.0);
    let out = rt
        .run(
            "sas_micro",
            &[HostTensor::F32(
                data.clone(),
                vec![micro.sas_rows, micro.sas_cols],
            )],
        )
        .expect("sas");
    let pallas = out[0].as_f32().unwrap();

    let sas = Sas::default();
    for r in 0..micro.sas_rows {
        let mut row = data[r * micro.sas_cols..(r + 1) * micro.sas_cols].to_vec();
        sas.softmax_row(&mut row);
        for (c, (&a, &b)) in row
            .iter()
            .zip(&pallas[r * micro.sas_cols..(r + 1) * micro.sas_cols])
            .enumerate()
        {
            assert!(
                (a - b).abs() < 1e-5,
                "row {r} col {c}: rust {a} vs pallas {b}"
            );
        }
    }
}

#[test]
fn rust_turbo_engine_tracks_pallas_turbo_kernel() {
    let Some(mut rt) = runtime() else { return };
    let micro = rt.manifest.micro.clone();
    let (h, n, d, blk) = (micro.heads, micro.seq, micro.d_head, micro.block);
    let mut rng = Rng::new(5);
    let qv = rng.normal_vec(h * n * d, 1.0);
    let kv = rng.normal_vec(h * n * d, 1.0);
    let vv = rng.normal_vec(h * n * d, 1.0);
    let shape = vec![h, n, d];
    let out = rt
        .run(
            "attn_turbo_micro",
            &[
                HostTensor::F32(qv.clone(), shape.clone()),
                HostTensor::F32(kv.clone(), shape.clone()),
                HostTensor::F32(vv.clone(), shape),
            ],
        )
        .expect("turbo micro");
    let pallas = out[0].as_f32().unwrap();

    let cfg = TurboConfig { br: blk, bc: blk, causal: true, ..Default::default() };
    for head in 0..h {
        let s = head * n * d;
        let q = Mat::from_vec(n, d, qv[s..s + n * d].to_vec());
        let k = Mat::from_vec(n, d, kv[s..s + n * d].to_vec());
        let v = Mat::from_vec(n, d, vv[s..s + n * d].to_vec());
        let rust = turbo_attention(&q, &k, &v, &cfg);
        let pall = Mat::from_vec(n, d, pallas[s..s + n * d].to_vec());
        let rel = rust.rel_err(&pall);
        // Same algorithm, independent implementations: differences are
        // only float-order + knife-edge quantization codes.
        assert!(rel < 0.03, "head {head} rel err {rel}");
    }
}
