//! Load-harness contracts (ISSUE 10):
//!
//! 1. Seeded workloads and open-loop schedules are bit-reproducible —
//!    across runs and across the order/thread-count in which requests
//!    are materialized.
//! 2. A closed loop at concurrency 1 produces token streams
//!    byte-identical to running the same prompts through the engine
//!    sequentially (the harness never perturbs engine output — the
//!    PR-5 purity invariant, observed end to end through the harness).
//! 3. A cancel-probability-1.0 sweep leaves the engine drained: no
//!    pinned sessions, empty queue, zero physical pool bytes.
//! 4. The TCP target works end to end with a sparse/dense mix, and
//!    both `STATS` forms agree on the same scrape.
//!
//! Everything runs the artifact-free TurboCpu path — no PJRT.

use std::net::TcpListener;
use std::sync::mpsc::channel;

use turboattention::coordinator::{
    Engine, EngineConfig, EngineHandle, GenRequest, PathMode, SamplingParams,
};
use turboattention::loadgen::{
    open_loop_schedule, run_closed_loop, Target, WorkloadConfig,
};
use turboattention::model::ModelBundle;
use turboattention::runtime::Runtime;
use turboattention::server;

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        mode: PathMode::TurboCpu,
        share_prefixes: true,
        decode_threads: 2,
        ..Default::default()
    }
}

/// Engine on its own thread behind a handle (the PJRT client is not
/// `Send`, so the engine owns its thread; the handle is the interface).
fn spawn_engine(
    cfg: EngineConfig,
) -> (EngineHandle, std::thread::JoinHandle<anyhow::Result<()>>) {
    let (tx, rx) = channel();
    let join = std::thread::spawn(move || {
        Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg)
            .run_loop(rx)
    });
    (EngineHandle::new(tx), join)
}

#[test]
fn workload_and_schedule_bit_reproducible_any_order() {
    let wl = WorkloadConfig {
        seed: 17,
        n_requests: 24,
        shared_prefix_ratio: 0.5,
        cancel_prob: 0.25,
        sparse_ratio: 0.5,
        ..Default::default()
    };
    let all = wl.generate();
    // Materializing in reverse (as a racing worker pool might) changes
    // nothing: request i is a pure function of (config, i).
    for i in (0..wl.n_requests).rev() {
        let r = wl.request(i);
        assert_eq!(r.prompt, all[i].prompt, "prompt {i}");
        assert_eq!(r.params, all[i].params, "params {i}");
        assert_eq!(r.cancel_after, all[i].cancel_after, "cancel {i}");
        assert_eq!(r.sparse_topk_pages, all[i].sparse_topk_pages, "sparse {i}");
    }
    // The arrival schedule is a fixture: bit-equal, not approximately
    // equal, across independent derivations.
    let a = open_loop_schedule(wl.seed, 16.0, 64);
    let b = open_loop_schedule(wl.seed, 16.0, 64);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn closed_loop_concurrency_one_matches_sequential_gen() {
    let wl = WorkloadConfig {
        seed: 21,
        n_requests: 5,
        shared_prefix_ratio: 0.5,
        sparse_ratio: 0.4,
        sparse_topk_pages: 2,
        base: SamplingParams::greedy(12),
        ..Default::default()
    };
    let reqs = wl.generate();

    // Baseline: the same prompts through a direct engine, strictly one
    // at a time — the `gen` subcommand's exact shape.
    let mut engine =
        Engine::new(ModelBundle::new(Runtime::cpu_substrate()), engine_cfg());
    let mut sequential: Vec<Vec<u8>> = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        engine.submit(
            GenRequest::with_params(i as u64 + 1, r.prompt.clone(), r.params)
                .with_sparse_topk(r.sparse_topk_pages),
        );
        let done = engine.run_to_completion().expect("sequential run");
        assert_eq!(done.len(), 1, "one request in flight");
        sequential.push(done.into_iter().next().unwrap().generated);
    }

    // Harness: identical workload through the closed loop at
    // concurrency 1 against a fresh engine with the same config.
    let (handle, join) = spawn_engine(engine_cfg());
    let summary = run_closed_loop(&Target::InProcess(handle.clone()), &wl, 1);
    handle.shutdown();
    join.join().expect("engine thread").expect("engine run");

    assert_eq!(summary.outcomes.len(), wl.n_requests);
    for (o, want) in summary.outcomes.iter().zip(&sequential) {
        assert!(o.ok(), "request {} failed: {:?}", o.index, o.error);
        assert_eq!(o.finish_reason, "max_tokens");
        assert_eq!(
            o.generated, *want,
            "request {}: harness bytes diverge from sequential gen",
            o.index
        );
    }
}

#[test]
fn cancel_rate_one_sweep_drains_engine() {
    let wl = WorkloadConfig {
        seed: 33,
        n_requests: 8,
        cancel_prob: 1.0,
        shared_prefix_ratio: 0.5,
        base: SamplingParams::greedy(16),
        ..Default::default()
    };
    let (handle, join) = spawn_engine(engine_cfg());
    let summary = run_closed_loop(&Target::InProcess(handle.clone()), &wl, 4);

    // Every stream reached a terminal event — nothing hung.
    for o in &summary.outcomes {
        assert!(o.ok(), "request {} not terminal: {:?}", o.index, o.error);
    }
    // Mostly cancels; a request can still finish legitimately if its
    // cancel raced the last token, but the sweep must produce some.
    let cancelled = summary
        .outcomes
        .iter()
        .filter(|o| o.finish_reason == "cancelled")
        .count();
    assert!(cancelled >= 1, "cancel_prob 1.0 produced no cancels");

    // Drained: queue empty, no pinned sessions, pool physically empty.
    handle.flush().expect("flush");
    let stats = handle.stats().expect("stats");
    let m = &stats.metrics;
    assert_eq!(m.queue_depth, 0, "waiting queue not drained");
    assert_eq!(
        m.pool_physical_bytes, 0,
        "pool holds bytes after a full-cancel sweep — pinned sessions?"
    );
    assert_eq!(
        m.requests_completed + m.requests_cancelled,
        wl.n_requests as u64,
        "every request accounted as completed or cancelled"
    );
    handle.shutdown();
    join.join().expect("engine thread").expect("engine run");
}

#[test]
fn tcp_target_end_to_end_with_sparse_and_stats_json() {
    let (handle, join) = spawn_engine(engine_cfg());
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    {
        let h = handle.clone();
        // Detached: serve() blocks on accept with no shutdown path;
        // the thread dies with the test process.
        std::thread::spawn(move || {
            let _ = server::serve(listener, h, SamplingParams::default());
        });
    }

    let wl = WorkloadConfig {
        seed: 5,
        n_requests: 4,
        sparse_ratio: 1.0,
        sparse_topk_pages: 2,
        base: SamplingParams::greedy(10),
        ..Default::default()
    };
    let summary = run_closed_loop(&Target::Tcp(addr), &wl, 2);
    assert_eq!(summary.outcomes.len(), wl.n_requests);
    for o in &summary.outcomes {
        assert!(o.ok(), "request {} failed: {:?}", o.index, o.error);
        assert_eq!(o.finish_reason, "max_tokens");
        assert_eq!(o.tokens, 10, "request {} token count", o.index);
        assert_eq!(o.generated.len(), 10);
        assert!(o.first_token_at.is_some());
    }

    // Mid-stream CANCEL through the shared client.
    let mut client =
        turboattention::loadgen::TcpClient::connect(addr).expect("connect");
    let id = client
        .gen(b"cancel this one", &SamplingParams::greedy(120), 0)
        .expect("gen");
    let mut streamed = 0usize;
    let reason = loop {
        match client.next_event().expect("event") {
            turboattention::loadgen::WireEvent::Tok { .. } => {
                streamed += 1;
                if streamed == 1 {
                    client.cancel(id).expect("cancel");
                }
            }
            turboattention::loadgen::WireEvent::Done { reason, .. } => {
                break reason;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    };
    assert_eq!(reason, "cancelled");
    assert!(streamed < 120, "cancel should cut the stream short");

    // Both STATS forms agree on one quiesced scrape. Values compare
    // numerically where numeric (the JSON round trip drops trailing
    // zeros: `0.000` comes back as `0`), byte-equal otherwise.
    handle.flush().expect("flush");
    let kv = client.stats().expect("stats kv");
    let js = client.stats_json().expect("stats json");
    let keys = |m: &std::collections::BTreeMap<String, String>| {
        m.keys().cloned().collect::<Vec<_>>()
    };
    assert_eq!(keys(&kv), keys(&js), "same fields in both STATS forms");
    for (k, a) in &kv {
        let b = &js[k];
        match (a.parse::<f64>(), b.parse::<f64>()) {
            (Ok(x), Ok(y)) => {
                assert_eq!(x, y, "field {k}: kv={a} json={b}");
            }
            _ => assert_eq!(a, b, "field {k}"),
        }
    }
    let completed: u64 =
        js.get("completed").expect("completed key").parse().expect("number");
    assert!(completed >= wl.n_requests as u64, "completed={completed}");
    assert_eq!(js.get("cancelled").map(String::as_str), Some("1"));
    client.quit().expect("quit");

    handle.shutdown();
    join.join().expect("engine thread").expect("engine run");
}
