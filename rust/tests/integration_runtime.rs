//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! Skipped gracefully when `artifacts/` is absent (run `make artifacts`).

use turboattention::runtime::{HostTensor, Runtime};
use turboattention::testutil::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime"))
}

#[test]
fn manifest_describes_all_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "prefill_turbo",
        "prefill_flash",
        "decode_turbo",
        "decode_flash",
        "attn_turbo_micro",
        "attn_flash_micro",
        "sas_micro",
    ] {
        let spec = rt.manifest.artifact(name).expect(name);
        assert!(!spec.inputs.is_empty(), "{name} has inputs");
        assert!(!spec.outputs.is_empty(), "{name} has outputs");
        assert!(
            std::path::Path::new("artifacts").join(&spec.file).exists(),
            "{name} file exists"
        );
    }
}

#[test]
fn micro_turbo_close_to_micro_flash() {
    let Some(mut rt) = runtime() else { return };
    let micro = rt.manifest.micro.clone();
    let n = micro.heads * micro.seq * micro.d_head;
    let shape = vec![micro.heads, micro.seq, micro.d_head];
    let mut rng = Rng::new(7);
    let q = HostTensor::F32(rng.normal_vec(n, 1.0), shape.clone());
    let k = HostTensor::F32(rng.normal_vec(n, 1.0), shape.clone());
    let v = HostTensor::F32(rng.normal_vec(n, 1.0), shape.clone());
    let t = rt
        .run("attn_turbo_micro", &[q.clone(), k.clone(), v.clone()])
        .expect("turbo");
    let f = rt.run("attn_flash_micro", &[q, k, v]).expect("flash");
    let (tv, fv) = (t[0].as_f32().unwrap(), f[0].as_f32().unwrap());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in tv.iter().zip(fv) {
        num += ((a - b) as f64).powi(2);
        den += (*b as f64).powi(2);
    }
    let rel = (num / den).sqrt();
    assert!(rel < 0.05, "quantized micro kernel drift: {rel}");
}

#[test]
fn sas_micro_rows_normalized() {
    let Some(mut rt) = runtime() else { return };
    let micro = rt.manifest.micro.clone();
    let mut rng = Rng::new(9);
    let x = HostTensor::F32(
        rng.normal_vec(micro.sas_rows * micro.sas_cols, 2.5),
        vec![micro.sas_rows, micro.sas_cols],
    );
    let out = rt.run("sas_micro", &[x]).expect("sas");
    let probs = out[0].as_f32().unwrap();
    for r in 0..micro.sas_rows {
        let s: f32 =
            probs[r * micro.sas_cols..(r + 1) * micro.sas_cols].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        assert!(probs[r * micro.sas_cols..(r + 1) * micro.sas_cols]
            .iter()
            .all(|&p| (0.0..=1.0001).contains(&p)));
    }
}

#[test]
fn executable_rejects_wrong_arity() {
    let Some(mut rt) = runtime() else { return };
    let err = rt.run("sas_micro", &[]).unwrap_err();
    assert!(format!("{err}").contains("expected 1 inputs"));
}

#[test]
fn prefill_turbo_emits_quantized_cache() {
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.model.clone();
    let mut tokens = vec![0i32; m.max_ctx];
    for (i, b) in b"the kernel packs low bits quickly. ".iter().enumerate() {
        tokens[i] = *b as i32;
    }
    let n = 35usize;
    let outs = rt
        .run(
            "prefill_turbo",
            &[
                HostTensor::I32(tokens, vec![m.max_ctx]),
                HostTensor::scalar_i32(n as i32),
            ],
        )
        .expect("prefill");
    assert_eq!(outs.len(), 5);
    let k8 = outs[1].as_i8().unwrap();
    let sk = outs[3].as_f32().unwrap();
    assert_eq!(k8.len(), m.n_layers * m.n_heads * m.max_ctx * m.d_head);
    // Scales for the valid blocks must be positive.
    let nb = m.max_ctx / m.block;
    let valid_blocks = n.div_ceil(m.block);
    for l in 0..m.n_layers {
        for h in 0..m.n_heads {
            for bidx in 0..valid_blocks {
                let s = sk[(l * m.n_heads + h) * nb + bidx];
                assert!(s > 0.0, "scale l={l} h={h} b={bidx}");
            }
        }
    }
    // Valid-region codes must not all be zero.
    assert!(k8[..n * m.d_head].iter().any(|&c| c != 0));
}
