//! Request-lifecycle integration over the streaming API: `Engine::step`
//! event ordering, `EngineHandle`/`ResponseHandle` streaming and
//! cancellation, cancellation vs the PR-4 page-pool invariants, and
//! per-request sampling determinism (batch-composition invariance).
//! Everything runs on the artifact-free `TurboCpu` path.

use std::sync::mpsc::channel;

use turboattention::coordinator::{
    Engine, EngineConfig, EngineHandle, FinishReason, GenRequest, PathMode,
    SamplingParams, TokenEvent,
};
use turboattention::model::{ModelBundle, Sampler};
use turboattention::runtime::Runtime;

fn cpu_engine(decode_threads: usize, share: bool) -> Engine {
    let cfg = EngineConfig {
        mode: PathMode::TurboCpu,
        decode_threads,
        share_prefixes: share,
        ..Default::default()
    };
    Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg)
}

/// Spawn an engine thread and return its client handle (the engine is
/// built inside the thread, mirroring the PJRT !Send constraint).
fn spawn_engine(
    decode_threads: usize,
) -> (EngineHandle, std::thread::JoinHandle<anyhow::Result<()>>) {
    let (tx, rx) = channel();
    let jh = std::thread::spawn(move || {
        cpu_engine(decode_threads, false).run_loop(rx)
    });
    (EngineHandle::new(tx), jh)
}

/// The acceptance criterion at the `step` level: the *first* scheduler
/// step after submission emits `First` while the request is still live
/// (no `Finished` anywhere near it), and the terminal step emits
/// `Finished` — tokens stream out across many steps instead of
/// arriving as one completion.
#[test]
fn step_emits_first_token_before_completion() {
    let max_new = 16usize;
    let mut e = cpu_engine(1, false);
    e.submit(GenRequest::with_params(
        1,
        b"the stream ".to_vec(),
        SamplingParams::greedy(max_new),
    ));
    // The admission step emits First (and the admitted request's first
    // decode Token — admission joins the same step's decode round), but
    // never a Finished.
    let first_step = e.step().expect("step");
    assert!(
        matches!(first_step[0].event, TokenEvent::First { token: _, ttft } if ttft > 0.0),
        "got {:?}",
        first_step[0].event
    );
    assert!(
        !first_step
            .iter()
            .any(|ev| matches!(ev.event, TokenEvent::Finished(_))),
        "First must arrive before the request completes"
    );
    assert!(!e.idle(), "request must still be decoding after First");

    let mut events = first_step;
    while !e.idle() {
        events.extend(e.step().expect("step"));
    }
    let mut tokens = 1usize; // the First token
    let mut finished = None;
    for ev in events.into_iter().skip(1) {
        match ev.event {
            TokenEvent::Token { index, .. } => {
                assert_eq!(index, tokens, "indices are dense");
                tokens += 1;
            }
            TokenEvent::Finished(c) => finished = Some(c),
            TokenEvent::First { .. } => panic!("duplicate First"),
        }
    }
    let c = finished.expect("terminal Finished event");
    assert_eq!(tokens, max_new, "one event per token");
    assert_eq!(c.generated.len(), max_new);
    assert_eq!(c.finish_reason, FinishReason::MaxTokens);
}

/// The same contract through the client API: a `ResponseHandle` yields
/// `First`, then every decode token, then `Finished` — and `wait()`
/// reproduces the old blocking behavior.
#[test]
fn response_handle_streams_then_finishes() {
    let (h, jh) = spawn_engine(2);
    let mut resp = h
        .submit(GenRequest::with_params(
            0,
            b"stream me ".to_vec(),
            SamplingParams::greedy(16),
        ))
        .expect("submit");
    assert!(resp.id() >= 1, "engine-allocated id in the ack");

    let mut got_first = false;
    let mut token_events = 0usize;
    let mut completion = None;
    while let Some(ev) = resp.recv() {
        match ev {
            TokenEvent::First { .. } => {
                assert!(!got_first, "First exactly once");
                assert_eq!(token_events, 0, "First precedes all Tokens");
                got_first = true;
            }
            TokenEvent::Token { .. } => {
                assert!(got_first, "Token only after First");
                token_events += 1;
            }
            TokenEvent::Finished(c) => completion = Some(c),
        }
    }
    let c = completion.expect("stream ends with Finished");
    assert!(got_first);
    assert_eq!(token_events, 15, "max_new - 1 decode tokens");
    assert_eq!(c.generated.len(), 16);

    // wait() on a second identical request gives the same bytes — the
    // blocking path is the streaming path, drained.
    let c2 = h
        .submit(GenRequest::with_params(
            0,
            b"stream me ".to_vec(),
            SamplingParams::greedy(16),
        ))
        .expect("submit")
        .wait()
        .expect("completion");
    assert_eq!(c2.generated, c.generated, "same (prompt, params) => same bytes");

    h.shutdown();
    jh.join().expect("join").expect("engine ok");
}

/// Client-initiated cancel through the handle: the stream terminates
/// with a `Cancelled` completion well short of the token budget, and
/// engine stats report the cancellation.
#[test]
fn cancel_finishes_stream_with_cancelled_reason() {
    let (h, jh) = spawn_engine(2);
    let mut resp = h
        .submit(GenRequest::with_params(
            0,
            b"cancel this ".to_vec(),
            SamplingParams::greedy(200),
        ))
        .expect("submit");
    // Wait for the first token so the session provably exists, then
    // cancel.
    assert!(matches!(resp.recv(), Some(TokenEvent::First { .. })));
    resp.cancel().expect("cancel");
    let mut completion = None;
    while let Some(ev) = resp.recv() {
        if let TokenEvent::Finished(c) = ev {
            completion = Some(c);
        }
    }
    let c = completion.expect("cancelled stream still ends with Finished");
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(c.generated.len() < 200, "cancel must beat the token budget");
    let stats = h.stats().expect("stats");
    assert_eq!(stats.metrics.requests_cancelled, 1);
    assert_eq!(stats.metrics.requests_completed, 0);
    h.shutdown();
    jh.join().expect("join").expect("engine ok");
}

/// Cancellation vs the PR-4 pool invariants: two sessions share a
/// two-page prompt prefix; before the cancel the pool dedups exactly
/// (B-1)/B; cancelling the *donor* mid-decode (after both sessions
/// have flushed private decode pages) must release its refs and pages
/// immediately — epoch bump, fewer live pages — while the survivor's
/// `Q1View` re-verifies cleanly and decodes to the same bytes as an
/// uncancelled run. Draining everything empties the pool: refcounts
/// balance.
#[test]
fn cancel_mid_decode_releases_pages_and_survivor_stays_valid() {
    let b_sessions = 2u64;
    // 64 tokens = exactly two shared 32-token pages; 48 generated
    // tokens cross one page flush (block = 32) so each session also
    // owns private pages by the time we cancel.
    let prompt: Vec<u8> = (0..64).map(|i| b'a' + (i % 13) as u8).collect();
    let params = SamplingParams::greedy(48);

    let mut e = cpu_engine(2, true);
    for id in 1..=b_sessions {
        e.submit(GenRequest::with_params(id, prompt.clone(), params));
    }
    // Admit both (1 prefill/step) plus a few decode rounds — well under
    // 32 generated tokens, so the pool holds only the shared prefix.
    for _ in 0..6 {
        e.step().expect("step");
    }
    assert_eq!(e.metrics.prefix_hits, b_sessions - 1, "fork happened");
    {
        let pool = e.page_pool().expect("turbo-family pool");
        let st = pool.read().expect("pool").stats();
        assert!(st.shared_bytes > 0, "prefix pages shared");
        assert_eq!(st.private_bytes, 0, "no private pages before flush");
        let want = (b_sessions - 1) as f64 / b_sessions as f64;
        assert!(
            (st.dedup_ratio() - want).abs() < 1e-9,
            "dedup {} != (B-1)/B = {want}",
            st.dedup_ratio()
        );
    }

    // Decode past the first buffer flush: ~36 generated tokens each.
    for _ in 0..30 {
        e.step().expect("step");
    }
    let (epoch_before, live_before) = {
        let pool = e.page_pool().expect("pool").read().expect("pool");
        let st = pool.stats();
        assert!(st.private_bytes > 0, "decode pages flushed before cancel");
        (pool.epoch(), pool.live_pages())
    };

    // Cancel the donor (id 1) — the harder direction: the survivor
    // adopted *its* pages.
    let c = e.cancel(1).expect("live request");
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(!c.generated.is_empty() && c.generated.len() < 48);
    assert_eq!(e.metrics.requests_cancelled, 1);
    {
        let pool = e.page_pool().expect("pool").read().expect("pool");
        assert!(
            pool.epoch() > epoch_before,
            "freeing the donor's private pages must bump the epoch"
        );
        assert!(
            pool.live_pages() < live_before,
            "donor's private pages released within the cancel"
        );
        assert_eq!(
            pool.stats().shared_bytes,
            0,
            "prefix refs dropped to 1 owner => all remaining pages private"
        );
    }

    // Survivor decodes to completion across the epoch bump (its view
    // re-verifies instead of panicking) and matches a solo run.
    let done = e.run_to_completion().expect("survivor run");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 2);
    assert_eq!(done[0].generated.len(), 48);
    {
        let pool = e.page_pool().expect("pool").read().expect("pool");
        assert_eq!(pool.live_pages(), 0, "refcounts balance after drain");
    }

    let mut solo = cpu_engine(2, true);
    solo.submit(GenRequest::with_params(9, prompt.clone(), params));
    let solo_done = solo.run_to_completion().expect("solo run");
    assert_eq!(
        done[0].generated, solo_done[0].generated,
        "cancel must not perturb the survivor's output"
    );
}

/// Cancelling a request still waiting for admission frees its queue
/// entry and reports an empty `Cancelled` completion.
#[test]
fn cancel_waiting_request_before_prefill() {
    let mut cfg = EngineConfig {
        mode: PathMode::TurboCpu,
        decode_threads: 1,
        ..Default::default()
    };
    cfg.batcher.max_running = 1;
    let mut e = Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg);
    e.submit(GenRequest::with_params(1, b"running ".to_vec(), SamplingParams::greedy(8)));
    e.submit(GenRequest::with_params(2, b"waiting ".to_vec(), SamplingParams::greedy(8)));
    e.step().expect("step"); // admits #1 only (slot cap)
    let c = e.cancel(2).expect("waiting request is cancellable");
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(c.generated.is_empty(), "never prefilled");
    assert!(e.cancel(2).is_none(), "idempotent");
    let done = e.run_to_completion().expect("run");
    assert_eq!(done.len(), 1, "only #1 completes");
    assert_eq!(done[0].id, 1);
}

/// The batch-composition-invariance acceptance criterion: two requests
/// with identical `(prompt, SamplingParams)` — a stochastic top-k
/// policy, so the per-session RNG is actually exercised — produce
/// bit-identical token streams whether run alone, batched together, or
/// batched with unrelated traffic, across `decode_threads {1, 4}`.
#[test]
fn identical_requests_are_batch_composition_invariant() {
    let prompt = b"determinism probe ".to_vec();
    let params = SamplingParams {
        sampler: Sampler::TopK { k: 8, temp: 0.8 },
        seed: 42,
        stop_byte: None,
        max_new_tokens: 24,
    };
    let unrelated = SamplingParams {
        sampler: Sampler::TopK { k: 4, temp: 0.6 },
        seed: 9,
        stop_byte: None,
        max_new_tokens: 31,
    };

    // Run the engine with 1 or 2 copies of the probe request, plus
    // optional unrelated traffic; return the probe outputs sorted by id.
    let run = |threads: usize, copies: usize, traffic: bool| -> Vec<Vec<u8>> {
        let mut e = cpu_engine(threads, false);
        for id in 1..=copies as u64 {
            e.submit(GenRequest::with_params(id, prompt.clone(), params));
        }
        if traffic {
            e.submit(GenRequest::with_params(
                7,
                b"unrelated traffic stream ".to_vec(),
                unrelated,
            ));
        }
        let mut done = e.run_to_completion().expect("run");
        done.sort_by_key(|c| c.id);
        done.into_iter()
            .filter(|c| c.id <= copies as u64)
            .map(|c| c.generated)
            .collect()
    };

    let reference = run(1, 1, false).remove(0);
    assert_eq!(reference.len(), 24);
    for threads in [1usize, 4] {
        let alone = run(threads, 1, false);
        assert_eq!(alone[0], reference, "alone, threads={threads}");
        let paired = run(threads, 2, false);
        assert_eq!(paired[0], reference, "paired #1, threads={threads}");
        assert_eq!(paired[1], reference, "paired #2, threads={threads}");
        let mixed = run(threads, 2, true);
        assert_eq!(mixed[0], reference, "mixed #1, threads={threads}");
        assert_eq!(mixed[1], reference, "mixed #2, threads={threads}");
    }

    // And the unrelated request is itself a pure function of its own
    // (prompt, params) — presence of the probes changes nothing.
    let solo_unrelated = {
        let mut e = cpu_engine(1, false);
        e.submit(GenRequest::with_params(
            7,
            b"unrelated traffic stream ".to_vec(),
            unrelated,
        ));
        e.run_to_completion().expect("run").remove(0).generated
    };
    let mixed_unrelated = {
        let mut e = cpu_engine(4, false);
        e.submit(GenRequest::with_params(1, prompt.clone(), params));
        e.submit(GenRequest::with_params(
            7,
            b"unrelated traffic stream ".to_vec(),
            unrelated,
        ));
        let mut done = e.run_to_completion().expect("run");
        done.sort_by_key(|c| c.id);
        done.pop().expect("id 7 sorts last").generated
    };
    assert_eq!(solo_unrelated, mixed_unrelated);
}

/// Disconnect-as-cancel: dropping a `ResponseHandle` without draining
/// it releases the request engine-side (the engine cancels it on the
/// next failed event send) — a disconnected client cannot pin its
/// batcher slot until `max_new_tokens`.
#[test]
fn dropped_response_handle_cancels_request() {
    let (h, jh) = spawn_engine(1);
    let resp = h
        .submit(GenRequest::with_params(
            0,
            b"disconnected client ".to_vec(),
            SamplingParams::greedy(200),
        ))
        .expect("submit");
    drop(resp); // client goes away without cancelling
    // Flush drives the engine until idle: if the disconnect were not
    // detected, this would decode all 200 tokens; either way it must
    // terminate, and the request must be recorded cancelled.
    h.flush().expect("flush");
    let stats = h.stats().expect("stats");
    assert_eq!(stats.metrics.requests_cancelled, 1);
    assert_eq!(stats.metrics.requests_completed, 0);
    h.shutdown();
    jh.join().expect("join").expect("engine ok");
}
