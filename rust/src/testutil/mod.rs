//! Test substrates: deterministic PRNG and a small property-testing
//! framework (proptest is unavailable offline — DESIGN.md §2).

pub mod prop;

/// SplitMix64: tiny, fast, high-quality deterministic PRNG.
///
/// Used everywhere randomness is needed (tests, workload generation,
/// synthetic tensors) so every run is reproducible from a seed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Vector of standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Exponentially-distributed value with the given rate (for Poisson
    /// arrival processes in the workload generator).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    /// Random boolean with probability `p` of true.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.range(3, 9);
            assert!((3..9).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
