//! Property-testing mini-framework (substitute for proptest, which is not
//! in the offline vendor set).
//!
//! A property is a closure over a [`Gen`] (seeded case generator). The
//! runner executes `cases` random cases; on failure it retries the same
//! seed to confirm, then reports the seed so the case can be replayed in a
//! unit test. Shrinking is seed-based: we re-run with "smaller" size hints
//! and report the smallest failing size.
//!
//! ```no_run
//! use turboattention::testutil::prop::{run, Gen};
//! run("abs is non-negative", 100, |g| {
//!     let x = g.f32_in(-100.0, 100.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use super::Rng;

/// Per-case generator handed to properties; wraps a seeded [`Rng`] plus a
/// size hint that the shrinking pass lowers on failure.
pub struct Gen {
    pub rng: Rng,
    /// Soft bound used by the sized generators; starts at 1.0, shrinks
    /// toward 0.0.
    pub size: f64,
    seed: u64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Rng::new(seed), size, seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Integer in [lo, hi), biased toward lo as size shrinks.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let span = ((hi - lo) as f64 * self.size).ceil().max(1.0) as usize;
        self.rng.range(lo, lo + span.min(hi - lo) + 1).min(hi - 1)
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    /// Standard-normal vector with the given scale.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        self.rng.normal_vec(n, scale)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }
}

/// Run `cases` random cases of `prop`. Panics (test failure) with the
/// failing seed and the smallest failing size found by the shrink pass.
pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    run_seeded(name, cases, 0xC0FFEE, prop)
}

/// [`run`] with an explicit base seed (for replaying failures).
pub fn run_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    base_seed: u64,
    prop: F,
) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E3779B9));
        if let Err(panic) = try_case(&prop, seed, 1.0) {
            // Shrink: binary-search the smallest failing size hint.
            let mut lo = 0.0f64;
            let mut hi = 1.0f64;
            for _ in 0..12 {
                let mid = (lo + hi) / 2.0;
                if try_case(&prop, seed, mid).is_err() {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
            let msg = panic_message(&panic);
            panic!(
                "property '{name}' failed (seed={seed:#x}, case {i}, \
                 min failing size={hi:.3}): {msg}\n\
                 replay with run_seeded(\"{name}\", 1, {seed:#x}, ..)"
            );
        }
    }
}

fn try_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    seed: u64,
    size: f64,
) -> Result<(), Box<dyn std::any::Any + Send>> {
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // silence expected panics
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, size);
        prop(&mut g);
    });
    std::panic::set_hook(hook);
    result
}

fn panic_message(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run("sum commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        run("always fails", 5, |g| {
            let x = g.usize_in(0, 100);
            assert!(x > 1000, "x={x}");
        });
    }

    #[test]
    fn generators_respect_bounds() {
        run("bounds", 200, |g| {
            let n = g.usize_in(1, 17);
            assert!((1..17).contains(&n));
            let f = g.f32_in(2.0, 3.0);
            assert!((2.0..3.0).contains(&f));
        });
    }

    #[test]
    fn deterministic_replay() {
        // Same seed must generate the same case.
        let mut g1 = Gen::new(42, 1.0);
        let mut g2 = Gen::new(42, 1.0);
        assert_eq!(g1.usize_in(0, 1000), g2.usize_in(0, 1000));
        assert_eq!(g1.f32_in(0.0, 1.0), g2.f32_in(0.0, 1.0));
    }
}
