//! Typed wrappers around the prefill/decode AOT executables.
//!
//! `ModelBundle` hides the PJRT tensor plumbing: padding prompts to the
//! artifact shape, assembling the q1 cache view the decode executable
//! consumes, and unpacking the (logits, K/V) outputs.

use anyhow::{bail, Result};

use crate::kvcache::KvCache;
use crate::runtime::{HostTensor, Runtime};

/// Prefill result: next-token logits for every prompt position plus the
/// q1-level cache tensors (turbo) or float cache (flash).
pub struct PrefillOut {
    /// Logits for position `i` predict token `i+1`; `[max_ctx * vocab]`.
    pub logits: Vec<f32>,
    /// Turbo: (k8, v8 `[L*H*C*dh]` i8, sk, sv `[L*H*nb]` f32).
    pub turbo_cache: Option<(Vec<i8>, Vec<i8>, Vec<f32>, Vec<f32>)>,
    /// Flash: (kf, vf `[L*H*C*dh]` f32).
    pub flash_cache: Option<(Vec<f32>, Vec<f32>)>,
}

/// Decode step result.
pub struct DecodeOut {
    pub logits: Vec<f32>,
    /// New token's K and V, `[L*H*dh]`.
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    /// Sparse-path accounting for this step, summed over every (layer,
    /// head) stream: full pages exactly attended / skipped via the
    /// mean-value fold. Zero on the dense path (knob off, AOT paths).
    pub sparse_pages_attended: u64,
    pub sparse_pages_skipped: u64,
    /// Cache-traffic bytes the skipped pages did not read
    /// (`skipped * 2 * block * d_head` K+V codes).
    pub sparse_bytes_saved: u64,
}

/// Persistent per-session q1 tensors in the decode executable's layout:
/// codes `[L, H, C, dh]` (INT8) + per-block scales `[L, H, C/block]`.
///
/// Owned by a turbo backend session and kept in sync *incrementally* from
/// the cache streams' `Q1View`s — the executable input for step `t+1` is
/// step `t`'s input plus the tokens folded in between, so nothing is
/// rematerialized per token. The buffers round-trip through the PJRT
/// boundary via take/restore, so a decode step allocates no cache-sized
/// memory.
pub struct TurboSlabs {
    pub k8: Vec<i8>,
    pub v8: Vec<i8>,
    pub sk: Vec<f32>,
    pub sv: Vec<f32>,
    /// Sparse-path page summaries mirrored from the pool at sync time,
    /// `[L, H, C/block, dh]` each: per-channel K min/max envelope
    /// (inputs of `kernels::page_score`) and per-channel V column mean
    /// (the mean-value fold for skipped pages). Zero-filled for blocks
    /// that are not yet flushed pages — the dense path and the buffer
    /// tail never read them.
    pub kmin: Vec<i8>,
    pub kmax: Vec<i8>,
    pub vmean: Vec<f32>,
}

impl TurboSlabs {
    /// Zeroed slabs for the given geometry (`scales` start at 1.0 so
    /// untouched blocks dequantize to zero harmlessly).
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        max_ctx: usize,
        d_head: usize,
        block: usize,
    ) -> TurboSlabs {
        // The page-aligned layout gives every block of tokens exactly
        // one scale slot; a ragged tail block would be silently capped
        // by the sync (`nbv.min(nb)`) and then indexed out of bounds in
        // the decode hot path — fail loudly here instead (same contract
        // as the `n_b == block` assert in `KvCache::new`).
        assert_eq!(
            max_ctx % block,
            0,
            "max_ctx {max_ctx} must be a multiple of block {block}"
        );
        let elems = n_layers * n_heads * max_ctx * d_head;
        let scales = n_layers * n_heads * (max_ctx / block);
        let sums = scales * d_head;
        TurboSlabs {
            k8: vec![0i8; elems],
            v8: vec![0i8; elems],
            sk: vec![1.0f32; scales],
            sv: vec![1.0f32; scales],
            kmin: vec![0i8; sums],
            kmax: vec![0i8; sums],
            vmean: vec![0.0f32; sums],
        }
    }

    /// Working-set bytes held by the slabs (codes + f32 scales +
    /// per-page summaries) — the decode working memory
    /// `CacheStats::slab_bytes` reports next to the compressed-cache
    /// storage.
    pub fn bytes(&self) -> usize {
        self.k8.len()
            + self.v8.len()
            + 4 * (self.sk.len() + self.sv.len())
            + self.kmin.len()
            + self.kmax.len()
            + 4 * self.vmean.len()
    }

    /// Split into `n_streams` equal, **disjoint** mutable shards — one
    /// per (layer, head), in the same layer-major order as
    /// [`KvCache::streams_mut`](crate::kvcache::KvCache::streams_mut).
    /// Shard `i` owns codes `[i * C * dh, (i + 1) * C * dh)` and scales
    /// `[i * nb, (i + 1) * nb)` of each slab. Built from `chunks_mut`,
    /// so the borrow checker proves no two workers alias a byte; this
    /// is what lets the parallel slab sync write with no locks.
    pub fn shards_mut(
        &mut self,
        n_streams: usize,
    ) -> impl Iterator<Item = SlabShardMut<'_>> + '_ {
        // Hard asserts (cost: once per sync): a ragged split would
        // produce shard offsets that disagree with the contiguous
        // `c = len / n_streams` stride every reader assumes, and the
        // zip-truncation guard downstream cannot catch that case.
        assert!(
            n_streams == 0
                || (self.k8.len() % n_streams == 0
                    && self.sk.len() % n_streams == 0),
            "slabs not evenly divisible into {n_streams} shards"
        );
        assert!(
            n_streams == 0 || self.k8.is_empty() || !self.sk.is_empty(),
            "codes without scales: max_ctx must be >= block"
        );
        // On empty geometry the slabs are empty and any positive chunk
        // size yields the correct zero shards.
        let code_chunk = if n_streams == 0 {
            1
        } else {
            (self.k8.len() / n_streams).max(1)
        };
        let scale_chunk = if n_streams == 0 {
            1
        } else {
            (self.sk.len() / n_streams).max(1)
        };
        let sum_chunk = if n_streams == 0 {
            1
        } else {
            (self.kmin.len() / n_streams).max(1)
        };
        self.k8
            .chunks_mut(code_chunk)
            .zip(self.v8.chunks_mut(code_chunk))
            .zip(
                self.sk
                    .chunks_mut(scale_chunk)
                    .zip(self.sv.chunks_mut(scale_chunk)),
            )
            .zip(
                self.kmin
                    .chunks_mut(sum_chunk)
                    .zip(self.kmax.chunks_mut(sum_chunk))
                    .zip(self.vmean.chunks_mut(sum_chunk)),
            )
            .map(|(((k8, v8), (sk, sv)), ((kmin, kmax), vmean))| {
                SlabShardMut { k8, v8, sk, sv, kmin, kmax, vmean }
            })
    }
}

/// One (layer, head) slice of every decode slab, handed to exactly one
/// worker per sync (see [`TurboSlabs::shards_mut`]).
pub struct SlabShardMut<'a> {
    /// K codes `[C * d_head]` for this stream.
    pub k8: &'a mut [i8],
    /// V codes `[C * d_head]` for this stream.
    pub v8: &'a mut [i8],
    /// K per-block scales `[C / block]`.
    pub sk: &'a mut [f32],
    /// V per-block scales `[C / block]`.
    pub sv: &'a mut [f32],
    /// K page envelope minima `[(C / block) * d_head]`.
    pub kmin: &'a mut [i8],
    /// K page envelope maxima `[(C / block) * d_head]`.
    pub kmax: &'a mut [i8],
    /// V page column means `[(C / block) * d_head]`.
    pub vmean: &'a mut [f32],
}

/// Persistent per-session float K/V slabs `[L, H, C, dh]` for the flash
/// (exact baseline) path, built directly from the prefill outputs. Same
/// take/restore round trip as [`TurboSlabs`] — the seed path cloned both
/// full slabs on every generated token.
pub struct FlashSlabs {
    pub kf: Vec<f32>,
    pub vf: Vec<f32>,
}

/// The serving model: a `Runtime` plus the shapes from its manifest.
pub struct ModelBundle {
    pub rt: Runtime,
}

impl ModelBundle {
    pub fn new(rt: Runtime) -> ModelBundle {
        ModelBundle { rt }
    }

    /// Fresh turbo decode slabs sized for this model.
    pub fn new_turbo_slabs(&self) -> TurboSlabs {
        let m = &self.rt.manifest.model;
        TurboSlabs::new(m.n_layers, m.n_heads, m.max_ctx, m.d_head, m.block)
    }

    pub fn vocab(&self) -> usize {
        self.rt.manifest.model.vocab
    }

    pub fn max_ctx(&self) -> usize {
        self.rt.manifest.model.max_ctx
    }

    pub fn block(&self) -> usize {
        self.rt.manifest.model.block
    }

    pub fn n_layers(&self) -> usize {
        self.rt.manifest.model.n_layers
    }

    pub fn n_heads(&self) -> usize {
        self.rt.manifest.model.n_heads
    }

    pub fn d_head(&self) -> usize {
        self.rt.manifest.model.d_head
    }

    fn cache_elems(&self) -> usize {
        let m = &self.rt.manifest.model;
        m.n_layers * m.n_heads * m.max_ctx * m.d_head
    }

    fn scale_elems(&self) -> usize {
        let m = &self.rt.manifest.model;
        m.n_layers * m.n_heads * (m.max_ctx / m.block)
    }

    /// Run prefill over `prompt` (byte tokens) on the given path.
    pub fn prefill(&mut self, prompt: &[u8], turbo: bool) -> Result<PrefillOut> {
        let m = &self.rt.manifest.model;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > m.max_ctx {
            bail!("prompt len {} exceeds max_ctx {}", prompt.len(), m.max_ctx);
        }
        let max_ctx = m.max_ctx;
        let mut tokens = vec![0i32; max_ctx];
        for (i, &b) in prompt.iter().enumerate() {
            tokens[i] = b as i32;
        }
        let inputs = [
            HostTensor::I32(tokens, vec![max_ctx]),
            HostTensor::scalar_i32(prompt.len() as i32),
        ];
        if turbo {
            let outs = self.rt.run("prefill_turbo", &inputs)?;
            let [logits, k8, v8, sk, sv] = take5(outs)?;
            Ok(PrefillOut {
                logits: logits.as_f32()?.to_vec(),
                turbo_cache: Some((
                    k8.as_i8()?.to_vec(),
                    v8.as_i8()?.to_vec(),
                    sk.as_f32()?.to_vec(),
                    sv.as_f32()?.to_vec(),
                )),
                flash_cache: None,
            })
        } else {
            let outs = self.rt.run("prefill_flash", &inputs)?;
            let [logits, kf, vf] = take3(outs)?;
            Ok(PrefillOut {
                logits: logits.as_f32()?.to_vec(),
                turbo_cache: None,
                flash_cache: Some((kf.as_f32()?.to_vec(), vf.as_f32()?.to_vec())),
            })
        }
    }

    /// Ingest a turbo prefill cache into the paged `KvCache`.
    ///
    /// Splits the `[L, H, max_ctx, dh]` q1 slabs into per-block chunks
    /// with their scales and feeds `ingest_q1_block`.
    pub fn ingest_prefill(
        &self,
        cache: &mut KvCache,
        k8: &[i8],
        v8: &[i8],
        sk: &[f32],
        sv: &[f32],
        n_tokens: usize,
    ) {
        self.ingest_prefill_from(cache, k8, v8, sk, sv, n_tokens, 0)
    }

    /// [`Self::ingest_prefill`] starting at the page-aligned token
    /// `skip_tokens`: the earlier tokens belong to an adopted shared
    /// prompt prefix whose pooled pages are already in the cache, so
    /// only the tail is quantized into new pages (prefix sharing).
    #[allow(clippy::too_many_arguments)]
    pub fn ingest_prefill_from(
        &self,
        cache: &mut KvCache,
        k8: &[i8],
        v8: &[i8],
        sk: &[f32],
        sv: &[f32],
        n_tokens: usize,
        skip_tokens: usize,
    ) {
        let m = &self.rt.manifest.model;
        assert_eq!(k8.len(), self.cache_elems());
        assert_eq!(sk.len(), self.scale_elems());
        let (l_n, h_n, c, dh, bc) =
            (m.n_layers, m.n_heads, m.max_ctx, m.d_head, m.block);
        assert_eq!(
            skip_tokens % bc,
            0,
            "shared prefix must be page-aligned"
        );
        assert!(skip_tokens <= n_tokens);
        let nb = c / bc;
        for l in 0..l_n {
            for h in 0..h_n {
                let base = ((l * h_n) + h) * c * dh;
                let sbase = ((l * h_n) + h) * nb;
                let mut t0 = skip_tokens;
                let mut bi = skip_tokens / bc;
                while t0 < n_tokens {
                    let t1 = (t0 + bc).min(n_tokens);
                    let codes = &k8[base + t0 * dh..base + t1 * dh];
                    cache.k_stream_mut(l, h).ingest_q1_block(
                        codes,
                        sk[sbase + bi],
                        t1 - t0,
                    );
                    let codes = &v8[base + t0 * dh..base + t1 * dh];
                    cache.v_stream_mut(l, h).ingest_q1_block(
                        codes,
                        sv[sbase + bi],
                        t1 - t0,
                    );
                    t0 = t1;
                    bi += 1;
                }
            }
        }
    }

    /// One turbo decode step: embed `token` at `pos`, attend over the
    /// session's q1 slabs (`nk` valid tokens), return logits and the new
    /// token's K/V.
    ///
    /// The slabs are the caller's (the backend session keeps them in sync
    /// from the cache's incremental `Q1View`s); this function no longer
    /// rematerializes the cache — the step is O(model) not O(context).
    /// The buffers are moved into the PJRT inputs and restored afterwards,
    /// even on execution error.
    pub fn decode_turbo(
        &mut self,
        slabs: &mut TurboSlabs,
        token: u8,
        pos: usize,
        nk: usize,
    ) -> Result<DecodeOut> {
        let m = &self.rt.manifest.model;
        let shape4 = vec![m.n_layers, m.n_heads, m.max_ctx, m.d_head];
        let shape3 = vec![m.n_layers, m.n_heads, m.max_ctx / m.block];
        let inputs = [
            HostTensor::scalar_i32(token as i32),
            HostTensor::scalar_i32(pos as i32),
            HostTensor::I8(std::mem::take(&mut slabs.k8), shape4.clone()),
            HostTensor::I8(std::mem::take(&mut slabs.v8), shape4),
            HostTensor::F32(std::mem::take(&mut slabs.sk), shape3.clone()),
            HostTensor::F32(std::mem::take(&mut slabs.sv), shape3),
            HostTensor::scalar_i32(nk as i32),
        ];
        let outs = self.rt.run("decode_turbo", &inputs);
        // Hand the slabs back to the session before surfacing any error.
        let mut it = inputs.into_iter().skip(2);
        if let (
            Some(HostTensor::I8(k8, _)),
            Some(HostTensor::I8(v8, _)),
            Some(HostTensor::F32(sk, _)),
            Some(HostTensor::F32(sv, _)),
        ) = (it.next(), it.next(), it.next(), it.next())
        {
            slabs.k8 = k8;
            slabs.v8 = v8;
            slabs.sk = sk;
            slabs.sv = sv;
        }
        let [logits, k_new, v_new] = take3(outs?)?;
        Ok(DecodeOut {
            logits: logits.as_f32()?.to_vec(),
            k_new: k_new.as_f32()?.to_vec(),
            v_new: v_new.as_f32()?.to_vec(),
            sparse_pages_attended: 0,
            sparse_pages_skipped: 0,
            sparse_bytes_saved: 0,
        })
    }

    /// One flash (exact baseline) decode step over the session's float
    /// slabs. Same take/restore round trip as [`Self::decode_turbo`] —
    /// previously this cloned both full `[L*H*C*dh]` slabs per token.
    pub fn decode_flash(
        &mut self,
        slabs: &mut FlashSlabs,
        token: u8,
        pos: usize,
        nk: usize,
    ) -> Result<DecodeOut> {
        let m = &self.rt.manifest.model;
        let shape4 = vec![m.n_layers, m.n_heads, m.max_ctx, m.d_head];
        let inputs = [
            HostTensor::scalar_i32(token as i32),
            HostTensor::scalar_i32(pos as i32),
            HostTensor::F32(std::mem::take(&mut slabs.kf), shape4.clone()),
            HostTensor::F32(std::mem::take(&mut slabs.vf), shape4),
            HostTensor::scalar_i32(nk as i32),
        ];
        let outs = self.rt.run("decode_flash", &inputs);
        let mut it = inputs.into_iter().skip(2);
        if let (Some(HostTensor::F32(kf, _)), Some(HostTensor::F32(vf, _))) =
            (it.next(), it.next())
        {
            slabs.kf = kf;
            slabs.vf = vf;
        }
        let [logits, k_new, v_new] = take3(outs?)?;
        Ok(DecodeOut {
            logits: logits.as_f32()?.to_vec(),
            k_new: k_new.as_f32()?.to_vec(),
            v_new: v_new.as_f32()?.to_vec(),
            sparse_pages_attended: 0,
            sparse_pages_skipped: 0,
            sparse_bytes_saved: 0,
        })
    }

    /// Logits row for position `pos` out of a prefill logits buffer.
    pub fn logits_at<'a>(&self, logits: &'a [f32], pos: usize) -> &'a [f32] {
        let v = self.vocab();
        &logits[pos * v..(pos + 1) * v]
    }
}

fn take3(mut v: Vec<HostTensor>) -> Result<[HostTensor; 3]> {
    if v.len() != 3 {
        bail!("expected 3 outputs, got {}", v.len());
    }
    let c = v.pop().unwrap();
    let b = v.pop().unwrap();
    let a = v.pop().unwrap();
    Ok([a, b, c])
}

fn take5(mut v: Vec<HostTensor>) -> Result<[HostTensor; 5]> {
    if v.len() != 5 {
        bail!("expected 5 outputs, got {}", v.len());
    }
    let e = v.pop().unwrap();
    let d = v.pop().unwrap();
    let c = v.pop().unwrap();
    let b = v.pop().unwrap();
    let a = v.pop().unwrap();
    Ok([a, b, c, d, e])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiling invariant behind the lock-free parallel sync: the shard
    /// iterator covers every slab element exactly once, in stream
    /// order, with no gaps and no overlap.
    #[test]
    fn slab_shards_tile_the_slabs_exactly() {
        let (l_n, h_n, c, dh, block) = (2usize, 3, 16, 4, 4);
        let n_streams = l_n * h_n;
        let mut slabs = TurboSlabs::new(l_n, h_n, c, dh, block);
        let mut count = 0usize;
        for (i, shard) in slabs.shards_mut(n_streams).enumerate() {
            assert_eq!(shard.k8.len(), c * dh);
            assert_eq!(shard.v8.len(), c * dh);
            assert_eq!(shard.sk.len(), c / block);
            assert_eq!(shard.sv.len(), c / block);
            assert_eq!(shard.kmin.len(), (c / block) * dh);
            assert_eq!(shard.kmax.len(), (c / block) * dh);
            assert_eq!(shard.vmean.len(), (c / block) * dh);
            // Tag every element with its shard id (+1 so untouched
            // elements stay distinguishable at 0 / 1.0 defaults).
            shard.k8.fill(i as i8 + 1);
            shard.v8.fill(-(i as i8 + 1));
            shard.sk.fill(i as f32 + 2.0);
            shard.sv.fill(-(i as f32 + 2.0));
            shard.kmin.fill(i as i8 + 3);
            shard.kmax.fill(-(i as i8 + 3));
            shard.vmean.fill(i as f32 + 4.0);
            count += 1;
        }
        assert_eq!(count, n_streams, "one shard per (layer, head)");
        // Full coverage + ordering: element j belongs to shard
        // j / (c * dh) (codes) or j / (c / block) (scales).
        for (j, &v) in slabs.k8.iter().enumerate() {
            assert_eq!(v, (j / (c * dh)) as i8 + 1, "k8[{j}]");
        }
        for (j, &v) in slabs.v8.iter().enumerate() {
            assert_eq!(v, -((j / (c * dh)) as i8 + 1), "v8[{j}]");
        }
        for (j, &v) in slabs.sk.iter().enumerate() {
            assert_eq!(v, (j / (c / block)) as f32 + 2.0, "sk[{j}]");
        }
        for (j, &v) in slabs.sv.iter().enumerate() {
            assert_eq!(v, -((j / (c / block)) as f32 + 2.0), "sv[{j}]");
        }
        let sums = (c / block) * dh;
        for (j, &v) in slabs.kmin.iter().enumerate() {
            assert_eq!(v, (j / sums) as i8 + 3, "kmin[{j}]");
        }
        for (j, &v) in slabs.kmax.iter().enumerate() {
            assert_eq!(v, -((j / sums) as i8 + 3), "kmax[{j}]");
        }
        for (j, &v) in slabs.vmean.iter().enumerate() {
            assert_eq!(v, (j / sums) as f32 + 4.0, "vmean[{j}]");
        }
    }

    #[test]
    fn slab_shards_zero_streams_is_empty() {
        let mut slabs = TurboSlabs::new(0, 0, 16, 4, 4);
        assert_eq!(slabs.shards_mut(0).count(), 0);
    }
}
