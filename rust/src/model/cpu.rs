//! Pure-Rust CPU-substrate transformer — the model behind the
//! `TurboCpu` serving backend.
//!
//! The PJRT paths run prefill/decode inside AOT executables, which means
//! the engine can only serve where artifacts (and the `pjrt` toolchain)
//! exist, and the CPU attention substrate (`turbo_decode_streams` + the
//! integer kernels) is never on a serving path. This module closes that
//! gap: a tiny byte-LM transformer whose weights are generated
//! **deterministically** from a seed and whose attention runs entirely
//! through the Turbo engines —
//!
//! * prefill: per-head [`turbo_attention`] (Algorithm 1 tiles on the
//!   integer kernels), heads fanned out on the decode worker pool;
//! * decode: [`turbo_decode_streams`] over the session's q1 slabs (one
//!   layer's heads per fan-out, because layers are sequential), with the
//!   current token merged via the SAS online-softmax float merge.
//!
//! Everything outside attention (embedding + sinusoidal positions, QKV /
//! output projections, a ReLU MLP, RMS pre-norms, the logit head) is
//! plain serial arithmetic, so decode output is bit-identical for
//! every `decode_threads` — the same determinism contract the parity
//! suite enforces for the slab sync and the stream fan-out.
//!
//! Decode-step intermediates live in a session-owned [`ModelScratch`]:
//! after the first step, the model math allocates nothing (the only
//! per-step allocations left are the three `DecodeOut` result vectors
//! the engine consumes). [`ModelScratch::grows`] counts buffer
//! (re)allocations so tests can assert the steady state.
//!
//! Prefix sharing hook: [`CpuModel::prefill_from`] runs the *full*
//! float forward (tail positions attend over exact prefix K/V — the
//! decode bit-parity contract between shared and private sessions
//! requires it) but quantizes and stores only the tokens past the
//! page-aligned `skip` point; the session adopted the prefix's pooled
//! q2 pages instead of rebuilding them, so the storage and page-
//! quantization work for the prefix is paid once per unique prefix,
//! not once per session.
//!
//! The model is untrained (random weights): it exists to serve the
//! *system* — scheduling, caching, quantized execution — not language
//! quality, exactly like the artifact tiny-LM before calibration.

use anyhow::{bail, Result};

use crate::attention::turbo::sas_merge_token_into;
use crate::attention::{
    turbo_attention, turbo_decode_streams, turbo_decode_streams_sparse,
    DecodeScratch, TurboConfig,
};
use crate::kvcache::KvCache;
use crate::model::{DecodeOut, TurboSlabs};
use crate::pool::WorkerPool;
use crate::quant::quant_sym_int8;
use crate::runtime::ModelInfo;
use crate::tensor::{dot, Mat};
use crate::testutil::Rng;

/// One transformer block's weights.
struct CpuLayer {
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    /// MLP up-projection `[d_model, d_ff]`.
    w1: Mat,
    /// MLP down-projection `[d_ff, d_model]`.
    w2: Mat,
}

/// Session-owned scratch for [`CpuModel::decode_step`]'s model math —
/// the per-token `vec_mat`/`rms` intermediates that used to be fresh
/// allocations. Buffers grow to their steady-state sizes on the first
/// step and are reused verbatim afterwards.
#[derive(Debug, Default)]
pub struct ModelScratch {
    /// Residual stream (`d_model`).
    x: Vec<f32>,
    /// RMS-normalized copy of `x`.
    xn: Vec<f32>,
    /// Q/K/V projections (`d_model` each).
    qv: Vec<f32>,
    kv: Vec<f32>,
    vv: Vec<f32>,
    /// Attention output (`d_model`), reused across layers.
    att: Vec<f32>,
    /// Per-head (running max, denominator) from the stream fan-out.
    ml: Vec<(f32, f32)>,
    /// Output projection (`d_model`).
    o: Vec<f32>,
    /// MLP hidden (`d_ff`).
    hid: Vec<f32>,
    /// MLP down-projection (`d_model`).
    down: Vec<f32>,
    /// Buffer (re)allocation events — stays flat once warmed up; the
    /// allocation-free-steady-state tests assert on it.
    grows: u64,
}

impl ModelScratch {
    pub fn new() -> ModelScratch {
        ModelScratch::default()
    }

    /// How many times any scratch buffer had to (re)allocate. After the
    /// first decode step this must not move.
    pub fn grows(&self) -> u64 {
        self.grows
    }
}

/// Size `v` to `n` zeroed entries, reusing capacity; counts real
/// allocations into `grows`.
fn scratch_buf<T: Clone + Default>(v: &mut Vec<T>, n: usize, grows: &mut u64) {
    if v.capacity() < n {
        *grows += 1;
    }
    v.clear();
    v.resize(n, T::default());
}

/// Resumable-prefill state for [`CpuModel::prefill_chunk`]: how far the
/// prompt has been processed, plus the per-layer float K/V of every
/// processed row (full `d_model` width). Later chunks attend over that
/// exact float prefix — re-deriving it from the quantized cache would
/// change bits — so the cursor costs
/// `2 * n_layers * done * d_model * 4` bytes while a prefill is in
/// flight; completion frees it. Dropping a cursor mid-flight abandons
/// the prefill with no cache-side cleanup beyond the session cache it
/// was ingesting into.
pub struct PrefillCursor {
    /// Prompt rows fully processed (always block-aligned until the
    /// final chunk lands).
    done: usize,
    /// Adopted shared-prefix rows (page-aligned): run through the float
    /// forward but never re-ingested.
    skip: usize,
    /// Prompt length the cursor was opened over.
    total: usize,
    /// Per-layer K projections of rows `[0, done)`.
    k: Vec<Mat>,
    /// Per-layer V projections of rows `[0, done)`.
    v: Vec<Mat>,
}

impl PrefillCursor {
    /// Prompt rows processed so far.
    pub fn done(&self) -> usize {
        self.done
    }

    /// Prompt length this cursor was opened over.
    pub fn total(&self) -> usize {
        self.total
    }

    pub fn is_complete(&self) -> bool {
        self.done == self.total
    }
}

/// Deterministic tiny transformer serving the artifact-free CPU path.
pub struct CpuModel {
    pub info: ModelInfo,
    /// Seed the weights were generated from (identical seed + geometry
    /// => bit-identical model, so sessions and engines can rebuild it).
    pub seed: u64,
    embed: Mat,
    layers: Vec<CpuLayer>,
    w_out: Mat,
}

impl CpuModel {
    /// Build the model for a geometry; all weights derive from `seed`
    /// via the crate's deterministic PRNG.
    pub fn new(info: &ModelInfo, seed: u64) -> CpuModel {
        assert_eq!(
            info.d_model,
            info.n_heads * info.d_head,
            "d_model must equal n_heads * d_head"
        );
        assert_eq!(
            info.max_ctx % info.block,
            0,
            "max_ctx must be page-aligned to block"
        );
        let mut rng = Rng::new(seed ^ 0x7452_B0A7_7E17_10D5);
        let dm = info.d_model;
        let d_ff = 2 * dm;
        let proj = 1.0 / (dm as f32).sqrt();
        let embed = Mat::randn(&mut rng, info.vocab, dm, 1.0);
        let layers = (0..info.n_layers)
            .map(|_| CpuLayer {
                wq: Mat::randn(&mut rng, dm, dm, proj),
                wk: Mat::randn(&mut rng, dm, dm, proj),
                wv: Mat::randn(&mut rng, dm, dm, proj),
                wo: Mat::randn(&mut rng, dm, dm, proj),
                w1: Mat::randn(&mut rng, dm, d_ff, proj),
                w2: Mat::randn(&mut rng, d_ff, dm, 1.0 / (d_ff as f32).sqrt()),
            })
            .collect();
        let w_out = Mat::randn(&mut rng, dm, info.vocab, proj);
        CpuModel { info: info.clone(), seed, embed, layers, w_out }
    }

    /// Run the prompt, ingesting every layer/head's K/V into `cache` as
    /// q1 blocks (per-block symmetric scales — the same write-back shape
    /// as `ModelBundle::ingest_prefill`), and return the prefill logits
    /// (`[prompt_len * vocab]`, row `i` predicting token `i + 1`).
    ///
    /// Per-head attention fans out on `pool`; each head's tile math is
    /// sequential and writes its own output, so the result is
    /// bit-identical for every pool width.
    pub fn prefill(
        &self,
        prompt: &[u8],
        pool: &WorkerPool,
        cache: &mut KvCache,
    ) -> Result<Vec<f32>> {
        self.prefill_from(prompt, 0, pool, cache)
    }

    /// [`Self::prefill`] for a session that adopted a shared,
    /// page-aligned `skip`-token prompt prefix: the float forward still
    /// covers the whole prompt (tail K/V must be computed against the
    /// *exact* prefix floats or shared and private decode would diverge
    /// bit-wise), but only tokens `[skip, len)` are quantized and
    /// written back — the prefix's pages are already in the cache as
    /// pooled handles.
    pub fn prefill_from(
        &self,
        prompt: &[u8],
        skip_tokens: usize,
        pool: &WorkerPool,
        cache: &mut KvCache,
    ) -> Result<Vec<f32>> {
        let mut cursor = self.begin_prefill(prompt, skip_tokens, cache)?;
        match self.prefill_chunk(prompt, &mut cursor, prompt.len(), pool, cache)?
        {
            Some(logits) => Ok(logits),
            None => bail!("full-prompt prefill chunk did not complete"),
        }
    }

    /// Validate a prompt and open a [`PrefillCursor`] over it. The
    /// cursor starts with zero rows processed; feed it to
    /// [`Self::prefill_chunk`] until completion. `skip_tokens` marks a
    /// page-aligned adopted shared prefix whose q2 pages are already in
    /// `cache` — those rows still run the float forward (chunk
    /// attention needs the exact prefix K/V floats at every layer) but
    /// are not re-quantized or re-ingested.
    pub fn begin_prefill(
        &self,
        prompt: &[u8],
        skip_tokens: usize,
        cache: &KvCache,
    ) -> Result<PrefillCursor> {
        let m = &self.info;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > m.max_ctx {
            bail!("prompt len {} exceeds max_ctx {}", prompt.len(), m.max_ctx);
        }
        if skip_tokens > prompt.len() {
            bail!("skip {} exceeds prompt len {}", skip_tokens, prompt.len());
        }
        if skip_tokens % m.block != 0 {
            bail!("skip {} not page-aligned to block {}", skip_tokens, m.block);
        }
        if cache.tokens() != skip_tokens {
            bail!(
                "cache holds {} tokens, expected the {}-token adopted prefix",
                cache.tokens(),
                skip_tokens
            );
        }
        let dm = m.d_model;
        Ok(PrefillCursor {
            done: 0,
            skip: skip_tokens,
            total: prompt.len(),
            k: (0..m.n_layers).map(|_| Mat::zeros(0, dm)).collect(),
            v: (0..m.n_layers).map(|_| Mat::zeros(0, dm)).collect(),
        })
    }

    /// Process the next `max_tokens` prompt rows of a resumable prefill
    /// and ingest their K/V into `cache`. Returns `Some(logits)` for
    /// the *final chunk's rows* (`[chunk_len * vocab]`; the last row
    /// predicts the first generated token) once the prompt is complete,
    /// `None` while rows remain.
    ///
    /// Bitwise contract: the concatenated per-row outputs are
    /// `f32::to_bits`-identical to a monolithic [`Self::prefill_from`]
    /// for *any* chunk schedule. Three properties make that hold:
    /// every non-final chunk boundary is a `block` multiple (grants are
    /// rounded down here), so `turbo_attention`'s row tiles, per-tile
    /// quantization groups, and `ingest_stream`'s q1 blocks all land on
    /// the same absolute boundaries; the kernel's causal early exit
    /// makes a row tile's column-tile walk a function of its absolute
    /// position only; and everything outside attention (embedding, RMS,
    /// projections, MLP) is row-local. The price of resumability is the
    /// cursor's per-layer float K/V of processed rows — chunk `i`'s
    /// attention reads the exact floats chunks `0..i` produced.
    pub fn prefill_chunk(
        &self,
        prompt: &[u8],
        cursor: &mut PrefillCursor,
        max_tokens: usize,
        pool: &WorkerPool,
        cache: &mut KvCache,
    ) -> Result<Option<Vec<f32>>> {
        let m = &self.info;
        if prompt.len() != cursor.total {
            bail!(
                "cursor opened over a {}-token prompt, got {}",
                cursor.total,
                prompt.len()
            );
        }
        if cursor.is_complete() {
            bail!("prefill cursor already complete");
        }
        let (n, dm, dh, h_n) = (prompt.len(), m.d_model, m.d_head, m.n_heads);
        let s = cursor.done;
        // Non-final chunk boundaries must stay block-aligned (see the
        // bitwise contract above); `s` is aligned by induction.
        let mut e = (s + max_tokens).min(n);
        if e < n {
            e = e / m.block * m.block;
        }
        if e <= s {
            bail!(
                "chunk grant {max_tokens} below one {}-token block",
                m.block
            );
        }
        debug_assert_eq!(cache.tokens(), s.max(cursor.skip));
        let cn = e - s;
        let tcfg = TurboConfig {
            br: m.block,
            bc: m.block,
            n_r: m.n_r,
            causal: true,
            kv_bits: None,
            exact_exp: false,
        };
        let mut x = Mat::zeros(cn, dm);
        for (r, (&tok, row)) in prompt[s..e]
            .iter()
            .zip(x.data.chunks_mut(dm))
            .enumerate()
        {
            row.copy_from_slice(self.embed.row(tok as usize));
            add_pos_embed(row, s + r);
        }
        let ingest_from = s.max(cursor.skip);
        for (l, lw) in self.layers.iter().enumerate() {
            let xn = rms_rows(&x);
            let qm = xn.matmul(&lw.wq);
            let km = xn.matmul(&lw.wk);
            let vm = xn.matmul(&lw.wv);
            // Append this chunk's K/V rows to the cursor's float
            // prefix, then slice heads over the *whole* processed
            // range [0, e) — tail-query causal attention (nq = cn,
            // nk = e) resolves each row's visibility from its absolute
            // position.
            cursor.k[l].append_rows(&km);
            cursor.v[l].append_rows(&vm);
            let heads: Vec<(Mat, Mat, Mat)> = (0..h_n)
                .map(|h| {
                    (
                        cols_slice(&qm, h * dh, dh),
                        cols_slice(&cursor.k[l], h * dh, dh),
                        cols_slice(&cursor.v[l], h * dh, dh),
                    )
                })
                .collect();
            // Quantized causal attention per head, fanned on the pool.
            let mut outs: Vec<Mat> = vec![Mat::zeros(0, 0); h_n];
            pool.scope(|scope| {
                let tcfg = &tcfg;
                for (hm, out_h) in heads.iter().zip(outs.iter_mut()) {
                    scope.execute(move || {
                        *out_h = turbo_attention(&hm.0, &hm.1, &hm.2, tcfg);
                    });
                }
            })?;
            let mut att = Mat::zeros(cn, dm);
            for (h, out_h) in outs.iter().enumerate() {
                for r in 0..cn {
                    att.row_mut(r)[h * dh..(h + 1) * dh]
                        .copy_from_slice(out_h.row(r));
                }
            }
            // Write this chunk's K/V into the paged cache, one q1
            // block (codes + symmetric scale) at a time — starting
            // past the adopted shared prefix, whose pages are already
            // there. The head mats cover rows [0, e), so the stream
            // ingests exactly [max(s, skip), e).
            for (h, hm) in heads.iter().enumerate() {
                ingest_stream(
                    cache.k_stream_mut(l, h),
                    &hm.1,
                    m.block,
                    dh,
                    ingest_from,
                );
                ingest_stream(
                    cache.v_stream_mut(l, h),
                    &hm.2,
                    m.block,
                    dh,
                    ingest_from,
                );
            }
            let o = att.matmul(&lw.wo);
            add_assign(&mut x.data, &o.data);
            let xn2 = rms_rows(&x);
            let mut hid = xn2.matmul(&lw.w1);
            for v in hid.data.iter_mut() {
                *v = v.max(0.0);
            }
            let down = hid.matmul(&lw.w2);
            add_assign(&mut x.data, &down.data);
        }
        cursor.done = e;
        if cursor.is_complete() {
            // The float prefix has served its purpose; drop it eagerly
            // so a retained cursor costs nothing.
            cursor.k.clear();
            cursor.v.clear();
            Ok(Some(rms_rows(&x).matmul(&self.w_out).data))
        } else {
            Ok(None)
        }
    }

    /// One decode step over the session's synced q1 slabs (`nk` valid
    /// tokens): returns next-token logits and the new token's K/V
    /// (`[n_layers * d_model]` each, layer-major — the fold layout).
    ///
    /// Attention runs through [`turbo_decode_streams`] one layer at a
    /// time (layers are sequential; a layer's heads are the parallel
    /// axis), then the current token — not yet in the cache — merges in
    /// via the SAS online-softmax float merge, in place.
    ///
    /// `sparse_topk_pages > 0` routes every stream through the
    /// SparQ-style [`turbo_decode_streams_sparse`] path instead: each
    /// stream attends only its top-k envelope-scored full pages and
    /// folds the rest as mean-value terms, using the summary slabs the
    /// backend synced alongside the codes. `0` (and any `k` covering
    /// all pages) is the dense path, bit-identical by delegation. The
    /// returned [`DecodeOut`] carries the step's attended/skipped page
    /// totals and the bytes of K/V codes the skips avoided reading.
    ///
    /// All model-math intermediates live in the session-owned `sc`
    /// ([`ModelScratch`]); in steady state the only allocations in this
    /// function are the three returned `DecodeOut` vectors.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step(
        &self,
        slabs: &TurboSlabs,
        nk: usize,
        token: u8,
        pos: usize,
        pool: &WorkerPool,
        scratches: &mut [DecodeScratch],
        sc: &mut ModelScratch,
        sparse_topk_pages: usize,
    ) -> Result<DecodeOut> {
        let m = &self.info;
        let (dm, dh, h_n, l_n) = (m.d_model, m.d_head, m.n_heads, m.n_layers);
        if pos >= m.max_ctx {
            bail!("pos {pos} exceeds max_ctx {}", m.max_ctx);
        }
        let n_streams = l_n * h_n;
        let c = slabs.k8.len() / (n_streams * dh);
        let nb = slabs.sk.len() / n_streams;
        if nk > c {
            bail!("nk {nk} exceeds slab capacity {c}");
        }
        let scale = 1.0 / (dh as f32).sqrt();
        scratch_buf(&mut sc.x, dm, &mut sc.grows);
        sc.x.copy_from_slice(self.embed.row(token as usize));
        add_pos_embed(&mut sc.x, pos);
        // Result buffers (consumed by the engine): the step's only
        // steady-state allocations.
        let mut k_new = vec![0.0f32; l_n * dm];
        let mut v_new = vec![0.0f32; l_n * dm];
        // Fully overwritten by every layer's fan-out.
        scratch_buf(&mut sc.att, dm, &mut sc.grows);
        scratch_buf(&mut sc.ml, h_n, &mut sc.grows);
        let spp = nb * dh; // summary floats/codes per stream
        let mut pages_attended = 0u64;
        let mut pages_skipped = 0u64;
        for (l, lw) in self.layers.iter().enumerate() {
            rms_vec_into(&sc.x, &mut sc.xn, &mut sc.grows);
            vec_mat_into(&sc.xn, &lw.wq, &mut sc.qv, &mut sc.grows);
            vec_mat_into(&sc.xn, &lw.wk, &mut sc.kv, &mut sc.grows);
            vec_mat_into(&sc.xn, &lw.wv, &mut sc.vv, &mut sc.grows);
            k_new[l * dm..(l + 1) * dm].copy_from_slice(&sc.kv);
            v_new[l * dm..(l + 1) * dm].copy_from_slice(&sc.vv);
            let base = l * h_n * c * dh;
            let sbase = l * h_n * nb;
            if sparse_topk_pages > 0 {
                let mbase = l * h_n * spp;
                let (att, skip) = turbo_decode_streams_sparse(
                    pool,
                    &sc.qv,
                    &slabs.k8[base..base + h_n * c * dh],
                    &slabs.v8[base..base + h_n * c * dh],
                    &slabs.sk[sbase..sbase + h_n * nb],
                    &slabs.sv[sbase..sbase + h_n * nb],
                    &slabs.kmin[mbase..mbase + h_n * spp],
                    &slabs.kmax[mbase..mbase + h_n * spp],
                    &slabs.vmean[mbase..mbase + h_n * spp],
                    dh,
                    nk,
                    m.block,
                    m.n_r,
                    sparse_topk_pages,
                    scratches,
                    &mut sc.ml,
                    &mut sc.att,
                )?;
                pages_attended += att;
                pages_skipped += skip;
            } else {
                turbo_decode_streams(
                    pool,
                    &sc.qv,
                    &slabs.k8[base..base + h_n * c * dh],
                    &slabs.v8[base..base + h_n * c * dh],
                    &slabs.sk[sbase..sbase + h_n * nb],
                    &slabs.sv[sbase..sbase + h_n * nb],
                    dh,
                    nk,
                    m.block,
                    m.n_r,
                    scratches,
                    &mut sc.ml,
                    &mut sc.att,
                )?;
            }
            for h in 0..h_n {
                let (am, al) = sc.ml[h];
                let q_h = &sc.qv[h * dh..(h + 1) * dh];
                let k_h = &sc.kv[h * dh..(h + 1) * dh];
                let v_h = &sc.vv[h * dh..(h + 1) * dh];
                let s_new = dot(q_h, k_h) * scale;
                sas_merge_token_into(
                    &mut sc.att[h * dh..(h + 1) * dh],
                    am,
                    al,
                    s_new,
                    v_h,
                    m.n_r,
                );
            }
            vec_mat_into(&sc.att, &lw.wo, &mut sc.o, &mut sc.grows);
            add_assign(&mut sc.x, &sc.o);
            rms_vec_into(&sc.x, &mut sc.xn, &mut sc.grows);
            vec_mat_into(&sc.xn, &lw.w1, &mut sc.hid, &mut sc.grows);
            for v in sc.hid.iter_mut() {
                *v = v.max(0.0);
            }
            vec_mat_into(&sc.hid, &lw.w2, &mut sc.down, &mut sc.grows);
            add_assign(&mut sc.x, &sc.down);
        }
        rms_vec_into(&sc.x, &mut sc.xn, &mut sc.grows);
        let logits = vec_mat(&sc.xn, &self.w_out);
        // Each skipped page avoided reading `block * d_head` INT8 codes
        // from both the K and the V slab.
        let sparse_bytes_saved =
            pages_skipped * 2 * (m.block as u64) * (dh as u64);
        Ok(DecodeOut {
            logits,
            k_new,
            v_new,
            sparse_pages_attended: pages_attended,
            sparse_pages_skipped: pages_skipped,
            sparse_bytes_saved,
        })
    }
}

/// Quantize `mat`'s rows (`[n, d]`) into q1 blocks of `block` tokens and
/// ingest them into one cache stream, starting at row `skip` (rows
/// before it belong to an adopted shared prefix already in the cache).
fn ingest_stream(
    stream: &mut crate::kvcache::StreamCache,
    mat: &Mat,
    block: usize,
    d: usize,
    skip: usize,
) {
    let n = mat.rows;
    let mut t0 = skip;
    while t0 < n {
        let t1 = (t0 + block).min(n);
        let q = quant_sym_int8(&mat.data[t0 * d..t1 * d]);
        stream.ingest_q1_block(&q.codes, q.scale, t1 - t0);
        t0 = t1;
    }
}

/// Copy a column band `[c0, c0 + w)` of a row-major matrix.
fn cols_slice(m: &Mat, c0: usize, w: usize) -> Mat {
    let mut out = Mat::zeros(m.rows, w);
    for (dst, src) in out.data.chunks_mut(w).zip(m.data.chunks(m.cols)) {
        dst.copy_from_slice(&src[c0..c0 + w]);
    }
    out
}

/// RMS-normalize every row (pre-norm without a learned gain).
fn rms_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    for row in out.data.chunks_mut(m.cols) {
        rms_inplace(row);
    }
    out
}

/// RMS-normalize `x` into the reusable scratch buffer `out`.
fn rms_vec_into(x: &[f32], out: &mut Vec<f32>, grows: &mut u64) {
    scratch_buf(out, x.len(), grows);
    out.copy_from_slice(x);
    rms_inplace(out);
}

fn rms_inplace(x: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len().max(1) as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// `x @ W` for a single row vector (`x.len() == w.rows`).
fn vec_mat(x: &[f32], w: &Mat) -> Vec<f32> {
    let mut out = Vec::new();
    let mut grows = 0u64;
    vec_mat_into(x, w, &mut out, &mut grows);
    out
}

/// [`vec_mat`] into a reusable scratch buffer.
fn vec_mat_into(x: &[f32], w: &Mat, out: &mut Vec<f32>, grows: &mut u64) {
    debug_assert_eq!(x.len(), w.rows);
    scratch_buf(out, w.cols, grows);
    for (&xi, row) in x.iter().zip(w.data.chunks(w.cols)) {
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xi * wv;
        }
    }
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (a, &b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

/// Sinusoidal position features added onto the token embedding.
fn add_pos_embed(x: &mut [f32], pos: usize) {
    let d = x.len();
    let mut c = 0usize;
    while c < d {
        let freq = 1.0 / 10000f32.powf(c as f32 / d as f32);
        let angle = pos as f32 * freq;
        x[c] += angle.sin();
        if c + 1 < d {
            x[c + 1] += angle.cos();
        }
        c += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvCacheConfig, PagePool, PrecisionMap};
    use crate::quant::Bits;

    fn tiny_info() -> ModelInfo {
        ModelInfo {
            vocab: 256,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_head: 8,
            max_ctx: 32,
            block: 4,
            n_r: -6.0,
        }
    }

    fn cache_for(info: &ModelInfo) -> KvCache {
        let pm =
            PrecisionMap::uniform(info.n_layers, info.n_heads, Bits::Int4);
        KvCache::new(KvCacheConfig::new(
            info.n_layers,
            info.n_heads,
            info.d_head,
            info.block,
            pm,
        ))
    }

    #[test]
    fn prefill_returns_logits_and_fills_cache() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 7);
        let pool = WorkerPool::new(2);
        let mut cache = cache_for(&info);
        let prompt = b"the cpu substrate ";
        let logits =
            model.prefill(prompt, &pool, &mut cache).expect("prefill");
        assert_eq!(logits.len(), prompt.len() * info.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(cache.tokens(), prompt.len());
    }

    #[test]
    fn prefill_rejects_bad_prompts() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 7);
        let pool = WorkerPool::new(1);
        let mut cache = cache_for(&info);
        assert!(model.prefill(b"", &pool, &mut cache).is_err());
        let long = vec![b'a'; info.max_ctx + 1];
        assert!(model.prefill(&long, &pool, &mut cache).is_err());
        // Sharing-path argument validation.
        let mut cache = cache_for(&info);
        assert!(
            model.prefill_from(b"abcdefgh", 3, &pool, &mut cache).is_err(),
            "unaligned skip"
        );
        let mut cache = cache_for(&info);
        assert!(
            model.prefill_from(b"abcd", 8, &pool, &mut cache).is_err(),
            "skip beyond prompt"
        );
        let mut cache = cache_for(&info);
        assert!(
            model.prefill_from(b"abcdefgh", 4, &pool, &mut cache).is_err(),
            "cache missing the adopted prefix"
        );
    }

    #[test]
    fn same_seed_same_model_bit_for_bit() {
        let info = tiny_info();
        let a = CpuModel::new(&info, 42);
        let b = CpuModel::new(&info, 42);
        let pool = WorkerPool::new(1);
        let la = a
            .prefill(b"determinism", &pool, &mut cache_for(&info))
            .unwrap();
        let lb = b
            .prefill(b"determinism", &pool, &mut cache_for(&info))
            .unwrap();
        let bits = |v: &[f32]| -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&la), bits(&lb));
        let c = CpuModel::new(&info, 43);
        let lc = c
            .prefill(b"determinism", &pool, &mut cache_for(&info))
            .unwrap();
        assert_ne!(bits(&la), bits(&lc), "different seed, different model");
    }

    #[test]
    fn prefill_pool_width_does_not_change_bits() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 3);
        let mut want: Option<Vec<u32>> = None;
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let logits = model
                .prefill(b"thread sweep", &pool, &mut cache_for(&info))
                .unwrap();
            let bits: Vec<u32> =
                logits.iter().map(|x| x.to_bits()).collect();
            match &want {
                None => want = Some(bits),
                Some(w) => assert_eq!(w, &bits, "threads={threads}"),
            }
        }
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bitwise() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 11);
        let pool = WorkerPool::new(2);
        // 19 tokens: four full 4-token blocks plus a ragged tail.
        let prompt = b"the chunked prefill";
        let mut mono_cache = cache_for(&info);
        let mono = model.prefill(prompt, &pool, &mut mono_cache).unwrap();
        let bits =
            |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        // 11 exercises the round-down-to-block path (grants land at 8).
        for chunk in [4usize, 8, 11] {
            let mut cache = cache_for(&info);
            let mut cursor = model.begin_prefill(prompt, 0, &cache).unwrap();
            let mut last = None;
            let mut calls = 0;
            while last.is_none() {
                last = model
                    .prefill_chunk(prompt, &mut cursor, chunk, &pool, &mut cache)
                    .unwrap();
                calls += 1;
                assert!(
                    cursor.done() == prompt.len()
                        || cursor.done() % info.block == 0,
                    "non-final chunk boundary must be block-aligned"
                );
            }
            assert!(calls > 1, "chunk={chunk} must take several calls");
            assert!(cursor.is_complete());
            assert_eq!(cache.tokens(), prompt.len());
            // The final chunk's logits are the monolithic tail rows.
            let logits = last.unwrap();
            let tail = &mono[mono.len() - logits.len()..];
            assert_eq!(bits(&logits), bits(tail), "chunk={chunk}");
        }
    }

    #[test]
    fn prefill_chunk_rejects_sub_block_grant() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 11);
        let pool = WorkerPool::new(1);
        let mut cache = cache_for(&info);
        let prompt = b"twelve..chars"; // 13 > block
        let mut cursor = model.begin_prefill(prompt, 0, &cache).unwrap();
        assert!(
            model
                .prefill_chunk(prompt, &mut cursor, 3, &pool, &mut cache)
                .is_err(),
            "a mid-prompt grant below one block cannot make progress"
        );
    }

    #[test]
    fn decode_step_shapes_and_finiteness() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 9);
        let pool = WorkerPool::new(2);
        let mut cache = cache_for(&info);
        model.prefill(b"abcdefg", &pool, &mut cache).unwrap();
        // Sync the slabs the way a session would.
        let slabs = {
            use crate::attention::backend::TurboSession;
            let mut sess = TurboSession::from_parts(
                cache,
                TurboSlabs::new(
                    info.n_layers,
                    info.n_heads,
                    info.max_ctx,
                    info.d_head,
                    info.block,
                ),
            );
            let nk = sess.sync_slabs().unwrap();
            assert_eq!(nk, 7);
            sess
        };
        let mut scratches = vec![DecodeScratch::new(); 2];
        let mut sc = ModelScratch::new();
        let out = model
            .decode_step(&slabs.slabs, 7, b'h', 7, &pool, &mut scratches, &mut sc, 0)
            .expect("decode");
        assert_eq!(out.logits.len(), info.vocab);
        assert_eq!(out.k_new.len(), info.n_layers * info.d_model);
        assert_eq!(out.v_new.len(), info.n_layers * info.d_model);
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    /// The ROADMAP allocation item: after the first decode step, the
    /// model scratch never (re)allocates — the TurboCpu decode step's
    /// model math is allocation-free in steady state.
    #[test]
    fn decode_scratch_is_allocation_free_in_steady_state() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 13);
        let pool = WorkerPool::new(2);
        let mut cache = cache_for(&info);
        model.prefill(b"warmup prompt", &pool, &mut cache).unwrap();
        use crate::attention::backend::TurboSession;
        let mut sess = TurboSession::from_parts(
            cache,
            TurboSlabs::new(
                info.n_layers,
                info.n_heads,
                info.max_ctx,
                info.d_head,
                info.block,
            ),
        );
        let mut nk = sess.sync_slabs().unwrap();
        let mut scratches = vec![DecodeScratch::new(); 2];
        let mut sc = ModelScratch::new();
        let mut pos = nk;
        let mut token = b'x';
        let out = model
            .decode_step(&sess.slabs, nk, token, pos, &pool, &mut scratches, &mut sc, 0)
            .expect("warmup step");
        let warmed = sc.grows();
        assert!(warmed > 0, "first step must size the buffers");
        // Keep decoding (with real folds, so buffer flushes happen too):
        // the counter must not move again.
        for _ in 0..6 {
            for l in 0..info.n_layers {
                for h in 0..info.n_heads {
                    let o = (l * info.n_heads + h) * info.d_head;
                    sess.cache
                        .k_stream_mut(l, h)
                        .push_token(&out.k_new[o..o + info.d_head]);
                    sess.cache
                        .v_stream_mut(l, h)
                        .push_token(&out.v_new[o..o + info.d_head]);
                }
            }
            nk = sess.sync_slabs().unwrap();
            pos += 1;
            let step = model
                .decode_step(
                    &sess.slabs, nk, token, pos, &pool, &mut scratches,
                    &mut sc, 0,
                )
                .expect("steady step");
            token = crate::model::argmax(&step.logits) as u8;
        }
        assert_eq!(
            sc.grows(),
            warmed,
            "steady-state decode must not grow the model scratch"
        );
    }

    /// Prefix-sharing arm: a session that adopts the donor's pooled
    /// prefix pages and prefills only the tail ends up with a cache
    /// byte-identical (q1 view) to a fully private prefill, and the
    /// prefill logits are bit-identical (the float pass is unchanged).
    #[test]
    fn prefill_from_shared_prefix_matches_private_bitwise() {
        let info = tiny_info();
        let model = CpuModel::new(&info, 17);
        let wpool = WorkerPool::new(2);
        let prompt = b"abcdefghij"; // 10 tokens: 2 pages of 4 + 2 buffered
        let skip = 8usize;

        let pages_pool = PagePool::new_shared();
        let pm =
            PrecisionMap::uniform(info.n_layers, info.n_heads, Bits::Int4);
        let mk_cache = || {
            KvCache::with_pool(
                KvCacheConfig::new(
                    info.n_layers,
                    info.n_heads,
                    info.d_head,
                    info.block,
                    pm.clone(),
                ),
                std::sync::Arc::clone(&pages_pool),
            )
        };
        let mut donor = mk_cache();
        let full_logits = model.prefill(prompt, &wpool, &mut donor).unwrap();

        let mut forked = mk_cache();
        for l in 0..info.n_layers {
            for h in 0..info.n_heads {
                let kh = donor.head(l, h).k.pages[..skip / info.block].to_vec();
                forked.k_stream_mut(l, h).adopt_pages(&kh);
                let vh = donor.head(l, h).v.pages[..skip / info.block].to_vec();
                forked.v_stream_mut(l, h).adopt_pages(&vh);
            }
        }
        let tail_logits = model
            .prefill_from(prompt, skip, &wpool, &mut forked)
            .unwrap();
        let bits =
            |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&full_logits), bits(&tail_logits), "logits bitwise");
        assert_eq!(forked.tokens(), prompt.len());

        // The forked cache reads identically to a fully private one.
        let mut private = cache_for(&info);
        model.prefill(prompt, &wpool, &mut private).unwrap();
        for l in 0..info.n_layers {
            for h in 0..info.n_heads {
                let (fc, fs, fnk) = {
                    let (c, s, n) = forked.k_stream_mut(l, h).q1_view();
                    (c.to_vec(), s.to_vec(), n)
                };
                let (pc, ps, pnk) = {
                    let (c, s, n) = private.k_stream_mut(l, h).q1_view();
                    (c.to_vec(), s.to_vec(), n)
                };
                assert_eq!(fnk, pnk, "token count (l={l} h={h})");
                assert_eq!(
                    fc[..fnk * info.d_head],
                    pc[..pnk * info.d_head],
                    "K codes (l={l} h={h})"
                );
                let nb = fnk.div_ceil(info.block);
                assert_eq!(fs[..nb], ps[..nb], "K scales (l={l} h={h})");
            }
        }
        // And the prefix really is shared storage.
        let st = forked.stats();
        assert!(st.shared_page_bytes > 0, "prefix pages shared");
    }
}
