//! Model-level glue: byte tokenizer, sampling, and typed wrappers around
//! the prefill/decode AOT executables.

pub mod bundle;
pub mod cpu;

pub use bundle::{
    DecodeOut, FlashSlabs, ModelBundle, PrefillOut, SlabShardMut, TurboSlabs,
};
pub use cpu::{CpuModel, ModelScratch, PrefillCursor};

use crate::testutil::Rng;

/// Byte-level "tokenizer" (vocab 256) — the tiny LM is a byte LM.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<u8> {
        text.as_bytes().to_vec()
    }

    pub fn decode(&self, tokens: &[u8]) -> String {
        tokens
            .iter()
            .map(|&b| {
                if b.is_ascii_graphic() || b == b' ' || b == b'\n' {
                    b as char
                } else {
                    '\u{FFFD}'
                }
            })
            .collect()
    }
}

/// Default `TopK` k — the one source for the CLI `--top-k` default and
/// the server's `GEN`-line override fallback.
pub const DEFAULT_TOP_K: usize = 8;
/// Default `TopK` temperature (CLI `--temp` default and server fallback).
pub const DEFAULT_TEMP: f32 = 0.8;

/// Sampling policy for next-token selection.
///
/// Owned by the *request* (`coordinator::SamplingParams`), not the
/// engine: every session samples with its own policy and its own
/// seeded RNG, so batch composition can never change a request's
/// output. The engine-global sampler + shared RNG this type used to
/// plug into (`EngineConfig.sampler`) is gone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampler {
    Greedy,
    /// Top-k sampling with temperature.
    TopK { k: usize, temp: f32 },
}

impl Sampler {
    /// Sample a token id from a logits slice.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u8 {
        match *self {
            Sampler::Greedy => argmax(logits) as u8,
            Sampler::TopK { k, temp } => {
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                idx.truncate(k.max(1));
                let m = logits[idx[0]];
                let mut probs: Vec<f64> = idx
                    .iter()
                    .map(|&i| (((logits[i] - m) / temp.max(1e-3)) as f64).exp())
                    .collect();
                let total: f64 = probs.iter().sum();
                for p in probs.iter_mut() {
                    *p /= total;
                }
                let mut u = rng.f64();
                for (j, &p) in probs.iter().enumerate() {
                    if u < p {
                        return idx[j] as u8;
                    }
                    u -= p;
                }
                idx[idx.len() - 1] as u8
            }
        }
    }
}

/// Index of the max element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the router routes tokens.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1, 5.0, -1.0, 4.9];
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let mut rng = Rng::new(1);
        let mut logits = vec![-10.0; 16];
        logits[3] = 5.0;
        logits[7] = 4.5;
        let s = Sampler::TopK { k: 2, temp: 1.0 };
        for _ in 0..50 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 3 || t == 7);
        }
    }

    #[test]
    fn topk_low_temp_is_greedy() {
        let mut rng = Rng::new(2);
        let logits = vec![1.0, 2.0, 3.0, 2.9];
        let s = Sampler::TopK { k: 4, temp: 0.01 };
        for _ in 0..20 {
            assert_eq!(s.sample(&logits, &mut rng), 2);
        }
    }
}
