//! Sweep-point aggregation and the `BENCH_serve.json` report.
//!
//! One [`SweepPoint`] per (mode × rate-or-concurrency × workload mix ×
//! pool-cap) cell: client-side goodput, tokens/s, and percentile
//! latencies next to the engine-side `STATS` delta for the same window
//! (dedup ratio, preemptions, prefill chunks, sparse bytes saved, …).
//! The saturation knee is *measured*: the first offered rate whose
//! goodput falls more than 10% short — reported only when the sweep
//! actually crossed it.

use std::collections::BTreeMap;

use crate::bench::json_str;

use super::generators::RunSummary;
use super::histogram::{hist_json_ms, LatencyBundle};

/// The knobs that produced one sweep point (echoed into the report so
/// a point is reproducible from its JSON alone).
#[derive(Debug, Clone)]
pub struct SweepPointConfig {
    /// "open" | "closed".
    pub mode: String,
    /// Offered request rate (open loop only).
    pub rate: Option<f64>,
    /// Worker count (closed loop only).
    pub concurrency: Option<usize>,
    pub mix: String,
    /// Pool byte cap in force (0 = uncapped).
    pub pool_byte_cap: usize,
    pub n_requests: usize,
    pub seed: u64,
    pub shared_prefix_ratio: f64,
    pub cancel_prob: f64,
    pub sparse_ratio: f64,
    pub sparse_topk_pages: usize,
    pub max_new: usize,
}

impl SweepPointConfig {
    /// Short human label, e.g. `open rate=8 mix=longtail cap=0`.
    pub fn label(&self) -> String {
        let axis = match (self.rate, self.concurrency) {
            (Some(r), _) => format!("rate={r}"),
            (_, Some(c)) => format!("conc={c}"),
            _ => "?".to_string(),
        };
        format!(
            "{} {axis} mix={} cap={}",
            self.mode, self.mix, self.pool_byte_cap
        )
    }
}

/// One measured sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub cfg: SweepPointConfig,
    pub wall_s: f64,
    pub completed: usize,
    pub cancelled: usize,
    pub errors: usize,
    /// Tokens observed across all streams (completed or not).
    pub tokens: usize,
    /// Offered load (open loop: the configured rate).
    pub offered_rps: Option<f64>,
    /// Terminal-and-not-cancelled requests per wall second.
    pub goodput_rps: f64,
    pub tokens_per_s: f64,
    pub lat: LatencyBundle,
    /// Engine-side `STATS` delta over the point's window (monotone
    /// counters subtracted; gauges and strings as scraped after).
    pub engine: BTreeMap<String, String>,
}

impl SweepPoint {
    pub fn build(
        cfg: SweepPointConfig,
        summary: &RunSummary,
        engine: BTreeMap<String, String>,
    ) -> SweepPoint {
        let mut lat = LatencyBundle::new();
        lat.record_all(&summary.outcomes);
        let completed =
            summary.outcomes.iter().filter(|o| o.completed()).count();
        let cancelled = summary
            .outcomes
            .iter()
            .filter(|o| o.finish_reason == "cancelled")
            .count();
        let errors =
            summary.outcomes.iter().filter(|o| o.error.is_some()).count();
        let tokens: usize = summary.outcomes.iter().map(|o| o.tokens).sum();
        let wall = summary.wall_s.max(1e-9);
        SweepPoint {
            offered_rps: cfg.rate,
            goodput_rps: completed as f64 / wall,
            tokens_per_s: tokens as f64 / wall,
            cfg,
            wall_s: summary.wall_s,
            completed,
            cancelled,
            errors,
            tokens,
            lat,
            engine,
        }
    }
}

/// Monotone engine counters that are meaningful as deltas across a
/// sweep window (everything else — gauges, strings, ratios — is
/// reported as scraped at the window's end).
const MONOTONE_KEYS: [&str; 12] = [
    "completed",
    "cancelled",
    "tokens",
    "prefill_tokens",
    "preempt",
    "replayed",
    "memo_evict",
    "memo_recompute",
    "prefill_chunks",
    "sparse_attended",
    "sparse_skipped",
    "sparse_bytes_saved",
];

/// Per-window engine stats: monotone counters become `after - before`
/// (so a long-lived `--connect` server doesn't leak earlier traffic
/// into a point), everything else passes through from `after`.
pub fn diff_engine_stats(
    before: &BTreeMap<String, String>,
    after: &BTreeMap<String, String>,
) -> BTreeMap<String, String> {
    after
        .iter()
        .map(|(k, v)| {
            let val = if MONOTONE_KEYS.contains(&k.as_str()) {
                match (
                    v.parse::<u64>(),
                    before.get(k).and_then(|b| b.parse::<u64>().ok()),
                ) {
                    (Ok(a), Some(b)) => a.saturating_sub(b).to_string(),
                    _ => v.clone(),
                }
            } else {
                v.clone()
            };
            (k.clone(), val)
        })
        .collect()
}

/// First offered rate whose goodput falls >10% short of it, scanning
/// open-loop points in rate order. `None` if the sweep never saturated
/// (the knee must be measured, not inferred).
pub fn saturation_knee(points: &[SweepPoint]) -> Option<f64> {
    let mut open: Vec<&SweepPoint> =
        points.iter().filter(|p| p.offered_rps.is_some()).collect();
    open.sort_by(|a, b| {
        a.offered_rps.partial_cmp(&b.offered_rps).expect("finite rates")
    });
    open.iter()
        .find(|p| p.goodput_rps < 0.9 * p.offered_rps.unwrap())
        .and_then(|p| p.offered_rps)
}

fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.4}")
    } else {
        "null".to_string()
    }
}

fn opt_num(x: Option<f64>) -> String {
    x.map(num).unwrap_or_else(|| "null".to_string())
}

fn engine_json(engine: &BTreeMap<String, String>) -> String {
    let body = engine
        .iter()
        .map(|(k, v)| {
            let val = match v.parse::<f64>() {
                Ok(x) if x.is_finite() => v.clone(),
                _ => json_str(v),
            };
            format!("{}:{val}", json_str(k))
        })
        .collect::<Vec<_>>()
        .join(",");
    format!("{{{body}}}")
}

fn point_json(p: &SweepPoint) -> String {
    let c = &p.cfg;
    format!(
        "{{\"label\":{},\"mode\":{},\"offered_rps\":{},\"concurrency\":{},\
         \"mix\":{},\"pool_byte_cap\":{},\"n_requests\":{},\"seed\":{},\
         \"shared_prefix_ratio\":{},\"cancel_prob\":{},\"sparse_ratio\":{},\
         \"sparse_topk_pages\":{},\"max_new\":{},\"wall_s\":{},\
         \"completed\":{},\"cancelled\":{},\"errors\":{},\"tokens\":{},\
         \"goodput_rps\":{},\"tokens_per_s\":{},\"ttft\":{},\"itl\":{},\
         \"queue_wait\":{},\"e2e\":{},\"engine\":{}}}",
        json_str(&c.label()),
        json_str(&c.mode),
        opt_num(c.rate),
        c.concurrency
            .map(|n| n.to_string())
            .unwrap_or_else(|| "null".to_string()),
        json_str(&c.mix),
        c.pool_byte_cap,
        c.n_requests,
        c.seed,
        num(c.shared_prefix_ratio),
        num(c.cancel_prob),
        num(c.sparse_ratio),
        c.sparse_topk_pages,
        c.max_new,
        num(p.wall_s),
        p.completed,
        p.cancelled,
        p.errors,
        p.tokens,
        num(p.goodput_rps),
        num(p.tokens_per_s),
        hist_json_ms(&p.lat.ttft),
        hist_json_ms(&p.lat.itl),
        hist_json_ms(&p.lat.queue_wait),
        hist_json_ms(&p.lat.e2e),
        engine_json(&p.engine),
    )
}

/// The full `BENCH_serve.json` document for a measured sweep.
pub fn render_report(points: &[SweepPoint], kernel_backend: &str) -> String {
    let sweep = points
        .iter()
        .map(|p| format!("    {}", point_json(p)))
        .collect::<Vec<_>>()
        .join(",\n");
    format!(
        "{{\n  \"bench\": \"serve\",\n  \"status\": \"measured\",\n  \
         \"kernel_backend\": {},\n  \"knee_rps\": {},\n  \"sweep\": [\n{}\n  ]\n}}\n",
        json_str(kernel_backend),
        opt_num(saturation_knee(points)),
        sweep
    )
}

/// One console line per sweep point.
pub fn summary_line(p: &SweepPoint) -> String {
    format!(
        "{} | {}/{} done, {} cancelled, {} err | goodput {:.2} req/s | \
         {:.1} tok/s | ttft p50 {:.1}ms | wait p50 {:.1}ms p99 {:.1}ms | \
         itl p50 {:.2}ms",
        p.cfg.label(),
        p.completed,
        p.cfg.n_requests,
        p.cancelled,
        p.errors,
        p.goodput_rps,
        p.tokens_per_s,
        p.lat.ttft.p50() * 1e3,
        p.lat.queue_wait.p50() * 1e3,
        p.lat.queue_wait.p99() * 1e3,
        p.lat.itl.p50() * 1e3,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadgen::generators::RequestOutcome;
    use crate::util::json::Json;

    fn summary(n: usize, wall_s: f64, cancel_every: usize) -> RunSummary {
        let outcomes = (0..n)
            .map(|i| {
                let sched = i as f64 * 0.01;
                let mut o = RequestOutcome::started(i, sched, sched + 0.001);
                o.first_token_at = Some(sched + 0.02);
                o.done_at = sched + 0.1;
                o.tokens = 8;
                o.itl = vec![0.01; 7];
                o.finish_reason =
                    if cancel_every > 0 && i % cancel_every == 0 {
                        "cancelled".to_string()
                    } else {
                        "max_tokens".to_string()
                    };
                o
            })
            .collect();
        RunSummary { outcomes, wall_s }
    }

    fn cfg(rate: Option<f64>) -> SweepPointConfig {
        SweepPointConfig {
            mode: if rate.is_some() { "open" } else { "closed" }.to_string(),
            rate,
            concurrency: if rate.is_some() { None } else { Some(2) },
            mix: "longtail".to_string(),
            pool_byte_cap: 0,
            n_requests: 10,
            seed: 0,
            shared_prefix_ratio: 0.5,
            cancel_prob: 0.2,
            sparse_ratio: 0.0,
            sparse_topk_pages: 0,
            max_new: 8,
        }
    }

    #[test]
    fn build_counts_and_rates() {
        let p = SweepPoint::build(
            cfg(None),
            &summary(10, 2.0, 5),
            BTreeMap::new(),
        );
        assert_eq!(p.completed, 8);
        assert_eq!(p.cancelled, 2);
        assert_eq!(p.errors, 0);
        assert_eq!(p.tokens, 80);
        assert!((p.goodput_rps - 4.0).abs() < 1e-9);
        assert!((p.tokens_per_s - 40.0).abs() < 1e-9);
    }

    #[test]
    fn report_is_valid_json_with_sane_percentiles() {
        let mut engine = BTreeMap::new();
        engine.insert("kernel".to_string(), "scalar".to_string());
        engine.insert("completed".to_string(), "8".to_string());
        let points = vec![
            SweepPoint::build(cfg(Some(4.0)), &summary(10, 2.0, 0), engine),
            SweepPoint::build(cfg(None), &summary(10, 1.0, 5), BTreeMap::new()),
        ];
        let doc = render_report(&points, "scalar");
        let j = Json::parse(&doc).expect("report parses");
        assert_eq!(j.path("bench").unwrap().as_str(), Some("serve"));
        let sweep = j.path("sweep").unwrap().as_arr().unwrap();
        assert_eq!(sweep.len(), 2);
        for pt in sweep {
            let p50 = pt.path("ttft/p50_ms").unwrap().as_f64().unwrap();
            let p99 = pt.path("ttft/p99_ms").unwrap().as_f64().unwrap();
            assert!(p50 <= p99 + 1e-9, "p50 {p50} > p99 {p99}");
        }
        assert_eq!(
            sweep[0].path("engine/kernel").unwrap().as_str(),
            Some("scalar")
        );
        assert_eq!(sweep[0].path("engine/completed").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn knee_found_only_when_crossed() {
        // Goodput tracks offered load at 2 and 4 req/s, collapses at 8.
        let mk = |rate: f64, wall: f64| {
            SweepPoint::build(cfg(Some(rate)), &summary(10, wall, 0), BTreeMap::new())
        };
        let under = vec![mk(2.0, 5.0), mk(4.0, 2.5)]; // goodput == offered
        assert_eq!(saturation_knee(&under), None);
        let mut crossed = under.clone();
        crossed.push(mk(8.0, 2.0)); // goodput 5 < 0.9 * 8
        assert_eq!(saturation_knee(&crossed), Some(8.0));
        // Closed-loop points never define a knee.
        assert_eq!(saturation_knee(&[mk_closed()]), None);
    }

    fn mk_closed() -> SweepPoint {
        SweepPoint::build(cfg(None), &summary(10, 1.0, 0), BTreeMap::new())
    }

    #[test]
    fn engine_delta_subtracts_monotone_counters_only() {
        let mut before = BTreeMap::new();
        before.insert("completed".to_string(), "10".to_string());
        before.insert("pool_bytes".to_string(), "4096".to_string());
        let mut after = BTreeMap::new();
        after.insert("completed".to_string(), "14".to_string());
        after.insert("pool_bytes".to_string(), "1024".to_string());
        after.insert("kernel".to_string(), "avx2".to_string());
        let d = diff_engine_stats(&before, &after);
        assert_eq!(d.get("completed").map(String::as_str), Some("4"));
        // Gauge: passed through, not subtracted.
        assert_eq!(d.get("pool_bytes").map(String::as_str), Some("1024"));
        assert_eq!(d.get("kernel").map(String::as_str), Some("avx2"));
    }
}
