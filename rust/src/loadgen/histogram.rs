//! Client-side latency collection: fixed-bucket log-scale histograms
//! (reusing [`crate::metrics::Histogram`]) so percentile summaries
//! never require storing per-sample data, whatever the sweep length.
//!
//! Four distributions per sweep point:
//! - **ttft** — send to first token (admission + queue + prefill).
//! - **itl** — client-observed inter-token gaps.
//! - **queue_wait** — *scheduled* arrival to first token. Under an
//!   open-loop generator past saturation this keeps growing while ttft
//!   measured from `sent_at` can look flat; it is the knee's signature.
//! - **e2e** — scheduled arrival to terminal event.

use crate::metrics::Histogram;

use super::generators::RequestOutcome;

/// The per-sweep-point latency histograms.
#[derive(Debug, Clone, Default)]
pub struct LatencyBundle {
    pub ttft: Histogram,
    pub itl: Histogram,
    pub queue_wait: Histogram,
    pub e2e: Histogram,
}

impl LatencyBundle {
    pub fn new() -> LatencyBundle {
        LatencyBundle::default()
    }

    /// Fold one finished request in. Transport errors contribute only
    /// to `e2e` (they have no token timings).
    pub fn record(&mut self, o: &RequestOutcome) {
        if let Some(first) = o.first_token_at {
            self.ttft.record((first - o.sent_at).max(0.0));
            self.queue_wait.record((first - o.scheduled_at).max(0.0));
        }
        for &gap in &o.itl {
            self.itl.record(gap.max(0.0));
        }
        self.e2e.record((o.done_at - o.scheduled_at).max(0.0));
    }

    pub fn record_all(&mut self, outcomes: &[RequestOutcome]) {
        for o in outcomes {
            self.record(o);
        }
    }

    /// Exact fold of another bundle (shared fixed bucket layout).
    pub fn merge(&mut self, other: &LatencyBundle) {
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        self.queue_wait.merge(&other.queue_wait);
        self.e2e.merge(&other.e2e);
    }
}

/// Render one histogram as a JSON object fragment, milliseconds.
pub fn hist_json_ms(h: &Histogram) -> String {
    format!(
        "{{\"n\":{},\"mean_ms\":{:.4},\"p50_ms\":{:.4},\"p90_ms\":{:.4},\
         \"p99_ms\":{:.4},\"max_ms\":{:.4}}}",
        h.count(),
        h.mean() * 1e3,
        h.p50() * 1e3,
        h.p90() * 1e3,
        h.p99() * 1e3,
        h.max() * 1e3
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(sched: f64, sent: f64, first: f64, done: f64) -> RequestOutcome {
        let mut o = RequestOutcome::started(0, sched, sent);
        o.first_token_at = Some(first);
        o.done_at = done;
        o.itl = vec![0.002, 0.003];
        o.finish_reason = "max_tokens".to_string();
        o
    }

    #[test]
    fn queue_wait_includes_scheduled_backlog() {
        let mut b = LatencyBundle::new();
        // Scheduled at t=1.0, actually sent at t=1.5 (dispatcher was
        // on time, engine queue was not): first token at 1.6.
        b.record(&outcome(1.0, 1.5, 1.6, 1.7));
        assert_eq!(b.ttft.count(), 1);
        // ttft ~0.1s, queue_wait ~0.6s: separate distributions.
        assert!(b.ttft.p50() < b.queue_wait.p50());
        assert_eq!(b.itl.count(), 2);
        assert_eq!(b.e2e.count(), 1);
    }

    #[test]
    fn error_outcomes_only_hit_e2e() {
        let mut b = LatencyBundle::new();
        let mut o = RequestOutcome::started(2, 0.0, 0.0);
        o.done_at = 0.25;
        o.error = Some("connect: refused".to_string());
        b.record(&o);
        assert_eq!(b.ttft.count(), 0);
        assert_eq!(b.e2e.count(), 1);
    }

    #[test]
    fn merged_bundle_matches_single() {
        let mut one = LatencyBundle::new();
        let mut a = LatencyBundle::new();
        let mut b = LatencyBundle::new();
        for i in 0..10 {
            let o = outcome(0.0, 0.0, 0.01 * (i + 1) as f64, 0.5);
            one.record(&o);
            if i % 2 == 0 { a.record(&o) } else { b.record(&o) }
        }
        a.merge(&b);
        assert_eq!(a.ttft.count(), one.ttft.count());
        assert_eq!(a.ttft.p50(), one.ttft.p50());
    }

    #[test]
    fn hist_json_is_valid_json() {
        let mut h = Histogram::new();
        h.record(0.012);
        h.record(0.020);
        let j = crate::util::json::Json::parse(&hist_json_ms(&h)).unwrap();
        assert_eq!(j.path("n").unwrap().as_usize(), Some(2));
        assert!(j.path("p50_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
