//! Deterministic serving workloads for the load harness.
//!
//! Two invariants make these workloads usable as benchmark fixtures:
//!
//! 1. **Index-keyed determinism.** Request `i` is derived from a private
//!    RNG seeded by `(seed, i)` alone — not from a shared stream — so
//!    [`WorkloadConfig::request`] returns the same `LoadRequest` no
//!    matter which worker thread asks, in what order, or how many
//!    requests were materialized before it. `generate()` is just
//!    `(0..n).map(request)`.
//! 2. **Open-loop honesty.** [`open_loop_schedule`] derives Poisson
//!    arrival offsets from the seed alone; nothing about engine service
//!    times can perturb *when* requests are offered. Queueing delay
//!    past the saturation knee is therefore measured, not hidden by
//!    client back-pressure (closed-loop generators measure capacity;
//!    only open-loop generators measure latency under load).
//!
//! Prompt text comes from the crate's training grammar
//! ([`crate::workload::prompt`]): single-line ASCII, in-distribution
//! for the CPU-substrate byte LM. Length mixes are bounded so
//! `shared_prefix_len + prompt + max_new` stays inside the substrate's
//! 256-token context (no accidental `context_full` storms).

use crate::coordinator::SamplingParams;
use crate::testutil::Rng;

/// Per-request seed salt (index-keyed derivation; any odd constant
/// works — this is wyhash's prime so request streams and the shared
/// prefix/schedule streams never collide).
const REQ_SALT: u64 = 0xA076_1D64_78BD_642F;
/// Salt for the shared-prefix text stream.
const PREFIX_SALT: u64 = 0xE703_7ED1_A0B4_28DB;
/// Salt for the open-loop arrival schedule stream.
const SCHED_SALT: u64 = 0x8EBC_6AF0_9C88_C6E3;

/// Prompt-length mix (bytes == tokens for the byte LM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LenMix {
    /// Uniform 16..48 — chat-style short prompts.
    Short,
    /// 80% uniform 16..64, 20% uniform 96..128 — the serving-paper
    /// shape: mostly short with a heavy tail that stresses prefill.
    LongTail,
    /// Uniform 96..144 — every prompt is long (prefill-bound).
    Heavy,
}

impl LenMix {
    pub fn parse(s: &str) -> Result<LenMix, String> {
        match s {
            "short" => Ok(LenMix::Short),
            "longtail" | "long-tail" => Ok(LenMix::LongTail),
            "heavy" => Ok(LenMix::Heavy),
            other => {
                Err(format!("unknown mix {other:?} (short|longtail|heavy)"))
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LenMix::Short => "short",
            LenMix::LongTail => "longtail",
            LenMix::Heavy => "heavy",
        }
    }

    fn sample_len(self, rng: &mut Rng) -> usize {
        match self {
            LenMix::Short => rng.range(16, 48),
            LenMix::LongTail => {
                if rng.bool(0.8) {
                    rng.range(16, 64)
                } else {
                    rng.range(96, 128)
                }
            }
            LenMix::Heavy => rng.range(96, 144),
        }
    }
}

/// One materialized harness request: what to send and how the client
/// should behave while it streams.
#[derive(Debug, Clone)]
pub struct LoadRequest {
    /// Position in the workload (stable across thread counts).
    pub index: usize,
    pub prompt: Vec<u8>,
    pub params: SamplingParams,
    /// Top-k page-sparse decode knob (0 = dense), per request so sweeps
    /// mix sparse and dense traffic in one batch.
    pub sparse_topk_pages: usize,
    /// `Some(k)`: the client cancels after observing the k-th token
    /// (exercising the disconnect-as-cancel path), then drains the
    /// stream to its terminal event. `None`: run to completion.
    pub cancel_after: Option<usize>,
}

/// Seeded workload description; every field participates in the
/// derivation, so two equal configs produce bit-identical workloads.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub seed: u64,
    pub n_requests: usize,
    pub mix: LenMix,
    /// Fraction of requests whose prompt starts with the workload's
    /// shared prefix (exercises the prefix index / page dedup).
    pub shared_prefix_ratio: f64,
    /// Length of that shared prefix in bytes (default two KV pages).
    pub shared_prefix_len: usize,
    /// Per-request probability of a mid-stream client cancel.
    pub cancel_prob: f64,
    /// Fraction of requests decoded with top-k page-sparse attention.
    pub sparse_ratio: f64,
    /// `sparse_topk_pages` for the sparse fraction.
    pub sparse_topk_pages: usize,
    /// Sampling defaults; per-request seeds are derived on top, and
    /// `max_new_tokens` is taken as-is.
    pub base: SamplingParams,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            seed: 0,
            n_requests: 16,
            mix: LenMix::LongTail,
            shared_prefix_ratio: 0.0,
            shared_prefix_len: 64,
            cancel_prob: 0.0,
            sparse_ratio: 0.0,
            sparse_topk_pages: 4,
            base: SamplingParams::greedy(32),
        }
    }
}

impl WorkloadConfig {
    /// The workload's shared prompt prefix (same for every request that
    /// draws it; derived from the seed alone).
    pub fn shared_prefix(&self) -> Vec<u8> {
        let mut rng = Rng::new(self.seed ^ PREFIX_SALT);
        crate::workload::prompt(&mut rng, self.shared_prefix_len.max(1))
    }

    /// Materialize request `i`. Pure function of `(self, i)`: the
    /// per-request RNG is keyed by the index, so no call order or
    /// thread schedule can change what request `i` looks like.
    pub fn request(&self, i: usize) -> LoadRequest {
        assert!(i < self.n_requests, "request {i} >= {}", self.n_requests);
        let mut rng = Rng::new(
            self.seed ^ (i as u64).wrapping_add(1).wrapping_mul(REQ_SALT),
        );
        // Draw order is part of the workload definition — reordering
        // these draws is a (deliberate) workload-breaking change.
        let shared = rng.bool(self.shared_prefix_ratio);
        let len = self.mix.sample_len(&mut rng);
        let mut prompt = if shared { self.shared_prefix() } else { Vec::new() };
        prompt.extend_from_slice(&crate::workload::prompt(&mut rng, len));
        let mut params = self.base;
        params.seed = rng.next_u64();
        let sparse = rng.bool(self.sparse_ratio);
        let cancel = rng.bool(self.cancel_prob);
        let cancel_after = if cancel {
            Some(rng.range(1, params.max_new_tokens.max(2)))
        } else {
            None
        };
        debug_assert!(
            prompt.iter().all(|&b| b.is_ascii() && b != b'\n'),
            "prompts must be single-line ASCII for the wire protocol"
        );
        LoadRequest {
            index: i,
            prompt,
            params,
            sparse_topk_pages: if sparse { self.sparse_topk_pages } else { 0 },
            cancel_after,
        }
    }

    /// The whole workload, in index order.
    pub fn generate(&self) -> Vec<LoadRequest> {
        (0..self.n_requests).map(|i| self.request(i)).collect()
    }
}

/// Seeded Poisson arrival offsets (seconds from sweep start) for an
/// open-loop run at `rate` requests/s. Derived from `(seed, rate, n)`
/// alone — service times never feed back into the schedule, which is
/// the open-loop honesty rule that makes post-knee queue-wait
/// percentiles meaningful.
pub fn open_loop_schedule(seed: u64, rate: f64, n: usize) -> Vec<f64> {
    assert!(rate > 0.0, "open-loop rate must be positive");
    let mut rng = Rng::new(seed ^ SCHED_SALT);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_is_index_keyed() {
        let wl = WorkloadConfig {
            seed: 7,
            n_requests: 12,
            shared_prefix_ratio: 0.5,
            cancel_prob: 0.3,
            sparse_ratio: 0.5,
            ..Default::default()
        };
        let all = wl.generate();
        // Asking for request i in any order reproduces generate()[i].
        for i in (0..wl.n_requests).rev() {
            let r = wl.request(i);
            assert_eq!(r.prompt, all[i].prompt);
            assert_eq!(r.params, all[i].params);
            assert_eq!(r.cancel_after, all[i].cancel_after);
            assert_eq!(r.sparse_topk_pages, all[i].sparse_topk_pages);
        }
    }

    #[test]
    fn workload_bit_reproducible() {
        let wl = WorkloadConfig {
            seed: 42,
            n_requests: 20,
            shared_prefix_ratio: 0.4,
            cancel_prob: 0.2,
            ..Default::default()
        };
        let a = wl.generate();
        let b = wl.generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.params.seed, y.params.seed);
        }
    }

    #[test]
    fn shared_prefix_actually_shared() {
        let wl = WorkloadConfig {
            seed: 3,
            n_requests: 32,
            shared_prefix_ratio: 1.0,
            ..Default::default()
        };
        let prefix = wl.shared_prefix();
        assert_eq!(prefix.len(), wl.shared_prefix_len);
        for r in wl.generate() {
            assert!(r.prompt.starts_with(&prefix));
            assert!(r.prompt.len() > prefix.len());
        }
        // ratio 0 ⇒ nothing forced to share it.
        let wl0 = WorkloadConfig { shared_prefix_ratio: 0.0, ..wl };
        assert!(wl0.generate().iter().any(|r| !r.prompt.starts_with(&prefix)));
    }

    #[test]
    fn mixes_respect_length_bounds() {
        for (mix, lo, hi) in [
            (LenMix::Short, 16, 48),
            (LenMix::LongTail, 16, 128),
            (LenMix::Heavy, 96, 144),
        ] {
            let wl = WorkloadConfig {
                seed: 9,
                n_requests: 64,
                mix,
                ..Default::default()
            };
            for r in wl.generate() {
                assert!(
                    (lo..hi).contains(&r.prompt.len()),
                    "{} prompt len {}",
                    mix.name(),
                    r.prompt.len()
                );
            }
        }
    }

    #[test]
    fn cancel_prob_extremes() {
        let all = WorkloadConfig {
            cancel_prob: 1.0,
            n_requests: 16,
            ..Default::default()
        };
        for r in all.generate() {
            let k = r.cancel_after.expect("cancel_prob 1.0");
            assert!(k >= 1 && k < r.params.max_new_tokens.max(2));
        }
        let none = WorkloadConfig { cancel_prob: 0.0, ..all };
        assert!(none.generate().iter().all(|r| r.cancel_after.is_none()));
    }

    #[test]
    fn schedule_bit_reproducible_and_monotone() {
        let a = open_loop_schedule(11, 8.0, 50);
        let b = open_loop_schedule(11, 8.0, 50);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            // Bit-level equality, not approximate: the schedule is a
            // fixture, and f64 arithmetic here is deterministic.
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for w in a.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // Mean inter-arrival ≈ 1/rate (loose: 50 samples).
        let mean = a.last().unwrap() / 50.0;
        assert!(mean > 0.04 && mean < 0.4, "mean gap {mean}");
    }

    #[test]
    fn parse_mix_names() {
        assert_eq!(LenMix::parse("short").unwrap(), LenMix::Short);
        assert_eq!(LenMix::parse("long-tail").unwrap(), LenMix::LongTail);
        assert_eq!(LenMix::parse("heavy").unwrap(), LenMix::Heavy);
        assert!(LenMix::parse("medium").is_err());
    }
}
