//! Serving load harness: seeded workloads, open/closed-loop
//! generators, client-side latency collection, and the
//! `BENCH_serve.json` saturation report (the `bench-serve`
//! subcommand's engine room).
//!
//! Layout:
//! - [`workload`] — deterministic request mixes (index-keyed seeding)
//!   and the seeded open-loop Poisson arrival schedule.
//! - [`client`] — the one client-side implementation of the server's
//!   `GEN → ACK/TOK…/DONE` wire protocol, plus `STATS` scraping.
//! - [`generators`] — open-loop (honest offered load: arrivals never
//!   wait on service) and closed-loop (fixed concurrency) drivers over
//!   a TCP or in-process target.
//! - [`histogram`] — fixed-bucket log-scale percentile collection
//!   (TTFT / ITL / queue wait / end-to-end).
//! - [`report`] — sweep-point aggregation, engine `STATS` deltas,
//!   saturation-knee detection, JSON rendering.
//!
//! Two standing invariants, relied on by the acceptance tests:
//! **the harness never perturbs engine output** (a closed-loop
//! concurrency-1 sweep reproduces sequential `gen` byte-for-byte — a
//! consequence of the engine's request-purity invariant, checked in
//! `tests/loadgen_harness.rs`), and **open-loop arrivals follow the
//! seeded schedule unconditionally** (queueing delay is measured, not
//! absorbed into client back-pressure).

pub mod client;
pub mod generators;
pub mod histogram;
pub mod report;
pub mod workload;

pub use client::{
    gen_line, parse_stats_json, parse_stats_kv, parse_wire_line, TcpClient,
    WireEvent,
};
pub use generators::{
    run_closed_loop, run_open_loop, RequestOutcome, RunSummary, Target,
};
pub use histogram::LatencyBundle;
pub use report::{
    diff_engine_stats, render_report, saturation_knee, summary_line,
    SweepPoint, SweepPointConfig,
};
pub use workload::{open_loop_schedule, LenMix, LoadRequest, WorkloadConfig};
