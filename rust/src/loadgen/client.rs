//! The one wire-protocol client for the server's line protocol.
//!
//! Everything that talks `GEN → ACK/TOK…/DONE` from the client side —
//! the load generators, the `streaming_client` example, ad-hoc tools —
//! goes through [`TcpClient`] / [`parse_wire_line`], so the protocol
//! has exactly one client-side parse. (`tests/server_stream.rs`
//! deliberately hand-parses raw bytes instead: it is the wire-format
//! oracle that pins the server's exact output, independent of this
//! client.)
//!
//! Token bytes are reconstructed from the `TOK` lines (exact), never
//! from the `DONE` trailer text (lossy: the server maps `\n` to space
//! there).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{RequestId, SamplingParams};
use crate::model::Sampler;
use crate::util::json::Json;

/// One parsed server reply line.
#[derive(Debug, Clone, PartialEq)]
pub enum WireEvent {
    /// Admission ack carrying the engine-assigned request id.
    Ack(RequestId),
    /// One streamed token (`index` 0 = first token).
    Tok { id: RequestId, index: usize, byte: u8 },
    /// Terminal line for a request; `text` is the lossy human trailer.
    Done {
        id: RequestId,
        reason: String,
        ttft_ms: f64,
        total_ms: f64,
        text: String,
    },
    /// `STATS` reply payload: `key=value ...` for the classic form, a
    /// `{...}` object for `STATS JSON`.
    Stats(String),
    Err(String),
    Bye,
}

/// Parse one server line (without its trailing newline).
pub fn parse_wire_line(line: &str) -> Result<WireEvent> {
    if line == "BYE" {
        return Ok(WireEvent::Bye);
    }
    if let Some(rest) = line.strip_prefix("ACK ") {
        let id = rest.trim().parse::<RequestId>().context("bad ACK id")?;
        return Ok(WireEvent::Ack(id));
    }
    if let Some(rest) = line.strip_prefix("TOK ") {
        let mut f = rest.split(' ');
        let id = f
            .next()
            .and_then(|w| w.parse::<RequestId>().ok())
            .with_context(|| format!("bad TOK id: {line:?}"))?;
        let index = f
            .next()
            .and_then(|w| w.parse::<usize>().ok())
            .with_context(|| format!("bad TOK index: {line:?}"))?;
        let byte = f
            .next()
            .and_then(|w| w.parse::<u16>().ok())
            .filter(|&b| b < 256)
            .with_context(|| format!("bad TOK byte: {line:?}"))?;
        ensure!(f.next().is_none(), "trailing TOK fields: {line:?}");
        return Ok(WireEvent::Tok { id, index, byte: byte as u8 });
    }
    if let Some(rest) = line.strip_prefix("DONE ") {
        let mut f = rest.splitn(5, ' ');
        let id = f
            .next()
            .and_then(|w| w.parse::<RequestId>().ok())
            .with_context(|| format!("bad DONE id: {line:?}"))?;
        let reason = f.next().context("missing DONE reason")?.to_string();
        let ttft_ms = f
            .next()
            .and_then(|w| w.parse::<f64>().ok())
            .with_context(|| format!("bad DONE ttft: {line:?}"))?;
        let total_ms = f
            .next()
            .and_then(|w| w.parse::<f64>().ok())
            .with_context(|| format!("bad DONE total: {line:?}"))?;
        let text = f.next().unwrap_or("").to_string();
        return Ok(WireEvent::Done { id, reason, ttft_ms, total_ms, text });
    }
    if let Some(rest) = line.strip_prefix("STATS ") {
        return Ok(WireEvent::Stats(rest.to_string()));
    }
    if let Some(rest) = line.strip_prefix("ERR ") {
        return Ok(WireEvent::Err(rest.to_string()));
    }
    bail!("unrecognized server line: {line:?}")
}

/// Render a `GEN` line for `(prompt, params, sparse_topk_pages)` —
/// the inverse of the server's `parse_gen`. The prompt must be
/// single-line (the protocol is line-delimited).
pub fn gen_line(
    prompt: &[u8],
    params: &SamplingParams,
    sparse_topk_pages: usize,
) -> String {
    let text = std::str::from_utf8(prompt).expect("prompt must be UTF-8");
    assert!(
        !text.contains('\n') && !text.is_empty(),
        "prompt must be one non-empty line"
    );
    let mut line = format!("GEN {} seed={}", params.max_new_tokens, params.seed);
    match params.sampler {
        Sampler::Greedy => line.push_str(" greedy"),
        Sampler::TopK { k, temp } => {
            line.push_str(&format!(" topk={k} temp={temp}"));
        }
    }
    if let Some(b) = params.stop_byte {
        line.push_str(&format!(" stop={b}"));
    }
    if sparse_topk_pages > 0 {
        line.push_str(&format!(" sparse={sparse_topk_pages}"));
    }
    line.push(' ');
    line.push_str(text);
    line
}

/// Parse the classic `STATS key=value ...` payload.
pub fn parse_stats_kv(payload: &str) -> BTreeMap<String, String> {
    payload
        .split_whitespace()
        .filter_map(|w| w.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Parse a `STATS JSON` payload into the same string-map shape as
/// [`parse_stats_kv`] (numbers rendered back to their literal form).
pub fn parse_stats_json(payload: &str) -> Result<BTreeMap<String, String>> {
    let j = Json::parse(payload).map_err(|e| anyhow!("STATS JSON: {e}"))?;
    let obj = j.as_obj().context("STATS JSON payload is not an object")?;
    Ok(obj
        .iter()
        .map(|(k, v)| {
            let s = match v {
                Json::Num(n) if n.fract() == 0.0 && n.abs() < 1e15 => {
                    format!("{}", *n as i64)
                }
                Json::Num(n) => format!("{n}"),
                Json::Str(s) => s.clone(),
                Json::Bool(b) => b.to_string(),
                other => crate::util::json::to_string(other),
            };
            (k.clone(), s)
        })
        .collect())
}

/// Blocking line-protocol client over one TCP connection.
pub struct TcpClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl TcpClient {
    pub fn connect(addr: SocketAddr) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(TcpClient { writer: stream, reader })
    }

    /// Send one raw protocol line.
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.writer, "{line}").context("socket write")
    }

    /// Next parsed server line, blocking; errors on EOF (the server
    /// only closes after `BYE` or on its own failure).
    pub fn next_event(&mut self) -> Result<WireEvent> {
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).context("socket read")?;
            ensure!(n > 0, "server closed the connection");
            let trimmed = line.trim_end_matches(['\n', '\r']);
            if trimmed.is_empty() {
                continue;
            }
            return parse_wire_line(trimmed);
        }
    }

    /// Submit a request; returns the ACKed id (an `ERR` reply becomes
    /// an error).
    pub fn gen(
        &mut self,
        prompt: &[u8],
        params: &SamplingParams,
        sparse_topk_pages: usize,
    ) -> Result<RequestId> {
        self.send_line(&gen_line(prompt, params, sparse_topk_pages))?;
        match self.next_event()? {
            WireEvent::Ack(id) => Ok(id),
            WireEvent::Err(e) => bail!("server rejected GEN: {e}"),
            other => bail!("expected ACK, got {other:?}"),
        }
    }

    /// Cancel an in-flight request (its stream still ends with a
    /// `DONE .. cancelled` line — keep reading to observe it).
    pub fn cancel(&mut self, id: RequestId) -> Result<()> {
        self.send_line(&format!("CANCEL {id}"))
    }

    /// Classic `STATS` scrape. Only sound on a connection with no
    /// in-flight streams (TOK lines would interleave with the reply).
    pub fn stats(&mut self) -> Result<BTreeMap<String, String>> {
        self.send_line("STATS")?;
        match self.next_event()? {
            WireEvent::Stats(p) => Ok(parse_stats_kv(&p)),
            other => bail!("expected STATS reply, got {other:?}"),
        }
    }

    /// `STATS JSON` scrape (machine-readable; same caveat as `stats`).
    pub fn stats_json(&mut self) -> Result<BTreeMap<String, String>> {
        self.send_line("STATS JSON")?;
        match self.next_event()? {
            WireEvent::Stats(p) => parse_stats_json(&p),
            other => bail!("expected STATS reply, got {other:?}"),
        }
    }

    /// Polite shutdown: `QUIT`, wait for `BYE`.
    pub fn quit(mut self) -> Result<()> {
        self.send_line("QUIT")?;
        match self.next_event()? {
            WireEvent::Bye => Ok(()),
            other => bail!("expected BYE, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_line_kind() {
        assert_eq!(parse_wire_line("ACK 7").unwrap(), WireEvent::Ack(7));
        assert_eq!(
            parse_wire_line("TOK 7 0 104").unwrap(),
            WireEvent::Tok { id: 7, index: 0, byte: 104 }
        );
        assert_eq!(
            parse_wire_line("DONE 7 max_tokens 12.5 80.1 hello there").unwrap(),
            WireEvent::Done {
                id: 7,
                reason: "max_tokens".into(),
                ttft_ms: 12.5,
                total_ms: 80.1,
                text: "hello there".into(),
            }
        );
        // Empty trailer (cancel before the first token).
        assert_eq!(
            parse_wire_line("DONE 3 cancelled 0.0 1.0 ").unwrap(),
            WireEvent::Done {
                id: 3,
                reason: "cancelled".into(),
                ttft_ms: 0.0,
                total_ms: 1.0,
                text: String::new(),
            }
        );
        assert_eq!(
            parse_wire_line("STATS completed=1 kernel=scalar").unwrap(),
            WireEvent::Stats("completed=1 kernel=scalar".into())
        );
        assert_eq!(
            parse_wire_line("ERR empty prompt").unwrap(),
            WireEvent::Err("empty prompt".into())
        );
        assert_eq!(parse_wire_line("BYE").unwrap(), WireEvent::Bye);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_wire_line("NOPE 1").is_err());
        assert!(parse_wire_line("ACK x").is_err());
        assert!(parse_wire_line("TOK 1 2").is_err());
        assert!(parse_wire_line("TOK 1 2 300").is_err());
        assert!(parse_wire_line("TOK 1 2 3 4").is_err());
        assert!(parse_wire_line("DONE 1 max_tokens 1.0").is_err());
    }

    #[test]
    fn gen_line_round_trips_through_server_grammar() {
        let topk = SamplingParams {
            sampler: Sampler::TopK { k: 6, temp: 0.8 },
            seed: 11,
            stop_byte: Some(46),
            max_new_tokens: 48,
        };
        assert_eq!(
            gen_line(b"the stream", &topk, 0),
            "GEN 48 seed=11 topk=6 temp=0.8 stop=46 the stream"
        );
        let greedy = SamplingParams::greedy(32);
        assert_eq!(
            gen_line(b"hi there", &greedy, 3),
            "GEN 32 seed=0 greedy sparse=3 hi there"
        );
    }

    #[test]
    fn stats_kv_parses() {
        let m = parse_stats_kv("completed=3 itl_p50_ms=0.120 kernel=avx2");
        assert_eq!(m.get("completed").map(String::as_str), Some("3"));
        assert_eq!(m.get("kernel").map(String::as_str), Some("avx2"));
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn stats_json_parses() {
        let m =
            parse_stats_json(r#"{"completed":3,"fill":0.25,"kernel":"scalar"}"#)
                .unwrap();
        assert_eq!(m.get("completed").map(String::as_str), Some("3"));
        assert_eq!(m.get("fill").map(String::as_str), Some("0.25"));
        assert_eq!(m.get("kernel").map(String::as_str), Some("scalar"));
        assert!(parse_stats_json("completed=3").is_err());
    }
}
