//! Integer-domain attention micro-kernels — the CPU stand-ins for the
//! paper's INT8 tensor-core tiles, now with explicit SIMD arms behind a
//! runtime dispatch layer.
//!
//! Four kernels cover the Turbo block loops (Algorithm 1 prefill
//! tiles and Algorithm 2 decode blocks) plus the sparse page selector:
//!
//! * [`idot_mr`] / [`qk_dot_block`] — multi-row QK^T: [`MR`] key rows
//!   per pass against one quantized query, one independent `i32`
//!   accumulator per row.
//! * [`ipv_acc`] — P·V accumulation kept **entirely in `i32`**; the
//!   caller applies the fused `p_scale * v_scale` once per block per
//!   output element (§3's "one dequantization per tile").
//! * [`page_score`] — envelope upper-bound dot for the SparQ-style
//!   sparse decode path: one pass over the per-channel key min/max
//!   bounds of a page yields an upper bound on every key row's score.
//! * [`sas_exp_block`] — the batched SAS shift-exp-and-sum
//!   ([`crate::sas::Sas::exp_block`] is the caller-facing wrapper that
//!   owns the LUT).
//!
//! # Dispatch architecture
//!
//! Each kernel has up to three arms: [`scalar`] (portable Rust, always
//! compiled), [`x86`] (AVX2, compiled on x86-64) and [`neon`] (aarch64).
//! The public functions in this module validate shapes, then route to
//! the arm picked **once per process** by [`dispatch`]: the
//! `--kernel-backend` CLI flag wins, then the `TURBO_KERNEL` env var
//! (`scalar` | `avx2` | `neon` | `auto`), then auto-detection
//! (`is_x86_feature_detected!("avx2")` on x86-64; NEON is baseline on
//! aarch64). `TURBO_KERNEL=scalar` forces the oracle arm — the first
//! thing to try when bisecting a suspected kernel bug. The selected arm
//! is reported in `STATS`, `gen` output and the bench JSON so numbers
//! stay attributable to the ISA that produced them.
//!
//! # Why SIMD cannot change results
//!
//! INT8 codes are bounded by 128 in magnitude, so a product is at most
//! `128 * 128 = 16384` and an `i32` accumulator holds at least
//! [`ACC_MAX_ROWS`] (= `i32::MAX / 16384` = 131071) terms with **zero**
//! possibility of wraparound — both accumulation kernels assert the
//! bound. Within it, integer accumulation is *exact* and therefore
//! order-independent: regrouping terms into SIMD lanes cannot change a
//! bit of the result, which is why swapping arms preserves the decode
//! determinism contract (`parallel_parity` bit-equality) and why "SIMD
//! == scalar, bitwise" is a property test rather than a tolerance. The
//! f32 SAS evaluator has no such algebraic shield, so its SIMD arms
//! instead replicate the scalar arm's exact op sequence (no FMA, no
//! reassociation, same NaN-edge semantics) and sum in slice order —
//! see [`x86`]/[`neon`] module docs for the per-intrinsic argument.
//!
//! # Who owns scales
//!
//! Kernels never see scales. Quantization scales (`q_scale * k_scale *
//! 1/sqrt(d)` for scores, `p_scale * v_scale` for P·V) are owned by the
//! caller ([`crate::attention::turbo`]), which applies them exactly
//! once per block on the `i32` results. Keeping scales out of the
//! inner loops is what keeps them integer-only.

pub mod dispatch;
pub mod neon;
pub mod scalar;
pub mod x86;

pub use dispatch::{force_kernel_backend, kernel_backend, KernelBackend};

/// Key rows computed per [`idot_mr`] pass.
pub const MR: usize = 4;

/// Largest number of i8·i8 products one `i32` accumulator is proven to
/// hold exactly: `i32::MAX / (128 * 128)`.
pub const ACC_MAX_ROWS: usize = (i32::MAX / (128 * 128)) as usize;

/// Multi-row QK^T micro-kernel: dot `q` against [`MR`] key rows stored
/// contiguously in `k4` (`k4.len() == MR * q.len()`), returning one
/// independent `i32` accumulator per row. Dispatches to the selected
/// backend arm; all arms are bit-identical (exact `i32` accumulation).
///
/// `q.len()` (the head dim) counts one product per accumulator term
/// and is far below [`ACC_MAX_ROWS`] everywhere in this repo; the
/// result is exact for every i8 value including `-128`.
#[inline]
pub fn idot_mr(q: &[i8], k4: &[i8]) -> [i32; MR] {
    assert_eq!(k4.len(), MR * q.len(), "k4 must hold exactly MR rows");
    debug_assert!(q.len() <= ACC_MAX_ROWS);
    match kernel_backend() {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe { x86::idot_mr(q, k4) },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe { neon::idot_mr(q, k4) },
        #[allow(unreachable_patterns)]
        _ => scalar::idot_mr(q, k4),
    }
}

/// QK^T over one whole key block: `k` holds `k.len() / d` contiguous
/// rows of width `d`; writes `out[r] = q · k_row[r]` for every row.
/// Rows are processed [`MR`] at a time with a single-row tail, so
/// ragged block lengths (the last cache block) cost only the remainder
/// rows. Dispatches to the selected backend arm.
#[inline]
pub fn qk_dot_block(q: &[i8], k: &[i8], d: usize, out: &mut [i32]) {
    assert!(d > 0, "head dim must be positive");
    debug_assert_eq!(k.len() % d, 0);
    let rows = k.len() / d;
    assert!(out.len() >= rows, "out must hold one score per key row");
    match kernel_backend() {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe { x86::qk_dot_block(q, k, d, out) },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe { neon::qk_dot_block(q, k, d, out) },
        #[allow(unreachable_patterns)]
        _ => scalar::qk_dot_block(q, k, d, out),
    }
}

/// P·V accumulation for one block, exact in `i32`:
/// `acc[j] = Σ_c p8[c] * v8[c * d + j]` over all `p8.len()` rows of
/// `v8`. `acc[..d]` is overwritten (per-block accumulator — the caller
/// folds it into the running f32 output with a **single**
/// `p_scale * v_scale` multiply per element). Zero probability codes
/// skip their row in every arm — SAS sparsity makes whole rows zero
/// below the `n_r` threshold, and a skipped row adds exactly 0, so the
/// short-circuit cannot change the (exact) sum.
///
/// Panics if the row count exceeds [`ACC_MAX_ROWS`] — beyond that the
/// `i32` no-overflow proof (`rows * 128 * 128 <= i32::MAX`) stops
/// holding. Every caller in this crate passes `bc <= 1024` rows. The
/// check lives here, before dispatch, so the contract is identical for
/// every backend arm.
#[inline]
pub fn ipv_acc(p8: &[i8], v8: &[i8], d: usize, acc: &mut [i32]) {
    assert!(d > 0, "head dim must be positive");
    let rows = p8.len();
    assert!(
        rows <= ACC_MAX_ROWS,
        "{rows} rows can overflow an i32 accumulator (max {ACC_MAX_ROWS})"
    );
    assert!(v8.len() >= rows * d, "v8 must hold one row per p code");
    assert!(acc.len() >= d, "acc must hold d lanes");
    match kernel_backend() {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe { x86::ipv_acc(p8, v8, d, acc) },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe { neon::ipv_acc(p8, v8, d, acc) },
        #[allow(unreachable_patterns)]
        _ => scalar::ipv_acc(p8, v8, d, acc),
    }
}

/// Envelope upper-bound page score for the sparse decode path: each
/// channel pairs the query code with whichever key-envelope end
/// maximizes the product (`q >= 0` with `kmax`, `q < 0` with `kmin`)
/// and the products sum in exact `i32`. For a page whose per-channel q1
/// key codes all lie inside `[kmin, kmax]`, the result is an upper
/// bound on `q · k_row` for every row of the page — the selection
/// signal `topk_pages` ranks by. Dispatches to the selected backend
/// arm; as with the dot kernels, exact integer accumulation makes every
/// arm bit-identical.
#[inline]
pub fn page_score(q: &[i8], kmin: &[i8], kmax: &[i8]) -> i32 {
    assert_eq!(q.len(), kmin.len(), "kmin must hold one bound per channel");
    assert_eq!(q.len(), kmax.len(), "kmax must hold one bound per channel");
    debug_assert!(q.len() <= ACC_MAX_ROWS);
    match kernel_backend() {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe { x86::page_score(q, kmin, kmax) },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe { neon::page_score(q, kmin, kmax) },
        #[allow(unreachable_patterns)]
        _ => scalar::page_score(q, kmin, kmax),
    }
}

/// Batched SAS shift-exp-and-sum: `row[i] <- SAS_exp(row[i] - m)`,
/// returning the sum of the results. `lut` holds `depth + 2` entries
/// (`e^-i` for `0..=depth`, then `0.0`); `n_r` is the sparsity
/// threshold. All arms are bit-identical to the scalar evaluator —
/// the SIMD arms replicate its f32 op sequence exactly (see module
/// docs). Callers go through [`crate::sas::Sas::exp_block`], which
/// owns the tables.
#[inline]
pub fn sas_exp_block(lut: &[f32], depth: usize, n_r: f32, row: &mut [f32], m: f32) -> f32 {
    assert_eq!(lut.len(), depth + 2, "lut must hold depth + 2 entries");
    match kernel_backend() {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe { x86::sas_exp_block(lut, depth, n_r, row, m) },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe { neon::sas_exp_block(lut, depth, n_r, row, m) },
        #[allow(unreachable_patterns)]
        _ => scalar::sas_exp_block(lut, depth, n_r, row, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::scalar::idot;
    use crate::testutil::prop;

    fn gen_codes(g: &mut prop::Gen, n: usize) -> Vec<i8> {
        (0..n)
            .map(|_| {
                // Bias toward the edge values the contract calls out.
                match g.usize_in(0, 8) {
                    0 => 127,
                    1 => -127,
                    2 => -128,
                    _ => (g.usize_in(0, 255) as i32 - 127) as i8,
                }
            })
            .collect()
    }

    // These property tests run against whichever arm the process
    // dispatched to (CI's kernel matrix covers scalar and the detected
    // SIMD arm), always comparing to the elementary scalar oracle. The
    // arm-specific bitwise tests live in x86.rs / neon.rs.

    #[test]
    fn idot_mr_matches_scalar_reference() {
        prop::run("idot_mr == idot x4", 60, |g| {
            // Ragged widths around the chunk size, incl. d < LANES.
            let d = g.usize_in(1, 3 * scalar::LANES + 3);
            let q = gen_codes(g, d);
            let k4 = gen_codes(g, MR * d);
            let got = idot_mr(&q, &k4);
            for (r, &s) in got.iter().enumerate() {
                let want = idot(&q, &k4[r * d..(r + 1) * d]);
                assert_eq!(s, want, "row {r} (d={d})");
            }
        });
    }

    #[test]
    fn idot_mr_exact_at_i8_extremes() {
        // 4 rows of -128 against a query of -128: products are +16384,
        // summed exactly (this is the worst case of the overflow proof).
        let d = 64;
        let q = vec![-128i8; d];
        let k4 = vec![-128i8; MR * d];
        for s in idot_mr(&q, &k4) {
            assert_eq!(s, (d as i32) * 16384);
        }
        let k4 = vec![127i8; MR * d];
        for s in idot_mr(&q, &k4) {
            assert_eq!(s, (d as i32) * (-128 * 127));
        }
    }

    #[test]
    fn qk_dot_block_covers_ragged_row_counts() {
        prop::run("qk_dot_block == idot rows", 60, |g| {
            let d = g.usize_in(1, 40);
            // 0..=11 rows: exercises 0, sub-MR, exact-MR and ragged tails.
            let rows = g.usize_in(0, 12);
            let q = gen_codes(g, d);
            let k = gen_codes(g, rows * d);
            let mut out = vec![0i32; rows + 2];
            out.fill(7); // poison: untouched slots must stay put
            qk_dot_block(&q, &k, d, &mut out);
            for r in 0..rows {
                assert_eq!(out[r], idot(&q, &k[r * d..(r + 1) * d]), "row {r}");
            }
            assert_eq!(&out[rows..], &[7, 7], "no write past the rows");
        });
    }

    #[test]
    fn ipv_acc_matches_widening_reference() {
        prop::run("ipv_acc == scalar sum", 60, |g| {
            let d = g.usize_in(1, 40);
            let rows = g.usize_in(0, 12);
            let p8 = gen_codes(g, rows);
            let v8 = gen_codes(g, rows * d);
            let mut acc = vec![-1i32; d];
            ipv_acc(&p8, &v8, d, &mut acc);
            for (j, &a) in acc.iter().enumerate() {
                let want: i32 = (0..rows)
                    .map(|c| p8[c] as i32 * v8[c * d + j] as i32)
                    .sum();
                assert_eq!(a, want, "lane {j}");
            }
        });
    }

    #[test]
    fn ipv_acc_overwrites_stale_accumulator() {
        let mut acc = vec![i32::MAX; 3];
        ipv_acc(&[], &[], 3, &mut acc);
        assert_eq!(acc, vec![0, 0, 0]);
    }

    #[test]
    fn ipv_acc_exact_at_the_overflow_bound() {
        // ACC_MAX_ROWS worst-case products must sum without wrap.
        let rows = ACC_MAX_ROWS;
        let p8 = vec![-128i8; rows];
        let v8 = vec![-128i8; rows];
        let mut acc = vec![0i32; 1];
        ipv_acc(&p8, &v8, 1, &mut acc);
        assert_eq!(acc[0] as i64, rows as i64 * 16384);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn ipv_acc_rejects_rows_beyond_the_proof() {
        // The bound is checked in the dispatching wrapper, before any
        // arm runs, so the contract is backend-independent.
        let rows = ACC_MAX_ROWS + 1;
        let p8 = vec![1i8; rows];
        let v8 = vec![1i8; rows];
        let mut acc = vec![0i32; 1];
        ipv_acc(&p8, &v8, 1, &mut acc);
    }

    #[test]
    fn dispatched_kernels_bit_identical_to_scalar_arm() {
        // Whatever arm this process runs, results must match the scalar
        // arm bit-for-bit — the cross-arm half of the determinism
        // contract (the arm-internal half is in x86/neon tests).
        prop::run("dispatch == scalar arm", 60, |g| {
            let d = g.usize_in(1, 67);
            let rows = g.usize_in(0, 12);
            let q = gen_codes(g, d);
            let k = gen_codes(g, rows * d);
            let mut a = vec![0i32; rows];
            let mut b = vec![0i32; rows];
            qk_dot_block(&q, &k, d, &mut a);
            scalar::qk_dot_block(&q, &k, d, &mut b);
            assert_eq!(a, b, "qk d={d} rows={rows}");
            let p8 = gen_codes(g, rows);
            let v8 = gen_codes(g, rows * d);
            let mut aa = vec![-1i32; d];
            let mut bb = vec![-1i32; d];
            ipv_acc(&p8, &v8, d, &mut aa);
            scalar::ipv_acc(&p8, &v8, d, &mut bb);
            assert_eq!(aa, bb, "ipv d={d} rows={rows}");
        });
    }

    #[test]
    fn page_score_dispatch_matches_scalar_and_bounds_rows() {
        prop::run("page_score == scalar arm, >= idot rows", 60, |g| {
            let d = g.usize_in(1, 67);
            let rows = g.usize_in(1, 8);
            let q = gen_codes(g, d);
            // Build an envelope as the per-channel min/max over a few
            // random key rows; every row then lies inside it.
            let k = gen_codes(g, rows * d);
            let mut kmin = vec![i8::MAX; d];
            let mut kmax = vec![i8::MIN; d];
            for r in 0..rows {
                for j in 0..d {
                    let v = k[r * d + j];
                    kmin[j] = kmin[j].min(v);
                    kmax[j] = kmax[j].max(v);
                }
            }
            let got = page_score(&q, &kmin, &kmax);
            assert_eq!(got, scalar::page_score(&q, &kmin, &kmax), "d={d}");
            for r in 0..rows {
                let row = idot(&q, &k[r * d..(r + 1) * d]);
                assert!(got >= row, "score {got} < row {r} dot {row} (d={d})");
            }
        });
    }

    #[test]
    fn constants_are_consistent() {
        assert_eq!(ACC_MAX_ROWS, 131071);
        // The paper block (64) and every block in this repo are far
        // below the proof bound.
        assert!(1024 < ACC_MAX_ROWS);
    }
}
