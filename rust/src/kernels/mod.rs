//! Integer-domain attention micro-kernels — the CPU stand-ins for the
//! paper's INT8 tensor-core tiles, written so rustc's autovectorizer can
//! keep the hot loops in SIMD integer arithmetic.
//!
//! Three kernels cover both Turbo block loops (Algorithm 1 prefill tiles
//! and Algorithm 2 decode blocks):
//!
//! * [`idot_mr`] / [`qk_dot_block`] — multi-row QK^T: [`MR`] key rows per
//!   pass against one quantized query, with one independent `i32`
//!   accumulator per row and fixed-width chunked slices, so there are no
//!   per-index bounds checks and the query chunk is loaded once per pass
//!   instead of once per row.
//! * [`ipv_acc`] — P·V accumulation kept **entirely in `i32`**. The
//!   caller applies the fused `p_scale * v_scale` product once per block
//!   per output element, instead of converting and scaling every
//!   `i32` product individually (§3's "one dequantization per tile").
//! * The batched SAS evaluator lives with its tables:
//!   [`Sas::exp_block`](crate::sas::Sas::exp_block).
//!
//! # No-overflow contract
//!
//! INT8 codes are bounded by 128 in magnitude (the quantizers emit
//! [-127, 127]; the kernels stay exact even for a hostile `-128`), so a
//! product is at most `128 * 128 = 16384` and an `i32` accumulator holds
//! at least [`ACC_MAX_ROWS`] (= `i32::MAX / 16384` = 131071) terms with
//! **zero** possibility of wraparound. Both accumulation kernels assert
//! this bound. Attention blocks are `bc` tokens (64 in the paper, ≤ 1024
//! anywhere in this repo), so the bound is ~128x away from real
//! workloads; the assert exists to make the contract loud, not to be
//! hit. Within the bound, integer accumulation is *exact* and therefore
//! order-independent — reordering rows or chunks cannot change a bit of
//! the result, which strengthens the decode determinism contract.
//!
//! # Who owns scales
//!
//! Kernels never see scales. Quantization scales (`q_scale * k_scale *
//! 1/sqrt(d)` for scores, `p_scale * v_scale` for P·V) are owned by the
//! caller ([`crate::attention::turbo`]), which applies them exactly once
//! per block on the `i32` results. Keeping scales out of the inner loops
//! is what keeps them integer-only.

/// Key rows computed per [`idot_mr`] pass.
pub const MR: usize = 4;

/// Lanes per inner-loop chunk — wide enough for one AVX2 register of
/// i16 products after widening, small enough that the ragged tail stays
/// cheap at the repo's head dims (16–64).
const LANES: usize = 16;

/// Largest number of i8·i8 products one `i32` accumulator is proven to
/// hold exactly: `i32::MAX / (128 * 128)`.
pub const ACC_MAX_ROWS: usize = (i32::MAX / (128 * 128)) as usize;

/// Single-row chunked integer dot product (the `MR`-kernel's tail case).
///
/// Same result as the scalar reference [`crate::tensor::idot`] — integer
/// accumulation is exact, so chunking cannot change the sum.
#[inline]
fn idot_1(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        let mut s = 0i32;
        for j in 0..LANES {
            s += xa[j] as i32 * xb[j] as i32;
        }
        acc += s;
    }
    for (&xa, &xb) in ca.remainder().iter().zip(cb.remainder()) {
        acc += xa as i32 * xb as i32;
    }
    acc
}

/// Multi-row QK^T micro-kernel: dot `q` against [`MR`] key rows stored
/// contiguously in `k4` (`k4.len() == MR * q.len()`), returning one
/// independent `i32` accumulator per row.
///
/// One pass over `q` serves all four rows — the query chunk is loaded
/// once per [`LANES`]-wide step instead of once per row, and the four
/// accumulators give the autovectorizer independent dependency chains.
/// All slices are consumed through `chunks_exact`, so the inner loop has
/// no bounds checks.
///
/// `q.len()` (the head dim) counts one product per accumulator term and
/// is far below [`ACC_MAX_ROWS`] everywhere in this repo; the result is
/// exact for every i8 value including `-128`.
#[inline]
pub fn idot_mr(q: &[i8], k4: &[i8]) -> [i32; MR] {
    let d = q.len();
    assert_eq!(k4.len(), MR * d, "k4 must hold exactly MR rows");
    debug_assert!(d <= ACC_MAX_ROWS);
    let (k0, rest) = k4.split_at(d);
    let (k1, rest) = rest.split_at(d);
    let (k2, k3) = rest.split_at(d);
    let mut acc = [0i32; MR];
    let mut cq = q.chunks_exact(LANES);
    let mut c0 = k0.chunks_exact(LANES);
    let mut c1 = k1.chunks_exact(LANES);
    let mut c2 = k2.chunks_exact(LANES);
    let mut c3 = k3.chunks_exact(LANES);
    loop {
        let (Some(xq), Some(x0), Some(x1), Some(x2), Some(x3)) =
            (cq.next(), c0.next(), c1.next(), c2.next(), c3.next())
        else {
            break;
        };
        let mut s = [0i32; MR];
        for j in 0..LANES {
            let qv = xq[j] as i32;
            s[0] += qv * x0[j] as i32;
            s[1] += qv * x1[j] as i32;
            s[2] += qv * x2[j] as i32;
            s[3] += qv * x3[j] as i32;
        }
        for (a, sv) in acc.iter_mut().zip(s) {
            *a += sv;
        }
    }
    let rq = cq.remainder();
    let tails = [
        c0.remainder(),
        c1.remainder(),
        c2.remainder(),
        c3.remainder(),
    ];
    for (a, tail) in acc.iter_mut().zip(tails) {
        for (&qv, &kv) in rq.iter().zip(tail) {
            *a += qv as i32 * kv as i32;
        }
    }
    acc
}

/// QK^T over one whole key block: `k` holds `k.len() / d` contiguous
/// rows of width `d`; writes `out[r] = q · k_row[r]` for every row.
/// Rows are processed [`MR`] at a time via [`idot_mr`] with a chunked
/// single-row tail, so ragged block lengths (the last cache block) cost
/// only the remainder rows.
#[inline]
pub fn qk_dot_block(q: &[i8], k: &[i8], d: usize, out: &mut [i32]) {
    assert!(d > 0, "head dim must be positive");
    debug_assert_eq!(k.len() % d, 0);
    let rows = k.len() / d;
    assert!(out.len() >= rows, "out must hold one score per key row");
    let mut r = 0usize;
    while r + MR <= rows {
        let scores = idot_mr(q, &k[r * d..(r + MR) * d]);
        out[r..r + MR].copy_from_slice(&scores);
        r += MR;
    }
    for rr in r..rows {
        out[rr] = idot_1(q, &k[rr * d..(rr + 1) * d]);
    }
}

/// P·V accumulation for one block, exact in `i32`:
/// `acc[j] = Σ_c p8[c] * v8[c * d + j]` over all `p8.len()` rows of `v8`.
///
/// `acc` is overwritten (per-block accumulator — the caller folds it
/// into the running f32 output with a **single** `p_scale * v_scale`
/// multiply per element). Zero probability codes skip their row — SAS
/// sparsity makes whole rows zero below the `n_r` threshold, and a
/// skipped row adds exactly 0, so the short-circuit cannot change the
/// (exact) sum.
///
/// Panics if the row count exceeds [`ACC_MAX_ROWS`] — beyond that the
/// `i32` no-overflow proof (`rows * 128 * 128 <= i32::MAX`) stops
/// holding. Every caller in this crate passes `bc <= 1024` rows.
#[inline]
pub fn ipv_acc(p8: &[i8], v8: &[i8], d: usize, acc: &mut [i32]) {
    assert!(d > 0, "head dim must be positive");
    let rows = p8.len();
    assert!(
        rows <= ACC_MAX_ROWS,
        "{rows} rows can overflow an i32 accumulator (max {ACC_MAX_ROWS})"
    );
    assert!(v8.len() >= rows * d, "v8 must hold one row per p code");
    assert!(acc.len() >= d, "acc must hold d lanes");
    let acc = &mut acc[..d];
    acc.fill(0);
    for (c, &pc) in p8.iter().enumerate() {
        if pc == 0 {
            continue;
        }
        let w = pc as i32;
        let v_row = &v8[c * d..(c + 1) * d];
        for (a, &vv) in acc.iter_mut().zip(v_row) {
            *a += w * vv as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // tensor::idot stays the scalar oracle here

    use super::*;
    use crate::tensor::idot;
    use crate::testutil::prop;

    fn gen_codes(g: &mut prop::Gen, n: usize) -> Vec<i8> {
        (0..n)
            .map(|_| {
                // Bias toward the edge values the contract calls out.
                match g.usize_in(0, 8) {
                    0 => 127,
                    1 => -127,
                    2 => -128,
                    _ => (g.usize_in(0, 255) as i32 - 127) as i8,
                }
            })
            .collect()
    }

    #[test]
    fn idot_mr_matches_scalar_reference() {
        prop::run("idot_mr == idot x4", 60, |g| {
            // Ragged widths around the chunk size, incl. d < LANES.
            let d = g.usize_in(1, 3 * LANES + 3);
            let q = gen_codes(g, d);
            let k4 = gen_codes(g, MR * d);
            let got = idot_mr(&q, &k4);
            for (r, &s) in got.iter().enumerate() {
                let want = idot(&q, &k4[r * d..(r + 1) * d]);
                assert_eq!(s, want, "row {r} (d={d})");
            }
        });
    }

    #[test]
    fn idot_mr_exact_at_i8_extremes() {
        // 4 rows of -128 against a query of -128: products are +16384,
        // summed exactly (this is the worst case of the overflow proof).
        let d = 64;
        let q = vec![-128i8; d];
        let k4 = vec![-128i8; MR * d];
        for s in idot_mr(&q, &k4) {
            assert_eq!(s, (d as i32) * 16384);
        }
        let k4 = vec![127i8; MR * d];
        for s in idot_mr(&q, &k4) {
            assert_eq!(s, (d as i32) * (-128 * 127));
        }
    }

    #[test]
    fn qk_dot_block_covers_ragged_row_counts() {
        prop::run("qk_dot_block == idot rows", 60, |g| {
            let d = g.usize_in(1, 40);
            // 0..=11 rows: exercises 0, sub-MR, exact-MR and ragged tails.
            let rows = g.usize_in(0, 12);
            let q = gen_codes(g, d);
            let k = gen_codes(g, rows * d);
            let mut out = vec![0i32; rows + 2];
            out.fill(7); // poison: untouched slots must stay put
            qk_dot_block(&q, &k, d, &mut out);
            for r in 0..rows {
                assert_eq!(out[r], idot(&q, &k[r * d..(r + 1) * d]), "row {r}");
            }
            assert_eq!(&out[rows..], &[7, 7], "no write past the rows");
        });
    }

    #[test]
    fn ipv_acc_matches_widening_reference() {
        prop::run("ipv_acc == scalar sum", 60, |g| {
            let d = g.usize_in(1, 40);
            let rows = g.usize_in(0, 12);
            let p8 = gen_codes(g, rows);
            let v8 = gen_codes(g, rows * d);
            let mut acc = vec![-1i32; d];
            ipv_acc(&p8, &v8, d, &mut acc);
            for (j, &a) in acc.iter().enumerate() {
                let want: i32 = (0..rows)
                    .map(|c| p8[c] as i32 * v8[c * d + j] as i32)
                    .sum();
                assert_eq!(a, want, "lane {j}");
            }
        });
    }

    #[test]
    fn ipv_acc_overwrites_stale_accumulator() {
        let mut acc = vec![i32::MAX; 3];
        ipv_acc(&[], &[], 3, &mut acc);
        assert_eq!(acc, vec![0, 0, 0]);
    }

    #[test]
    fn ipv_acc_exact_at_the_overflow_bound() {
        // ACC_MAX_ROWS worst-case products must sum without wrap.
        let rows = ACC_MAX_ROWS;
        let p8 = vec![-128i8; rows];
        let v8 = vec![-128i8; rows];
        let mut acc = vec![0i32; 1];
        ipv_acc(&p8, &v8, 1, &mut acc);
        assert_eq!(acc[0] as i64, rows as i64 * 16384);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn ipv_acc_rejects_rows_beyond_the_proof() {
        let rows = ACC_MAX_ROWS + 1;
        let p8 = vec![1i8; rows];
        let v8 = vec![1i8; rows];
        let mut acc = vec![0i32; 1];
        ipv_acc(&p8, &v8, 1, &mut acc);
    }

    #[test]
    fn constants_are_consistent() {
        assert_eq!(ACC_MAX_ROWS, 131071);
        // The paper block (64) and every block in this repo are far
        // below the proof bound.
        assert!(1024 < ACC_MAX_ROWS);
    }
}
