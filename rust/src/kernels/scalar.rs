//! Scalar reference kernels — the portable fallback arm of the dispatch
//! layer and the **oracle** every SIMD backend is property-tested
//! against (bit-for-bit, see the backend test modules).
//!
//! These are the original autovectorizer-friendly loops: fixed-width
//! chunked slices, independent accumulators, no per-index bounds checks.
//! They are correct on every target, and within the
//! [`ACC_MAX_ROWS`](super::ACC_MAX_ROWS) no-overflow contract the `i32`
//! accumulation is *exact*, so any backend that computes the same
//! products — in any order, with any lane grouping — must produce the
//! same bits. That is what makes "bit-identical to scalar" a testable
//! property rather than a tolerance.
//!
//! Input validation (shape asserts, the overflow panic) lives in the
//! public wrappers in [`super`]; the backends, this one included, may
//! assume validated shapes and only `debug_assert!` them.

use super::{ACC_MAX_ROWS, MR};
use crate::sas::SAS_POLY;

/// Lanes per inner-loop chunk — wide enough for one AVX2 register of
/// i16 products after widening, small enough that the ragged tail stays
/// cheap at the repo's head dims (16–64).
pub(crate) const LANES: usize = 16;

/// Elementary integer dot product — the simplest possible loop, kept as
/// the root oracle for everything else (the chunked kernels, the SIMD
/// backends, and the deprecated [`crate::tensor::idot`] shim all bottom
/// out here in tests).
#[inline]
pub fn idot(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&xa, &xb) in a.iter().zip(b) {
        acc += xa as i32 * xb as i32;
    }
    acc
}

/// Single-row chunked integer dot product (the `MR`-kernel's tail case).
///
/// Same result as [`idot`] — integer accumulation is exact, so chunking
/// cannot change the sum.
#[inline]
pub(crate) fn idot_1(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        let mut s = 0i32;
        for j in 0..LANES {
            s += xa[j] as i32 * xb[j] as i32;
        }
        acc += s;
    }
    for (&xa, &xb) in ca.remainder().iter().zip(cb.remainder()) {
        acc += xa as i32 * xb as i32;
    }
    acc
}

/// Multi-row QK^T micro-kernel, scalar arm: dot `q` against [`MR`] key
/// rows stored contiguously in `k4`, one independent `i32` accumulator
/// per row. One pass over `q` serves all four rows and the four
/// accumulators give the autovectorizer independent dependency chains.
#[inline]
pub fn idot_mr(q: &[i8], k4: &[i8]) -> [i32; MR] {
    let d = q.len();
    debug_assert_eq!(k4.len(), MR * d);
    debug_assert!(d <= ACC_MAX_ROWS);
    let (k0, rest) = k4.split_at(d);
    let (k1, rest) = rest.split_at(d);
    let (k2, k3) = rest.split_at(d);
    let mut acc = [0i32; MR];
    let mut cq = q.chunks_exact(LANES);
    let mut c0 = k0.chunks_exact(LANES);
    let mut c1 = k1.chunks_exact(LANES);
    let mut c2 = k2.chunks_exact(LANES);
    let mut c3 = k3.chunks_exact(LANES);
    loop {
        let (Some(xq), Some(x0), Some(x1), Some(x2), Some(x3)) =
            (cq.next(), c0.next(), c1.next(), c2.next(), c3.next())
        else {
            break;
        };
        let mut s = [0i32; MR];
        for j in 0..LANES {
            let qv = xq[j] as i32;
            s[0] += qv * x0[j] as i32;
            s[1] += qv * x1[j] as i32;
            s[2] += qv * x2[j] as i32;
            s[3] += qv * x3[j] as i32;
        }
        for (a, sv) in acc.iter_mut().zip(s) {
            *a += sv;
        }
    }
    let rq = cq.remainder();
    let tails = [
        c0.remainder(),
        c1.remainder(),
        c2.remainder(),
        c3.remainder(),
    ];
    for (a, tail) in acc.iter_mut().zip(tails) {
        for (&qv, &kv) in rq.iter().zip(tail) {
            *a += qv as i32 * kv as i32;
        }
    }
    acc
}

/// QK^T over one whole key block, scalar arm: rows [`MR`] at a time via
/// [`idot_mr`] with a chunked single-row tail.
#[inline]
pub fn qk_dot_block(q: &[i8], k: &[i8], d: usize, out: &mut [i32]) {
    debug_assert!(d > 0);
    let rows = k.len() / d;
    debug_assert!(out.len() >= rows);
    let mut r = 0usize;
    while r + MR <= rows {
        let scores = idot_mr(q, &k[r * d..(r + MR) * d]);
        out[r..r + MR].copy_from_slice(&scores);
        r += MR;
    }
    for rr in r..rows {
        out[rr] = idot_1(q, &k[rr * d..(rr + 1) * d]);
    }
}

/// Envelope upper-bound page score, scalar arm (the oracle): each
/// channel contributes the larger of `q * kmax` and `q * kmin`, picked
/// by the sign of the query code (`q >= 0` pairs with the max end,
/// `q < 0` with the min end), summed in exact `i32`. Over a page whose
/// per-channel key codes all lie in `[kmin, kmax]`, the result bounds
/// every key row's dot product from above — the top-k selection signal
/// of the sparse decode path.
#[inline]
pub fn page_score(q: &[i8], kmin: &[i8], kmax: &[i8]) -> i32 {
    debug_assert_eq!(q.len(), kmin.len());
    debug_assert_eq!(q.len(), kmax.len());
    let mut acc = 0i32;
    for ((&qc, &lo), &hi) in q.iter().zip(kmin).zip(kmax) {
        let k = if qc >= 0 { hi } else { lo };
        acc += qc as i32 * k as i32;
    }
    acc
}

/// P·V accumulation for one block, scalar arm, exact in `i32`:
/// `acc[j] = Σ_c p8[c] * v8[c * d + j]`. `acc[..d]` is overwritten.
/// Zero probability codes skip their row — SAS sparsity makes whole
/// rows zero, and a skipped row adds exactly 0 to an exact sum.
#[inline]
pub fn ipv_acc(p8: &[i8], v8: &[i8], d: usize, acc: &mut [i32]) {
    let rows = p8.len();
    debug_assert!(d > 0);
    debug_assert!(rows <= ACC_MAX_ROWS);
    debug_assert!(v8.len() >= rows * d);
    let acc = &mut acc[..d];
    acc.fill(0);
    for (c, &pc) in p8.iter().enumerate() {
        if pc == 0 {
            continue;
        }
        let w = pc as i32;
        let v_row = &v8[c * d..(c + 1) * d];
        for (a, &vv) in acc.iter_mut().zip(v_row) {
            *a += w * vv as i32;
        }
    }
}

/// Batched SAS shift-exp-and-sum, scalar arm (see
/// [`crate::sas::Sas::exp_block`] for the caller-facing contract).
///
/// `lut` holds `depth + 2` entries (`e^-i` for `0..=depth`, then `0.0`);
/// `n_r` is the sparsity threshold. Branch-free: threshold → 0/1 mask,
/// LUT index clamped, straight-line clamp + gather + Horner cubic. The
/// SIMD arms replicate this exact f32 op sequence per element and sum
/// the written row in slice order, which is why they stay bit-identical
/// (f32 ops here are neither reassociated nor fused).
#[inline]
pub fn sas_exp_block(lut: &[f32], depth: usize, n_r: f32, row: &mut [f32], m: f32) -> f32 {
    debug_assert_eq!(lut.len(), depth + 2);
    let [c3, c2, c1, c0] = SAS_POLY;
    let cap = (depth + 1) as f32;
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        let xx = *x - m;
        // 1.0 when x is above the sparsity threshold, else 0.0.
        let live = (xx >= n_r) as u32 as f32;
        // Clamp keeps the LUT index in range for dead lanes; live lanes
        // satisfy -xx <= -n_r < depth + 1, so the min is a no-op there
        // and t/ti/td match the per-element scalar path exactly.
        let t = (-xx).min(cap);
        let ti = t as i32; // t >= 0: trunc == floor
        let td = t - ti as f32;
        let idx = (ti as usize).min(depth + 1);
        let poly = ((c3 * td + c2) * td + c1) * td + c0;
        let v = (live * lut[idx]) * poly;
        *x = v;
        sum += v;
    }
    sum
}
