//! AVX2 kernel arm (x86-64).
//!
//! Integer kernels widen i8 lanes to i16 (`vpmovsxbw`) and multiply-add
//! pairs with `vpmaddwd` — exact for every i8 input including `-128`
//! (two i16 products of magnitude ≤ 16384 sum to ≤ 32768, well inside
//! i32), unlike the `vpmaddubsw` shortcut which saturates. Because i32
//! accumulation under the [`ACC_MAX_ROWS`](super::ACC_MAX_ROWS)
//! contract is exact, the lane regrouping here cannot change a bit of
//! any result — the scalar-oracle property tests below assert exactly
//! that.
//!
//! The SAS evaluator performs the *same f32 operation sequence* as
//! [`super::scalar::sas_exp_block`] per element — separate mul/add (no
//! FMA contraction, matching rustc's default), sign-bit negation,
//! `vcmpps(GE_OQ)` for the `>=` mask, `vminps` whose NaN semantics
//! coincide with `f32::min` when the second operand (the cap) is never
//! NaN, truncating `vcvttps2dq`, and an unsigned-min index clamp that
//! reproduces the `(ti as usize).min(depth + 1)` wraparound for
//! negative `ti` — then folds the written row in slice order, which is
//! the scalar evaluator's exact summation order. Bit-identical, so the
//! sas bitwise test holds under dispatch.
//!
//! Every `unsafe fn` here requires AVX2 (`#[target_feature]`): the
//! dispatch layer only routes here after `is_x86_feature_detected!`.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

use super::MR;
use crate::sas::SAS_POLY;

/// Widen 16 i8 lanes from `a`/`b` to i16 and fold their products into
/// eight i32 accumulator lanes (exact: `vpmaddwd` adds i16-product
/// pairs, bounded by 2 * 16384).
///
/// # Safety
/// Requires AVX2; `a` and `b` must be readable for 16 bytes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn dot16(acc: __m256i, a: *const i8, b: *const i8) -> __m256i {
    let wa = _mm256_cvtepi8_epi16(_mm_loadu_si128(a as *const __m128i));
    let wb = _mm256_cvtepi8_epi16(_mm_loadu_si128(b as *const __m128i));
    _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb))
}

/// Sum the eight i32 lanes of `v` (exact integer adds).
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let s = _mm_add_epi32(
        _mm256_castsi256_si128(v),
        _mm256_extracti128_si256::<1>(v),
    );
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s));
    _mm_cvtsi128_si32(s)
}

/// Single-row integer dot product, AVX2 arm.
///
/// # Safety
/// Requires AVX2; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn idot_1(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let d = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= d {
        acc = dot16(acc, a.as_ptr().add(i), b.as_ptr().add(i));
        i += 16;
    }
    let mut s = hsum_epi32(acc);
    while i < d {
        s += *a.get_unchecked(i) as i32 * *b.get_unchecked(i) as i32;
        i += 1;
    }
    s
}

/// Multi-row QK^T micro-kernel, AVX2 arm: the widened query chunk is
/// loaded once per 16-lane step and reused across all [`MR`] key rows.
///
/// # Safety
/// Requires AVX2; `k4.len() == MR * q.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn idot_mr(q: &[i8], k4: &[i8]) -> [i32; MR] {
    let d = q.len();
    debug_assert_eq!(k4.len(), MR * d);
    let mut acc = [_mm256_setzero_si256(); MR];
    let qp = q.as_ptr();
    let kp = k4.as_ptr();
    let mut i = 0usize;
    while i + 16 <= d {
        let wq = _mm256_cvtepi8_epi16(_mm_loadu_si128(qp.add(i) as *const __m128i));
        for (r, a) in acc.iter_mut().enumerate() {
            let wk = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                kp.add(r * d + i) as *const __m128i,
            ));
            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(wq, wk));
        }
        i += 16;
    }
    let mut out = [0i32; MR];
    for (r, o) in out.iter_mut().enumerate() {
        let mut s = hsum_epi32(acc[r]);
        for j in i..d {
            s += *q.get_unchecked(j) as i32 * *k4.get_unchecked(r * d + j) as i32;
        }
        *o = s;
    }
    out
}

/// QK^T over one whole key block, AVX2 arm.
///
/// # Safety
/// Requires AVX2; shapes validated by the public wrapper
/// (`k.len() % d == 0`, `out.len() >= k.len() / d`, `d > 0`).
#[target_feature(enable = "avx2")]
pub unsafe fn qk_dot_block(q: &[i8], k: &[i8], d: usize, out: &mut [i32]) {
    let rows = k.len() / d;
    debug_assert!(out.len() >= rows);
    let mut r = 0usize;
    while r + MR <= rows {
        let scores = idot_mr(q, &k[r * d..(r + MR) * d]);
        out[r..r + MR].copy_from_slice(&scores);
        r += MR;
    }
    for rr in r..rows {
        out[rr] = idot_1(q, &k[rr * d..(rr + 1) * d]);
    }
}

/// Envelope upper-bound page score, AVX2 arm: a byte-sign mask on the
/// query codes (`pcmpgtb` against zero) blends the matching envelope
/// end per channel (`pblendvb`: `q < 0` takes `kmin`, else `kmax`),
/// then the selected bytes run the exact widen-and-`vpmaddwd` dot chain
/// — the arithmetic is the scalar arm's product set regrouped into
/// lanes, so the i32 result is bit-identical.
///
/// # Safety
/// Requires AVX2; `q.len() == kmin.len() == kmax.len()` (validated by
/// the public wrapper).
#[target_feature(enable = "avx2")]
pub unsafe fn page_score(q: &[i8], kmin: &[i8], kmax: &[i8]) -> i32 {
    debug_assert_eq!(q.len(), kmin.len());
    debug_assert_eq!(q.len(), kmax.len());
    let d = q.len();
    let mut acc = _mm256_setzero_si256();
    let zero = _mm_setzero_si128();
    let mut i = 0usize;
    while i + 16 <= d {
        let qv = _mm_loadu_si128(q.as_ptr().add(i) as *const __m128i);
        let lo = _mm_loadu_si128(kmin.as_ptr().add(i) as *const __m128i);
        let hi = _mm_loadu_si128(kmax.as_ptr().add(i) as *const __m128i);
        // 0xFF where q < 0: those channels take the kmin end.
        let neg = _mm_cmpgt_epi8(zero, qv);
        let sel = _mm_blendv_epi8(hi, lo, neg);
        let wq = _mm256_cvtepi8_epi16(qv);
        let wk = _mm256_cvtepi8_epi16(sel);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wq, wk));
        i += 16;
    }
    let mut s = hsum_epi32(acc);
    while i < d {
        let qc = *q.get_unchecked(i) as i32;
        let k = if qc >= 0 {
            *kmax.get_unchecked(i)
        } else {
            *kmin.get_unchecked(i)
        };
        s += qc * k as i32;
        i += 1;
    }
    s
}

/// P·V accumulation, AVX2 arm: broadcast the probability code, multiply
/// 16 value lanes in i16 (exact — |p·v| ≤ 16384 fits i16), widen to i32
/// and add into the accumulator. Keeps the scalar arm's `pc == 0` row
/// skip (SAS sparsity), which cannot change an exact sum.
///
/// # Safety
/// Requires AVX2; shapes validated by the public wrapper
/// (`rows <= ACC_MAX_ROWS`, `v8.len() >= rows * d`, `acc.len() >= d`).
#[target_feature(enable = "avx2")]
pub unsafe fn ipv_acc(p8: &[i8], v8: &[i8], d: usize, acc: &mut [i32]) {
    let acc = &mut acc[..d];
    acc.fill(0);
    let ap = acc.as_mut_ptr();
    for (c, &pc) in p8.iter().enumerate() {
        if pc == 0 {
            continue;
        }
        let w16 = _mm256_set1_epi16(pc as i16);
        let w = pc as i32;
        let vp = v8.as_ptr().add(c * d);
        let mut j = 0usize;
        while j + 16 <= d {
            let v16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(vp.add(j) as *const __m128i));
            let prod = _mm256_mullo_epi16(w16, v16);
            let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
            let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
            let a0 = _mm256_loadu_si256(ap.add(j) as *const __m256i);
            let a1 = _mm256_loadu_si256(ap.add(j + 8) as *const __m256i);
            _mm256_storeu_si256(ap.add(j) as *mut __m256i, _mm256_add_epi32(a0, lo));
            _mm256_storeu_si256(ap.add(j + 8) as *mut __m256i, _mm256_add_epi32(a1, hi));
            j += 16;
        }
        while j < d {
            *acc.get_unchecked_mut(j) += w * *vp.add(j) as i32;
            j += 1;
        }
    }
}

/// Batched SAS shift-exp-and-sum, AVX2 arm — eight f32 lanes through
/// the scalar arm's exact op sequence (see module docs for the
/// bit-exactness argument), scalar tail for `d % 8`, then one in-order
/// fold over the written row (the scalar evaluator's summation order).
///
/// # Safety
/// Requires AVX2; `lut.len() == depth + 2`.
#[target_feature(enable = "avx2")]
pub unsafe fn sas_exp_block(
    lut: &[f32],
    depth: usize,
    n_r: f32,
    row: &mut [f32],
    m: f32,
) -> f32 {
    debug_assert_eq!(lut.len(), depth + 2);
    let [c3, c2, c1, c0] = SAS_POLY;
    let cap = (depth + 1) as f32;
    let n = row.len();
    let rp = row.as_mut_ptr();
    let vm = _mm256_set1_ps(m);
    let vnr = _mm256_set1_ps(n_r);
    let vcap = _mm256_set1_ps(cap);
    let vone = _mm256_set1_ps(1.0);
    let vsign = _mm256_set1_ps(-0.0);
    let vidx_cap = _mm256_set1_epi32((depth + 1) as i32);
    let (vc3, vc2, vc1, vc0) = (
        _mm256_set1_ps(c3),
        _mm256_set1_ps(c2),
        _mm256_set1_ps(c1),
        _mm256_set1_ps(c0),
    );
    let mut i = 0usize;
    while i + 8 <= n {
        let xx = _mm256_sub_ps(_mm256_loadu_ps(rp.add(i)), vm);
        // (xx >= n_r) as f32: ordered-quiet GE is false on NaN, exactly
        // like the scalar `>=`.
        let live = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(xx, vnr), vone);
        // (-xx).min(cap): minps returns the second operand on NaN,
        // matching f32::min with a never-NaN cap.
        let t = _mm256_min_ps(_mm256_xor_ps(xx, vsign), vcap);
        // `t as i32`: cvttps2dq truncates toward zero; t <= cap rules
        // out positive overflow, and negative overflow saturates to
        // i32::MIN on both paths.
        let ti = _mm256_cvttps_epi32(t);
        let td = _mm256_sub_ps(t, _mm256_cvtepi32_ps(ti));
        // (ti as usize).min(depth + 1): negative ti reinterprets as a
        // huge unsigned value, so an *unsigned* min clamps it to the
        // zero LUT slot exactly like the scalar usize cast.
        let idx = _mm256_min_epu32(ti, vidx_cap);
        let lv = _mm256_i32gather_ps::<4>(lut.as_ptr(), idx);
        // Horner with separate mul/add — rustc does not contract to FMA
        // on the scalar path, so neither do we.
        let mut p = _mm256_add_ps(_mm256_mul_ps(vc3, td), vc2);
        p = _mm256_add_ps(_mm256_mul_ps(p, td), vc1);
        p = _mm256_add_ps(_mm256_mul_ps(p, td), vc0);
        let v = _mm256_mul_ps(_mm256_mul_ps(live, lv), p);
        _mm256_storeu_ps(rp.add(i), v);
        i += 8;
    }
    // Scalar tail: the literal scalar-arm body.
    for x in row[i..].iter_mut() {
        let xx = *x - m;
        let live = (xx >= n_r) as u32 as f32;
        let t = (-xx).min(cap);
        let ti = t as i32;
        let td = t - ti as f32;
        let idx = (ti as usize).min(depth + 1);
        let poly = ((c3 * td + c2) * td + c1) * td + c0;
        *x = (live * lut[idx]) * poly;
    }
    // In-order fold == the scalar evaluator's interleaved running sum.
    let mut sum = 0.0f32;
    for &v in row.iter() {
        sum += v;
    }
    sum
}

#[cfg(test)]
mod tests {
    //! Bitwise scalar-oracle parity for the AVX2 arm, run only when the
    //! host actually has AVX2 (always true on the repo's CI runners).

    use super::*;
    use crate::kernels::scalar;
    use crate::sas::Sas;
    use crate::testutil::prop;

    fn avx2() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    fn gen_codes(g: &mut prop::Gen, n: usize) -> Vec<i8> {
        (0..n)
            .map(|_| match g.usize_in(0, 8) {
                0 => 127,
                1 => -127,
                2 => -128,
                _ => (g.usize_in(0, 255) as i32 - 127) as i8,
            })
            .collect()
    }

    #[test]
    fn idot_mr_bit_identical_to_scalar() {
        if !avx2() {
            return;
        }
        prop::run("avx2 idot_mr == scalar", 80, |g| {
            // Ragged widths around the 16-lane step, incl. d < 16.
            let d = g.usize_in(1, 67);
            let q = gen_codes(g, d);
            let k4 = gen_codes(g, MR * d);
            let got = unsafe { idot_mr(&q, &k4) };
            assert_eq!(got, scalar::idot_mr(&q, &k4), "d={d}");
        });
    }

    #[test]
    fn idot_mr_exact_at_i8_extremes() {
        if !avx2() {
            return;
        }
        // -128 * -128 is the worst case of the no-overflow proof and the
        // reason maddubs-style tricks are banned.
        for d in [1, 15, 16, 17, 64] {
            let q = vec![-128i8; d];
            for fill in [-128i8, 127] {
                let k4 = vec![fill; MR * d];
                let got = unsafe { idot_mr(&q, &k4) };
                assert_eq!(got, scalar::idot_mr(&q, &k4), "d={d} fill={fill}");
            }
        }
    }

    #[test]
    fn qk_dot_block_bit_identical_to_scalar() {
        if !avx2() {
            return;
        }
        prop::run("avx2 qk_dot_block == scalar", 60, |g| {
            let d = g.usize_in(1, 50);
            let rows = g.usize_in(0, 12);
            let q = gen_codes(g, d);
            let k = gen_codes(g, rows * d);
            let mut a = vec![7i32; rows + 2];
            let mut b = a.clone();
            unsafe { qk_dot_block(&q, &k, d, &mut a) };
            scalar::qk_dot_block(&q, &k, d, &mut b);
            assert_eq!(a, b, "d={d} rows={rows}");
        });
    }

    #[test]
    fn ipv_acc_bit_identical_to_scalar() {
        if !avx2() {
            return;
        }
        prop::run("avx2 ipv_acc == scalar", 80, |g| {
            let d = g.usize_in(1, 67);
            let rows = g.usize_in(0, 12);
            let mut p8 = gen_codes(g, rows);
            if !p8.is_empty() {
                p8[g.usize_in(0, rows)] = 0; // exercise the zero-row skip
            }
            let v8 = gen_codes(g, rows * d);
            let mut a = vec![-1i32; d];
            let mut b = vec![i32::MAX; d]; // both arms must overwrite stale state
            unsafe { ipv_acc(&p8, &v8, d, &mut a) };
            scalar::ipv_acc(&p8, &v8, d, &mut b);
            assert_eq!(a, b, "d={d} rows={rows}");
        });
    }

    #[test]
    fn page_score_bit_identical_to_scalar() {
        if !avx2() {
            return;
        }
        prop::run("avx2 page_score == scalar", 80, |g| {
            // Ragged widths around the 16-lane step, incl. d < 16.
            let d = g.usize_in(1, 67);
            let q = gen_codes(g, d);
            let a = gen_codes(g, d);
            let b = gen_codes(g, d);
            // Envelope ends: per-channel (min, max) of two random rows.
            let kmin: Vec<i8> =
                a.iter().zip(&b).map(|(&x, &y)| x.min(y)).collect();
            let kmax: Vec<i8> =
                a.iter().zip(&b).map(|(&x, &y)| x.max(y)).collect();
            let got = unsafe { page_score(&q, &kmin, &kmax) };
            assert_eq!(got, scalar::page_score(&q, &kmin, &kmax), "d={d}");
        });
    }

    #[test]
    fn sas_exp_block_bit_identical_to_scalar() {
        if !avx2() {
            return;
        }
        prop::run("avx2 sas_exp_block == scalar", 80, |g| {
            let sas = if g.bool() { Sas::default() } else { Sas::new(-3.5) };
            let (lut, depth, n_r) = sas.tables();
            // 0..=19: covers empty rows, pure-tail rows (< 8) and
            // ragged vector+tail mixes.
            let n = g.usize_in(0, 20);
            let m = g.f32_in(-2.0, 8.0);
            let row: Vec<f32> = (0..n)
                .map(|_| match g.usize_in(0, 5) {
                    0 => m + n_r,            // exactly at the threshold
                    1 => m + n_r - 1e-3,     // just below: must be zero
                    2 => m - 20.0,           // deep in the sparse region
                    _ => m + g.f32_in(n_r, 0.0),
                })
                .collect();
            let mut a = row.clone();
            let mut b = row;
            let sa = unsafe { sas_exp_block(lut, depth, n_r, &mut a, m) };
            let sb = scalar::sas_exp_block(lut, depth, n_r, &mut b, m);
            assert_eq!(sa.to_bits(), sb.to_bits(), "sum (n={n})");
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "elem {i} (n={n})");
            }
        });
    }
}
