//! Runtime kernel-backend selection.
//!
//! The backend is picked **once** per process (a [`OnceLock`]), in this
//! priority order:
//!
//! 1. [`force_kernel_backend`] — the `--kernel-backend` CLI flag, which
//!    `main` applies before any kernel runs, so it wins over the env.
//! 2. The `TURBO_KERNEL` env var (`scalar` | `avx2` | `neon` | `auto`).
//! 3. Auto-detection: AVX2 via `is_x86_feature_detected!` on x86_64,
//!    NEON unconditionally on aarch64 (baseline ISA there), scalar
//!    everywhere else.
//!
//! Requesting an ISA the host cannot run is an error, and an invalid
//! `TURBO_KERNEL` value panics on first kernel use — CLI-boundary
//! fail-fast, same policy as the arg parser. There is deliberately no
//! way to change the backend after first use: a mid-run switch would
//! let two decode steps of one request run different code paths, which
//! the determinism contract (thread-count-invariant, bit-exact decode)
//! is not allowed to depend on. It never *breaks* it — every backend is
//! bit-identical — but a single sticky choice keeps "which ISA produced
//! this number" a per-process fact that [`crate::metrics`] can report.

use std::sync::OnceLock;

/// The kernel ISA actually dispatched to. All variants exist on every
/// target so that match arms and string parsing stay portable; whether
/// a variant is *runnable* on this host is [`KernelBackend::supported`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable Rust loops — fallback arm and property-test oracle.
    Scalar,
    /// x86-64 AVX2: `pmaddwd` i8→i32 dot chains, 8-lane f32 SAS.
    Avx2,
    /// aarch64 NEON: `smull`/`sadalp` dot chains, 4-lane f32 SAS.
    Neon,
}

impl KernelBackend {
    /// Stable lowercase name — the `TURBO_KERNEL` / `--kernel-backend`
    /// vocabulary, and what `STATS` / bench JSON report.
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }

    /// Can this host actually execute the backend?
    pub fn supported(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            KernelBackend::Neon => true, // NEON is baseline on aarch64
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

/// Best backend the host supports (priority 3 above).
fn detect_best() -> KernelBackend {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return KernelBackend::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    return KernelBackend::Neon;
    #[allow(unreachable_code)]
    KernelBackend::Scalar
}

/// Pure selection logic (testable without touching process state):
/// `None` / `""` / `"auto"` auto-detect; a named backend must be
/// supported by this host or the request is an error.
pub fn select(requested: Option<&str>) -> Result<KernelBackend, String> {
    let want = match requested.map(str::trim) {
        None | Some("") | Some("auto") => return Ok(detect_best()),
        Some("scalar") => KernelBackend::Scalar,
        Some("avx2") => KernelBackend::Avx2,
        Some("neon") => KernelBackend::Neon,
        Some(other) => {
            return Err(format!(
                "unknown kernel backend {other:?} (expected scalar|avx2|neon|auto)"
            ))
        }
    };
    if !want.supported() {
        return Err(format!(
            "kernel backend {:?} is not supported on this host",
            want.name()
        ));
    }
    Ok(want)
}

static BACKEND: OnceLock<KernelBackend> = OnceLock::new();

/// The process-wide backend, resolving `TURBO_KERNEL` on first use.
/// Panics (fail-fast) if the env names an unknown or unsupported
/// backend — better a loud startup error than silently benchmarking the
/// wrong ISA.
#[inline]
pub fn kernel_backend() -> KernelBackend {
    *BACKEND.get_or_init(|| {
        let env = std::env::var("TURBO_KERNEL").ok();
        select(env.as_deref())
            .unwrap_or_else(|e| panic!("TURBO_KERNEL: {e}"))
    })
}

/// Force the backend (the `--kernel-backend` CLI path). Must run before
/// any kernel executes; errs if the name is invalid, the host cannot
/// run it, or a different backend was already pinned.
pub fn force_kernel_backend(name: &str) -> Result<KernelBackend, String> {
    let want = select(Some(name))?;
    let got = *BACKEND.get_or_init(|| want);
    if got != want {
        return Err(format!(
            "kernel backend already pinned to {:?}; cannot switch to {:?}",
            got.name(),
            want.name()
        ));
    }
    Ok(got)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_stable() {
        assert_eq!(KernelBackend::Scalar.name(), "scalar");
        assert_eq!(KernelBackend::Avx2.name(), "avx2");
        assert_eq!(KernelBackend::Neon.name(), "neon");
    }

    #[test]
    fn select_scalar_always_works() {
        assert_eq!(select(Some("scalar")), Ok(KernelBackend::Scalar));
        assert_eq!(select(Some("  scalar ")), Ok(KernelBackend::Scalar));
    }

    #[test]
    fn select_auto_detects_a_supported_backend() {
        for req in [None, Some(""), Some("auto")] {
            let got = select(req).expect("auto must always resolve");
            assert!(got.supported(), "{:?} not runnable here", got.name());
        }
    }

    #[test]
    fn select_rejects_unknown_names() {
        let err = select(Some("sse9")).unwrap_err();
        assert!(err.contains("unknown kernel backend"), "{err}");
    }

    #[test]
    fn select_rejects_unsupported_isa() {
        // At most one of avx2/neon is runnable on any host; the other
        // must be refused rather than dispatched to an illegal path.
        for name in ["avx2", "neon"] {
            let want = select(Some(name));
            match want {
                Ok(b) => assert!(b.supported()),
                Err(e) => assert!(e.contains("not supported"), "{e}"),
            }
        }
        assert!(
            select(Some("avx2")).is_err() || select(Some("neon")).is_err(),
            "avx2 and neon cannot both be native"
        );
    }

    #[test]
    fn process_backend_is_sticky_and_supported() {
        let b = kernel_backend();
        assert!(b.supported());
        assert_eq!(kernel_backend(), b, "must not change between calls");
        // Re-forcing the same backend is fine; a different one errs.
        assert_eq!(force_kernel_backend(b.name()), Ok(b));
        let other = if b == KernelBackend::Scalar { "avx2" } else { "scalar" };
        if select(Some(other)).is_ok() {
            assert!(force_kernel_backend(other).is_err());
        }
    }
}
