//! NEON kernel arm (aarch64).
//!
//! Integer kernels use the textbook exact i8 dot chain: `smull` widens
//! i8×i8 products to i16 (≤ 16384 in magnitude, never saturates) and
//! `sadalp` pairwise-accumulates them into i32 lanes — exact for every
//! input including `-128`, so under the
//! [`ACC_MAX_ROWS`](super::ACC_MAX_ROWS) contract the lane regrouping
//! cannot change a bit of any result.
//!
//! The SAS evaluator mirrors [`super::scalar::sas_exp_block`]'s f32 op
//! sequence per element: separate mul/add (no `vfmaq`/`vmlaq` — rustc
//! does not contract the scalar path to FMA), `vcgeq` for the `>=` mask
//! (false on NaN), **`vminnmq`** for the cap clamp (FMINNM returns the
//! non-NaN operand, matching `f32::min`; plain FMIN would propagate
//! NaN), saturating-truncating `fcvtzs` (same saturation as Rust's
//! `as i32`), and an unsigned-min index clamp reproducing the
//! `(ti as usize).min(depth + 1)` wraparound for negative `ti`. The
//! LUT gather is 4 scalar loads through a spilled index vector — NEON
//! has no gather. The written row is folded in slice order afterwards,
//! which is the scalar evaluator's exact summation order.
//!
//! NEON is baseline on aarch64, so these fns are safe to call on any
//! aarch64 host; dispatch still routes through [`super::dispatch`] so
//! `TURBO_KERNEL=scalar` can force the oracle arm.

#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

use super::MR;
use crate::sas::SAS_POLY;

/// Fold 16 i8 lanes of products from `a`/`b` into four i32 accumulator
/// lanes (exact: smull → i16, sadalp pairwise into i32).
///
/// # Safety
/// `a` and `b` must be readable for 16 bytes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn dot16(acc: int32x4_t, a: *const i8, b: *const i8) -> int32x4_t {
    let va = vld1q_s8(a);
    let vb = vld1q_s8(b);
    let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
    let hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
    vpadalq_s16(vpadalq_s16(acc, lo), hi)
}

/// Single-row integer dot product, NEON arm.
///
/// # Safety
/// `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn idot_1(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let d = a.len();
    let mut acc = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 16 <= d {
        acc = dot16(acc, a.as_ptr().add(i), b.as_ptr().add(i));
        i += 16;
    }
    let mut s = vaddvq_s32(acc);
    while i < d {
        s += *a.get_unchecked(i) as i32 * *b.get_unchecked(i) as i32;
        i += 1;
    }
    s
}

/// Multi-row QK^T micro-kernel, NEON arm: the query vector is loaded
/// once per 16-lane step and reused across all [`MR`] key rows.
///
/// # Safety
/// `k4.len() == MR * q.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn idot_mr(q: &[i8], k4: &[i8]) -> [i32; MR] {
    let d = q.len();
    debug_assert_eq!(k4.len(), MR * d);
    let mut acc = [vdupq_n_s32(0); MR];
    let qp = q.as_ptr();
    let kp = k4.as_ptr();
    let mut i = 0usize;
    while i + 16 <= d {
        let vq = vld1q_s8(qp.add(i));
        let (ql, qh) = (vget_low_s8(vq), vget_high_s8(vq));
        for (r, a) in acc.iter_mut().enumerate() {
            let vk = vld1q_s8(kp.add(r * d + i));
            let lo = vmull_s8(ql, vget_low_s8(vk));
            let hi = vmull_s8(qh, vget_high_s8(vk));
            *a = vpadalq_s16(vpadalq_s16(*a, lo), hi);
        }
        i += 16;
    }
    let mut out = [0i32; MR];
    for (r, o) in out.iter_mut().enumerate() {
        let mut s = vaddvq_s32(acc[r]);
        for j in i..d {
            s += *q.get_unchecked(j) as i32 * *k4.get_unchecked(r * d + j) as i32;
        }
        *o = s;
    }
    out
}

/// QK^T over one whole key block, NEON arm.
///
/// # Safety
/// Shapes validated by the public wrapper (`k.len() % d == 0`,
/// `out.len() >= k.len() / d`, `d > 0`).
#[target_feature(enable = "neon")]
pub unsafe fn qk_dot_block(q: &[i8], k: &[i8], d: usize, out: &mut [i32]) {
    let rows = k.len() / d;
    debug_assert!(out.len() >= rows);
    let mut r = 0usize;
    while r + MR <= rows {
        let scores = idot_mr(q, &k[r * d..(r + MR) * d]);
        out[r..r + MR].copy_from_slice(&scores);
        r += MR;
    }
    for rr in r..rows {
        out[rr] = idot_1(q, &k[rr * d..(rr + 1) * d]);
    }
}

/// Envelope upper-bound page score, NEON arm: a byte-sign mask on the
/// query codes (`vcltq_s8` against zero) selects the matching envelope
/// end per channel (`vbslq_s8`: `q < 0` takes `kmin`, else `kmax`),
/// then the selected bytes run the exact `smull`/`sadalp` dot chain —
/// the scalar arm's product set regrouped into lanes, bit-identical in
/// i32.
///
/// # Safety
/// `q.len() == kmin.len() == kmax.len()` (validated by the public
/// wrapper).
#[target_feature(enable = "neon")]
pub unsafe fn page_score(q: &[i8], kmin: &[i8], kmax: &[i8]) -> i32 {
    debug_assert_eq!(q.len(), kmin.len());
    debug_assert_eq!(q.len(), kmax.len());
    let d = q.len();
    let zero = vdupq_n_s8(0);
    let mut acc = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 16 <= d {
        let qv = vld1q_s8(q.as_ptr().add(i));
        let lo = vld1q_s8(kmin.as_ptr().add(i));
        let hi = vld1q_s8(kmax.as_ptr().add(i));
        // All-ones where q < 0: those channels take the kmin end.
        let neg = vcltq_s8(qv, zero);
        let sel = vbslq_s8(neg, lo, hi);
        let plo = vmull_s8(vget_low_s8(qv), vget_low_s8(sel));
        let phi = vmull_s8(vget_high_s8(qv), vget_high_s8(sel));
        acc = vpadalq_s16(vpadalq_s16(acc, plo), phi);
        i += 16;
    }
    let mut s = vaddvq_s32(acc);
    while i < d {
        let qc = *q.get_unchecked(i) as i32;
        let k = if qc >= 0 {
            *kmax.get_unchecked(i)
        } else {
            *kmin.get_unchecked(i)
        };
        s += qc * k as i32;
        i += 1;
    }
    s
}

/// P·V accumulation, NEON arm: broadcast the probability code, `smull`
/// eight value lanes to exact i16 products, widen to i32 and add into
/// the accumulator. Keeps the `pc == 0` row skip (SAS sparsity).
///
/// # Safety
/// Shapes validated by the public wrapper (`rows <= ACC_MAX_ROWS`,
/// `v8.len() >= rows * d`, `acc.len() >= d`).
#[target_feature(enable = "neon")]
pub unsafe fn ipv_acc(p8: &[i8], v8: &[i8], d: usize, acc: &mut [i32]) {
    let acc = &mut acc[..d];
    acc.fill(0);
    let ap = acc.as_mut_ptr();
    for (c, &pc) in p8.iter().enumerate() {
        if pc == 0 {
            continue;
        }
        let w8 = vdup_n_s8(pc);
        let w = pc as i32;
        let vp = v8.as_ptr().add(c * d);
        let mut j = 0usize;
        while j + 8 <= d {
            let prod = vmull_s8(w8, vld1_s8(vp.add(j)));
            let lo = vmovl_s16(vget_low_s16(prod));
            let hi = vmovl_s16(vget_high_s16(prod));
            vst1q_s32(ap.add(j), vaddq_s32(vld1q_s32(ap.add(j)), lo));
            vst1q_s32(ap.add(j + 4), vaddq_s32(vld1q_s32(ap.add(j + 4)), hi));
            j += 8;
        }
        while j < d {
            *acc.get_unchecked_mut(j) += w * *vp.add(j) as i32;
            j += 1;
        }
    }
}

/// Batched SAS shift-exp-and-sum, NEON arm — four f32 lanes through the
/// scalar arm's exact op sequence (module docs carry the bit-exactness
/// argument), scalar tail for `n % 4`, then one in-order fold over the
/// written row.
///
/// # Safety
/// `lut.len() == depth + 2`.
#[target_feature(enable = "neon")]
pub unsafe fn sas_exp_block(
    lut: &[f32],
    depth: usize,
    n_r: f32,
    row: &mut [f32],
    m: f32,
) -> f32 {
    debug_assert_eq!(lut.len(), depth + 2);
    let [c3, c2, c1, c0] = SAS_POLY;
    let cap = (depth + 1) as f32;
    let n = row.len();
    let rp = row.as_mut_ptr();
    let vm = vdupq_n_f32(m);
    let vnr = vdupq_n_f32(n_r);
    let vcap = vdupq_n_f32(cap);
    let vone = vreinterpretq_u32_f32(vdupq_n_f32(1.0));
    let vidx_cap = vdupq_n_u32((depth + 1) as u32);
    let (vc3, vc2, vc1, vc0) = (
        vdupq_n_f32(c3),
        vdupq_n_f32(c2),
        vdupq_n_f32(c1),
        vdupq_n_f32(c0),
    );
    let mut i = 0usize;
    while i + 4 <= n {
        let xx = vsubq_f32(vld1q_f32(rp.add(i)), vm);
        // (xx >= n_r) as f32: vcgeq is false on NaN like the scalar >=.
        let live = vreinterpretq_f32_u32(vandq_u32(vcgeq_f32(xx, vnr), vone));
        // (-xx).min(cap): FMINNM returns the non-NaN operand, matching
        // f32::min with a never-NaN cap (plain FMIN would give NaN).
        let t = vminnmq_f32(vnegq_f32(xx), vcap);
        // `t as i32`: fcvtzs truncates toward zero and saturates on
        // overflow — identical to Rust's saturating cast.
        let ti = vcvtq_s32_f32(t);
        let td = vsubq_f32(t, vcvtq_f32_s32(ti));
        // (ti as usize).min(depth + 1): negative ti reinterprets as a
        // huge unsigned value, so an unsigned min clamps it to the zero
        // LUT slot exactly like the scalar usize cast.
        let idx = vminq_u32(vreinterpretq_u32_s32(ti), vidx_cap);
        // NEON has no gather: spill the indices and load 4 LUT entries.
        let mut ix = [0u32; 4];
        vst1q_u32(ix.as_mut_ptr(), idx);
        let gathered = [
            lut[ix[0] as usize],
            lut[ix[1] as usize],
            lut[ix[2] as usize],
            lut[ix[3] as usize],
        ];
        let lv = vld1q_f32(gathered.as_ptr());
        // Horner with separate mul/add — no FMA, matching the scalar arm.
        let mut p = vaddq_f32(vmulq_f32(vc3, td), vc2);
        p = vaddq_f32(vmulq_f32(p, td), vc1);
        p = vaddq_f32(vmulq_f32(p, td), vc0);
        let v = vmulq_f32(vmulq_f32(live, lv), p);
        vst1q_f32(rp.add(i), v);
        i += 4;
    }
    // Scalar tail: the literal scalar-arm body.
    for x in row[i..].iter_mut() {
        let xx = *x - m;
        let live = (xx >= n_r) as u32 as f32;
        let t = (-xx).min(cap);
        let ti = t as i32;
        let td = t - ti as f32;
        let idx = (ti as usize).min(depth + 1);
        let poly = ((c3 * td + c2) * td + c1) * td + c0;
        *x = (live * lut[idx]) * poly;
    }
    // In-order fold == the scalar evaluator's interleaved running sum.
    let mut sum = 0.0f32;
    for &v in row.iter() {
        sum += v;
    }
    sum
}

#[cfg(test)]
mod tests {
    //! Bitwise scalar-oracle parity for the NEON arm (NEON is baseline
    //! on aarch64, so no runtime guard is needed).

    use super::*;
    use crate::kernels::scalar;
    use crate::sas::Sas;
    use crate::testutil::prop;

    fn gen_codes(g: &mut prop::Gen, n: usize) -> Vec<i8> {
        (0..n)
            .map(|_| match g.usize_in(0, 8) {
                0 => 127,
                1 => -127,
                2 => -128,
                _ => (g.usize_in(0, 255) as i32 - 127) as i8,
            })
            .collect()
    }

    #[test]
    fn idot_mr_bit_identical_to_scalar() {
        prop::run("neon idot_mr == scalar", 80, |g| {
            let d = g.usize_in(1, 67);
            let q = gen_codes(g, d);
            let k4 = gen_codes(g, MR * d);
            let got = unsafe { idot_mr(&q, &k4) };
            assert_eq!(got, scalar::idot_mr(&q, &k4), "d={d}");
        });
    }

    #[test]
    fn idot_mr_exact_at_i8_extremes() {
        for d in [1, 15, 16, 17, 64] {
            let q = vec![-128i8; d];
            for fill in [-128i8, 127] {
                let k4 = vec![fill; MR * d];
                let got = unsafe { idot_mr(&q, &k4) };
                assert_eq!(got, scalar::idot_mr(&q, &k4), "d={d} fill={fill}");
            }
        }
    }

    #[test]
    fn qk_dot_block_bit_identical_to_scalar() {
        prop::run("neon qk_dot_block == scalar", 60, |g| {
            let d = g.usize_in(1, 50);
            let rows = g.usize_in(0, 12);
            let q = gen_codes(g, d);
            let k = gen_codes(g, rows * d);
            let mut a = vec![7i32; rows + 2];
            let mut b = a.clone();
            unsafe { qk_dot_block(&q, &k, d, &mut a) };
            scalar::qk_dot_block(&q, &k, d, &mut b);
            assert_eq!(a, b, "d={d} rows={rows}");
        });
    }

    #[test]
    fn ipv_acc_bit_identical_to_scalar() {
        prop::run("neon ipv_acc == scalar", 80, |g| {
            let d = g.usize_in(1, 67);
            let rows = g.usize_in(0, 12);
            let mut p8 = gen_codes(g, rows);
            if !p8.is_empty() {
                p8[g.usize_in(0, rows)] = 0; // exercise the zero-row skip
            }
            let v8 = gen_codes(g, rows * d);
            let mut a = vec![-1i32; d];
            let mut b = vec![i32::MAX; d];
            unsafe { ipv_acc(&p8, &v8, d, &mut a) };
            scalar::ipv_acc(&p8, &v8, d, &mut b);
            assert_eq!(a, b, "d={d} rows={rows}");
        });
    }

    #[test]
    fn page_score_bit_identical_to_scalar() {
        prop::run("neon page_score == scalar", 80, |g| {
            let d = g.usize_in(1, 67);
            let q = gen_codes(g, d);
            let a = gen_codes(g, d);
            let b = gen_codes(g, d);
            let kmin: Vec<i8> =
                a.iter().zip(&b).map(|(&x, &y)| x.min(y)).collect();
            let kmax: Vec<i8> =
                a.iter().zip(&b).map(|(&x, &y)| x.max(y)).collect();
            let got = unsafe { page_score(&q, &kmin, &kmax) };
            assert_eq!(got, scalar::page_score(&q, &kmin, &kmax), "d={d}");
        });
    }

    #[test]
    fn sas_exp_block_bit_identical_to_scalar() {
        prop::run("neon sas_exp_block == scalar", 80, |g| {
            let sas = if g.bool() { Sas::default() } else { Sas::new(-3.5) };
            let (lut, depth, n_r) = sas.tables();
            let n = g.usize_in(0, 20);
            let m = g.f32_in(-2.0, 8.0);
            let row: Vec<f32> = (0..n)
                .map(|_| match g.usize_in(0, 5) {
                    0 => m + n_r,
                    1 => m + n_r - 1e-3,
                    2 => m - 20.0,
                    _ => m + g.f32_in(n_r, 0.0),
                })
                .collect();
            let mut a = row.clone();
            let mut b = row;
            let sa = unsafe { sas_exp_block(lut, depth, n_r, &mut a, m) };
            let sb = scalar::sas_exp_block(lut, depth, n_r, &mut b, m);
            assert_eq!(sa.to_bits(), sb.to_bits(), "sum (n={n})");
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "elem {i} (n={n})");
            }
        });
    }
}
