//! Minimal JSON parser/writer (serde is unavailable in the offline vendor
//! set — see DESIGN.md §2 "Offline crate substitutions").
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the config system: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are held as f64 (manifest values are shapes and
//! scalars — all exactly representable).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `j.get("model")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Nested access with `/`-separated path: `j.path("model/d_model")`.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('/') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Serialize a [`Json`] value (compact form).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("x".into())
        );
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j, Json::Str("a\nb\t\"q\" A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"he\"llo","b":false}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&to_string(&j)).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn path_access() {
        let j = Json::parse(r#"{"model": {"d_model": 128}}"#).unwrap();
        assert_eq!(j.path("model/d_model").unwrap().as_usize(), Some(128));
        assert!(j.path("model/nope").is_none());
    }
}
