//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Typed getters parse on access and surface nice errors.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: Vec<String>,
    opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// The first non-`--` token becomes the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// From the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// Typed option with default; panics with a clear message on parse
    /// failure (CLI boundary — fail fast).
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.opt(name) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                panic!("--{name}: cannot parse {s:?} as {}", std::any::type_name::<T>())
            }),
        }
    }

    /// Comma-separated list option: `--sizes 1,2,4`.
    pub fn opt_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.opt(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|_| {
                        panic!("--{name}: cannot parse element {p:?}")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_opts() {
        let a = args("serve --port 8080 --verbose --name=turbo pos1");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("name"), Some("turbo"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_access() {
        let a = args("bench --iters 100 --ratio 0.5");
        assert_eq!(a.opt_parse("iters", 1usize), 100);
        assert_eq!(a.opt_parse("ratio", 0.0f64), 0.5);
        assert_eq!(a.opt_parse("missing", 7u32), 7);
    }

    #[test]
    fn list_option() {
        let a = args("x --sizes 1,2,4");
        assert_eq!(a.opt_list("sizes", &[9usize]), vec![1, 2, 4]);
        assert_eq!(a.opt_list("other", &[9usize]), vec![9]);
    }

    #[test]
    fn flag_at_end_without_value() {
        let a = args("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("fast"), None);
    }
}
