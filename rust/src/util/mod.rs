//! Cross-cutting utilities: JSON, CLI parsing, logging.
//!
//! These are hand-rolled substrates: the offline vendor set has no serde,
//! clap or env_logger (DESIGN.md §2).

pub mod cli;
pub mod json;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug
// std::sync::OnceLock instead of once_cell: the offline vendor set has
// no once_cell, and the crate only depends on anyhow.
static START: OnceLock<Instant> = OnceLock::new();

/// Set global log verbosity (0=off, 1=error, 2=info, 3=debug).
pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_level() -> u8 {
    LOG_LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first use (for log timestamps).
pub fn uptime() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Log at info level with a `[+12.345s tag]` prefix.
#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)*) => {
        if $crate::util::log_level() >= 2 {
            eprintln!("[+{:9.3}s {}] {}", $crate::util::uptime(), $tag,
                      format!($($arg)*));
        }
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! debug {
    ($tag:expr, $($arg:tt)*) => {
        if $crate::util::log_level() >= 3 {
            eprintln!("[+{:9.3}s {} dbg] {}", $crate::util::uptime(), $tag,
                      format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn log_level_roundtrip() {
        let prev = super::log_level();
        super::set_log_level(3);
        assert_eq!(super::log_level(), 3);
        super::set_log_level(prev);
    }
}
