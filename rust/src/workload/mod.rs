//! Synthetic workload generation: arrival processes, prompt/output length
//! distributions, the grammar corpus (mirroring train.py), and the
//! calibrated QKV generators behind Figures 4/8/9/10 and Table 2.

pub mod synth;

pub use synth::{outlier_kv_slab, OutlierProfile};

use crate::testutil::Rng;

/// The training grammar, mirrored from python/compile/train.py so Rust
/// can generate in-distribution prompts without touching Python.
pub const SUBJECTS: [&str; 8] = [
    "the router", "a worker", "the scheduler", "one shard", "the cache",
    "a batch", "the kernel", "this head",
];
pub const VERBS: [&str; 8] = [
    "routes", "quantizes", "merges", "streams", "evicts", "scores", "packs",
    "flushes",
];
pub const OBJECTS: [&str; 8] = [
    "the tokens", "eight pages", "a tile", "the buffer", "low bits",
    "two heads", "the scales", "old blocks",
];
pub const ADVERBS: [&str; 8] = [
    "quickly", "in order", "without loss", "per layer", "at once", "lazily",
    "again", "safely",
];

/// One grammar sentence (ends with ". ").
pub fn sentence(rng: &mut Rng) -> String {
    format!(
        "{} {} {} {}. ",
        SUBJECTS[rng.range(0, 8)],
        VERBS[rng.range(0, 8)],
        OBJECTS[rng.range(0, 8)],
        ADVERBS[rng.range(0, 8)]
    )
}

/// A prompt of roughly `target_len` bytes of in-distribution text.
pub fn prompt(rng: &mut Rng, target_len: usize) -> Vec<u8> {
    let mut s = String::new();
    while s.len() < target_len {
        s.push_str(&sentence(rng));
    }
    s.truncate(target_len.max(1));
    s.into_bytes()
}

/// Arrival process for request generation.
#[derive(Debug, Clone, Copy)]
pub enum Arrivals {
    /// Poisson with the given rate (requests/s).
    Poisson { rate: f64 },
    /// All at time zero (offline/batch evaluation).
    Burst,
    /// Fixed inter-arrival gap in seconds.
    Uniform { gap: f64 },
}

/// A synthetic request trace entry.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival offset from trace start, seconds.
    pub at: f64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// Workload described by length distributions + arrivals.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub arrivals: Arrivals,
    pub n_requests: usize,
    pub prompt_len: (usize, usize),
    pub gen_len: (usize, usize),
    pub seed: u64,
}

impl WorkloadSpec {
    /// Materialize the trace (deterministic from the seed).
    pub fn generate(&self) -> Vec<TraceEntry> {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|_| {
                let at = match self.arrivals {
                    Arrivals::Burst => 0.0,
                    Arrivals::Poisson { rate } => {
                        t += rng.exponential(rate);
                        t
                    }
                    Arrivals::Uniform { gap } => {
                        t += gap;
                        t
                    }
                };
                let plen = rng.range(self.prompt_len.0, self.prompt_len.1 + 1);
                let glen = rng.range(self.gen_len.0, self.gen_len.1 + 1);
                TraceEntry { at, prompt: prompt(&mut rng, plen), max_new_tokens: glen }
            })
            .collect()
    }
}

/// The paper's three CoT evaluation suites, re-expressed as prompt-length
/// profiles (GSM8k ~900, AQuA ~1304, BBH ~1021 tokens with 8-shot CoT;
/// scaled by `scale` to fit the tiny model's context).
pub fn eval_suites(scale: f64) -> Vec<(&'static str, usize, usize)> {
    let s = |n: usize| ((n as f64 * scale) as usize).max(16);
    vec![
        ("GSM8k-like", s(900), 256),
        ("AQuA-like", s(1304), 256),
        ("BBH-like", s(1021), 256),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_length_and_content() {
        let mut rng = Rng::new(0);
        let p = prompt(&mut rng, 100);
        assert_eq!(p.len(), 100);
        assert!(p.iter().all(|&b| b.is_ascii()));
    }

    #[test]
    fn trace_deterministic() {
        let spec = WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 10.0 },
            n_requests: 20,
            prompt_len: (16, 64),
            gen_len: (4, 16),
            seed: 42,
        };
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!((x.at - y.at).abs() < 1e-12);
        }
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let spec = WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 5.0 },
            n_requests: 50,
            prompt_len: (8, 16),
            gen_len: (1, 4),
            seed: 1,
        };
        let trace = spec.generate();
        for w in trace.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn burst_all_at_zero() {
        let spec = WorkloadSpec {
            arrivals: Arrivals::Burst,
            n_requests: 5,
            prompt_len: (8, 9),
            gen_len: (1, 2),
            seed: 2,
        };
        assert!(spec.generate().iter().all(|e| e.at == 0.0));
    }

    #[test]
    fn suites_scale() {
        let suites = eval_suites(0.1);
        assert_eq!(suites.len(), 3);
        assert_eq!(suites[0].1, 90);
        assert_eq!(suites[1].1, 130);
    }
}
