//! Calibrated synthetic QKV generators.
//!
//! Figure 4/8/9 show that real models' K (and for Phi-3, V) caches have
//! *persistent channelwise outliers*: a few channels whose magnitude is
//! 5-20x the rest, consistent across tokens. That structure is what makes
//! channelwise quantization win (Figure 10) and what the head-priority
//! metric detects. These generators reproduce it so the accuracy
//! experiments exercise the same mechanism without model checkpoints.

use crate::tensor::Mat;
use crate::testutil::Rng;

/// Outlier structure profile for a generated K/V slab.
#[derive(Debug, Clone)]
pub struct OutlierProfile {
    /// Fraction of channels that are outliers.
    pub frac_channels: f64,
    /// Magnitude multiplier for outlier channels.
    pub boost: f32,
    /// Slowly-varying per-token drift (temporal correlation strength).
    pub token_drift: f32,
}

impl OutlierProfile {
    /// LLaMA-3-like K cache: moderate channel outliers.
    pub fn llama_k() -> OutlierProfile {
        OutlierProfile { frac_channels: 0.08, boost: 8.0, token_drift: 0.3 }
    }

    /// Phi-3-like V cache: pronounced channel outliers (Figure 9).
    pub fn phi3_v() -> OutlierProfile {
        OutlierProfile { frac_channels: 0.12, boost: 15.0, token_drift: 0.2 }
    }

    /// No outliers (control).
    pub fn plain() -> OutlierProfile {
        OutlierProfile { frac_channels: 0.0, boost: 1.0, token_drift: 0.0 }
    }
}

/// Generate a `[tokens, channels]` K or V slab with the given outlier
/// structure (deterministic from `rng`).
pub fn outlier_kv_slab(
    rng: &mut Rng,
    tokens: usize,
    channels: usize,
    profile: &OutlierProfile,
) -> Mat {
    let mut m = Mat::randn(rng, tokens, channels, 1.0);
    // Pick outlier channels.
    let n_out = ((channels as f64) * profile.frac_channels).round() as usize;
    let mut chans: Vec<usize> = (0..channels).collect();
    rng.shuffle(&mut chans);
    let outliers = &chans[..n_out];
    for &c in outliers {
        // Each outlier channel gets a persistent sign + magnitude.
        let sign = if rng.bool(0.5) { 1.0 } else { -1.0 };
        let mag = profile.boost * (0.5 + rng.f32());
        for t in 0..tokens {
            let v = m.get(t, c);
            m.set(t, c, v * mag * 0.3 + sign * mag);
        }
    }
    // Temporal drift: smooth low-frequency component over tokens.
    if profile.token_drift > 0.0 {
        for c in 0..channels {
            let mut drift = 0.0f32;
            for t in 0..tokens {
                drift = 0.95 * drift + 0.05 * rng.normal();
                let v = m.get(t, c);
                m.set(t, c, v + drift * profile.token_drift * 3.0);
            }
        }
    }
    m
}

/// Channelwise vs tokenwise min-max gap distributions of a slab — the
/// histogram data behind Figures 8/9.
pub fn gap_distributions(m: &Mat) -> (Vec<f32>, Vec<f32>) {
    let mut chan_gaps = vec![0.0f32; m.cols];
    for c in 0..m.cols {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for r in 0..m.rows {
            lo = lo.min(m.get(r, c));
            hi = hi.max(m.get(r, c));
        }
        chan_gaps[c] = hi - lo;
    }
    let mut tok_gaps = vec![0.0f32; m.rows];
    for r in 0..m.rows {
        let row = m.row(r);
        let lo = row.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let hi = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        tok_gaps[r] = hi - lo;
    }
    (chan_gaps, tok_gaps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outlier_channels_dominate_gaps() {
        let mut rng = Rng::new(0);
        let m = outlier_kv_slab(&mut rng, 256, 64, &OutlierProfile::phi3_v());
        let (chan, _tok) = gap_distributions(&m);
        let mut sorted = chan.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // Top channels' gap far exceeds the median channel gap.
        let median = sorted[sorted.len() / 2];
        assert!(sorted[0] > median * 3.0, "top {} median {median}", sorted[0]);
    }

    #[test]
    fn plain_profile_has_no_heavy_tail() {
        let mut rng = Rng::new(1);
        let m = outlier_kv_slab(&mut rng, 256, 64, &OutlierProfile::plain());
        let (chan, _) = gap_distributions(&m);
        let max = chan.iter().fold(0.0f32, |a, &b| a.max(b));
        let mean = chan.iter().sum::<f32>() / chan.len() as f32;
        assert!(max < mean * 2.0, "max {max} mean {mean}");
    }

    #[test]
    fn tokenwise_gaps_widen_with_outlier_channels() {
        // With channel outliers, every token's row spans the outlier
        // magnitude -> tokenwise gaps become uniformly large (Fig 8's
        // observation that tokenwise grouping is the wrong axis).
        let mut rng = Rng::new(2);
        let m = outlier_kv_slab(&mut rng, 128, 32, &OutlierProfile::llama_k());
        let (chan, tok) = gap_distributions(&m);
        let chan_med = {
            let mut s = chan.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[s.len() / 2]
        };
        let tok_med = {
            let mut s = tok.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[s.len() / 2]
        };
        assert!(tok_med > chan_med, "tok {tok_med} chan {chan_med}");
    }
}
