//! Cost-model-driven latency/throughput figures (1, 6, 7a).
//!
//! These reproduce the *shape* of the paper's A100 measurements via the
//! analytical model in `costmodel` (DESIGN.md §2 substitution table).

use crate::bench::Table;
use crate::costmodel::{
    attention_decode_cost, attention_prefill_cost, e2e::decode_throughput,
    e2e_step_cost, max_batch, AttnWorkload, GpuSpec, Method, ModelShape,
};
use crate::util::cli::Args;

fn methods() -> Vec<Method> {
    vec![
        Method::FlashFp16,
        Method::Kivi { bits: 4 },
        Method::GearL { bits: 4, rank: 4 },
        Method::Turbo { avg_bits: 3.0 },
    ]
}

/// Figure 1: (a) attention share of e2e latency vs prompt length,
/// (b) attention-kernel timeshare per method, (c) e2e phase timeshare.
pub fn fig1_timeshare(_args: &Args) -> anyhow::Result<()> {
    let gpu = GpuSpec::a100_80gb();
    let shape = ModelShape::phi3_medium();

    println!("Figure 1a — attention share of inference time (prompt:output 8:1, Flash-FP16)\n");
    let mut t = Table::new(&["prompt", "attention ms", "linear ms", "attn share"]);
    for ctx in [1_000usize, 8_000, 20_000, 40_000, 80_000, 120_000] {
        // One prefill pass + ctx/8 decode steps (8:1 prompt:output).
        let m = Method::FlashFp16;
        let (attn_p, lin_p, _) = e2e_step_cost(&gpu, &shape, &m, 1, ctx, true);
        let n_dec = ctx / 8;
        let (attn_d, lin_d, _) = e2e_step_cost(&gpu, &shape, &m, 1, ctx, false);
        let attn = attn_p.total() + attn_d.total() * n_dec as f64;
        let lin = lin_p + lin_d * n_dec as f64;
        t.row(&[
            format!("{ctx}"),
            format!("{:.1}", attn * 1e3),
            format!("{:.1}", lin * 1e3),
            format!("{:.0}%", 100.0 * attn / (attn + lin)),
        ]);
    }
    t.print();

    println!("\nFigure 1b — decode attention kernel timeshare at 16k ctx, batch 4\n");
    let w = AttnWorkload { batch: 4, heads: shape.n_heads, d_head: shape.d_head(), nq: 1, nk: 16_000 };
    let mut t = Table::new(&[
        "method", "matmul+KV ms", "softmax ms", "dequant ms", "total ms", "vs Flash",
    ]);
    let flash_total = attention_decode_cost(&gpu, &Method::FlashFp16, &w).total();
    for m in methods() {
        let c = attention_decode_cost(&gpu, &m, &w);
        t.row(&[
            m.label(),
            format!("{:.3}", c.matmul_kv * 1e3 * shape.n_layers as f64),
            format!("{:.3}", c.softmax * 1e3 * shape.n_layers as f64),
            format!("{:.3}", c.dequant * 1e3 * shape.n_layers as f64),
            format!("{:.3}", c.total() * 1e3 * shape.n_layers as f64),
            format!("{:.2}x", flash_total / c.total()),
        ]);
    }
    t.print();

    println!("\nFigure 1c — e2e prefill timeshare at 16k ctx (per method)\n");
    let mut t = Table::new(&["method", "matmul+KV", "softmax", "writeback", "linear"]);
    for m in methods() {
        let (attn, lin, total) = e2e_step_cost(&gpu, &shape, &m, 4, 16_000, true);
        t.row(&[
            m.label(),
            format!("{:.0}%", 100.0 * attn.matmul_kv / total),
            format!("{:.0}%", 100.0 * attn.softmax / total),
            format!("{:.0}%", 100.0 * attn.writeback / total),
            format!("{:.0}%", 100.0 * lin / total),
        ]);
    }
    t.print();
    Ok(())
}

/// Figure 6: attention speedup vs Flash-FP16, batch and context sweeps,
/// prefill and decode, with OOM markers.
pub fn fig6_speedup(args: &Args) -> anyhow::Result<()> {
    let gpu = GpuSpec::a100_80gb();
    let shape = ModelShape::phi3_medium();
    let batches = args.opt_list("batches", &[1usize, 4, 16, 64]);
    let ctxs = args.opt_list("ctxs", &[4_000usize, 8_000, 16_000, 32_000]);

    for (phase, prefill) in [("prefill", true), ("decode", false)] {
        println!("\nFigure 6 ({phase}) — speedup vs Flash-FP16, ctx=1k, batch sweep\n");
        let mut t = Table::new(&["method", "b=1", "b=4", "b=16", "b=64"]);
        for m in methods() {
            let mut cells = vec![m.label()];
            for &b in &batches {
                let w = AttnWorkload {
                    batch: b,
                    heads: shape.n_heads,
                    d_head: shape.d_head(),
                    nq: if prefill { 1_000 } else { 1 },
                    nk: 1_000,
                };
                let cost = |mm: &Method| {
                    if prefill {
                        attention_prefill_cost(&gpu, mm, &w).total()
                    } else {
                        attention_decode_cost(&gpu, mm, &w).total()
                    }
                };
                cells.push(format!("{:.2}x", cost(&Method::FlashFp16) / cost(&m)));
            }
            t.row(&cells);
        }
        t.print();

        println!("\nFigure 6 ({phase}) — speedup vs Flash-FP16, batch=4, ctx sweep (OOM per max_batch)\n");
        let mut t = Table::new(&["method", "4k", "8k", "16k", "32k"]);
        for m in methods() {
            let mut cells = vec![m.label()];
            for &ctx in &ctxs {
                let oom = max_batch(&gpu, &shape, &m, ctx) < 4;
                if oom {
                    cells.push("OOM".into());
                    continue;
                }
                let w = AttnWorkload {
                    batch: 4,
                    heads: shape.n_heads,
                    d_head: shape.d_head(),
                    nq: if prefill { ctx } else { 1 },
                    nk: ctx,
                };
                let cost = |mm: &Method| {
                    if prefill {
                        attention_prefill_cost(&gpu, mm, &w).total()
                    } else {
                        attention_decode_cost(&gpu, mm, &w).total()
                    }
                };
                // The paper marks FP16 OOM but still reports other
                // methods' speedups relative to (hypothetical) FP16 cost.
                cells.push(format!("{:.2}x", cost(&Method::FlashFp16) / cost(&m)));
            }
            t.row(&cells);
        }
        t.print();
        println!(
            "FP16 max batch at 32k ctx: {} (paper reports OOM beyond 4k at batch 4)",
            max_batch(&gpu, &shape, &Method::FlashFp16, 32_000)
        );
    }
    Ok(())
}

/// Figure 7a: max throughput vs batch size (ctx 1k, gen 125).
pub fn fig7a_throughput(args: &Args) -> anyhow::Result<()> {
    let gpu = GpuSpec::a100_80gb();
    let shape = ModelShape::phi3_medium();
    let ctx = args.opt_parse("ctx", 1_000usize);
    let gen = args.opt_parse("gen", 125usize);
    println!("Figure 7a — decode throughput (tokens/s) vs batch, ctx={ctx}, gen={gen}\n");
    let batches = [1usize, 4, 16, 64, 128, 256, 512];
    let mut t = Table::new(&["method", "b=1", "b=4", "b=16", "b=64", "b=128", "b=256", "b=512", "max tput", "vs FP16"]);
    let mut fp16_max = 0.0;
    let mut rows = Vec::new();
    for m in methods() {
        let cap = max_batch(&gpu, &shape, &m, ctx + gen);
        let mut cells = vec![m.label()];
        let mut best: f64 = 0.0;
        for &b in &batches {
            if b > cap {
                cells.push("OOM".into());
            } else {
                let tp = decode_throughput(&gpu, &shape, &m, b, ctx + gen / 2);
                best = best.max(tp);
                cells.push(format!("{tp:.0}"));
            }
        }
        if matches!(m, Method::FlashFp16) {
            fp16_max = best;
        }
        rows.push((cells, best));
    }
    for (mut cells, best) in rows {
        cells.push(format!("{best:.0}"));
        cells.push(format!("{:.2}x", best / fp16_max));
        t.row(&cells);
    }
    t.print();
    println!("\n(paper: TurboAttention up to 2.37x max throughput over Flash-FP16)");
    Ok(())
}
