//! Experiment drivers — one per table/figure in the paper's evaluation
//! (DESIGN.md §5 maps each ID to its modules). Every driver prints the
//! same rows/series the paper reports; EXPERIMENTS.md records the
//! paper-vs-measured comparison.

pub mod accuracy;
pub mod figures;
pub mod perf_figures;

use crate::util::cli::Args;

/// Dispatch `turboattn experiment <id>`.
pub fn run(id: &str, args: &Args) -> anyhow::Result<()> {
    match id {
        "fig1" => perf_figures::fig1_timeshare(args),
        "fig4" | "fig8" | "fig9" => figures::fig4_distributions(args),
        "fig5" => figures::fig5_poly_fit(args),
        "fig6" => perf_figures::fig6_speedup(args),
        "fig7a" => perf_figures::fig7a_throughput(args),
        "fig7b" => accuracy::fig7b_head_selection(args),
        "fig10" => figures::fig10_quant_error(args),
        "tab2" => accuracy::tab2_reasoning(args),
        "tab3" => accuracy::tab3_block_size(args),
        "tab4" => accuracy::tab4_flashq_sas(args),
        "tab5" => accuracy::tab5_weight_quant(args),
        "sparse" => accuracy::sparse_topk_agreement(args),
        "all" => {
            for id in [
                "fig1", "fig4", "fig5", "fig6", "fig7a", "fig7b", "fig10",
                "tab2", "tab3", "tab4", "tab5", "sparse",
            ] {
                println!("\n================ {id} ================");
                run(id, args)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other}; ids: fig1 fig4 fig5 fig6 fig7a \
             fig7b fig10 tab2 tab3 tab4 tab5 sparse all"
        ),
    }
}
