//! Distribution and kernel-approximation figures (4/8/9, 5, 10).

use crate::attention::baselines::fake_quant_grouped;
use crate::bench::Table;
use crate::quant::{head_priority, HeadStats};
use crate::sas::Sas;
use crate::tensor::Mat;
use crate::testutil::Rng;
use crate::util::cli::Args;
use crate::workload::synth::{gap_distributions, outlier_kv_slab, OutlierProfile};

/// Figures 4/8/9: Q/K/V channel min-max gap distributions, channel vs
/// token axis, for LLaMA-like and Phi3-like outlier profiles.
pub fn fig4_distributions(args: &Args) -> anyhow::Result<()> {
    let tokens = args.opt_parse("tokens", 512usize);
    let channels = args.opt_parse("channels", 64usize);
    let seed = args.opt_parse("seed", 0u64);
    println!(
        "Figure 4/8/9 — channelwise vs tokenwise min-max gap distributions"
    );
    println!(
        "(synthetic slabs calibrated to the paper's observed outlier \
         structure; tokens={tokens} channels={channels})\n"
    );
    let mut table = Table::new(&[
        "profile", "axis", "p50 gap", "p90 gap", "max gap", "max/p50",
    ]);
    for (name, profile) in [
        ("LLaMA3-like K", OutlierProfile::llama_k()),
        ("Phi3-like V", OutlierProfile::phi3_v()),
        ("no-outlier ctrl", OutlierProfile::plain()),
    ] {
        let mut rng = Rng::new(seed);
        let slab = outlier_kv_slab(&mut rng, tokens, channels, &profile);
        let (chan, tok) = gap_distributions(&slab);
        for (axis, gaps) in [("channel", &chan), ("token", &tok)] {
            let mut s = gaps.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let p50 = s[s.len() / 2];
            let p90 = s[s.len() * 9 / 10];
            let max = *s.last().unwrap();
            table.row(&[
                name.into(),
                axis.into(),
                format!("{p50:.2}"),
                format!("{p90:.2}"),
                format!("{max:.2}"),
                format!("{:.1}x", max / p50.max(1e-6)),
            ]);
        }
    }
    table.print();

    // Headwise priority view (Figure 4's "certain heads have outliers").
    println!("\nHead priorities (gap x std), 8 heads, outliers in heads 2 & 5:");
    let mut rng = Rng::new(seed + 1);
    for h in 0..8usize {
        let profile = if h == 2 || h == 5 {
            OutlierProfile::phi3_v()
        } else {
            OutlierProfile::plain()
        };
        let slab = outlier_kv_slab(&mut rng, tokens, channels, &profile);
        let stats = HeadStats::from_slab(&slab.data, tokens, channels);
        let pr = head_priority(&stats);
        println!("  head {h}: priority {pr:10.2} {}", if pr > 100.0 { "<- keep 4-bit" } else { "" });
    }
    Ok(())
}

/// Figure 5: cubic polynomial fit of e^{-x} on [0, 1].
pub fn fig5_poly_fit(_args: &Args) -> anyhow::Result<()> {
    println!("Figure 5 — POLY(x) vs e^(-x) on [0,1] (paper Eq. 15)\n");
    let mut table = Table::new(&["x", "e^-x", "POLY(x)", "abs err"]);
    let mut max_err = 0.0f32;
    let mut sum_err = 0.0f64;
    let n = 1000;
    for i in 0..=n {
        let x = i as f32 / n as f32;
        let exact = (-x).exp();
        let poly = Sas::poly(x);
        let err = (poly - exact).abs();
        max_err = max_err.max(err);
        sum_err += err as f64;
        if i % 100 == 0 {
            table.row(&[
                format!("{x:.1}"),
                format!("{exact:.6}"),
                format!("{poly:.6}"),
                format!("{err:.2e}"),
            ]);
        }
    }
    table.print();
    println!(
        "\nmax |err| = {max_err:.2e}, mean |err| = {:.2e} (paper: 'captures \
         the essential behavior with minimal overhead')",
        sum_err / (n + 1) as f64
    );

    // Full SAS (LUT x POLY + sparsity) error over [n_r, 0].
    let sas = Sas::default();
    println!(
        "full SAS max |err| on [-6,0]: {:.2e}; SAS(x < -6) = 0 (sparsified)",
        sas.max_abs_error(-6.0, 6000)
    );
    Ok(())
}

/// Figure 10: channelwise vs tokenwise group quantization error.
pub fn fig10_quant_error(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_parse("seed", 0u64);
    println!("Figure 10 — group quantization error by axis (MSE)\n");
    let mut table = Table::new(&[
        "profile", "bits", "channelwise MSE", "tokenwise MSE", "token/chan",
    ]);
    for (name, profile) in [
        ("LLaMA3-like K", OutlierProfile::llama_k()),
        ("Phi3-like V", OutlierProfile::phi3_v()),
    ] {
        for bits in [2u32, 4] {
            let mut rng = Rng::new(seed);
            let x: Mat = outlier_kv_slab(&mut rng, 256, 64, &profile);
            let chan = fake_quant_grouped(&x, bits, 32, 0);
            let tok = fake_quant_grouped(&x, bits, 32, 1);
            let mse_c = x.mse(&chan);
            let mse_t = x.mse(&tok);
            table.row(&[
                name.into(),
                format!("{bits}"),
                format!("{mse_c:.4}"),
                format!("{mse_t:.4}"),
                format!("{:.1}x", mse_t / mse_c.max(1e-12)),
            ]);
        }
    }
    table.print();
    println!("\n(paper: channelwise grouping has less quantization error)");
    Ok(())
}
