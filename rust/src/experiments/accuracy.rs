//! Accuracy experiments (Tables 2/3/4/5, Figure 7b).
//!
//! Substitution (DESIGN.md §2): the paper measures CoT task accuracy on
//! 7-8B checkpoints; here the same quantization mechanisms act on
//! calibrated synthetic multi-head QKV (channel-outlier structure per
//! Figure 4) and accuracy is *next-token agreement*: the % of positions
//! where a fixed random readout over the attention output picks the same
//! token as the exact-FP16 path. The orderings the paper reports (Turbo
//! ~ FP16 > GEAR > KIVI; mixed-2/4 modest loss; robustness across block
//! sizes) are driven by exactly the outlier-handling mechanisms this
//! proxy preserves.

use crate::attention::baselines::{fake_quant_grouped, gear_compress, kivi_compress};
use crate::attention::{attention_exact, turbo_attention, TurboConfig};
use crate::bench::Table;
use crate::quant::{head_score, select_2bit_heads, Bits, HeadStats, SelectionRule};
use crate::sas::Sas;
use crate::tensor::Mat;
use crate::testutil::Rng;
use crate::util::cli::Args;
use crate::workload::synth::{outlier_kv_slab, OutlierProfile};

/// One evaluation suite: multi-head QKV with calibrated outliers.
pub struct Suite {
    pub name: String,
    pub q: Vec<Mat>,
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    /// Fixed random readout `[heads * d, vocab]`.
    pub readout: Mat,
}

pub const SUITE_HEADS: usize = 8;
pub const SUITE_D: usize = 32;
const READOUT_VOCAB: usize = 64;

impl Suite {
    /// Build a suite with `nq` positions; heads 2 and 5 get strong
    /// channel outliers (the Figure 4 pattern).
    pub fn build(name: &str, nq: usize, seed: u64) -> Suite {
        let mut rng = Rng::new(seed);
        let mut q = Vec::new();
        let mut k = Vec::new();
        let mut v = Vec::new();
        for h in 0..SUITE_HEADS {
            let profile = if h == 2 || h == 5 {
                OutlierProfile::llama_k()
            } else {
                OutlierProfile::plain()
            };
            let v_profile = if h == 2 || h == 5 {
                OutlierProfile::phi3_v()
            } else {
                OutlierProfile::plain()
            };
            q.push(Mat::randn(&mut rng, nq, SUITE_D, 1.0));
            k.push(outlier_kv_slab(&mut rng, nq, SUITE_D, &profile));
            v.push(outlier_kv_slab(&mut rng, nq, SUITE_D, &v_profile));
        }
        let readout =
            Mat::randn(&mut rng, SUITE_HEADS * SUITE_D, READOUT_VOCAB, 1.0);
        Suite { name: name.into(), q, k, v, readout }
    }

    /// Readout argmax per position over concatenated head outputs.
    fn decisions(&self, head_outputs: &[Mat]) -> Vec<usize> {
        let nq = head_outputs[0].rows;
        let mut decisions = Vec::with_capacity(nq);
        for r in 0..nq {
            let mut logits = vec![0.0f32; READOUT_VOCAB];
            for (h, out) in head_outputs.iter().enumerate() {
                let row = out.row(r);
                for (c, &x) in row.iter().enumerate() {
                    let w_row = self.readout.row(h * SUITE_D + c);
                    for (l, &w) in logits.iter_mut().zip(w_row) {
                        *l += x * w;
                    }
                }
            }
            decisions.push(crate::model::argmax(&logits));
        }
        decisions
    }

    /// Agreement % between a method's outputs and the exact outputs.
    pub fn agreement(&self, exact: &[Mat], method: &[Mat]) -> f64 {
        let a = self.decisions(exact);
        let b = self.decisions(method);
        let hits = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        100.0 * hits as f64 / a.len() as f64
    }

    pub fn exact_outputs(&self) -> Vec<Mat> {
        (0..SUITE_HEADS)
            .map(|h| attention_exact(&self.q[h], &self.k[h], &self.v[h], true))
            .collect()
    }
}

/// A method under accuracy test: per-head attention outputs.
pub enum AccMethod {
    Exact,
    Turbo { bits_per_head: Vec<Bits>, br: usize, bc: usize, exact_exp: bool },
    /// exact scores + SAS softmax (Table 4's SAS-only row).
    SasOnly,
    Kivi { bits: u32 },
    Gear { bits: u32, rank: usize },
    /// Top-k page-sparse decode over a q1 cache: every position decoded
    /// through the serving path's `turbo_decode_into_sparse` (envelope
    /// scoring + mean-value fold of skipped pages). `topk = 0` is the
    /// dense decode baseline.
    SparseTopK { topk: usize, bc: usize },
}

impl AccMethod {
    pub fn turbo_uniform(bits: Bits, br: usize, bc: usize) -> AccMethod {
        AccMethod::Turbo {
            bits_per_head: vec![bits; SUITE_HEADS],
            br,
            bc,
            exact_exp: false,
        }
    }

    pub fn run(&self, suite: &Suite) -> Vec<Mat> {
        (0..SUITE_HEADS)
            .map(|h| {
                let (q, k, v) = (&suite.q[h], &suite.k[h], &suite.v[h]);
                match self {
                    AccMethod::Exact => attention_exact(q, k, v, true),
                    AccMethod::SasOnly => sas_only_attention(q, k, v),
                    AccMethod::Turbo { bits_per_head, br, bc, exact_exp } => {
                        let cfg = TurboConfig {
                            br: *br,
                            bc: *bc,
                            causal: true,
                            kv_bits: Some(bits_per_head[h]),
                            exact_exp: *exact_exp,
                            ..Default::default()
                        };
                        turbo_attention(q, k, v, &cfg)
                    }
                    AccMethod::Kivi { bits } => {
                        // Per-channel K, per-token V, fp residual window.
                        let n_b = 16.min(k.rows / 2);
                        let kq = kivi_compress(k, *bits, 32, n_b, true);
                        let vq = kivi_compress(v, *bits, 32, n_b, false);
                        attention_exact(q, &kq, &vq, true)
                    }
                    AccMethod::Gear { bits, rank } => {
                        let n_b = 16.min(k.rows / 2);
                        let kq = gear_compress(k, *bits, 32, n_b, *rank);
                        let vq = gear_compress(v, *bits, 32, n_b, *rank);
                        attention_exact(q, &kq, &vq, true)
                    }
                    AccMethod::SparseTopK { topk, bc } => {
                        sparse_decode_attention(q, k, v, *bc, *topk)
                    }
                }
            })
            .collect()
    }
}

/// Causal attention where every query row runs one *decode* step of the
/// sparse serving path over a q1 cache of the keys it can see: blocks of
/// `bc` tokens quantized INT8 with per-block scales (full blocks =
/// pages, summarized by key envelope + V column mean), then
/// [`turbo_decode_into_sparse`] with the given `topk`.
///
/// [`turbo_decode_into_sparse`]: crate::attention::turbo_decode_into_sparse
fn sparse_decode_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    bc: usize,
    topk: usize,
) -> Mat {
    use crate::attention::{turbo_decode_into_sparse, DecodeScratch};
    use crate::quant::quant_sym_int8;
    let (n, d) = (k.rows, k.cols);
    let nb = n.div_ceil(bc);
    let mut k8 = vec![0i8; n * d];
    let mut v8 = vec![0i8; n * d];
    let mut sk = vec![0.0f32; nb];
    let mut sv = vec![0.0f32; nb];
    for b in 0..nb {
        let lo = b * bc;
        let hi = ((b + 1) * bc).min(n);
        let qk = quant_sym_int8(&k.data[lo * d..hi * d]);
        k8[lo * d..hi * d].copy_from_slice(&qk.codes);
        sk[b] = qk.scale;
        let qv = quant_sym_int8(&v.data[lo * d..hi * d]);
        v8[lo * d..hi * d].copy_from_slice(&qv.codes);
        sv[b] = qv.scale;
    }
    // Per-page summaries over the full pages (the pool's memo content).
    let n_pages = n / bc;
    let mut kmin = vec![i8::MAX; n_pages * d];
    let mut kmax = vec![i8::MIN; n_pages * d];
    let mut vmean = vec![0.0f32; n_pages * d];
    for b in 0..n_pages {
        for t in 0..bc {
            for j in 0..d {
                let kc = k8[(b * bc + t) * d + j];
                kmin[b * d + j] = kmin[b * d + j].min(kc);
                kmax[b * d + j] = kmax[b * d + j].max(kc);
                vmean[b * d + j] += v8[(b * bc + t) * d + j] as f32;
            }
        }
        for j in 0..d {
            vmean[b * d + j] /= bc as f32;
        }
    }
    let mut scratch = DecodeScratch::new();
    let mut out = Mat::zeros(q.rows, d);
    for r in 0..q.rows {
        // Causal visibility with tail-query semantics (nq <= nk).
        let nk = r + 1 + n - q.rows;
        let mut row = vec![0.0f32; d];
        turbo_decode_into_sparse(
            q.row(r),
            &k8,
            &v8,
            &sk,
            &sv,
            &kmin,
            &kmax,
            &vmean,
            nk,
            bc,
            -6.0,
            topk,
            &mut scratch,
            &mut row,
        );
        out.row_mut(r).copy_from_slice(&row);
    }
    out
}

fn sas_only_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let d = q.cols;
    let scale = 1.0 / (d as f32).sqrt();
    let sas = Sas::default();
    let mut scores = q.matmul_t(k);
    for s in scores.data.iter_mut() {
        *s *= scale;
    }
    for i in 0..scores.rows {
        let limit = i + k.rows - q.rows;
        for j in 0..scores.cols {
            if j > limit {
                scores.set(i, j, f32::NEG_INFINITY);
            }
        }
        sas.softmax_row(scores.row_mut(i));
    }
    scores.matmul(v)
}

/// Mixed 2/4-bit turbo using the paper's priority selection on K stats.
fn turbo_mixed(suite: &Suite, n_2bit: usize, rule: SelectionRule, br: usize, bc: usize) -> AccMethod {
    let scores: Vec<f32> = (0..SUITE_HEADS)
        .map(|h| {
            let stats =
                HeadStats::from_slab(&suite.k[h].data, suite.k[h].rows, SUITE_D);
            head_score(&stats, rule)
        })
        .collect();
    let mask = select_2bit_heads(&scores, n_2bit);
    AccMethod::Turbo {
        bits_per_head: mask
            .iter()
            .map(|&two| if two { Bits::Int2 } else { Bits::Int4 })
            .collect(),
        br,
        bc,
        exact_exp: false,
    }
}

fn default_suites(args: &Args) -> Vec<Suite> {
    // Prefill profile of GSM8k/AQuA/BBH CoT prompts, scaled ~1/7 to the
    // CPU engine's comfortable range.
    let scale = args.opt_parse("suite-scale", 0.14f64);
    crate::workload::eval_suites(scale)
        .into_iter()
        .enumerate()
        .map(|(i, (name, nq, _))| Suite::build(name, nq, 100 + i as u64))
        .collect()
}

/// Table 2: CoT-reasoning accuracy proxy across methods and bit widths.
pub fn tab2_reasoning(args: &Args) -> anyhow::Result<()> {
    let suites = default_suites(args);
    println!(
        "Table 2 — next-token agreement vs FP16 (%), synthetic CoT-shaped \
         suites\n(paper metric: task accuracy; ordering is the reproduced \
         content)\n"
    );
    let br = 32;
    let rows: Vec<(String, String, AccMethod)> = vec![
        ("FP16".into(), "16".into(), AccMethod::Exact),
        ("KIVI".into(), "4".into(), AccMethod::Kivi { bits: 4 }),
        ("GEAR-L".into(), "4".into(), AccMethod::Gear { bits: 4, rank: 4 }),
        (
            "TurboAttention".into(),
            "4".into(),
            AccMethod::turbo_uniform(Bits::Int4, br, br),
        ),
        ("KIVI".into(), "3".into(), AccMethod::Kivi { bits: 3 }),
        ("GEAR-L".into(), "3".into(), AccMethod::Gear { bits: 3, rank: 4 }),
    ];
    let mut table = Table::new(&[
        "Method", "Bit", &suites[0].name, &suites[1].name, &suites[2].name,
        "Ave.",
    ]);
    let exacts: Vec<Vec<Mat>> = suites.iter().map(|s| s.exact_outputs()).collect();
    let mut run_row = |label: String, bit: String, m: &AccMethod| {
        let mut cells = vec![label, bit];
        let mut sum = 0.0;
        for (s, e) in suites.iter().zip(&exacts) {
            let acc = s.agreement(e, &m.run(s));
            sum += acc;
            cells.push(format!("{acc:.2}"));
        }
        cells.push(format!("{:.2}", sum / suites.len() as f64));
        cells
    };
    for (label, bit, m) in &rows {
        let cells = run_row(label.clone(), bit.clone(), m);
        table.row(&cells);
    }
    // Mixed 2/4 (half the heads 2-bit) — compared against 3-bit baselines.
    let mixed_cells = {
        let mut cells =
            vec!["TurboAttention (mixed)".to_string(), "2/4".to_string()];
        let mut sum = 0.0;
        for (s, e) in suites.iter().zip(&exacts) {
            let m = turbo_mixed(s, SUITE_HEADS / 2, SelectionRule::Priority, br, br);
            let acc = s.agreement(e, &m.run(s));
            sum += acc;
            cells.push(format!("{acc:.2}"));
        }
        cells.push(format!("{:.2}", sum / suites.len() as f64));
        cells
    };
    table.row(&mixed_cells);
    table.print();
    println!(
        "\nExpected shape (paper): Turbo-4bit ~ FP16; Turbo-mixed beats the \
         3-bit baselines; KIVI lowest at matched bits."
    );
    Ok(())
}

/// Table 3: block-size ablation.
pub fn tab3_block_size(args: &Args) -> anyhow::Result<()> {
    let suite = Suite::build("GSM8k-like", args.opt_parse("nq", 128usize), 7);
    let exact = suite.exact_outputs();
    println!("Table 3 — TurboAttention agreement across block sizes (B_r, B_c)\n");
    let mut table = Table::new(&["Block size (Br,Bc)", "Dataset", "Agreement %"]);
    for (br, bc) in [(16, 16), (16, 32), (32, 16), (32, 32), (32, 64), (64, 32), (64, 64)] {
        let m = AccMethod::turbo_uniform(Bits::Int4, br, bc);
        let acc = suite.agreement(&exact, &m.run(&suite));
        table.row(&[
            format!("({br},{bc})"),
            "GSM8k-like".into(),
            format!("{acc:.2}"),
        ]);
    }
    table.print();
    println!("\n(paper: accuracy is robust across block sizes — spread < 1 point)");
    Ok(())
}

/// Table 4: FlashQ-only vs SAS-only vs both.
pub fn tab4_flashq_sas(args: &Args) -> anyhow::Result<()> {
    let suite = Suite::build("AQuA-like", args.opt_parse("nq", 160usize), 11);
    let exact = suite.exact_outputs();
    println!("Table 4 — FlashQ and SAS accuracy decomposition\n");
    let mut table = Table::new(&["Method", "Agreement %"]);
    let flashq_only = AccMethod::Turbo {
        bits_per_head: vec![Bits::Int4; SUITE_HEADS],
        br: 32,
        bc: 32,
        exact_exp: true,
    };
    let rows: Vec<(&str, AccMethod)> = vec![
        ("FP16", AccMethod::Exact),
        ("FlashQ-4bit", flashq_only),
        ("SAS", AccMethod::SasOnly),
        ("FlashQ-4bit + SAS", AccMethod::turbo_uniform(Bits::Int4, 32, 32)),
    ];
    for (name, m) in rows {
        let acc = suite.agreement(&exact, &m.run(&suite));
        table.row(&[name.into(), format!("{acc:.2}")]);
    }
    table.print();
    println!("\n(paper: both techniques individually near-lossless)");
    Ok(())
}

/// Table 5: integration with weight quantization (readout proxy).
pub fn tab5_weight_quant(args: &Args) -> anyhow::Result<()> {
    let mut suite = Suite::build("GSM8k-like", args.opt_parse("nq", 128usize), 13);
    let exact = suite.exact_outputs();
    println!(
        "Table 5 — TurboAttention composed with weight quantization\n\
         (readout matrix quantized as the linear-layer proxy)\n"
    );
    let mut table = Table::new(&["Method", "Agreement %"]);
    // FP16 weights.
    let turbo = AccMethod::turbo_uniform(Bits::Int4, 32, 32);
    let base = suite.agreement(&exact, &turbo.run(&suite));
    table.row(&["FP16 weights".into(), "100.00".into()]);
    table.row(&["TurboAttention".into(), format!("{base:.2}")]);
    // LLM.int8-like: per-channel symmetric INT8 on the readout.
    let orig = suite.readout.clone();
    suite.readout = fake_quant_grouped(&orig, 8, orig.rows, 0);
    let acc8 = suite.agreement(&exact, &turbo.run(&suite));
    table.row(&["LLM.int8() + TurboAttention".into(), format!("{acc8:.2}")]);
    // Qserve-like: 4-bit groupwise weights.
    suite.readout = fake_quant_grouped(&orig, 4, 32, 0);
    let acc4 = suite.agreement(&exact, &turbo.run(&suite));
    table.row(&["Qserve(W4) + TurboAttention".into(), format!("{acc4:.2}")]);
    suite.readout = orig;
    table.print();
    println!("\n(paper: composition costs < 1 point on top of either technique)");
    Ok(())
}

/// Sparse-decode ablation: next-token agreement vs `sparse_topk_pages`.
///
/// Sweeps the per-request top-k knob over the accuracy suites, with the
/// dense decode path (`topk = 0`) as the 100%-traffic reference — the
/// SparQ-style trade: how much agreement survives as decode reads fewer
/// KV pages. Also reports the fraction of full pages actually attended
/// at the longest context in the suite.
pub fn sparse_topk_agreement(args: &Args) -> anyhow::Result<()> {
    let suites = default_suites(args);
    let bc = args.opt_parse("sparse-bc", 32usize);
    println!(
        "Sparse top-k decode — next-token agreement vs dense decode (%), \
         by sparse_topk_pages\n(pages of {bc} tokens; k = 0 is the dense \
         reference; the buffer tail is always attended)\n"
    );
    let mut table = Table::new(&[
        "topk", &suites[0].name, &suites[1].name, &suites[2].name, "Ave.",
        "pages kept",
    ]);
    // Agreement is measured against the *dense decode* outputs, so the
    // sweep isolates the sparsity error from quantization error.
    let dense: Vec<Vec<Mat>> = suites
        .iter()
        .map(|s| AccMethod::SparseTopK { topk: 0, bc }.run(s))
        .collect();
    let max_pages = suites
        .iter()
        .map(|s| s.k[0].rows / bc)
        .max()
        .unwrap_or(0)
        .max(1);
    for topk in [1usize, 2, 4, 8, 16] {
        let mut cells = vec![format!("{topk}")];
        let mut sum = 0.0;
        for (s, e) in suites.iter().zip(&dense) {
            let m = AccMethod::SparseTopK { topk, bc };
            let acc = s.agreement(e, &m.run(s));
            sum += acc;
            cells.push(format!("{acc:.2}"));
        }
        cells.push(format!("{:.2}", sum / suites.len() as f64));
        cells.push(format!("{}/{}", topk.min(max_pages), max_pages));
        table.row(&cells);
        if topk >= max_pages {
            break;
        }
    }
    table.print();
    println!(
        "\n(expected: agreement -> 100 as k approaches the page count; \
         k covering all pages is bit-identical to dense)"
    );
    Ok(())
}

/// Figure 7b: head-selection rule ablation across 2-bit head counts.
///
/// Heads get *graded, structurally different* outlier patterns (one huge
/// channel vs many medium channels vs drift-only ...) so the four rules
/// rank them differently; the metric is mean relative output error (x100,
/// lower = better) — agreement saturates too early to separate rules.
pub fn fig7b_head_selection(args: &Args) -> anyhow::Result<()> {
    let nq = args.opt_parse("nq", 160usize);
    let mut rng = Rng::new(17);
    let profiles: [OutlierProfile; SUITE_HEADS] = [
        OutlierProfile::plain(),
        OutlierProfile { frac_channels: 0.03, boost: 15.0, token_drift: 0.1 },
        OutlierProfile { frac_channels: 0.40, boost: 3.0, token_drift: 0.2 },
        OutlierProfile { frac_channels: 0.10, boost: 6.0, token_drift: 0.3 },
        OutlierProfile { frac_channels: 0.50, boost: 1.8, token_drift: 0.1 },
        OutlierProfile { frac_channels: 0.0, boost: 1.0, token_drift: 0.8 },
        OutlierProfile { frac_channels: 0.06, boost: 10.0, token_drift: 0.0 },
        OutlierProfile::plain(),
    ];
    let mut q = Vec::new();
    let mut k = Vec::new();
    let mut v = Vec::new();
    for p in &profiles {
        q.push(Mat::randn(&mut rng, nq, SUITE_D, 1.0));
        k.push(outlier_kv_slab(&mut rng, nq, SUITE_D, p));
        v.push(outlier_kv_slab(&mut rng, nq, SUITE_D, p));
    }
    let readout = Mat::randn(&mut rng, SUITE_HEADS * SUITE_D, 64, 1.0);
    let suite = Suite { name: "graded".into(), q, k, v, readout };
    let exact = suite.exact_outputs();
    let rel_err = |outs: &[Mat]| -> f64 {
        outs.iter()
            .zip(&exact)
            .map(|(a, b)| a.rel_err(b))
            .sum::<f64>()
            / outs.len() as f64
            * 100.0
    };
    println!(
        "Figure 7b — mean relative output error (x100, lower = better) vs \
         number of 2-bit heads, by selection rule\n"
    );
    let rules = [
        ("priority (ours)", SelectionRule::Priority),
        ("entropy", SelectionRule::Entropy),
        ("min-max", SelectionRule::MinMax),
        ("variation", SelectionRule::Variation),
    ];
    let counts = [0usize, 2, 4, 6, 8];
    let mut table = Table::new(&["rule", "0", "2", "4", "6", "8"]);
    for (name, rule) in rules {
        let mut cells = vec![name.to_string()];
        for &n in &counts {
            let m = turbo_mixed(&suite, n, rule, 32, 32);
            cells.push(format!("{:.2}", rel_err(&m.run(&suite))));
        }
        table.row(&cells);
    }
    table.print();
    println!(
        "\n(paper: the priority rule degrades most gracefully as 2-bit \
         head count grows)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_agreement_reflexive() {
        let s = Suite::build("t", 32, 0);
        let e = s.exact_outputs();
        assert_eq!(s.agreement(&e, &e), 100.0);
    }

    #[test]
    fn turbo4_beats_kivi2() {
        let s = Suite::build("t", 64, 1);
        let e = s.exact_outputs();
        let t4 = AccMethod::turbo_uniform(Bits::Int4, 16, 16);
        let k2 = AccMethod::Kivi { bits: 2 };
        let a_t = s.agreement(&e, &t4.run(&s));
        let a_k = s.agreement(&e, &k2.run(&s));
        assert!(a_t >= a_k, "turbo4 {a_t} vs kivi2 {a_k}");
    }

    #[test]
    fn sas_only_near_lossless() {
        let s = Suite::build("t", 64, 2);
        let e = s.exact_outputs();
        let acc = s.agreement(&e, &AccMethod::SasOnly.run(&s));
        assert!(acc > 95.0, "sas-only {acc}");
    }

    #[test]
    fn sparse_covering_k_matches_dense_decode_exactly() {
        // 96 positions, 16-token pages -> up to 6 full pages; a k that
        // covers them all must reproduce the dense decode bit-for-bit,
        // and agreement must not decrease as k grows.
        let s = Suite::build("t", 96, 3);
        let bc = 16;
        let dense = AccMethod::SparseTopK { topk: 0, bc }.run(&s);
        let covering = AccMethod::SparseTopK { topk: 6, bc }.run(&s);
        for (a, b) in dense.iter().zip(&covering) {
            let ab: Vec<u32> = a.data.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "covering k must be the dense path");
        }
        let a1 = s.agreement(&dense, &AccMethod::SparseTopK { topk: 1, bc }.run(&s));
        let a4 = s.agreement(&dense, &AccMethod::SparseTopK { topk: 4, bc }.run(&s));
        assert!(
            a4 + 5.0 >= a1,
            "agreement should not degrade with k: {a1} vs {a4}"
        );
        assert!(a1 > 30.0, "even k=1 keeps the tail + top page: {a1}");
    }
}
