//! Hand-rolled worker pool for per-(layer, head) decode parallelism.
//!
//! TurboAttention's headwise quantization (paper §3) makes every
//! (layer, head) stream independent during decode: slab sync copies
//! disjoint ranges and the INT8 attention reads shared immutable slabs.
//! This module supplies the fork/join substrate that exploits that —
//! with **no new dependencies** (std only; crossbeam/rayon are not in
//! the offline vendor set):
//!
//! * [`WorkerPool`] owns a fixed set of worker threads fed from one
//!   mpsc channel (jobs are pulled, not pushed, so uneven shards
//!   load-balance naturally, FlashInfer-style).
//! * [`WorkerPool::scope`] is a scoped fork/join region: jobs may
//!   borrow stack data (`&mut` slab shards, stream caches) because the
//!   scope blocks until every job submitted inside it has finished
//!   before returning — the same contract as `std::thread::scope`, but
//!   over persistent threads so a decode step spawns nothing.
//! * A panic inside a job is caught on the worker, reported as a
//!   [`ScopeError`] from `scope`, and leaves the pool fully usable —
//!   workers never die with the job, so one poisoned step cannot poison
//!   the next.
//! * `threads <= 1` builds a **serial** pool: no threads are spawned
//!   and jobs run inline on the caller in submission order — the exact
//!   old serial decode path, used as the determinism oracle by the
//!   parity tests.
//!
//! Determinism contract: the pool only ever runs jobs whose writes are
//! disjoint by construction (the borrow checker proves it at the call
//! site), and each job's own arithmetic is sequential — so results are
//! bit-identical for every thread count, including 1.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A unit of work after lifetime erasure (see `Scope::execute`).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Number of worker threads to use when the caller does not specify:
/// the machine's available parallelism (1 if it cannot be queried).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Sizes for dealing `n_items` into at most `max_jobs` contiguous
/// groups whose sizes differ by at most one (the first groups take the
/// remainder). Yields `min(max_jobs.max(1), n_items)` positive sizes
/// summing to `n_items`; empty when `n_items == 0`.
///
/// Both decode fan-outs (`TurboSession::sync_slabs` and
/// `turbo_decode_streams`) partition streams with this one helper, so
/// their group boundaries — part of the bit-determinism story — cannot
/// drift apart.
pub fn balanced_chunk_sizes(
    n_items: usize,
    max_jobs: usize,
) -> impl Iterator<Item = usize> {
    let jobs = max_jobs.max(1).min(n_items);
    let per = n_items.checked_div(jobs).unwrap_or(0);
    let extra = n_items.checked_rem(jobs).unwrap_or(0);
    (0..jobs).map(move |ji| per + usize::from(ji < extra))
}

/// Error returned by [`WorkerPool::scope`] when one or more jobs
/// panicked. The pool itself remains usable.
#[derive(Debug, Clone)]
pub struct ScopeError {
    /// How many jobs in the scope panicked.
    pub panicked_jobs: usize,
    /// Payload of the first panic observed (caught on the worker).
    pub first_panic: String,
}

impl std::fmt::Display for ScopeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} pool job(s) panicked; first: {}",
            self.panicked_jobs, self.first_panic
        )
    }
}

impl std::error::Error for ScopeError {}

/// Fork/join bookkeeping shared between one scope and its jobs.
#[derive(Default)]
struct ScopeSync {
    state: Mutex<ScopeState>,
    done: Condvar,
}

#[derive(Default)]
struct ScopeState {
    pending: usize,
    panicked_jobs: usize,
    first_panic: Option<String>,
}

impl ScopeSync {
    fn fork(&self) {
        self.state.lock().expect("scope state").pending += 1;
    }

    /// Mark one job finished (with its panic payload, if any) and wake
    /// the joining thread when it was the last.
    fn join_one(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.state.lock().expect("scope state");
        if let Some(p) = panic {
            st.panicked_jobs += 1;
            if st.first_panic.is_none() {
                st.first_panic = Some(panic_message(p.as_ref()));
            }
        }
        st.pending -= 1;
        if st.pending == 0 {
            self.done.notify_all();
        }
    }

    /// Record a panic from an inline (serial-mode) job.
    fn record_panic(&self, p: Box<dyn std::any::Any + Send>) {
        let mut st = self.state.lock().expect("scope state");
        st.panicked_jobs += 1;
        if st.first_panic.is_none() {
            st.first_panic = Some(panic_message(p.as_ref()));
        }
    }

    fn wait_all(&self) {
        let mut st = self.state.lock().expect("scope state");
        while st.pending > 0 {
            st = self.done.wait(st).expect("scope wait");
        }
    }

    fn take_failure(&self) -> Option<ScopeError> {
        let mut st = self.state.lock().expect("scope state");
        if st.panicked_jobs == 0 {
            return None;
        }
        let err = ScopeError {
            panicked_jobs: st.panicked_jobs,
            first_panic: st
                .first_panic
                .take()
                .unwrap_or_else(|| "<no payload>".into()),
        };
        st.panicked_jobs = 0;
        Some(err)
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".into()
    }
}

/// Handle onto a pool's live-worker counter that outlives the pool —
/// lets tests assert that dropping the pool joins every thread (the
/// no-leak bookkeeping the stress suite checks across 1k steps).
#[derive(Clone)]
pub struct PoolProbe(Arc<AtomicUsize>);

impl PoolProbe {
    /// Worker threads currently alive in the probed pool.
    pub fn live(&self) -> usize {
        self.0.load(Ordering::SeqCst)
    }
}

/// Decrements the live counter even if a worker unwinds.
struct LiveGuard(Arc<AtomicUsize>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Fixed set of worker threads around one channel-based work queue.
pub struct WorkerPool {
    /// Job sender; `None` in serial mode. Dropping it (pool drop) is the
    /// workers' shutdown signal.
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    live: Arc<AtomicUsize>,
    /// Cumulative nanoseconds of job execution (all scopes) — the
    /// "busy" side of the engine's parallel wall/busy decode metrics.
    busy_ns: Arc<AtomicU64>,
}

impl WorkerPool {
    /// Pool with `threads` workers. `threads <= 1` spawns nothing and
    /// runs jobs inline on the caller (the exact serial path).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        if threads == 1 {
            return WorkerPool {
                tx: None,
                workers: Vec::new(),
                threads,
                live: Arc::new(AtomicUsize::new(0)),
                busy_ns: Arc::new(AtomicU64::new(0)),
            };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let live = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let live = Arc::clone(&live);
                live.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("turbo-pool-{i}"))
                    .spawn(move || worker_loop(rx, live))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            workers,
            threads,
            live,
            busy_ns: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn with_default_threads() -> WorkerPool {
        WorkerPool::new(default_threads())
    }

    /// Configured parallelism (1 for the serial pool).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when jobs run inline on the caller.
    pub fn is_serial(&self) -> bool {
        self.tx.is_none()
    }

    /// Cumulative time spent executing jobs, summed across all workers
    /// and all scopes. Sample before/after a region to get its busy
    /// time. A serial pool accumulates whole-scope time instead of
    /// per-job time — same total, but the inline fast path pays no
    /// per-job clock reads.
    pub fn busy(&self) -> Duration {
        Duration::from_nanos(self.busy_ns.load(Ordering::Relaxed))
    }

    /// Counter handle for leak tests — see [`PoolProbe`].
    pub fn probe(&self) -> PoolProbe {
        PoolProbe(Arc::clone(&self.live))
    }

    /// Fork/join region. Jobs submitted via [`Scope::execute`] may
    /// borrow anything that outlives the `scope` call; the call returns
    /// only after every job has finished. Returns the closure's value,
    /// or [`ScopeError`] if any job panicked (the pool stays usable).
    ///
    /// If `f` itself panics, already-submitted jobs are still joined
    /// before the panic resumes unwinding (borrowed data must outlive
    /// running jobs).
    pub fn scope<'pool, 'scope, R, F>(&'pool self, f: F) -> Result<R, ScopeError>
    where
        F: FnOnce(&Scope<'pool, 'scope>) -> R,
    {
        let scope = Scope {
            pool: self,
            sync: Arc::new(ScopeSync::default()),
            _scope: std::marker::PhantomData,
        };
        // Serial pools time the whole scope (inline jobs are the body),
        // keeping the per-job fast path free of clock reads.
        let serial_t0 = self.tx.is_none().then(Instant::now);
        let out = {
            // Join-on-drop guard: runs on normal exit *and* if `f`
            // unwinds, so no job can outlive its borrows either way.
            let _join = JoinGuard(&scope.sync);
            f(&scope)
        };
        if let Some(t0) = serial_t0 {
            self.busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        match scope.sync.take_failure() {
            Some(err) => Err(err),
            None => Ok(out),
        }
    }
}

struct JoinGuard<'a>(&'a ScopeSync);

impl Drop for JoinGuard<'_> {
    fn drop(&mut self) {
        self.0.wait_all();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel is the shutdown signal; then join so no
        // worker outlives the pool (leak-free across sessions).
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, live: Arc<AtomicUsize>) {
    let _guard = LiveGuard(live);
    loop {
        // Take the lock only to pull the next job; run it unlocked so
        // workers execute concurrently.
        let job = {
            let rx = rx.lock().expect("pool queue");
            rx.recv()
        };
        match job {
            Ok(job) => job(),
            Err(_) => break, // channel closed: pool dropped
        }
    }
}

/// Fork handle passed to the closure of [`WorkerPool::scope`].
///
/// Invariant in `'scope` (the `Cell` marker) so borrows captured by
/// jobs cannot be shortened below the scope region — the same trick as
/// `std::thread::scope`.
pub struct Scope<'pool, 'scope> {
    pool: &'pool WorkerPool,
    sync: Arc<ScopeSync>,
    _scope: std::marker::PhantomData<std::cell::Cell<&'scope ()>>,
}

impl<'pool, 'scope> Scope<'pool, 'scope> {
    /// Submit one job. On a serial pool it runs immediately, inline, in
    /// submission order; otherwise it is queued for the workers. Panics
    /// are caught either way and surface as the scope's `ScopeError`.
    pub fn execute<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        let Some(tx) = &self.pool.tx else {
            // Serial inline path: no per-job timing (the enclosing
            // scope is timed as a whole), no queue round trip.
            if let Err(p) = catch_unwind(AssertUnwindSafe(f)) {
                self.sync.record_panic(p);
            }
            return;
        };
        let busy = Arc::clone(&self.pool.busy_ns);
        self.sync.fork();
        let sync = Arc::clone(&self.sync);
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            let t0 = Instant::now();
            let result = catch_unwind(AssertUnwindSafe(f));
            busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            sync.join_one(result.err());
        });
        // SAFETY: the job cannot outlive `'scope`: every path out of
        // `WorkerPool::scope` (normal return or unwind) first blocks on
        // `ScopeSync::wait_all`, so the closure — and every borrow it
        // captured — is consumed before the borrows can expire. The
        // transmute only erases the lifetime; layout is identical.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
        };
        tx.send(job).expect("worker pool queue closed");
    }

    /// The pool this scope forks onto.
    pub fn pool(&self) -> &'pool WorkerPool {
        self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_runs_all_jobs_and_returns_value() {
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            let hits = AtomicUsize::new(0);
            let r = pool
                .scope(|s| {
                    for _ in 0..17 {
                        s.execute(|| {
                            hits.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                    "done"
                })
                .expect("no panics");
            assert_eq!(r, "done");
            assert_eq!(hits.load(Ordering::SeqCst), 17, "threads={threads}");
        }
    }

    #[test]
    fn disjoint_mut_borrows_cross_into_jobs() {
        // The whole point of the scoped design: jobs borrow disjoint
        // &mut shards of caller-owned data, no 'static required.
        let pool = WorkerPool::new(3);
        let mut data = vec![0usize; 32];
        pool.scope(|s| {
            for (i, chunk) in data.chunks_mut(8).enumerate() {
                s.execute(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = i * 8 + j;
                    }
                });
            }
        })
        .expect("no panics");
        let want: Vec<usize> = (0..32).collect();
        assert_eq!(data, want);
    }

    #[test]
    fn empty_scope_is_fine() {
        let pool = WorkerPool::new(4);
        let r = pool.scope(|_| 7).expect("empty scope");
        assert_eq!(r, 7);
    }

    #[test]
    fn more_jobs_than_threads_and_fewer() {
        let pool = WorkerPool::new(8);
        for n_jobs in [1usize, 3, 8, 40] {
            let hits = AtomicUsize::new(0);
            pool.scope(|s| {
                for _ in 0..n_jobs {
                    s.execute(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
            })
            .expect("no panics");
            assert_eq!(hits.load(Ordering::SeqCst), n_jobs);
        }
    }

    #[test]
    fn serial_pool_runs_inline_in_order() {
        let pool = WorkerPool::new(1);
        assert!(pool.is_serial());
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        let order = Mutex::new(Vec::new());
        pool.scope(|s| {
            for i in 0..5 {
                let order = &order;
                s.execute(move || {
                    assert_eq!(std::thread::current().id(), caller);
                    order.lock().unwrap().push(i);
                });
            }
        })
        .expect("no panics");
        assert_eq!(order.into_inner().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_pool_uses_worker_threads() {
        let pool = WorkerPool::new(2);
        let caller = std::thread::current().id();
        pool.scope(|s| {
            s.execute(move || {
                assert_ne!(std::thread::current().id(), caller);
            });
        })
        .expect("no panics");
    }

    #[test]
    fn panic_in_job_is_err_not_poison() {
        for threads in [1, 4] {
            let pool = WorkerPool::new(threads);
            let err = pool
                .scope(|s| {
                    s.execute(|| panic!("shard exploded"));
                    s.execute(|| {}); // healthy sibling still runs
                })
                .expect_err("must surface the panic");
            assert_eq!(err.panicked_jobs, 1);
            assert!(err.first_panic.contains("shard exploded"), "{err}");
            // Later steps are unaffected: same pool, clean scope.
            let hits = AtomicUsize::new(0);
            pool.scope(|s| {
                for _ in 0..4 {
                    s.execute(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
            })
            .expect("pool not poisoned");
            assert_eq!(hits.load(Ordering::SeqCst), 4);
        }
    }

    #[test]
    fn multiple_panics_counted() {
        let pool = WorkerPool::new(2);
        let err = pool
            .scope(|s| {
                for i in 0..3 {
                    s.execute(move || panic!("boom {i}"));
                }
            })
            .expect_err("panics");
        assert_eq!(err.panicked_jobs, 3);
        assert!(err.first_panic.contains("boom"));
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(3);
        let probe = pool.probe();
        assert_eq!(probe.live(), 3);
        pool.scope(|s| {
            for _ in 0..6 {
                s.execute(|| {});
            }
        })
        .expect("no panics");
        assert_eq!(probe.live(), 3, "scopes neither spawn nor kill workers");
        drop(pool);
        assert_eq!(probe.live(), 0, "drop must join every worker");
    }

    #[test]
    fn reuse_across_many_steps_leaks_no_threads() {
        // The decode loop calls one scope per step for the lifetime of a
        // session; 1k steps must keep the worker set exactly fixed.
        let pool = WorkerPool::new(2);
        let probe = pool.probe();
        let total = AtomicUsize::new(0);
        for _ in 0..1000 {
            pool.scope(|s| {
                for _ in 0..4 {
                    s.execute(|| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            })
            .expect("no panics");
        }
        assert_eq!(total.load(Ordering::Relaxed), 4000);
        assert_eq!(probe.live(), 2);
        drop(pool);
        assert_eq!(probe.live(), 0);
    }

    #[test]
    fn busy_time_accumulates() {
        for threads in [1, 2] {
            let pool = WorkerPool::new(threads);
            let before = pool.busy();
            pool.scope(|s| {
                for _ in 0..2 {
                    s.execute(|| {
                        std::thread::sleep(Duration::from_millis(5));
                    });
                }
            })
            .expect("no panics");
            let busy = pool.busy() - before;
            assert!(
                busy >= Duration::from_millis(9),
                "threads={threads}: busy {busy:?} must sum both jobs"
            );
        }
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
        assert!(WorkerPool::with_default_threads().threads() >= 1);
    }

    #[test]
    fn scope_error_formats() {
        let e = ScopeError { panicked_jobs: 2, first_panic: "k".into() };
        let s = format!("{e}");
        assert!(s.contains('2') && s.contains('k'));
    }

    #[test]
    fn balanced_chunks_cover_exactly() {
        for n_items in 0..40usize {
            for max_jobs in 1..10usize {
                let sizes: Vec<usize> =
                    balanced_chunk_sizes(n_items, max_jobs).collect();
                assert_eq!(
                    sizes.iter().sum::<usize>(),
                    n_items,
                    "n={n_items} jobs={max_jobs}"
                );
                assert_eq!(sizes.len(), max_jobs.min(n_items));
                if let (Some(max), Some(min)) =
                    (sizes.iter().max(), sizes.iter().min())
                {
                    assert!(max - min <= 1, "{sizes:?}");
                    assert!(*min >= 1, "no empty group: {sizes:?}");
                }
            }
        }
        assert_eq!(balanced_chunk_sizes(5, 0).sum::<usize>(), 5);
    }
}
