//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Wall-clock timing with warmup, adaptive iteration count, and robust
//! statistics. Used by `benches/*.rs` (cargo bench with `harness = false`)
//! and the experiment drivers.

use std::time::{Duration, Instant};

/// Result statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl BenchStats {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// One JSON object with the case's statistics (seconds).
    pub fn json(&self) -> String {
        format!(
            "{{\"name\":{},\"iters\":{},\"mean_s\":{:e},\"median_s\":{:e},\
             \"min_s\":{:e},\"stddev_s\":{:e}}}",
            json_str(&self.name),
            self.iters,
            self.mean.as_secs_f64(),
            self.median.as_secs_f64(),
            self.min.as_secs_f64(),
            self.stddev.as_secs_f64()
        )
    }

    /// `name  mean ± σ  (median, min, n)` line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:<10} (median {:>12}, min {:>12}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.median),
            fmt_dur(self.min),
            self.iters
        )
    }
}

/// Minimal JSON string escaping for bench-case names.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Human duration formatting at ns/us/ms/s granularity.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Bencher with a custom time budget and iteration cap (benches whose
    /// per-iteration state grows, e.g. a cache folding one token per
    /// iteration, use the cap to bound total growth).
    pub fn with_limits(
        warmup: Duration,
        budget: Duration,
        max_iters: u64,
    ) -> Bencher {
        Bencher { warmup, budget, max_iters, ..Default::default() }
    }

    /// Quick-mode bencher for CI / tests.
    pub fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(200),
            min_iters: 3,
            max_iters: 1000,
            ..Default::default()
        }
    }

    /// Time `f`, preventing dead-code elimination via the returned value.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // Warmup + estimate per-iter cost.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 1 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > self.max_iters {
                break;
            }
        }
        let per_iter = w0.elapsed().as_secs_f64() / warm_iters as f64;
        let target = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(target as usize);
        for _ in 0..target {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: target,
            mean,
            median: samples[n / 2],
            min: samples[0],
            stddev: Duration::from_secs_f64(var.sqrt()),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// All cases as one JSON array (machine-readable bench output).
    pub fn results_json(&self) -> String {
        let items: Vec<String> =
            self.results.iter().map(BenchStats::json).collect();
        format!("[{}]", items.join(","))
    }

    /// Speedup of `base` over `new` by case name.
    pub fn speedup(&self, base: &str, new: &str) -> Option<f64> {
        let b = self.results.iter().find(|r| r.name == base)?;
        let n = self.results.iter().find(|r| r.name == new)?;
        Some(b.mean_s() / n.mean_s())
    }
}

/// Simple text table printer for paper-style output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::quick();
        let stats = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(stats.iters >= 3);
        assert!(stats.min <= stats.median);
        assert!(stats.median <= stats.mean * 10);
    }

    #[test]
    fn speedup_lookup() {
        let mut b = Bencher::quick();
        b.bench("slow", || std::thread::sleep(Duration::from_micros(200)));
        b.bench("fast", || std::thread::sleep(Duration::from_micros(50)));
        let s = b.speedup("slow", "fast").unwrap();
        assert!(s > 1.5, "speedup {s}");
    }

    #[test]
    fn json_output_is_wellformed() {
        let mut b = Bencher::quick();
        b.bench("a \"quoted\" name", || 1 + 1);
        let s = b.results_json();
        assert!(s.starts_with('[') && s.ends_with(']'), "{s}");
        assert!(s.contains("\\\"quoted\\\""), "{s}");
        assert!(s.contains("\"mean_s\":"), "{s}");
        // And it parses with the crate's own JSON reader.
        let parsed = crate::util::json::Json::parse(&s).expect("valid json");
        assert!(parsed.as_arr().is_some());
    }

    #[test]
    fn fmt_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains('s'));
    }

    #[test]
    #[should_panic(expected = "column mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".into()]);
    }
}
