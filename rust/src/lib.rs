//! TurboAttention — reproduction of "TurboAttention: Efficient Attention
//! Approximation For High Throughput LLMs" (Kang et al., 2024) as a
//! three-layer Rust + JAX + Pallas serving stack.
//!
//! Layer 1 (build time): Pallas kernels implementing FlashQ + SAS
//! (`python/compile/kernels/`). Layer 2 (build time): a JAX transformer
//! whose attention runs through those kernels, AOT-lowered to HLO text
//! (`python/compile/`). Layer 3 (this crate): the serving coordinator —
//! PJRT runtime, quantized paged KV cache, continuous batcher, request
//! server — with Python never on the request path.
//!
//! See DESIGN.md for the full system inventory and the per-experiment
//! index, and EXPERIMENTS.md for paper-vs-measured results.

pub mod attention;
pub mod bench;
pub mod coordinator;
pub mod costmodel;
pub mod experiments;
pub mod kernels;
pub mod kvcache;
pub mod loadgen;
pub mod metrics;
pub mod model;
pub mod pool;
pub mod quant;
pub mod runtime;
pub mod sas;
pub mod server;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod workload;
