//! Paged, quantized KV cache — the Rust coordinator's ownership of the
//! paper's FlashQ storage hierarchy.
//!
//! Layout per (layer, head):
//!
//! ```text
//!   [ q2 pages: INT4/INT2 packed, bc tokens each ][ INT8 buffer: < n_b ]
//! ```
//!
//! * Prefill writes q1 (INT8 + per-block scale) blocks; the cache
//!   immediately compresses full blocks to q2 at the head's precision
//!   (paper Algorithm 1 write-back) and keeps the tail in the buffer.
//! * Decode appends one token at a time to the enhanced INT8 buffer
//!   (universal clamped scale — §3.3); when the buffer reaches `n_b`
//!   tokens it is flushed through progressive quantization into a page.
//! * Reads reconstruct the q1 view (INT8 codes + per-block scales) that
//!   the decode executable consumes; q2 -> q1 is pure integer work and is
//!   the optimized hot path.
//! * Each stream keeps an **incrementally materialized** q1 view
//!   ([`store::Q1View`]): pages are immutable once flushed, so each is
//!   dequantized exactly once when it appears, and buffer tokens are
//!   mirrored as they arrive. Decode reads are then O(new tokens) per
//!   step instead of O(context) — the fix for the per-token full-cache
//!   rematerialization the serving path used to do.
//! * Flushed pages live in a shared, **refcounted** [`pagepool::PagePool`]
//!   rather than inside the stream: sessions whose prompts share a
//!   page-aligned prefix adopt the same physical pages
//!   ([`store::StreamCache::adopt_pages`]), the pool memoizes each
//!   page's q1 dequantization lazily on first read (one memo globally,
//!   evictable under the pool's optional byte cap and recomputed on
//!   demand — it is derivable state), and exact shared/private byte
//!   accounting ([`pagepool::PoolStats`]) feeds the engine's dedup and
//!   memory-pressure metrics.
//! * Each page also carries a lazy [`page::PageSummary`] memo (per-channel
//!   key min/max envelope + per-channel V column mean) feeding the
//!   SparQ-style top-k page-sparse decode path. Summaries obey the same
//!   contract as q1 memos: **derivable state**, evictable under the pool
//!   byte cap *without* an epoch bump, recomputed from the immutable page
//!   on the next read. The sparse path's own invariants: top-k selection
//!   is deterministic (stable ties broken toward the lower page index, so
//!   thread-count invariance holds), and `k = 0` / `k >= pages` delegate
//!   to the dense block loop and are bit-identical to it.

pub mod buffer;
pub mod page;
pub mod pagepool;
pub mod precision;
pub mod store;

pub use buffer::DecodeBuffer;
pub use page::{PageSummary, QuantPage};
pub use pagepool::{
    PageHandle, PagePool, PoolEpoch, PoolStats, SharedPagePool,
};
pub use precision::PrecisionMap;
pub use store::{
    CacheStats, HeadCache, HeadCacheMut, KvCache, KvCacheConfig, Q1View,
    StreamCache,
};
