//! Shared, refcounted pool of immutable flushed q2 pages.
//!
//! PR 1 made flushed pages immutable (`QuantPage` never mutates after
//! `from_q1`), which is exactly the property that makes them *shareable*:
//! N batched sessions whose prompts share a page-aligned prefix can read
//! the same physical pages instead of each quantizing and storing a
//! private copy (the FlashInfer lesson — composable/shared page formats
//! are where serving-throughput memory wins live). This module is the
//! ownership layer that makes that safe:
//!
//! * [`PagePool`] owns every page behind an **explicit refcount** —
//!   `insert` creates a page with one owner, `retain`/`release` move
//!   ownership edges, and the page is freed exactly when the last owner
//!   releases it. Pages are *not* `Arc<QuantPage>`: an opaque `Arc`
//!   count could not distinguish shared from private storage, and the
//!   shared/private byte split ([`PoolStats`]) must stay exact for the
//!   dedup accounting in `EngineMetrics`.
//! * [`PageHandle`] is a generational index: a freed slot bumps its
//!   generation, so any handle kept past its last `release` is detected
//!   (`get`/`retain` panic on a stale handle) instead of silently
//!   reading a recycled page — the use-after-free check the refcount
//!   property tests lean on.
//! * Every page free bumps the pool **epoch**. Dependent incremental
//!   views (`store::Q1View`) record the epoch they were built under and
//!   re-verify their handles when it moves — the PR-1 invariant
//!   ("eviction/rewrite must invalidate the view") extended to the
//!   pooled world. A live stream's handles can never actually dangle
//!   (it holds a ref), so the check is free in steady state and loud
//!   the moment a future eviction path violates the contract.
//! * The pool memoizes each page's q1 dequantization at `insert`
//!   ([`PagePool::q1`]): the dequantize-once property that PR 1 gave
//!   each stream now amortizes across *sessions* — a page shared by N
//!   sessions is dequantized once globally, and every session's view
//!   sync is a memcpy.
//!
//! The pool itself is shared via [`SharedPagePool`]
//! (`Arc<RwLock<PagePool>>`, like the decode `WorkerPool`): the decode
//! hot path only ever takes the read lock (view sync from worker
//! threads is lock-concurrent), and mutations (insert on flush,
//! retain/release at session fork/teardown) are brief engine-thread
//! write locks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::QuantPage;

/// Shared handle onto one [`PagePool`] — cloned into every
/// [`StreamCache`](super::store::StreamCache) built over the pool.
pub type SharedPagePool = Arc<RwLock<PagePool>>;

/// Lock-free handle onto a pool's epoch counter (same shape as the
/// worker pool's `PoolProbe`): lets a view's steady-state sync check
/// "has anything been freed since I last looked?" with one relaxed
/// atomic load instead of taking the pool's read lock — the lock is
/// only acquired when pages actually need copying or the epoch moved.
#[derive(Debug, Clone)]
pub struct PoolEpoch(Arc<AtomicU64>);

impl PoolEpoch {
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Generational index of one pooled page. Copyable and cheap; validity
/// is checked against the slot's generation on every access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageHandle {
    index: u32,
    gen: u32,
}

/// One pool slot: the page (if live), its q1 memo, and the refcount.
#[derive(Debug, Default)]
struct Slot {
    page: Option<QuantPage>,
    /// Memoized q2 -> q1 dequantization (`tokens * channels` codes),
    /// computed once at insert — derivable metadata, like the per-page
    /// dequant tables.
    q1: Vec<i8>,
    refs: u32,
    gen: u32,
}

/// Aggregate pool accounting — the dedup signal next to the per-session
/// `CacheStats`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolStats {
    /// Pages currently live.
    pub live_pages: usize,
    /// Live pages with more than one owner.
    pub shared_pages: usize,
    /// Storage bytes actually held (each live page counted once).
    pub physical_bytes: usize,
    /// Storage bytes the owners *reference* (each page counted once per
    /// ref) — what the same sessions would hold with private caches.
    pub logical_bytes: usize,
    /// Physical bytes of pages with refs > 1.
    pub shared_bytes: usize,
    /// Physical bytes of pages with exactly one owner.
    pub private_bytes: usize,
    /// Bytes of the memoized q1 dequantizations (working memory, not
    /// storage — the pooled analogue of `CacheStats::view_bytes`).
    pub q1_memo_bytes: usize,
}

impl PoolStats {
    /// Fraction of referenced storage deduplicated away by sharing:
    /// `1 - physical / logical`. For B sessions sharing one prefix of P
    /// page-bytes (and nothing else), this is (B-1)/B.
    pub fn dedup_ratio(&self) -> f64 {
        if self.logical_bytes == 0 {
            0.0
        } else {
            1.0 - self.physical_bytes as f64 / self.logical_bytes as f64
        }
    }
}

/// The refcounted page store. See the module docs for the contract.
#[derive(Debug, Default)]
pub struct PagePool {
    slots: Vec<Slot>,
    /// Indices of freed slots available for reuse.
    free: Vec<u32>,
    /// Bumped on every page free — the view-invalidation signal.
    /// Atomic (and handed out via [`Self::epoch_probe`]) so the decode
    /// hot path can poll it without the pool lock.
    epoch: Arc<AtomicU64>,
}

impl PagePool {
    pub fn new() -> PagePool {
        PagePool::default()
    }

    /// A fresh pool behind the shared `Arc<RwLock<_>>` handle.
    pub fn new_shared() -> SharedPagePool {
        Arc::new(RwLock::new(PagePool::new()))
    }

    /// Move a page into the pool with one owner; dequantizes the q1
    /// memo once, here, so every later read is a copy.
    pub fn insert(&mut self, page: QuantPage) -> PageHandle {
        let q1 = page.dequant_q1();
        let index = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot::default());
                (self.slots.len() - 1) as u32
            }
        };
        let slot = &mut self.slots[index as usize];
        debug_assert!(slot.page.is_none(), "free list handed out a live slot");
        slot.page = Some(page);
        slot.q1 = q1;
        slot.refs = 1;
        PageHandle { index, gen: slot.gen }
    }

    fn slot(&self, h: PageHandle) -> &Slot {
        let slot = &self.slots[h.index as usize];
        assert!(
            slot.page.is_some() && slot.gen == h.gen,
            "stale page handle (use-after-free): {h:?}"
        );
        slot
    }

    /// The page behind a handle. Panics on a stale handle — a stale
    /// access is an ownership bug, never a runtime condition.
    pub fn get(&self, h: PageHandle) -> &QuantPage {
        self.slot(h).page.as_ref().expect("checked live")
    }

    /// The page's memoized q1 codes (`tokens * channels`).
    pub fn q1(&self, h: PageHandle) -> &[i8] {
        &self.slot(h).q1
    }

    /// Current owner count of a live page.
    pub fn refs(&self, h: PageHandle) -> u32 {
        self.slot(h).refs
    }

    /// Whether the handle still points at a live page (non-panicking —
    /// what index pruning and epoch re-verification use).
    pub fn is_live(&self, h: PageHandle) -> bool {
        self.slots
            .get(h.index as usize)
            .map(|s| s.page.is_some() && s.gen == h.gen)
            .unwrap_or(false)
    }

    /// Add one owner to a live page.
    pub fn retain(&mut self, h: PageHandle) {
        let slot = &mut self.slots[h.index as usize];
        assert!(
            slot.page.is_some() && slot.gen == h.gen,
            "retain of stale page handle: {h:?}"
        );
        slot.refs += 1;
    }

    /// Drop one owner; frees the page (and bumps the epoch + slot
    /// generation) when it was the last.
    pub fn release(&mut self, h: PageHandle) {
        let slot = &mut self.slots[h.index as usize];
        assert!(
            slot.page.is_some() && slot.gen == h.gen,
            "release of stale page handle: {h:?}"
        );
        slot.refs -= 1;
        if slot.refs == 0 {
            slot.page = None;
            slot.q1 = Vec::new();
            slot.gen = slot.gen.wrapping_add(1);
            self.free.push(h.index);
            self.epoch.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Teardown-path variant of [`Self::release`]: a no-op on a stale
    /// handle. Used by `StreamCache::drop` so that unwinding after a
    /// *detected* invariant violation (a page freed under a live view)
    /// cannot panic again inside drop and abort the process. Regular
    /// code paths must use the strict [`Self::release`].
    pub fn release_if_live(&mut self, h: PageHandle) {
        if self.is_live(h) {
            self.release(h);
        }
    }

    /// Monotone counter bumped on every page free — dependent views
    /// compare it to re-verify their handles (PR-1 invariant).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Lock-free epoch handle for the steady-state view fast path.
    pub fn epoch_probe(&self) -> PoolEpoch {
        PoolEpoch(Arc::clone(&self.epoch))
    }

    /// Live page count.
    pub fn live_pages(&self) -> usize {
        self.slots.iter().filter(|s| s.page.is_some()).count()
    }

    /// Exact shared/private accounting over every live page.
    pub fn stats(&self) -> PoolStats {
        let mut st = PoolStats::default();
        for slot in &self.slots {
            let Some(page) = &slot.page else { continue };
            let bytes = page.bytes();
            st.live_pages += 1;
            st.physical_bytes += bytes;
            st.logical_bytes += bytes * slot.refs as usize;
            st.q1_memo_bytes += slot.q1.len();
            if slot.refs > 1 {
                st.shared_pages += 1;
                st.shared_bytes += bytes;
            } else {
                st.private_bytes += bytes;
            }
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quant_sym_int8, Bits};
    use crate::testutil::{prop, Rng};

    fn page(rng: &mut Rng, tokens: usize, channels: usize) -> QuantPage {
        let x = rng.normal_vec(tokens * channels, 1.0);
        let q1 = quant_sym_int8(&x);
        QuantPage::from_q1(&q1.codes, tokens, channels, q1.scale, Bits::Int4)
    }

    #[test]
    fn insert_get_roundtrip_and_q1_memo() {
        let mut rng = Rng::new(1);
        let mut pool = PagePool::new();
        let p = page(&mut rng, 4, 8);
        let want = p.dequant_q1();
        let h = pool.insert(p);
        assert_eq!(pool.refs(h), 1);
        assert_eq!(pool.q1(h), &want[..], "memo == fresh dequantization");
        assert_eq!(pool.get(h).tokens, 4);
        assert_eq!(pool.live_pages(), 1);
    }

    #[test]
    fn release_frees_and_bumps_epoch() {
        let mut rng = Rng::new(2);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        pool.retain(h);
        assert_eq!(pool.refs(h), 2);
        let e0 = pool.epoch();
        pool.release(h);
        assert_eq!(pool.epoch(), e0, "non-final release must not bump epoch");
        assert!(pool.is_live(h));
        pool.release(h);
        assert_eq!(pool.epoch(), e0 + 1, "final release bumps the epoch");
        assert!(!pool.is_live(h));
        assert_eq!(pool.live_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "use-after-free")]
    fn stale_handle_get_panics() {
        let mut rng = Rng::new(3);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        pool.release(h);
        let _ = pool.get(h);
    }

    #[test]
    #[should_panic(expected = "retain of stale")]
    fn stale_handle_retain_panics() {
        let mut rng = Rng::new(4);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        pool.release(h);
        pool.retain(h);
    }

    #[test]
    fn slot_reuse_changes_generation() {
        let mut rng = Rng::new(5);
        let mut pool = PagePool::new();
        let h0 = pool.insert(page(&mut rng, 4, 8));
        pool.release(h0);
        // The freed slot is reused for the next insert...
        let h1 = pool.insert(page(&mut rng, 4, 8));
        assert_ne!(h0, h1, "generation must differ on slot reuse");
        // ...and the old handle stays dead even though the slot is live.
        assert!(!pool.is_live(h0));
        assert!(pool.is_live(h1));
    }

    #[test]
    fn stats_split_shared_and_private() {
        let mut rng = Rng::new(6);
        let mut pool = PagePool::new();
        let a = pool.insert(page(&mut rng, 4, 8)); // stays private
        let b = pool.insert(page(&mut rng, 4, 8));
        pool.retain(b); // shared by 2
        pool.retain(b); // shared by 3
        let st = pool.stats();
        assert_eq!(st.live_pages, 2);
        assert_eq!(st.shared_pages, 1);
        let ab = pool.get(a).bytes();
        let bb = pool.get(b).bytes();
        assert_eq!(st.physical_bytes, ab + bb);
        assert_eq!(st.logical_bytes, ab + 3 * bb);
        assert_eq!(st.private_bytes, ab);
        assert_eq!(st.shared_bytes, bb);
        assert!(st.q1_memo_bytes >= 2 * 4 * 8);
        let want = 1.0 - (ab + bb) as f64 / (ab + 3 * bb) as f64;
        assert!((st.dedup_ratio() - want).abs() < 1e-12);
    }

    #[test]
    fn empty_pool_stats_are_zero() {
        let pool = PagePool::new();
        let st = pool.stats();
        assert_eq!(st, PoolStats::default());
        assert_eq!(st.dedup_ratio(), 0.0);
    }

    /// Refcount conservation under random retain/release interleavings:
    /// every page is freed exactly when its last owner releases it, and
    /// the epoch counts exactly the frees.
    #[test]
    fn refcount_balance_property() {
        prop::run("pool refcount balance", 30, |g| {
            let mut rng = Rng::new(g.seed());
            let mut pool = PagePool::new();
            // (handle, remaining owners) ledger mirrored outside the pool.
            let mut ledger: Vec<(PageHandle, u32)> = Vec::new();
            let mut frees = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                match g.usize_in(0, 3) {
                    0 => {
                        let h = pool.insert(page(&mut rng, 2, 4));
                        ledger.push((h, 1));
                    }
                    1 if !ledger.is_empty() => {
                        let i = g.usize_in(0, ledger.len());
                        pool.retain(ledger[i].0);
                        ledger[i].1 += 1;
                    }
                    _ if !ledger.is_empty() => {
                        let i = g.usize_in(0, ledger.len());
                        pool.release(ledger[i].0);
                        ledger[i].1 -= 1;
                        if ledger[i].1 == 0 {
                            let (h, _) = ledger.swap_remove(i);
                            frees += 1;
                            assert!(!pool.is_live(h), "freed at zero refs");
                        }
                    }
                    _ => {}
                }
                // Invariants after every op.
                assert_eq!(pool.live_pages(), ledger.len());
                assert_eq!(pool.epoch(), frees);
                for &(h, refs) in &ledger {
                    assert!(pool.is_live(h));
                    assert_eq!(pool.refs(h), refs);
                }
            }
            // Drain: releasing every remaining owner empties the pool.
            for (h, refs) in ledger {
                for _ in 0..refs {
                    pool.release(h);
                }
            }
            assert_eq!(pool.live_pages(), 0);
        });
    }
}
