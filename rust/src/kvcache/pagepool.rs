//! Shared, refcounted pool of immutable flushed q2 pages.
//!
//! PR 1 made flushed pages immutable (`QuantPage` never mutates after
//! `from_q1`), which is exactly the property that makes them *shareable*:
//! N batched sessions whose prompts share a page-aligned prefix can read
//! the same physical pages instead of each quantizing and storing a
//! private copy (the FlashInfer lesson — composable/shared page formats
//! are where serving-throughput memory wins live). This module is the
//! ownership layer that makes that safe:
//!
//! * [`PagePool`] owns every page behind an **explicit refcount** —
//!   `insert` creates a page with one owner, `retain`/`release` move
//!   ownership edges, and the page is freed exactly when the last owner
//!   releases it. Pages are *not* `Arc<QuantPage>`: an opaque `Arc`
//!   count could not distinguish shared from private storage, and the
//!   shared/private byte split ([`PoolStats`]) must stay exact for the
//!   dedup accounting in `EngineMetrics`.
//! * [`PageHandle`] is a generational index: a freed slot bumps its
//!   generation, so any handle kept past its last `release` is detected
//!   (`get`/`retain` panic on a stale handle) instead of silently
//!   reading a recycled page — the use-after-free check the refcount
//!   property tests lean on.
//! * Every page free bumps the pool **epoch**. Dependent incremental
//!   views (`store::Q1View`) record the epoch they were built under and
//!   re-verify their handles when it moves — the PR-1 invariant
//!   ("eviction/rewrite must invalidate the view") extended to the
//!   pooled world. A live stream's handles can never actually dangle
//!   (it holds a ref), so the check is free in steady state and loud
//!   the moment a future eviction path violates the contract.
//! * The pool memoizes each page's q1 dequantization **lazily**, on the
//!   first [`PagePool::q1`] read (the first view sync that reaches the
//!   page): a page shared by N sessions is still dequantized once
//!   globally, and every session's view sync is a memcpy — but a page
//!   nobody reads costs no memo bytes.
//! * The memo is **derivable state** and therefore evictable: under a
//!   [byte cap](PagePool::set_byte_cap), [`PagePool::enforce_cap`]
//!   drops least-recently-used memos (XQuant's rematerialize-over-store
//!   argument applied to our own recomputable state). Evicting a memo
//!   does **not** bump the epoch — views *copy* memo contents, never
//!   alias them, so an existing view stays valid; the memo is simply
//!   recomputed from the immutable page on the next `q1` read (counted
//!   in [`PoolStats::memo_recomputes`]). Pages themselves are never
//!   evicted here: shrinking physical storage means releasing refs,
//!   which only the owners (engine preemption) may do, via the strict
//!   rules above.
//!
//! The pool itself is shared via [`SharedPagePool`]
//! (`Arc<RwLock<PagePool>>`, like the decode `WorkerPool`): the decode
//! hot path only ever takes the read lock (view sync from worker
//! threads is lock-concurrent — the lazy memo fill uses a per-slot
//! `OnceLock` so concurrent readers stay safe), and mutations (insert
//! on flush, retain/release at session fork/teardown, memo eviction)
//! are brief engine-thread write locks.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use super::{PageSummary, QuantPage};

/// Shared handle onto one [`PagePool`] — cloned into every
/// [`StreamCache`](super::store::StreamCache) built over the pool.
pub type SharedPagePool = Arc<RwLock<PagePool>>;

/// Lock-free handle onto a pool's epoch counter (same shape as the
/// worker pool's `PoolProbe`): lets a view's steady-state sync check
/// "has anything been freed since I last looked?" with one relaxed
/// atomic load instead of taking the pool's read lock — the lock is
/// only acquired when pages actually need copying or the epoch moved.
#[derive(Debug, Clone)]
pub struct PoolEpoch(Arc<AtomicU64>);

impl PoolEpoch {
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Generational index of one pooled page. Copyable and cheap; validity
/// is checked against the slot's generation on every access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageHandle {
    index: u32,
    gen: u32,
}

/// One pool slot: the page (if live), its lazy q1 memo, and the
/// refcount.
#[derive(Debug, Default)]
struct Slot {
    page: Option<QuantPage>,
    /// Memoized q2 -> q1 dequantization (`tokens * channels` codes).
    /// Filled on the first [`PagePool::q1`] read (under the pool's
    /// *read* lock — `OnceLock` makes concurrent first reads safe) and
    /// dropped by [`PagePool::enforce_cap`] under memory pressure.
    /// Derivable state: eviction never touches correctness, only cost.
    q1: OnceLock<Vec<i8>>,
    /// Lamport stamp of the last `q1` read — LRU victim selection key.
    last_used: AtomicU64,
    /// Set when the memo was evicted, so the next fill counts as a
    /// recompute rather than a first compute.
    q1_dropped: AtomicBool,
    /// Memoized [`PageSummary`] (min/max envelope + column mean) for the
    /// sparse decode path. Same lifecycle as the q1 memo: filled lazily
    /// on the first [`PagePool::summary`] read, evicted under the byte
    /// cap, recomputed from the immutable page — derivable state, so
    /// dropping it never bumps the epoch.
    summary: OnceLock<PageSummary>,
    /// Set when the summary memo was evicted (recompute accounting).
    summary_dropped: AtomicBool,
    refs: u32,
    gen: u32,
}

/// Aggregate pool accounting — the dedup signal next to the per-session
/// `CacheStats`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolStats {
    /// Pages currently live.
    pub live_pages: usize,
    /// Live pages with more than one owner.
    pub shared_pages: usize,
    /// Storage bytes actually held (each live page counted once).
    pub physical_bytes: usize,
    /// Storage bytes the owners *reference* (each page counted once per
    /// ref) — what the same sessions would hold with private caches.
    pub logical_bytes: usize,
    /// Physical bytes of pages with refs > 1.
    pub shared_bytes: usize,
    /// Physical bytes of pages with exactly one owner.
    pub private_bytes: usize,
    /// Bytes of the currently materialized q1 memos (working memory,
    /// not storage — the pooled analogue of `CacheStats::view_bytes`).
    /// Zero for pages nobody has read and for evicted memos.
    pub q1_memo_bytes: usize,
    /// Bytes of currently materialized page summaries (the sparse decode
    /// path's min/max/mean memos — same evictable tier as q1 memos).
    pub summary_memo_bytes: usize,
    /// Configured byte cap over `physical_bytes + q1_memo_bytes`
    /// (`None` = unbounded).
    pub byte_cap: Option<usize>,
    /// Memos dropped under pressure since pool creation (monotone).
    pub memo_evictions: u64,
    /// Memos rebuilt after an eviction since pool creation (monotone).
    pub memo_recomputes: u64,
}

impl PoolStats {
    /// Fraction of referenced storage deduplicated away by sharing:
    /// `1 - physical / logical`. For B sessions sharing one prefix of P
    /// page-bytes (and nothing else), this is (B-1)/B.
    pub fn dedup_ratio(&self) -> f64 {
        if self.logical_bytes == 0 {
            0.0
        } else {
            1.0 - self.physical_bytes as f64 / self.logical_bytes as f64
        }
    }
}

/// The refcounted page store. See the module docs for the contract.
#[derive(Debug, Default)]
pub struct PagePool {
    slots: Vec<Slot>,
    /// Indices of freed slots available for reuse.
    free: Vec<u32>,
    /// Bumped on every page free — the view-invalidation signal.
    /// Atomic (and handed out via [`Self::epoch_probe`]) so the decode
    /// hot path can poll it without the pool lock.
    epoch: Arc<AtomicU64>,
    /// Byte budget over pages + memos (`None` = unbounded).
    byte_cap: Option<usize>,
    /// Lamport clock stamping `Slot::last_used` on every `q1` read.
    clock: AtomicU64,
    /// Monotone pressure counters (atomics so the lock-concurrent `q1`
    /// read path can bump recomputes through `&self`).
    memo_evictions: AtomicU64,
    memo_recomputes: AtomicU64,
}

impl PagePool {
    pub fn new() -> PagePool {
        PagePool::default()
    }

    /// A fresh pool behind the shared `Arc<RwLock<_>>` handle.
    pub fn new_shared() -> SharedPagePool {
        Arc::new(RwLock::new(PagePool::new()))
    }

    /// Set (or clear) the byte cap enforced by [`Self::enforce_cap`]
    /// over `physical_bytes + q1_memo_bytes`.
    pub fn set_byte_cap(&mut self, cap: Option<usize>) {
        self.byte_cap = cap;
    }

    /// The configured byte cap, if any.
    pub fn byte_cap(&self) -> Option<usize> {
        self.byte_cap
    }

    /// Move a page into the pool with one owner. The q1 memo is *not*
    /// computed here — it materializes on the first [`Self::q1`] read.
    pub fn insert(&mut self, page: QuantPage) -> PageHandle {
        let index = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot::default());
                (self.slots.len() - 1) as u32
            }
        };
        let slot = &mut self.slots[index as usize];
        debug_assert!(slot.page.is_none(), "free list handed out a live slot");
        slot.page = Some(page);
        slot.q1 = OnceLock::new();
        slot.last_used = AtomicU64::new(0);
        slot.q1_dropped = AtomicBool::new(false);
        slot.summary = OnceLock::new();
        slot.summary_dropped = AtomicBool::new(false);
        slot.refs = 1;
        let h = PageHandle { index, gen: slot.gen };
        self.enforce_cap();
        h
    }

    fn slot(&self, h: PageHandle) -> &Slot {
        let slot = &self.slots[h.index as usize];
        assert!(
            slot.page.is_some() && slot.gen == h.gen,
            "stale page handle (use-after-free): {h:?}"
        );
        slot
    }

    /// The page behind a handle. Panics on a stale handle — a stale
    /// access is an ownership bug, never a runtime condition.
    pub fn get(&self, h: PageHandle) -> &QuantPage {
        self.slot(h).page.as_ref().expect("checked live")
    }

    /// The page's memoized q1 codes (`tokens * channels`), dequantized
    /// on first read (or re-dequantized after a cap eviction). Takes
    /// `&self`: worker-thread view syncs fill memos concurrently under
    /// the pool's read lock, serialized per slot by the `OnceLock`.
    pub fn q1(&self, h: PageHandle) -> &[i8] {
        let slot = self.slot(h);
        let now = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        slot.last_used.store(now, Ordering::Relaxed);
        slot.q1.get_or_init(|| {
            if slot.q1_dropped.swap(false, Ordering::Relaxed) {
                self.memo_recomputes.fetch_add(1, Ordering::Relaxed);
            }
            let page = slot.page.as_ref().expect("checked live");
            let mut out = vec![0i8; page.tokens * page.channels];
            let mut scratch = Vec::new();
            page.dequant_q1_into(&mut scratch, &mut out);
            out
        })
    }

    /// The page's memoized [`PageSummary`], computed on first read (or
    /// after a cap eviction). Same concurrency contract as [`Self::q1`]:
    /// `&self` under the pool's read lock, per-slot `OnceLock`. Reuses
    /// the q1 memo when it happens to be materialized; otherwise
    /// dequantizes into a local buffer without pinning a q1 memo.
    pub fn summary(&self, h: PageHandle) -> &PageSummary {
        let slot = self.slot(h);
        let now = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        slot.last_used.store(now, Ordering::Relaxed);
        slot.summary.get_or_init(|| {
            if slot.summary_dropped.swap(false, Ordering::Relaxed) {
                self.memo_recomputes.fetch_add(1, Ordering::Relaxed);
            }
            let page = slot.page.as_ref().expect("checked live");
            match slot.q1.get() {
                Some(codes) => {
                    PageSummary::from_q1(codes, page.tokens, page.channels)
                }
                None => {
                    let mut out = vec![0i8; page.tokens * page.channels];
                    let mut scratch = Vec::new();
                    page.dequant_q1_into(&mut scratch, &mut out);
                    PageSummary::from_q1(&out, page.tokens, page.channels)
                }
            }
        })
    }

    /// Current owner count of a live page.
    pub fn refs(&self, h: PageHandle) -> u32 {
        self.slot(h).refs
    }

    /// Whether the handle still points at a live page (non-panicking —
    /// what index pruning and epoch re-verification use).
    pub fn is_live(&self, h: PageHandle) -> bool {
        self.slots
            .get(h.index as usize)
            .map(|s| s.page.is_some() && s.gen == h.gen)
            .unwrap_or(false)
    }

    /// Add one owner to a live page.
    pub fn retain(&mut self, h: PageHandle) {
        let slot = &mut self.slots[h.index as usize];
        assert!(
            slot.page.is_some() && slot.gen == h.gen,
            "retain of stale page handle: {h:?}"
        );
        slot.refs += 1;
    }

    /// Drop one owner; frees the page (and bumps the epoch + slot
    /// generation) when it was the last.
    pub fn release(&mut self, h: PageHandle) {
        let slot = &mut self.slots[h.index as usize];
        assert!(
            slot.page.is_some() && slot.gen == h.gen,
            "release of stale page handle: {h:?}"
        );
        slot.refs -= 1;
        if slot.refs == 0 {
            slot.page = None;
            slot.q1 = OnceLock::new();
            slot.last_used = AtomicU64::new(0);
            slot.q1_dropped = AtomicBool::new(false);
            slot.summary = OnceLock::new();
            slot.summary_dropped = AtomicBool::new(false);
            slot.gen = slot.gen.wrapping_add(1);
            self.free.push(h.index);
            self.epoch.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Teardown-path variant of [`Self::release`]: a no-op on a stale
    /// handle. Used by `StreamCache::drop` so that unwinding after a
    /// *detected* invariant violation (a page freed under a live view)
    /// cannot panic again inside drop and abort the process. Regular
    /// code paths must use the strict [`Self::release`].
    pub fn release_if_live(&mut self, h: PageHandle) {
        if self.is_live(h) {
            self.release(h);
        }
    }

    /// Monotone counter bumped on every page free — dependent views
    /// compare it to re-verify their handles (PR-1 invariant).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Lock-free epoch handle for the steady-state view fast path.
    pub fn epoch_probe(&self) -> PoolEpoch {
        PoolEpoch(Arc::clone(&self.epoch))
    }

    /// Live page count.
    pub fn live_pages(&self) -> usize {
        self.slots.iter().filter(|s| s.page.is_some()).count()
    }

    /// Storage bytes of every live page (the irreducible tier — only
    /// owner releases can shrink it).
    pub fn physical_bytes(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.page.as_ref())
            .map(|p| p.bytes())
            .sum()
    }

    /// Bytes of currently materialized memos — q1 dequantizations plus
    /// page summaries, the whole evictable tier.
    pub fn memo_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| {
                s.q1.get().map_or(0, |v| v.len())
                    + s.summary.get().map_or(0, |sm| sm.bytes())
            })
            .sum()
    }

    /// Tier-1 pressure relief: while `physical + memo` exceeds the cap,
    /// drop the least-recently-used slot's materialized memos (its q1
    /// dequantization and page summary go together — they share the
    /// LRU stamp). Returns the number of victim slots evicted. Never
    /// frees pages (that is the owners' job, via `release`) and never
    /// bumps the epoch — views copy memo contents, so an eviction
    /// cannot invalidate anything; each memo is transparently
    /// recomputed on the next [`Self::q1`] / [`Self::summary`] read.
    pub fn enforce_cap(&mut self) -> usize {
        let Some(cap) = self.byte_cap else { return 0 };
        let physical = self.physical_bytes();
        let mut memo = self.memo_bytes();
        let mut evicted = 0usize;
        while physical + memo > cap {
            let victim = self.slots.iter_mut().filter(|s| {
                s.page.is_some()
                    && (s.q1.get().is_some() || s.summary.get().is_some())
            });
            let victim =
                victim.min_by_key(|s| s.last_used.load(Ordering::Relaxed));
            let Some(slot) = victim else { break };
            if let Some(v) = slot.q1.take() {
                memo -= v.len();
                slot.q1_dropped.store(true, Ordering::Relaxed);
            }
            if let Some(sm) = slot.summary.take() {
                memo -= sm.bytes();
                slot.summary_dropped.store(true, Ordering::Relaxed);
            }
            evicted += 1;
        }
        self.memo_evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        evicted
    }

    /// Exact shared/private accounting over every live page.
    pub fn stats(&self) -> PoolStats {
        let mut st = PoolStats {
            byte_cap: self.byte_cap,
            memo_evictions: self.memo_evictions.load(Ordering::Relaxed),
            memo_recomputes: self.memo_recomputes.load(Ordering::Relaxed),
            ..PoolStats::default()
        };
        for slot in &self.slots {
            let Some(page) = &slot.page else { continue };
            let bytes = page.bytes();
            st.live_pages += 1;
            st.physical_bytes += bytes;
            st.logical_bytes += bytes * slot.refs as usize;
            st.q1_memo_bytes += slot.q1.get().map_or(0, |v| v.len());
            st.summary_memo_bytes +=
                slot.summary.get().map_or(0, |sm| sm.bytes());
            if slot.refs > 1 {
                st.shared_pages += 1;
                st.shared_bytes += bytes;
            } else {
                st.private_bytes += bytes;
            }
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quant_sym_int8, Bits};
    use crate::testutil::{prop, Rng};

    fn page(rng: &mut Rng, tokens: usize, channels: usize) -> QuantPage {
        let x = rng.normal_vec(tokens * channels, 1.0);
        let q1 = quant_sym_int8(&x);
        QuantPage::from_q1(&q1.codes, tokens, channels, q1.scale, Bits::Int4)
    }

    #[test]
    #[allow(deprecated)]
    fn insert_get_roundtrip_and_lazy_q1_memo() {
        let mut rng = Rng::new(1);
        let mut pool = PagePool::new();
        let p = page(&mut rng, 4, 8);
        let want = p.dequant_q1();
        let h = pool.insert(p);
        assert_eq!(pool.refs(h), 1);
        assert_eq!(pool.stats().q1_memo_bytes, 0, "memo is lazy");
        assert_eq!(pool.q1(h), &want[..], "memo == fresh dequantization");
        assert_eq!(pool.stats().q1_memo_bytes, 4 * 8, "materialized on read");
        assert_eq!(pool.get(h).tokens, 4);
        assert_eq!(pool.live_pages(), 1);
    }

    #[test]
    fn release_frees_and_bumps_epoch() {
        let mut rng = Rng::new(2);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        pool.retain(h);
        assert_eq!(pool.refs(h), 2);
        let e0 = pool.epoch();
        pool.release(h);
        assert_eq!(pool.epoch(), e0, "non-final release must not bump epoch");
        assert!(pool.is_live(h));
        pool.release(h);
        assert_eq!(pool.epoch(), e0 + 1, "final release bumps the epoch");
        assert!(!pool.is_live(h));
        assert_eq!(pool.live_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "use-after-free")]
    fn stale_handle_get_panics() {
        let mut rng = Rng::new(3);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        pool.release(h);
        let _ = pool.get(h);
    }

    #[test]
    #[should_panic(expected = "retain of stale")]
    fn stale_handle_retain_panics() {
        let mut rng = Rng::new(4);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        pool.release(h);
        pool.retain(h);
    }

    #[test]
    fn slot_reuse_changes_generation() {
        let mut rng = Rng::new(5);
        let mut pool = PagePool::new();
        let h0 = pool.insert(page(&mut rng, 4, 8));
        pool.release(h0);
        // The freed slot is reused for the next insert...
        let h1 = pool.insert(page(&mut rng, 4, 8));
        assert_ne!(h0, h1, "generation must differ on slot reuse");
        // ...and the old handle stays dead even though the slot is live.
        assert!(!pool.is_live(h0));
        assert!(pool.is_live(h1));
    }

    #[test]
    fn stats_split_shared_and_private() {
        let mut rng = Rng::new(6);
        let mut pool = PagePool::new();
        let a = pool.insert(page(&mut rng, 4, 8)); // stays private
        let b = pool.insert(page(&mut rng, 4, 8));
        pool.retain(b); // shared by 2
        pool.retain(b); // shared by 3
        assert_eq!(pool.stats().q1_memo_bytes, 0, "no memo before any read");
        let _ = pool.q1(a);
        let _ = pool.q1(b);
        let st = pool.stats();
        assert_eq!(st.live_pages, 2);
        assert_eq!(st.shared_pages, 1);
        let ab = pool.get(a).bytes();
        let bb = pool.get(b).bytes();
        assert_eq!(st.physical_bytes, ab + bb);
        assert_eq!(st.logical_bytes, ab + 3 * bb);
        assert_eq!(st.private_bytes, ab);
        assert_eq!(st.shared_bytes, bb);
        assert_eq!(st.q1_memo_bytes, 2 * 4 * 8);
        let want = 1.0 - (ab + bb) as f64 / (ab + 3 * bb) as f64;
        assert!((st.dedup_ratio() - want).abs() < 1e-12);
    }

    #[test]
    fn empty_pool_stats_are_zero() {
        let pool = PagePool::new();
        let st = pool.stats();
        assert_eq!(st, PoolStats::default());
        assert_eq!(st.dedup_ratio(), 0.0);
    }

    #[test]
    #[allow(deprecated)]
    fn memo_eviction_recomputes_identically_without_epoch_bump() {
        let mut rng = Rng::new(7);
        let mut pool = PagePool::new();
        let p = page(&mut rng, 4, 8);
        let want = p.dequant_q1();
        let h = pool.insert(p);
        assert_eq!(pool.q1(h), &want[..]);
        let e0 = pool.epoch();
        // Cap below physical + memo: the memo must go, the page stays.
        pool.set_byte_cap(Some(pool.physical_bytes()));
        assert_eq!(pool.enforce_cap(), 1);
        let st = pool.stats();
        assert_eq!(st.q1_memo_bytes, 0, "memo evicted");
        assert_eq!(st.memo_evictions, 1);
        assert_eq!(pool.epoch(), e0, "memo eviction must not bump the epoch");
        assert!(pool.is_live(h), "pages are never freed by the cap");
        assert_eq!(pool.refs(h), 1);
        // The next read transparently rematerializes the same bytes.
        assert_eq!(pool.q1(h), &want[..], "recompute == original");
        assert_eq!(pool.stats().memo_recomputes, 1);
        assert_eq!(pool.enforce_cap(), 1, "and it is evictable again");
    }

    #[test]
    #[allow(deprecated)]
    fn summary_memo_is_lazy_evictable_and_recomputes_identically() {
        let mut rng = Rng::new(17);
        let mut pool = PagePool::new();
        let p = page(&mut rng, 4, 8);
        let want = PageSummary::from_q1(&p.dequant_q1(), 4, 8);
        let h = pool.insert(p);
        assert_eq!(pool.stats().summary_memo_bytes, 0, "summary is lazy");
        let got = pool.summary(h).clone();
        assert_eq!(got.min, want.min);
        assert_eq!(got.max, want.max);
        assert_eq!(got.mean, want.mean);
        assert_eq!(pool.stats().summary_memo_bytes, want.bytes());
        let e0 = pool.epoch();
        // Cap at bare page bytes: the summary memo must go, no epoch
        // bump (derivable state, same contract as q1 memos).
        pool.set_byte_cap(Some(pool.physical_bytes()));
        assert_eq!(pool.enforce_cap(), 1);
        assert_eq!(pool.stats().summary_memo_bytes, 0, "summary evicted");
        assert_eq!(pool.epoch(), e0, "summary eviction never bumps epoch");
        assert!(pool.is_live(h));
        // Recompute on next read returns identical values and counts.
        let again = pool.summary(h);
        assert_eq!(again.min, want.min);
        assert_eq!(again.max, want.max);
        assert_eq!(again.mean, want.mean);
        assert_eq!(pool.stats().memo_recomputes, 1);
    }

    #[test]
    fn cap_evicts_q1_and_summary_memos_together() {
        let mut rng = Rng::new(18);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        let _ = pool.q1(h);
        let _ = pool.summary(h);
        let both = pool.memo_bytes();
        assert!(both > 4 * 8, "both memo kinds materialized");
        pool.set_byte_cap(Some(pool.physical_bytes()));
        assert_eq!(pool.enforce_cap(), 1, "one victim slot covers both");
        assert_eq!(pool.memo_bytes(), 0);
    }

    #[test]
    fn cap_evicts_least_recently_used_memo_first() {
        let mut rng = Rng::new(8);
        let mut pool = PagePool::new();
        let mut hs = Vec::new();
        for _ in 0..3 {
            hs.push(pool.insert(page(&mut rng, 4, 8)));
        }
        for &h in &hs {
            let _ = pool.q1(h);
        }
        // Re-touch 0 and 2: page 1 becomes the LRU memo.
        let _ = pool.q1(hs[0]);
        let _ = pool.q1(hs[2]);
        pool.set_byte_cap(Some(pool.physical_bytes() + 2 * 4 * 8));
        assert_eq!(pool.enforce_cap(), 1, "exactly one memo over budget");
        // Recently used memos survived: re-reading them recomputes
        // nothing, while the LRU victim rebuilds.
        let _ = pool.q1(hs[0]);
        let _ = pool.q1(hs[2]);
        assert_eq!(pool.stats().memo_recomputes, 0, "MRU memos survived");
        let _ = pool.q1(hs[1]);
        assert_eq!(pool.stats().memo_recomputes, 1, "LRU memo was the victim");
    }

    #[test]
    fn cap_cannot_evict_below_physical_bytes() {
        let mut rng = Rng::new(9);
        let mut pool = PagePool::new();
        let h = pool.insert(page(&mut rng, 4, 8));
        let _ = pool.q1(h);
        // Cap below even the bare page bytes: eviction drops the memo
        // and then stops — pages are owner-managed, never cap-freed.
        pool.set_byte_cap(Some(1));
        assert_eq!(pool.enforce_cap(), 1);
        assert_eq!(pool.enforce_cap(), 0, "no memos left to evict");
        assert!(pool.is_live(h));
        assert!(pool.physical_bytes() > 1, "page storage is irreducible");
    }

    /// Refcount conservation under random retain/release interleavings:
    /// every page is freed exactly when its last owner releases it, and
    /// the epoch counts exactly the frees.
    #[test]
    fn refcount_balance_property() {
        prop::run("pool refcount balance", 30, |g| {
            let mut rng = Rng::new(g.seed());
            let mut pool = PagePool::new();
            // (handle, remaining owners) ledger mirrored outside the pool.
            let mut ledger: Vec<(PageHandle, u32)> = Vec::new();
            let mut frees = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                match g.usize_in(0, 3) {
                    0 => {
                        let h = pool.insert(page(&mut rng, 2, 4));
                        ledger.push((h, 1));
                    }
                    1 if !ledger.is_empty() => {
                        let i = g.usize_in(0, ledger.len());
                        pool.retain(ledger[i].0);
                        ledger[i].1 += 1;
                    }
                    _ if !ledger.is_empty() => {
                        let i = g.usize_in(0, ledger.len());
                        pool.release(ledger[i].0);
                        ledger[i].1 -= 1;
                        if ledger[i].1 == 0 {
                            let (h, _) = ledger.swap_remove(i);
                            frees += 1;
                            assert!(!pool.is_live(h), "freed at zero refs");
                        }
                    }
                    _ => {}
                }
                // Invariants after every op.
                assert_eq!(pool.live_pages(), ledger.len());
                assert_eq!(pool.epoch(), frees);
                for &(h, refs) in &ledger {
                    assert!(pool.is_live(h));
                    assert_eq!(pool.refs(h), refs);
                }
            }
            // Drain: releasing every remaining owner empties the pool.
            for (h, refs) in ledger {
                for _ in 0..refs {
                    pool.release(h);
                }
            }
            assert_eq!(pool.live_pages(), 0);
        });
    }

    /// The eviction-safety property (ISSUE 7 satellite): random
    /// interleavings of insert/retain/release *with cap-driven memo
    /// eviction and q1 reads* preserve every refcount invariant — pages
    /// with refs > 0 are never freed, the epoch counts exactly the
    /// frees (memo evictions bump nothing), stale handles stay dead,
    /// and every q1 read returns the page's exact dequantization no
    /// matter how often its memo was dropped in between.
    #[test]
    #[allow(deprecated)]
    fn cap_eviction_safety_property() {
        prop::run("pool cap eviction safety", 30, |g| {
            let mut rng = Rng::new(g.seed());
            let mut pool = PagePool::new();
            // Tiny cap: with 2x4 pages (28 bytes each, 8-byte memos)
            // almost every insert/read runs over budget.
            pool.set_byte_cap(Some(g.usize_in(30, 120)));
            // (handle, remaining owners, expected q1) ledger.
            let mut ledger: Vec<(PageHandle, u32, Vec<i8>)> = Vec::new();
            let mut dead: Vec<PageHandle> = Vec::new();
            let mut frees = 0u64;
            for _ in 0..g.usize_in(1, 80) {
                match g.usize_in(0, 5) {
                    0 => {
                        let p = page(&mut rng, 2, 4);
                        let want = p.dequant_q1();
                        let h = pool.insert(p);
                        ledger.push((h, 1, want));
                    }
                    1 if !ledger.is_empty() => {
                        let i = g.usize_in(0, ledger.len());
                        pool.retain(ledger[i].0);
                        ledger[i].1 += 1;
                    }
                    2 if !ledger.is_empty() => {
                        let i = g.usize_in(0, ledger.len());
                        pool.release(ledger[i].0);
                        ledger[i].1 -= 1;
                        if ledger[i].1 == 0 {
                            let (h, _, _) = ledger.swap_remove(i);
                            frees += 1;
                            dead.push(h);
                        }
                    }
                    3 if !ledger.is_empty() => {
                        // Read q1 — possibly a recompute after eviction.
                        let i = g.usize_in(0, ledger.len());
                        let (h, _, ref want) = ledger[i];
                        assert_eq!(pool.q1(h), &want[..], "q1 stable");
                    }
                    4 => {
                        pool.enforce_cap();
                    }
                    _ => {}
                }
                // Invariants after every op.
                let st = pool.stats();
                assert_eq!(st.live_pages, ledger.len());
                assert_eq!(pool.epoch(), frees, "epoch == page frees only");
                if let Some(cap) = st.byte_cap {
                    // The evictable tier is fully reclaimable: at most
                    // one enforce_cap brings memos within whatever the
                    // cap leaves above irreducible page storage.
                    pool.enforce_cap();
                    let st = pool.stats();
                    assert!(
                        st.physical_bytes + st.q1_memo_bytes
                            <= cap.max(st.physical_bytes),
                        "memos within cap headroom after enforcement"
                    );
                }
                for &(h, refs, _) in &ledger {
                    assert!(pool.is_live(h), "refs > 0 page never freed");
                    assert_eq!(pool.refs(h), refs);
                }
                for &h in &dead {
                    assert!(!pool.is_live(h), "stale handles stay dead");
                }
            }
            // Drain and confirm the counters moved only as evictions.
            for (h, refs, _) in ledger {
                for _ in 0..refs {
                    pool.release(h);
                }
            }
            assert_eq!(pool.live_pages(), 0);
        });
    }
}
