//! Head-wise precision assignment (paper §3.2) for the whole model.

use crate::quant::{head_score, select_2bit_heads, Bits, HeadStats, SelectionRule};

/// Per-(layer, head) storage precision for the q2 KV cache.
#[derive(Debug, Clone)]
pub struct PrecisionMap {
    pub n_layers: usize,
    pub n_heads: usize,
    /// bits[layer * n_heads + head]
    bits: Vec<Bits>,
}

impl PrecisionMap {
    /// Uniform precision for every head.
    pub fn uniform(n_layers: usize, n_heads: usize, bits: Bits) -> PrecisionMap {
        PrecisionMap { n_layers, n_heads, bits: vec![bits; n_layers * n_heads] }
    }

    /// Mixed precision from calibration statistics: per layer, the `n_h`
    /// lowest-priority heads get 2-bit, the rest 4-bit (Eq. 12).
    ///
    /// `stats[layer][head]` are K (or K+V merged) calibration stats.
    pub fn mixed_from_stats(
        stats: &[Vec<HeadStats>],
        n_h: usize,
        rule: SelectionRule,
    ) -> PrecisionMap {
        let n_layers = stats.len();
        let n_heads = stats.first().map(|l| l.len()).unwrap_or(0);
        let mut bits = Vec::with_capacity(n_layers * n_heads);
        for layer in stats {
            assert_eq!(layer.len(), n_heads, "ragged head stats");
            let scores: Vec<f32> =
                layer.iter().map(|s| head_score(s, rule)).collect();
            let mask = select_2bit_heads(&scores, n_h);
            bits.extend(
                mask.iter().map(|&two| if two { Bits::Int2 } else { Bits::Int4 }),
            );
        }
        PrecisionMap { n_layers, n_heads, bits }
    }

    pub fn get(&self, layer: usize, head: usize) -> Bits {
        self.bits[layer * self.n_heads + head]
    }

    pub fn set(&mut self, layer: usize, head: usize, bits: Bits) {
        self.bits[layer * self.n_heads + head] = bits;
    }

    /// Average storage bits per cached element (the "Bit" column of
    /// Table 2).
    pub fn avg_bits(&self) -> f64 {
        let total: u32 = self.bits.iter().map(|b| b.bits()).sum();
        total as f64 / self.bits.len() as f64
    }

    pub fn count(&self, bits: Bits) -> usize {
        self.bits.iter().filter(|&&b| b == bits).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn stats_with_outlier(rng: &mut Rng, outlier: bool) -> HeadStats {
        let mut data = rng.normal_vec(64 * 8, 1.0);
        if outlier {
            for t in 0..64 {
                data[t * 8 + 2] *= 12.0;
            }
        }
        HeadStats::from_slab(&data, 64, 8)
    }

    #[test]
    fn uniform_map() {
        let m = PrecisionMap::uniform(2, 4, Bits::Int4);
        assert_eq!(m.get(1, 3), Bits::Int4);
        assert_eq!(m.avg_bits(), 4.0);
    }

    #[test]
    fn mixed_assigns_2bit_to_low_priority() {
        let mut rng = Rng::new(0);
        // Layer with heads [plain, outlier, plain, outlier]:
        let layer: Vec<HeadStats> = (0..4)
            .map(|h| stats_with_outlier(&mut rng, h % 2 == 1))
            .collect();
        let m = PrecisionMap::mixed_from_stats(
            &[layer],
            2,
            SelectionRule::Priority,
        );
        // The outlier heads (1, 3) must stay 4-bit.
        assert_eq!(m.get(0, 1), Bits::Int4);
        assert_eq!(m.get(0, 3), Bits::Int4);
        assert_eq!(m.get(0, 0), Bits::Int2);
        assert_eq!(m.get(0, 2), Bits::Int2);
        assert_eq!(m.avg_bits(), 3.0);
    }

    #[test]
    fn half_heads_2bit_gives_3_avg_bits() {
        let mut rng = Rng::new(1);
        let stats: Vec<Vec<HeadStats>> = (0..3)
            .map(|_| (0..8).map(|_| stats_with_outlier(&mut rng, false)).collect())
            .collect();
        let m = PrecisionMap::mixed_from_stats(&stats, 4, SelectionRule::Priority);
        assert_eq!(m.avg_bits(), 3.0);
        assert_eq!(m.count(Bits::Int2), 12);
        assert_eq!(m.count(Bits::Int4), 12);
    }
}
