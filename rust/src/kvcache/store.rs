//! The full model-level quantized KV cache: pooled pages + buffers per
//! (layer, head, K/V), with memory accounting and an incrementally
//! materialized q1 view per stream (the decode hot path).
//!
//! Since the shared-pool refactor, a stream does not *own* its flushed
//! q2 pages: it holds [`PageHandle`]s into a refcounted [`PagePool`]
//! shared by every session of a backend. Private sessions behave as
//! before (every page has one owner); prefix-sharing sessions adopt the
//! donor's handles ([`StreamCache::adopt_pages`]) so N sessions with a
//! common prompt prefix store those pages once.

use super::pagepool::{PageHandle, PagePool, PoolEpoch, SharedPagePool};
use super::{DecodeBuffer, PrecisionMap, QuantPage};
use crate::quant::Bits;

/// Cache geometry and policy.
#[derive(Debug, Clone)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Page size in tokens (= the attention tile B_c).
    pub block: usize,
    /// Decode-buffer capacity n_b (paper uses 64). Must equal `block`:
    /// a flush turns the buffer into exactly one full page, which the
    /// page-aligned q1 view layout (and `read_q1_into`) depends on.
    pub n_b: usize,
    pub precision: PrecisionMap,
}

impl KvCacheConfig {
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        block: usize,
        precision: PrecisionMap,
    ) -> KvCacheConfig {
        KvCacheConfig { n_layers, n_heads, d_head, block, n_b: block, precision }
    }
}

/// Incrementally materialized q1 (INT8 codes + per-block scale) view of
/// one stream — what the decode path reads instead of re-dequantizing the
/// whole cache on every generated token.
///
/// Why dequantize-once is safe: pages are immutable after flush (see
/// [`QuantPage`]), and buffer codes are append-only within an epoch (the
/// universal scale is fixed at the epoch's first token — paper §3.3), so
/// a region copied into the view never changes underneath it. The
/// invalidation events are (1) a buffer flush, which converts the
/// mirrored buffer tail into a new page — the next sync rewrites exactly
/// that region with the page's (lossier) q2 -> q1 dequantization — and
/// (2) a [`PagePool`] epoch move (some page somewhere was freed), after
/// which the view re-verifies that every handle it mirrors is still
/// live. A live stream holds a ref on each of its pages, so (2) is a
/// pure invariant check: it fires only if an eviction path violates the
/// refcount contract, and then it fires loudly.
///
/// The view is derivable metadata, like the pages' dequant tables: it is
/// excluded from the storage accounting in [`StreamCache::bytes`] and
/// reported separately via [`CacheStats::view_bytes`].
#[derive(Debug, Default)]
pub struct Q1View {
    /// Materialized INT8 codes `[capacity_tokens * d_head]`; the first
    /// `valid_tokens * d_head` entries are meaningful. Page-aligned: page
    /// `i` occupies tokens `[i*block, (i+1)*block)`.
    codes: Vec<i8>,
    /// One q1 scale per `block` tokens (pages' `fp_scale`, then the
    /// buffer's universal scale for the tail group).
    scales: Vec<f32>,
    /// Tokens currently materialized (page region + mirrored buffer tail).
    valid_tokens: usize,
    /// Pages copied from the pool memo so far — each exactly once.
    valid_pages: usize,
    /// Buffer tokens mirrored after the page region.
    buffered: usize,
    /// Pool epoch the view was last verified against; a moved epoch
    /// triggers handle re-verification (see type docs).
    pool_epoch: u64,
}

impl Q1View {
    pub fn valid_tokens(&self) -> usize {
        self.valid_tokens
    }

    pub fn valid_pages(&self) -> usize {
        self.valid_pages
    }

    /// Working-memory bytes held by the view (codes + scales).
    pub fn overhead_bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len()
    }
}

/// One K or V stream for one (layer, head): pooled q2 page handles + the
/// INT8 decode buffer. Holds one ref on every page it lists; refs are
/// released on drop.
#[derive(Debug)]
pub struct StreamCache {
    /// Handles of this stream's pages, oldest first. Every page is
    /// exactly `block` tokens (`ingest_q1_block` only pages full groups
    /// and a flush drains a full buffer), which keeps `tokens()` and the
    /// page-aligned view layout pool-free.
    pub pages: Vec<PageHandle>,
    pub buffer: DecodeBuffer,
    pool: SharedPagePool,
    /// Lock-free mirror of the pool's epoch — the steady-state sync
    /// polls this instead of taking the pool read lock.
    epoch: PoolEpoch,
    view: Q1View,
    bits: Bits,
    d_head: usize,
    block: usize,
}

impl StreamCache {
    fn new(
        d_head: usize,
        block: usize,
        n_b: usize,
        bits: Bits,
        pool: SharedPagePool,
        epoch: PoolEpoch,
    ) -> StreamCache {
        StreamCache {
            pages: Vec::new(),
            buffer: DecodeBuffer::new(d_head, n_b),
            pool,
            epoch,
            view: Q1View::default(),
            bits,
            d_head,
            block,
        }
    }

    /// Tokens stored (pages + buffer). Pool-free: every page holds
    /// exactly `block` tokens by construction.
    pub fn tokens(&self) -> usize {
        self.pages.len() * self.block + self.buffer.len()
    }

    /// The pool this stream's pages live in.
    pub fn page_pool(&self) -> &SharedPagePool {
        &self.pool
    }

    /// Move a freshly built page into the pool and append its handle.
    fn push_page(&mut self, page: QuantPage) {
        let h = self
            .pool
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(page);
        self.pages.push(h);
    }

    /// Adopt already-pooled pages as this stream's prefix (prefix
    /// sharing): retains one ref per handle. The stream must be empty —
    /// adopted pages form the page-aligned head of the stream.
    pub fn adopt_pages(&mut self, handles: &[PageHandle]) {
        assert!(
            self.pages.is_empty() && self.buffer.is_empty(),
            "adopt_pages into a non-empty stream"
        );
        let mut pool = self.pool.write().unwrap_or_else(|e| e.into_inner());
        for &h in handles {
            debug_assert_eq!(
                pool.get(h).tokens,
                self.block,
                "adopted page must be one full block"
            );
            pool.retain(h);
            self.pages.push(h);
        }
    }

    /// Ingest a prefill q1 block (INT8 codes, one fp scale, `tokens`
    /// tokens). Full `block`-sized groups become pages immediately
    /// (Algorithm 1 write-back); a trailing partial group seeds the
    /// buffer with the block's scale as the universal scale.
    pub fn ingest_q1_block(&mut self, codes: &[i8], fp_scale: f32, tokens: usize) {
        assert_eq!(codes.len(), tokens * self.d_head);
        let mut t0 = 0;
        while t0 < tokens {
            let t1 = (t0 + self.block).min(tokens);
            let chunk = &codes[t0 * self.d_head..t1 * self.d_head];
            if t1 - t0 == self.block && self.buffer.is_empty() {
                self.push_page(QuantPage::from_q1(
                    chunk,
                    self.block,
                    self.d_head,
                    fp_scale,
                    self.bits,
                ));
            } else {
                // Partial group (or buffer already seeded): go through the
                // buffer token by token to preserve flush semantics.
                for t in t0..t1 {
                    let row = &codes[t * self.d_head..(t + 1) * self.d_head];
                    let vals: Vec<f32> =
                        row.iter().map(|&c| c as f32 * fp_scale).collect();
                    self.push_token(&vals);
                }
                t0 = t1;
                continue;
            }
            t0 = t1;
        }
    }

    /// Append one decode token (float channel vector); flushes the buffer
    /// into a q2 page when it reaches capacity.
    pub fn push_token(&mut self, values: &[f32]) {
        let full = self.buffer.push(values);
        if full {
            let (codes, scale, tokens) = self.buffer.drain();
            self.push_page(QuantPage::from_q1(
                &codes,
                tokens,
                self.d_head,
                scale,
                self.bits,
            ));
        }
    }

    /// Materialize the q1 view into caller buffers:
    /// `q1` is `[capacity_tokens, d_head]` (page-aligned capacity), and
    /// `scales` one entry per `block` tokens. Returns valid token count.
    ///
    /// This is the from-scratch oracle the incremental view is tested
    /// against, so it dequantizes the pages directly rather than reading
    /// the pool's q1 memo.
    pub fn read_q1_into(
        &self,
        scratch: &mut Vec<u8>,
        q1: &mut [i8],
        scales: &mut [f32],
    ) -> usize {
        let d = self.d_head;
        let pool = self.pool.read().unwrap_or_else(|e| e.into_inner());
        let mut t = 0usize;
        for (pi, &h) in self.pages.iter().enumerate() {
            let page = pool.get(h);
            debug_assert_eq!(page.tokens, self.block, "non-final page must be full");
            page.dequant_q1_into(
                scratch,
                &mut q1[t * d..(t + page.tokens) * d],
            );
            scales[pi] = page.fp_scale;
            t += page.tokens;
        }
        let bl = self.buffer.len();
        if bl > 0 {
            debug_assert_eq!(t % self.block, 0);
            q1[t * d..(t + bl) * d].copy_from_slice(self.buffer.codes());
            scales[t / self.block] = self.buffer.scale();
            t += bl;
        }
        t
    }

    /// Bring the materialized q1 view up to date and return it as
    /// `(codes, scales, valid_tokens)` — the decode path's borrowed,
    /// zero-copy cache read.
    ///
    /// Work done is proportional to what changed since the last call:
    /// pages created since then are copied from the pool's
    /// dequantize-once q1 memo (materialized lazily by the first
    /// session's sync to read the page — shared pages pay it once
    /// across all sessions; under a pool byte cap the memo may have
    /// been evicted and is transparently recomputed by `PagePool::q1`,
    /// which is safe precisely because the view *copies* memo contents
    /// and never aliases them), and only buffer tokens not yet
    /// mirrored are copied.
    /// Steady-state decode (one `push_token` between syncs) costs
    /// O(d_head) per call, versus O(tokens * d_head) for a fresh
    /// [`Self::read_q1_into`].
    ///
    /// `codes` may be longer than `valid_tokens * d_head` (page-aligned
    /// backing with buffer headroom); callers must use the returned count.
    pub fn q1_view(&mut self) -> (&[i8], &[f32], usize) {
        let d = self.d_head;
        let b = self.block;
        let n_pages = self.pages.len();
        // Steady-state fast path: nothing freed anywhere (lock-free
        // epoch poll) and no new pages to copy — the pool is not
        // touched at all, so B sharing sessions' syncs don't contend
        // on the pool lock. The slow path below re-reads the epoch
        // under the lock before trusting it.
        if self.epoch.get() != self.view.pool_epoch
            || self.view.valid_pages < n_pages
        {
            let pool = self.pool.read().unwrap_or_else(|e| e.into_inner());
            let ep = pool.epoch();
            if ep != self.view.pool_epoch {
                // Some page somewhere was freed since the last sync. Our
                // refs should make that impossible for *our* pages —
                // verify it (the PR-1 eviction-invalidates-views rule).
                for &h in &self.pages {
                    assert!(
                        pool.is_live(h),
                        "page freed under a live view (pool epoch {ep})"
                    );
                }
                self.view.pool_epoch = ep;
            }
            if self.view.valid_pages < n_pages {
                // Grow in page steps, keeping one page of headroom for the
                // buffer tail (buffer capacity n_b <= block).
                self.view.codes.resize((n_pages + 1) * b * d, 0);
                self.view.scales.resize(n_pages + 1, 0.0);
                for pi in self.view.valid_pages..n_pages {
                    let h = self.pages[pi];
                    debug_assert_eq!(
                        pool.get(h).tokens,
                        b,
                        "non-final page must be full"
                    );
                    let o = pi * b * d;
                    self.view.codes[o..o + b * d]
                        .copy_from_slice(pool.q1(h));
                    self.view.scales[pi] = pool.get(h).fp_scale;
                }
                self.view.valid_pages = n_pages;
                // A flush consumed the buffer tokens this view had
                // mirrored; the page copy above rewrote that region.
                self.view.buffered = 0;
            }
        }
        let base = n_pages * b;
        let bl = self.buffer.len();
        if bl > self.view.buffered {
            if self.view.codes.len() < (base + b) * d {
                self.view.codes.resize((base + b) * d, 0);
            }
            if self.view.scales.len() <= n_pages {
                self.view.scales.resize(n_pages + 1, 0.0);
            }
            let src = self.buffer.codes();
            self.view.codes[(base + self.view.buffered) * d..(base + bl) * d]
                .copy_from_slice(&src[self.view.buffered * d..bl * d]);
            self.view.scales[n_pages] = self.buffer.scale();
            self.view.buffered = bl;
        }
        self.view.valid_tokens = base + bl;
        (&self.view.codes, &self.view.scales, self.view.valid_tokens)
    }

    /// Read access to the view's bookkeeping (tests / accounting).
    pub fn view(&self) -> &Q1View {
        &self.view
    }

    /// Working-memory bytes held by the materialized view.
    pub fn view_bytes(&self) -> usize {
        self.view.overhead_bytes()
    }

    /// Storage bytes referenced by this stream (packed pages + buffer
    /// codes). Shared pages are counted in full here — this is the
    /// *logical* per-session footprint; the physical/shared split lives
    /// in [`CacheStats::shared_page_bytes`] and the pool stats.
    pub fn bytes(&self) -> usize {
        let pool = self.pool.read().unwrap_or_else(|e| e.into_inner());
        self.bytes_in(&pool)
    }

    /// [`Self::bytes`] against an already-locked pool.
    pub fn bytes_in(&self, pool: &PagePool) -> usize {
        self.pages.iter().map(|&h| pool.get(h).bytes()).sum::<usize>()
            + self.buffer.len() * self.d_head
            + 4
    }

    /// (shared, private) page-storage bytes of this stream, judged by
    /// the pool's current refcounts.
    pub fn shared_private_bytes_in(&self, pool: &PagePool) -> (usize, usize) {
        let mut shared = 0usize;
        let mut private = 0usize;
        for &h in &self.pages {
            let b = pool.get(h).bytes();
            if pool.refs(h) > 1 {
                shared += b;
            } else {
                private += b;
            }
        }
        (shared, private)
    }
}

impl Drop for StreamCache {
    fn drop(&mut self) {
        if self.pages.is_empty() {
            return;
        }
        let mut pool = self.pool.write().unwrap_or_else(|e| e.into_inner());
        if std::thread::panicking() {
            // Unwinding (possibly from a detected invariant violation —
            // a page freed under a live view): a strict release would
            // panic in drop and abort the process, so be lenient here
            // and only here.
            for &h in &self.pages {
                pool.release_if_live(h);
            }
        } else {
            // Normal teardown stays strict: a stale handle at drop time
            // means some eviction path broke the refcount contract, and
            // that must stay loud, not be silently swallowed.
            for &h in &self.pages {
                pool.release(h);
            }
        }
    }
}

/// Aggregate memory statistics (drives the compression-ratio reporting).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheStats {
    pub tokens: usize,
    /// Storage bytes referenced by this cache (packed pages + buffer
    /// codes). Shared pages count in full — the logical footprint.
    pub bytes: usize,
    pub fp16_equiv_bytes: usize,
    /// Working memory held by the materialized q1 views — derivable
    /// metadata, reported separately from `bytes` (the paper's
    /// compression claim is about cache *storage*; the view is the
    /// decode scratch that storage is expanded into, once).
    pub view_bytes: usize,
    /// Working-set bytes of the session's executable-layout decode
    /// slabs (`TurboSlabs`: two full `[L*H*max_ctx*dh]` INT8 slabs plus
    /// per-block scales — usually *larger* than the compressed cache).
    /// `KvCache` itself owns no slabs, so [`KvCache::stats`] reports 0;
    /// the owning backend session fills this in. Capacity planning from
    /// `bytes` alone under-provisions without it.
    pub slab_bytes: usize,
    /// Of `bytes`, page storage this cache shares with at least one
    /// other owner (pool refcount > 1).
    pub shared_page_bytes: usize,
    /// Of `bytes`, page storage owned by this cache alone.
    pub private_page_bytes: usize,
}

impl CacheStats {
    pub fn compression_ratio(&self) -> f64 {
        self.fp16_equiv_bytes as f64 / self.bytes.max(1) as f64
    }
}

/// Full-model cache: `[n_layers][n_heads]` K and V streams over one
/// (possibly shared) page pool.
pub struct KvCache {
    pub cfg: KvCacheConfig,
    pool: SharedPagePool,
    k: Vec<StreamCache>,
    v: Vec<StreamCache>,
}

/// One (layer, head) pair of K/V stream views.
pub struct HeadCache<'a> {
    pub k: &'a StreamCache,
    pub v: &'a StreamCache,
}

/// One (layer, head) pair of **exclusive** K/V streams — the unit the
/// parallel decode sync hands to a worker. Produced only by
/// [`KvCache::streams_mut`], whose iterator yields each pair exactly
/// once, so two workers can never alias a stream (the borrow checker
/// proves non-overlap instead of a runtime lock; the shared page pool
/// is only ever *read* inside the sync, so pool access stays
/// lock-concurrent).
pub struct HeadCacheMut<'a> {
    pub k: &'a mut StreamCache,
    pub v: &'a mut StreamCache,
}

impl KvCache {
    /// Cache over a fresh private pool (the non-sharing default).
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        KvCache::with_pool(cfg, PagePool::new_shared())
    }

    /// Cache whose pages live in `pool` — what a sharing backend passes
    /// so every session's flushed pages land in one refcounted store.
    pub fn with_pool(cfg: KvCacheConfig, pool: SharedPagePool) -> KvCache {
        // A flush must fill exactly one page: every page-aligned consumer
        // (`read_q1_into`, `Q1View`, the slab sync) indexes scales by
        // `token / block` and would misalign on partial pages.
        assert!(
            cfg.n_b == cfg.block,
            "n_b {} must equal block {}",
            cfg.n_b,
            cfg.block
        );
        let epoch = pool
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .epoch_probe();
        let mut k = Vec::new();
        let mut v = Vec::new();
        for layer in 0..cfg.n_layers {
            for head in 0..cfg.n_heads {
                let bits = cfg.precision.get(layer, head);
                k.push(StreamCache::new(
                    cfg.d_head,
                    cfg.block,
                    cfg.n_b,
                    bits,
                    std::sync::Arc::clone(&pool),
                    epoch.clone(),
                ));
                v.push(StreamCache::new(
                    cfg.d_head,
                    cfg.block,
                    cfg.n_b,
                    bits,
                    std::sync::Arc::clone(&pool),
                    epoch.clone(),
                ));
            }
        }
        KvCache { cfg, pool, k, v }
    }

    /// The pool this cache's pages live in.
    pub fn page_pool(&self) -> &SharedPagePool {
        &self.pool
    }

    fn idx(&self, layer: usize, head: usize) -> usize {
        layer * self.cfg.n_heads + head
    }

    pub fn head(&self, layer: usize, head: usize) -> HeadCache<'_> {
        let i = self.idx(layer, head);
        HeadCache { k: &self.k[i], v: &self.v[i] }
    }

    pub fn k_stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        let i = self.idx(layer, head);
        &mut self.k[i]
    }

    pub fn v_stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        let i = self.idx(layer, head);
        &mut self.v[i]
    }

    /// Disjoint `&mut` K/V stream pairs for every (layer, head), in
    /// layer-major order — stream `i` of the iterator is
    /// `(layer, head) = (i / n_heads, i % n_heads)`, matching the slab
    /// layout of [`crate::model::TurboSlabs`]. This is the shard axis of
    /// the parallel decode sync: each worker takes one pair, and because
    /// the pairs come from one pass over the underlying storage, no two
    /// shards can overlap.
    pub fn streams_mut(
        &mut self,
    ) -> impl Iterator<Item = HeadCacheMut<'_>> + '_ {
        self.k
            .iter_mut()
            .zip(self.v.iter_mut())
            .map(|(k, v)| HeadCacheMut { k, v })
    }

    /// Token count of the (layer 0, head 0) K stream — by construction all
    /// streams hold the same count.
    pub fn tokens(&self) -> usize {
        self.k.first().map(|s| s.tokens()).unwrap_or(0)
    }

    pub fn stats(&self) -> CacheStats {
        let pool = self.pool.read().unwrap_or_else(|e| e.into_inner());
        let mut bytes = 0usize;
        let mut view_bytes = 0usize;
        let mut shared = 0usize;
        let mut private = 0usize;
        for s in self.k.iter().chain(&self.v) {
            bytes += s.bytes_in(&pool);
            view_bytes += s.view_bytes();
            let (sh, pr) = s.shared_private_bytes_in(&pool);
            shared += sh;
            private += pr;
        }
        let tokens = self.tokens();
        let fp16 = 2 * tokens
            * self.cfg.d_head
            * self.cfg.n_layers
            * self.cfg.n_heads
            * 2; // K and V, 2 bytes each
        CacheStats {
            tokens,
            bytes,
            fp16_equiv_bytes: fp16,
            view_bytes,
            slab_bytes: 0,
            shared_page_bytes: shared,
            private_page_bytes: private,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_sym_int8;
    use crate::testutil::{prop, Rng};

    fn cfg(block: usize) -> KvCacheConfig {
        KvCacheConfig::new(2, 2, 8, block, PrecisionMap::uniform(2, 2, Bits::Int4))
    }

    #[test]
    fn ingest_full_blocks_makes_pages() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(0);
        let x = rng.normal_vec(8 * 8, 1.0); // 8 tokens
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 8);
        let s = &cache.head(0, 0).k;
        assert_eq!(s.pages.len(), 2);
        assert_eq!(s.buffer.len(), 0);
        assert_eq!(s.tokens(), 8);
    }

    #[test]
    fn ingest_partial_block_seeds_buffer() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(6 * 8, 1.0); // 6 tokens: 1 page + 2 buffered
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 6);
        let s = &cache.head(0, 0).k;
        assert_eq!(s.pages.len(), 1);
        assert_eq!(s.buffer.len(), 2);
        assert_eq!(s.tokens(), 6);
    }

    #[test]
    fn decode_pushes_flush_at_capacity() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(2);
        for i in 0..9 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(1, 1).push_token(&v);
            assert_eq!(cache.head(1, 1).k.tokens(), i + 1);
        }
        let s = &cache.head(1, 1).k;
        assert_eq!(s.pages.len(), 2);
        assert_eq!(s.buffer.len(), 1);
    }

    #[test]
    fn read_q1_roundtrip_tracks_values() {
        prop::run("cache q1 read", 25, |g| {
            let block = 4;
            let mut cache = KvCache::new(cfg(block));
            let n = g.usize_in(1, 20);
            let mut originals: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n {
                let v = g.normal_vec(8, 1.0);
                cache.k_stream_mut(0, 1).push_token(&v);
                originals.push(v);
            }
            let cap = 24; // page-aligned capacity
            let mut q1 = vec![0i8; cap * 8];
            let mut scales = vec![0.0f32; cap / block];
            let mut scratch = Vec::new();
            let got =
                cache.head(0, 1).k.read_q1_into(&mut scratch, &mut q1, &mut scales);
            assert_eq!(got, n);
            // Every non-clamped token approximately recoverable:
            // q1 * block_scale (int8 round + int4 progressive error is a
            // bounded number of quantizer steps; values beyond the
            // universal scale's 127-code range are clamped by design).
            for (t, orig) in originals.iter().enumerate() {
                let s = scales[t / block];
                for c in 0..8 {
                    if orig[c].abs() > 126.0 * s {
                        continue; // clamped outlier (paper §3.3 semantics)
                    }
                    let approx = q1[t * 8 + c] as f32 * s;
                    assert!(
                        (approx - orig[c]).abs() <= 30.0 * s + 1e-4,
                        "t={t} c={c}: {approx} vs {}",
                        orig[c]
                    );
                }
            }
        });
    }

    /// The ISSUE's view invariant: after *any* interleaving of prefill
    /// ingests, decode pushes, and mid-stream syncs, the incremental view
    /// must equal a fresh full materialization.
    #[test]
    fn q1_view_matches_fresh_materialization() {
        prop::run("q1 view == read_q1_into", 40, |g| {
            let block = 4;
            let d = 8;
            let mut cache = KvCache::new(cfg(block));
            let n_ops = g.usize_in(1, 40);
            for _ in 0..n_ops {
                match g.usize_in(0, 4) {
                    0 => {
                        // Prefill-style ingest of a q1 block.
                        let tokens = g.usize_in(1, 10);
                        let x = g.normal_vec(tokens * d, 1.0);
                        let q1 = quant_sym_int8(&x);
                        cache
                            .k_stream_mut(0, 0)
                            .ingest_q1_block(&q1.codes, q1.scale, tokens);
                    }
                    1 | 2 => {
                        // Decode push.
                        let v = g.normal_vec(d, 1.0);
                        cache.k_stream_mut(0, 0).push_token(&v);
                    }
                    _ => {
                        // Interleaved sync: exercises partial-progress
                        // states (the incremental paths).
                        let _ = cache.k_stream_mut(0, 0).q1_view();
                    }
                }
            }
            let s = cache.k_stream_mut(0, 0);
            let (codes, scales, n) = s.q1_view();
            let nb_used = n.div_ceil(block);
            let view_codes = codes[..n * d].to_vec();
            let view_scales = scales[..nb_used].to_vec();
            // Fresh materialization oracle.
            let cap = (nb_used + 1) * block;
            let mut q1 = vec![0i8; cap * d];
            let mut sc = vec![0.0f32; cap / block];
            let mut scratch = Vec::new();
            let got = s.read_q1_into(&mut scratch, &mut q1, &mut sc);
            assert_eq!(got, n, "token counts agree");
            assert_eq!(view_codes, q1[..n * d], "codes agree");
            assert_eq!(view_scales, sc[..nb_used], "scales agree");
        });
    }

    #[test]
    fn q1_view_is_incremental_not_rebuilt() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(9);
        for _ in 0..9 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(0, 0).push_token(&v);
        }
        let s = cache.k_stream_mut(0, 0);
        let (_, _, n) = s.q1_view();
        assert_eq!(n, 9);
        assert_eq!(s.view().valid_pages(), 2);
        assert_eq!(s.view().valid_tokens(), 9);
        // A sync with no mutation leaves bookkeeping untouched.
        let (_, _, n2) = s.q1_view();
        assert_eq!(n2, 9);
        assert_eq!(s.view().valid_pages(), 2);
        // One more push: only the buffer tail advances.
        let v = rng.normal_vec(8, 1.0);
        s.push_token(&v);
        let (_, _, n3) = s.q1_view();
        assert_eq!(n3, 10);
        assert_eq!(s.view().valid_pages(), 2);
    }

    #[test]
    #[allow(deprecated)]
    fn q1_view_rewrites_buffer_region_on_flush() {
        // Mirror the buffer tail, then flush it into a page: the view must
        // pick up the page's (lossier) q2->q1 codes, not the raw tail.
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(0, 0).push_token(&v);
        }
        let _ = cache.k_stream_mut(0, 0).q1_view(); // mirrors 3 buffer tokens
        let v = rng.normal_vec(8, 1.0);
        cache.k_stream_mut(0, 0).push_token(&v); // 4th push -> flush -> page
        let s = cache.k_stream_mut(0, 0);
        let (codes, scale0, n) = {
            let (c, sc, n) = s.q1_view();
            (c[..4 * 8].to_vec(), sc[0], n)
        };
        assert_eq!(n, 4);
        assert_eq!(s.pages.len(), 1);
        let h = s.pages[0];
        let pool = cache.page_pool().read().expect("pool");
        let want = pool.get(h).dequant_q1();
        assert_eq!(codes, want, "page region rewritten");
        assert_eq!(scale0, pool.get(h).fp_scale);
    }

    /// Shard-coverage invariant behind the parallel sync: the mutable
    /// stream iterator visits every (layer, head) exactly once, in the
    /// layer-major order the slab layout assumes.
    #[test]
    fn streams_mut_covers_each_head_exactly_once_in_order() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(8);
        // Tag each stream with a distinct token count: (l, h) gets
        // l * H + h + 1 tokens in K and 2x that in V.
        for l in 0..2 {
            for h in 0..2 {
                let n = l * 2 + h + 1;
                for _ in 0..n {
                    let t = rng.normal_vec(8, 1.0);
                    cache.k_stream_mut(l, h).push_token(&t);
                }
                for _ in 0..2 * n {
                    let t = rng.normal_vec(8, 1.0);
                    cache.v_stream_mut(l, h).push_token(&t);
                }
            }
        }
        let mut seen = 0usize;
        for (i, shard) in cache.streams_mut().enumerate() {
            assert_eq!(shard.k.tokens(), i + 1, "K order, shard {i}");
            assert_eq!(shard.v.tokens(), 2 * (i + 1), "V order, shard {i}");
            seen += 1;
        }
        assert_eq!(seen, 4, "exactly n_layers * n_heads shards");
    }

    #[test]
    fn stats_reflect_compression() {
        // Realistic geometry: page/parameter overhead amortizes over the
        // block and head dim (tiny 4x8 pages are overhead-dominated).
        let pm = PrecisionMap::uniform(2, 2, Bits::Int4);
        let cfg = KvCacheConfig::new(2, 2, 32, 16, pm);
        let mut cache = KvCache::new(cfg);
        let mut rng = Rng::new(3);
        for _ in 0..64 {
            for l in 0..2 {
                for h in 0..2 {
                    let kv = rng.normal_vec(32, 1.0);
                    cache.k_stream_mut(l, h).push_token(&kv);
                    cache.v_stream_mut(l, h).push_token(&kv);
                }
            }
        }
        let stats = cache.stats();
        assert_eq!(stats.tokens, 64);
        // INT4 pages + small buffer: better than 2.5x vs FP16.
        assert!(stats.compression_ratio() > 2.5, "{}", stats.compression_ratio());
        // Fully private cache: no shared storage.
        assert_eq!(stats.shared_page_bytes, 0);
        assert!(stats.private_page_bytes > 0);
    }

    #[test]
    fn mixed_precision_2bit_heads_smaller() {
        let mut pm = PrecisionMap::uniform(1, 2, Bits::Int4);
        pm.set(0, 1, Bits::Int2);
        let cfg = KvCacheConfig::new(1, 2, 8, 4, pm);
        let mut cache = KvCache::new(cfg);
        let mut rng = Rng::new(4);
        for _ in 0..8 {
            for h in 0..2 {
                let kv = rng.normal_vec(8, 1.0);
                cache.k_stream_mut(0, h).push_token(&kv);
            }
        }
        let b4 = cache.head(0, 0).k.bytes();
        let b2 = cache.head(0, 1).k.bytes();
        assert!(b2 < b4, "2-bit head {b2}B vs 4-bit head {b4}B");
    }

    // -- shared-pool behavior ------------------------------------------

    /// Two caches over one pool: adopting a prefix shares the physical
    /// pages (refs = 2), the adopter's view is byte-identical to the
    /// donor's, and pages outlive the donor while the adopter holds them.
    #[test]
    fn adopted_pages_share_storage_across_caches() {
        let pool = PagePool::new_shared();
        let mut donor =
            KvCache::with_pool(cfg(4), std::sync::Arc::clone(&pool));
        let mut rng = Rng::new(21);
        let x = rng.normal_vec(8 * 8, 1.0); // 2 full pages
        let q1 = quant_sym_int8(&x);
        donor.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 8);
        let handles = donor.head(0, 0).k.pages.clone();
        assert_eq!(handles.len(), 2);

        let mut fork = KvCache::with_pool(cfg(4), std::sync::Arc::clone(&pool));
        fork.k_stream_mut(0, 0).adopt_pages(&handles);
        {
            let p = pool.read().expect("pool");
            assert_eq!(p.refs(handles[0]), 2);
            assert_eq!(p.refs(handles[1]), 2);
            let st = p.stats();
            assert_eq!(st.live_pages, 2);
            assert_eq!(st.shared_pages, 2);
            assert_eq!(st.private_bytes, 0);
            assert!(st.shared_bytes > 0);
        }
        // The adopter reads exactly the donor's codes and scales.
        let (dc, ds, dn) = donor.k_stream_mut(0, 0).q1_view();
        let (want_codes, want_scales) = (dc[..8 * 8].to_vec(), ds[..2].to_vec());
        assert_eq!(dn, 8);
        let (fc, fs, fn_) = fork.k_stream_mut(0, 0).q1_view();
        assert_eq!(fn_, 8);
        assert_eq!(&fc[..8 * 8], &want_codes[..]);
        assert_eq!(&fs[..2], &want_scales[..]);
        // Donor teardown releases its refs but the pages live on.
        drop(donor);
        {
            let p = pool.read().expect("pool");
            assert_eq!(p.live_pages(), 2);
            assert_eq!(p.refs(handles[0]), 1);
        }
        // The adopter can still read them after the donor is gone.
        let (_, _, n) = fork.k_stream_mut(0, 0).q1_view();
        assert_eq!(n, 8);
        // Last owner out frees everything.
        drop(fork);
        assert_eq!(pool.read().expect("pool").live_pages(), 0);
    }

    /// Per-cache stats split shared vs private page storage exactly.
    #[test]
    fn stats_split_shared_and_private_pages() {
        let pool = PagePool::new_shared();
        let mut donor =
            KvCache::with_pool(cfg(4), std::sync::Arc::clone(&pool));
        let mut rng = Rng::new(22);
        let x = rng.normal_vec(4 * 8, 1.0); // 1 full page
        let q1 = quant_sym_int8(&x);
        donor.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 4);
        let handles = donor.head(0, 0).k.pages.clone();

        let mut fork = KvCache::with_pool(cfg(4), std::sync::Arc::clone(&pool));
        fork.k_stream_mut(0, 0).adopt_pages(&handles);
        // Fork grows a private page of its own on top of the shared one.
        for _ in 0..4 {
            let v = rng.normal_vec(8, 1.0);
            fork.k_stream_mut(0, 0).push_token(&v);
        }
        let st = fork.stats();
        assert!(st.shared_page_bytes > 0, "adopted page is shared");
        assert!(st.private_page_bytes > 0, "own flushed page is private");
        // Every non-page byte is the buffers' (empty buffers still cost
        // their 4-byte scale slot; 2 layers x 2 heads x {K, V} = 8
        // streams), so the shared/private split covers all page storage.
        assert_eq!(
            st.bytes,
            st.shared_page_bytes + st.private_page_bytes + 8 * 4,
            "page bytes + buffer bytes == total"
        );
    }

    /// The pooled arm of the PR-1 invariant: if a page is freed while a
    /// view still mirrors it (a buggy eviction path would do this), the
    /// next sync detects it via the pool epoch instead of serving stale
    /// codes.
    #[test]
    #[should_panic(expected = "page freed under a live view")]
    fn view_detects_page_freed_underneath() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(23);
        let x = rng.normal_vec(4 * 8, 1.0);
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 4);
        let _ = cache.k_stream_mut(0, 0).q1_view();
        // Simulate an eviction that ignores the refcount contract.
        let h = cache.head(0, 0).k.pages[0];
        cache
            .page_pool()
            .write()
            .expect("pool")
            .release(h);
        let _ = cache.k_stream_mut(0, 0).q1_view();
    }
}
