//! The full model-level quantized KV cache: pages + buffers per
//! (layer, head, K/V), with memory accounting and an incrementally
//! materialized q1 view per stream (the decode hot path).

use super::{DecodeBuffer, PrecisionMap, QuantPage};
use crate::quant::Bits;

/// Cache geometry and policy.
#[derive(Debug, Clone)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Page size in tokens (= the attention tile B_c).
    pub block: usize,
    /// Decode-buffer capacity n_b (paper uses 64). Must equal `block`:
    /// a flush turns the buffer into exactly one full page, which the
    /// page-aligned q1 view layout (and `read_q1_into`) depends on.
    pub n_b: usize,
    pub precision: PrecisionMap,
}

impl KvCacheConfig {
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        block: usize,
        precision: PrecisionMap,
    ) -> KvCacheConfig {
        KvCacheConfig { n_layers, n_heads, d_head, block, n_b: block, precision }
    }
}

/// Incrementally materialized q1 (INT8 codes + per-block scale) view of
/// one stream — what the decode path reads instead of re-dequantizing the
/// whole cache on every generated token.
///
/// Why dequantize-once is safe: pages are immutable after flush (see
/// [`QuantPage`]), and buffer codes are append-only within an epoch (the
/// universal scale is fixed at the epoch's first token — paper §3.3), so
/// a region copied into the view never changes underneath it. The single
/// invalidation event is a buffer flush, which converts the mirrored
/// buffer tail into a new page; the next sync rewrites exactly that
/// region with the page's (lossier) q2 -> q1 dequantization.
///
/// The view is derivable metadata, like the pages' dequant tables: it is
/// excluded from the storage accounting in [`StreamCache::bytes`] and
/// reported separately via [`CacheStats::view_bytes`].
#[derive(Debug, Default)]
pub struct Q1View {
    /// Materialized INT8 codes `[capacity_tokens * d_head]`; the first
    /// `valid_tokens * d_head` entries are meaningful. Page-aligned: page
    /// `i` occupies tokens `[i*block, (i+1)*block)`.
    codes: Vec<i8>,
    /// One q1 scale per `block` tokens (pages' `fp_scale`, then the
    /// buffer's universal scale for the tail group).
    scales: Vec<f32>,
    /// Tokens currently materialized (page region + mirrored buffer tail).
    valid_tokens: usize,
    /// Pages dequantized so far — each exactly once.
    valid_pages: usize,
    /// Buffer tokens mirrored after the page region.
    buffered: usize,
    /// Reusable unpack scratch for the generic dequant path.
    scratch: Vec<u8>,
}

impl Q1View {
    pub fn valid_tokens(&self) -> usize {
        self.valid_tokens
    }

    pub fn valid_pages(&self) -> usize {
        self.valid_pages
    }

    /// Working-memory bytes held by the view (codes + scales + scratch).
    pub fn overhead_bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len() + self.scratch.len()
    }
}

/// One K or V stream for one (layer, head): q2 pages + INT8 buffer.
#[derive(Debug)]
pub struct StreamCache {
    pub pages: Vec<QuantPage>,
    pub buffer: DecodeBuffer,
    view: Q1View,
    bits: Bits,
    d_head: usize,
    block: usize,
}

impl StreamCache {
    fn new(d_head: usize, block: usize, n_b: usize, bits: Bits) -> StreamCache {
        StreamCache {
            pages: Vec::new(),
            buffer: DecodeBuffer::new(d_head, n_b),
            view: Q1View::default(),
            bits,
            d_head,
            block,
        }
    }

    /// Tokens stored (pages + buffer).
    pub fn tokens(&self) -> usize {
        self.pages.iter().map(|p| p.tokens).sum::<usize>() + self.buffer.len()
    }

    /// Ingest a prefill q1 block (INT8 codes, one fp scale, `tokens`
    /// tokens). Full `block`-sized groups become pages immediately
    /// (Algorithm 1 write-back); a trailing partial group seeds the
    /// buffer with the block's scale as the universal scale.
    pub fn ingest_q1_block(&mut self, codes: &[i8], fp_scale: f32, tokens: usize) {
        assert_eq!(codes.len(), tokens * self.d_head);
        let mut t0 = 0;
        while t0 < tokens {
            let t1 = (t0 + self.block).min(tokens);
            let chunk = &codes[t0 * self.d_head..t1 * self.d_head];
            if t1 - t0 == self.block && self.buffer.is_empty() {
                self.pages.push(QuantPage::from_q1(
                    chunk,
                    self.block,
                    self.d_head,
                    fp_scale,
                    self.bits,
                ));
            } else {
                // Partial group (or buffer already seeded): go through the
                // buffer token by token to preserve flush semantics.
                for t in t0..t1 {
                    let row = &codes[t * self.d_head..(t + 1) * self.d_head];
                    let vals: Vec<f32> =
                        row.iter().map(|&c| c as f32 * fp_scale).collect();
                    self.push_token(&vals);
                }
                t0 = t1;
                continue;
            }
            t0 = t1;
        }
    }

    /// Append one decode token (float channel vector); flushes the buffer
    /// into a q2 page when it reaches capacity.
    pub fn push_token(&mut self, values: &[f32]) {
        let full = self.buffer.push(values);
        if full {
            let (codes, scale, tokens) = self.buffer.drain();
            self.pages.push(QuantPage::from_q1(
                &codes,
                tokens,
                self.d_head,
                scale,
                self.bits,
            ));
        }
    }

    /// Materialize the q1 view into caller buffers:
    /// `q1` is `[capacity_tokens, d_head]` (page-aligned capacity), and
    /// `scales` one entry per `block` tokens. Returns valid token count.
    pub fn read_q1_into(
        &self,
        scratch: &mut Vec<u8>,
        q1: &mut [i8],
        scales: &mut [f32],
    ) -> usize {
        let d = self.d_head;
        let mut t = 0usize;
        for (pi, page) in self.pages.iter().enumerate() {
            debug_assert_eq!(page.tokens, self.block, "non-final page must be full");
            page.dequant_q1_into(
                scratch,
                &mut q1[t * d..(t + page.tokens) * d],
            );
            scales[pi] = page.fp_scale;
            t += page.tokens;
        }
        let bl = self.buffer.len();
        if bl > 0 {
            debug_assert_eq!(t % self.block, 0);
            q1[t * d..(t + bl) * d].copy_from_slice(self.buffer.codes());
            scales[t / self.block] = self.buffer.scale();
            t += bl;
        }
        t
    }

    /// Bring the materialized q1 view up to date and return it as
    /// `(codes, scales, valid_tokens)` — the decode path's borrowed,
    /// zero-copy cache read.
    ///
    /// Work done is proportional to what changed since the last call:
    /// pages created since then are dequantized exactly once, and only
    /// buffer tokens not yet mirrored are copied. Steady-state decode
    /// (one `push_token` between syncs) costs O(d_head) per call, versus
    /// O(tokens * d_head) for a fresh [`Self::read_q1_into`].
    ///
    /// `codes` may be longer than `valid_tokens * d_head` (page-aligned
    /// backing with buffer headroom); callers must use the returned count.
    pub fn q1_view(&mut self) -> (&[i8], &[f32], usize) {
        let d = self.d_head;
        let b = self.block;
        let n_pages = self.pages.len();
        if self.view.valid_pages < n_pages {
            // Grow in page steps, keeping one page of headroom for the
            // buffer tail (buffer capacity n_b <= block).
            self.view.codes.resize((n_pages + 1) * b * d, 0);
            self.view.scales.resize(n_pages + 1, 0.0);
            for pi in self.view.valid_pages..n_pages {
                let page = &self.pages[pi];
                debug_assert_eq!(page.tokens, b, "non-final page must be full");
                let o = pi * b * d;
                page.dequant_q1_into(
                    &mut self.view.scratch,
                    &mut self.view.codes[o..o + b * d],
                );
                self.view.scales[pi] = page.fp_scale;
            }
            self.view.valid_pages = n_pages;
            // A flush consumed the buffer tokens this view had mirrored;
            // the page dequantization above rewrote that region.
            self.view.buffered = 0;
        }
        let base = n_pages * b;
        let bl = self.buffer.len();
        if bl > self.view.buffered {
            if self.view.codes.len() < (base + b) * d {
                self.view.codes.resize((base + b) * d, 0);
            }
            if self.view.scales.len() <= n_pages {
                self.view.scales.resize(n_pages + 1, 0.0);
            }
            let src = self.buffer.codes();
            self.view.codes[(base + self.view.buffered) * d..(base + bl) * d]
                .copy_from_slice(&src[self.view.buffered * d..bl * d]);
            self.view.scales[n_pages] = self.buffer.scale();
            self.view.buffered = bl;
        }
        self.view.valid_tokens = base + bl;
        (&self.view.codes, &self.view.scales, self.view.valid_tokens)
    }

    /// Read access to the view's bookkeeping (tests / accounting).
    pub fn view(&self) -> &Q1View {
        &self.view
    }

    /// Working-memory bytes held by the materialized view.
    pub fn view_bytes(&self) -> usize {
        self.view.overhead_bytes()
    }

    /// Storage bytes (packed pages + buffer codes).
    pub fn bytes(&self) -> usize {
        self.pages.iter().map(|p| p.bytes()).sum::<usize>()
            + self.buffer.len() * self.d_head
            + 4
    }
}

/// Aggregate memory statistics (drives the compression-ratio reporting).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheStats {
    pub tokens: usize,
    /// Compressed storage bytes (packed pages + buffer codes).
    pub bytes: usize,
    pub fp16_equiv_bytes: usize,
    /// Working memory held by the materialized q1 views — derivable
    /// metadata, reported separately from `bytes` (the paper's
    /// compression claim is about cache *storage*; the view is the
    /// decode scratch that storage is expanded into, once).
    pub view_bytes: usize,
    /// Working-set bytes of the session's executable-layout decode
    /// slabs (`TurboSlabs`: two full `[L*H*max_ctx*dh]` INT8 slabs plus
    /// per-block scales — usually *larger* than the compressed cache).
    /// `KvCache` itself owns no slabs, so [`KvCache::stats`] reports 0;
    /// the owning backend session fills this in. Capacity planning from
    /// `bytes` alone under-provisions without it.
    pub slab_bytes: usize,
}

impl CacheStats {
    pub fn compression_ratio(&self) -> f64 {
        self.fp16_equiv_bytes as f64 / self.bytes.max(1) as f64
    }
}

/// Full-model cache: `[n_layers][n_heads]` K and V streams.
pub struct KvCache {
    pub cfg: KvCacheConfig,
    k: Vec<StreamCache>,
    v: Vec<StreamCache>,
}

/// One (layer, head) pair of K/V stream views.
pub struct HeadCache<'a> {
    pub k: &'a StreamCache,
    pub v: &'a StreamCache,
}

/// One (layer, head) pair of **exclusive** K/V streams — the unit the
/// parallel decode sync hands to a worker. Produced only by
/// [`KvCache::streams_mut`], whose iterator yields each pair exactly
/// once, so two workers can never alias a stream (the borrow checker
/// proves non-overlap instead of a runtime lock).
pub struct HeadCacheMut<'a> {
    pub k: &'a mut StreamCache,
    pub v: &'a mut StreamCache,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        // A flush must fill exactly one page: every page-aligned consumer
        // (`read_q1_into`, `Q1View`, the slab sync) indexes scales by
        // `token / block` and would misalign on partial pages.
        assert!(
            cfg.n_b == cfg.block,
            "n_b {} must equal block {}",
            cfg.n_b,
            cfg.block
        );
        let mut k = Vec::new();
        let mut v = Vec::new();
        for layer in 0..cfg.n_layers {
            for head in 0..cfg.n_heads {
                let bits = cfg.precision.get(layer, head);
                k.push(StreamCache::new(cfg.d_head, cfg.block, cfg.n_b, bits));
                v.push(StreamCache::new(cfg.d_head, cfg.block, cfg.n_b, bits));
            }
        }
        KvCache { cfg, k, v }
    }

    fn idx(&self, layer: usize, head: usize) -> usize {
        layer * self.cfg.n_heads + head
    }

    pub fn head(&self, layer: usize, head: usize) -> HeadCache<'_> {
        let i = self.idx(layer, head);
        HeadCache { k: &self.k[i], v: &self.v[i] }
    }

    pub fn k_stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        let i = self.idx(layer, head);
        &mut self.k[i]
    }

    pub fn v_stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        let i = self.idx(layer, head);
        &mut self.v[i]
    }

    /// Disjoint `&mut` K/V stream pairs for every (layer, head), in
    /// layer-major order — stream `i` of the iterator is
    /// `(layer, head) = (i / n_heads, i % n_heads)`, matching the slab
    /// layout of [`crate::model::TurboSlabs`]. This is the shard axis of
    /// the parallel decode sync: each worker takes one pair, and because
    /// the pairs come from one pass over the underlying storage, no two
    /// shards can overlap.
    pub fn streams_mut(
        &mut self,
    ) -> impl Iterator<Item = HeadCacheMut<'_>> + '_ {
        self.k
            .iter_mut()
            .zip(self.v.iter_mut())
            .map(|(k, v)| HeadCacheMut { k, v })
    }

    /// Token count of the (layer 0, head 0) K stream — by construction all
    /// streams hold the same count.
    pub fn tokens(&self) -> usize {
        self.k.first().map(|s| s.tokens()).unwrap_or(0)
    }

    pub fn stats(&self) -> CacheStats {
        let bytes: usize =
            self.k.iter().chain(&self.v).map(|s| s.bytes()).sum();
        let view_bytes: usize =
            self.k.iter().chain(&self.v).map(|s| s.view_bytes()).sum();
        let tokens = self.tokens();
        let fp16 = 2 * tokens
            * self.cfg.d_head
            * self.cfg.n_layers
            * self.cfg.n_heads
            * 2; // K and V, 2 bytes each
        CacheStats {
            tokens,
            bytes,
            fp16_equiv_bytes: fp16,
            view_bytes,
            slab_bytes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_sym_int8;
    use crate::testutil::{prop, Rng};

    fn cfg(block: usize) -> KvCacheConfig {
        KvCacheConfig::new(2, 2, 8, block, PrecisionMap::uniform(2, 2, Bits::Int4))
    }

    #[test]
    fn ingest_full_blocks_makes_pages() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(0);
        let x = rng.normal_vec(8 * 8, 1.0); // 8 tokens
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 8);
        let s = &cache.head(0, 0).k;
        assert_eq!(s.pages.len(), 2);
        assert_eq!(s.buffer.len(), 0);
        assert_eq!(s.tokens(), 8);
    }

    #[test]
    fn ingest_partial_block_seeds_buffer() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(6 * 8, 1.0); // 6 tokens: 1 page + 2 buffered
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 6);
        let s = &cache.head(0, 0).k;
        assert_eq!(s.pages.len(), 1);
        assert_eq!(s.buffer.len(), 2);
        assert_eq!(s.tokens(), 6);
    }

    #[test]
    fn decode_pushes_flush_at_capacity() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(2);
        for i in 0..9 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(1, 1).push_token(&v);
            assert_eq!(cache.head(1, 1).k.tokens(), i + 1);
        }
        let s = &cache.head(1, 1).k;
        assert_eq!(s.pages.len(), 2);
        assert_eq!(s.buffer.len(), 1);
    }

    #[test]
    fn read_q1_roundtrip_tracks_values() {
        prop::run("cache q1 read", 25, |g| {
            let block = 4;
            let mut cache = KvCache::new(cfg(block));
            let n = g.usize_in(1, 20);
            let mut originals: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n {
                let v = g.normal_vec(8, 1.0);
                cache.k_stream_mut(0, 1).push_token(&v);
                originals.push(v);
            }
            let cap = 24; // page-aligned capacity
            let mut q1 = vec![0i8; cap * 8];
            let mut scales = vec![0.0f32; cap / block];
            let mut scratch = Vec::new();
            let got =
                cache.head(0, 1).k.read_q1_into(&mut scratch, &mut q1, &mut scales);
            assert_eq!(got, n);
            // Every non-clamped token approximately recoverable:
            // q1 * block_scale (int8 round + int4 progressive error is a
            // bounded number of quantizer steps; values beyond the
            // universal scale's 127-code range are clamped by design).
            for (t, orig) in originals.iter().enumerate() {
                let s = scales[t / block];
                for c in 0..8 {
                    if orig[c].abs() > 126.0 * s {
                        continue; // clamped outlier (paper §3.3 semantics)
                    }
                    let approx = q1[t * 8 + c] as f32 * s;
                    assert!(
                        (approx - orig[c]).abs() <= 30.0 * s + 1e-4,
                        "t={t} c={c}: {approx} vs {}",
                        orig[c]
                    );
                }
            }
        });
    }

    /// The ISSUE's view invariant: after *any* interleaving of prefill
    /// ingests, decode pushes, and mid-stream syncs, the incremental view
    /// must equal a fresh full materialization.
    #[test]
    fn q1_view_matches_fresh_materialization() {
        prop::run("q1 view == read_q1_into", 40, |g| {
            let block = 4;
            let d = 8;
            let mut cache = KvCache::new(cfg(block));
            let n_ops = g.usize_in(1, 40);
            for _ in 0..n_ops {
                match g.usize_in(0, 4) {
                    0 => {
                        // Prefill-style ingest of a q1 block.
                        let tokens = g.usize_in(1, 10);
                        let x = g.normal_vec(tokens * d, 1.0);
                        let q1 = quant_sym_int8(&x);
                        cache
                            .k_stream_mut(0, 0)
                            .ingest_q1_block(&q1.codes, q1.scale, tokens);
                    }
                    1 | 2 => {
                        // Decode push.
                        let v = g.normal_vec(d, 1.0);
                        cache.k_stream_mut(0, 0).push_token(&v);
                    }
                    _ => {
                        // Interleaved sync: exercises partial-progress
                        // states (the incremental paths).
                        let _ = cache.k_stream_mut(0, 0).q1_view();
                    }
                }
            }
            let s = cache.k_stream_mut(0, 0);
            let (codes, scales, n) = s.q1_view();
            let nb_used = n.div_ceil(block);
            let view_codes = codes[..n * d].to_vec();
            let view_scales = scales[..nb_used].to_vec();
            // Fresh materialization oracle.
            let cap = (nb_used + 1) * block;
            let mut q1 = vec![0i8; cap * d];
            let mut sc = vec![0.0f32; cap / block];
            let mut scratch = Vec::new();
            let got = s.read_q1_into(&mut scratch, &mut q1, &mut sc);
            assert_eq!(got, n, "token counts agree");
            assert_eq!(view_codes, q1[..n * d], "codes agree");
            assert_eq!(view_scales, sc[..nb_used], "scales agree");
        });
    }

    #[test]
    fn q1_view_is_incremental_not_rebuilt() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(9);
        for _ in 0..9 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(0, 0).push_token(&v);
        }
        let s = cache.k_stream_mut(0, 0);
        let (_, _, n) = s.q1_view();
        assert_eq!(n, 9);
        assert_eq!(s.view().valid_pages(), 2);
        assert_eq!(s.view().valid_tokens(), 9);
        // A sync with no mutation leaves bookkeeping untouched.
        let (_, _, n2) = s.q1_view();
        assert_eq!(n2, 9);
        assert_eq!(s.view().valid_pages(), 2);
        // One more push: only the buffer tail advances.
        let v = rng.normal_vec(8, 1.0);
        s.push_token(&v);
        let (_, _, n3) = s.q1_view();
        assert_eq!(n3, 10);
        assert_eq!(s.view().valid_pages(), 2);
    }

    #[test]
    fn q1_view_rewrites_buffer_region_on_flush() {
        // Mirror the buffer tail, then flush it into a page: the view must
        // pick up the page's (lossier) q2->q1 codes, not the raw tail.
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(0, 0).push_token(&v);
        }
        let _ = cache.k_stream_mut(0, 0).q1_view(); // mirrors 3 buffer tokens
        let v = rng.normal_vec(8, 1.0);
        cache.k_stream_mut(0, 0).push_token(&v); // 4th push -> flush -> page
        let s = cache.k_stream_mut(0, 0);
        let (codes, scale0, n) = {
            let (c, sc, n) = s.q1_view();
            (c[..4 * 8].to_vec(), sc[0], n)
        };
        assert_eq!(n, 4);
        assert_eq!(s.pages.len(), 1);
        let want = s.pages[0].dequant_q1();
        assert_eq!(codes, want, "page region rewritten");
        assert_eq!(scale0, s.pages[0].fp_scale);
    }

    /// Shard-coverage invariant behind the parallel sync: the mutable
    /// stream iterator visits every (layer, head) exactly once, in the
    /// layer-major order the slab layout assumes.
    #[test]
    fn streams_mut_covers_each_head_exactly_once_in_order() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(8);
        // Tag each stream with a distinct token count: (l, h) gets
        // l * H + h + 1 tokens in K and 2x that in V.
        for l in 0..2 {
            for h in 0..2 {
                let n = l * 2 + h + 1;
                for _ in 0..n {
                    let t = rng.normal_vec(8, 1.0);
                    cache.k_stream_mut(l, h).push_token(&t);
                }
                for _ in 0..2 * n {
                    let t = rng.normal_vec(8, 1.0);
                    cache.v_stream_mut(l, h).push_token(&t);
                }
            }
        }
        let mut seen = 0usize;
        for (i, shard) in cache.streams_mut().enumerate() {
            assert_eq!(shard.k.tokens(), i + 1, "K order, shard {i}");
            assert_eq!(shard.v.tokens(), 2 * (i + 1), "V order, shard {i}");
            seen += 1;
        }
        assert_eq!(seen, 4, "exactly n_layers * n_heads shards");
    }

    #[test]
    fn stats_reflect_compression() {
        // Realistic geometry: page/parameter overhead amortizes over the
        // block and head dim (tiny 4x8 pages are overhead-dominated).
        let pm = PrecisionMap::uniform(2, 2, Bits::Int4);
        let cfg = KvCacheConfig::new(2, 2, 32, 16, pm);
        let mut cache = KvCache::new(cfg);
        let mut rng = Rng::new(3);
        for _ in 0..64 {
            for l in 0..2 {
                for h in 0..2 {
                    let kv = rng.normal_vec(32, 1.0);
                    cache.k_stream_mut(l, h).push_token(&kv);
                    cache.v_stream_mut(l, h).push_token(&kv);
                }
            }
        }
        let stats = cache.stats();
        assert_eq!(stats.tokens, 64);
        // INT4 pages + small buffer: better than 2.5x vs FP16.
        assert!(stats.compression_ratio() > 2.5, "{}", stats.compression_ratio());
    }

    #[test]
    fn mixed_precision_2bit_heads_smaller() {
        let mut pm = PrecisionMap::uniform(1, 2, Bits::Int4);
        pm.set(0, 1, Bits::Int2);
        let cfg = KvCacheConfig::new(1, 2, 8, 4, pm);
        let mut cache = KvCache::new(cfg);
        let mut rng = Rng::new(4);
        for _ in 0..8 {
            for h in 0..2 {
                let kv = rng.normal_vec(8, 1.0);
                cache.k_stream_mut(0, h).push_token(&kv);
            }
        }
        let b4 = cache.head(0, 0).k.bytes();
        let b2 = cache.head(0, 1).k.bytes();
        assert!(b2 < b4, "2-bit head {b2}B vs 4-bit head {b4}B");
    }
}
