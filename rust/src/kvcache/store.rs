//! The full model-level quantized KV cache: pages + buffers per
//! (layer, head, K/V), with memory accounting.

use super::{DecodeBuffer, PrecisionMap, QuantPage};
use crate::quant::Bits;

/// Cache geometry and policy.
#[derive(Debug, Clone)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Page size in tokens (= the attention tile B_c).
    pub block: usize,
    /// Decode-buffer capacity n_b (paper uses 64; must be <= block so a
    /// flush fills at most one page).
    pub n_b: usize,
    pub precision: PrecisionMap,
}

impl KvCacheConfig {
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        block: usize,
        precision: PrecisionMap,
    ) -> KvCacheConfig {
        KvCacheConfig { n_layers, n_heads, d_head, block, n_b: block, precision }
    }
}

/// One K or V stream for one (layer, head): q2 pages + INT8 buffer.
#[derive(Debug)]
pub struct StreamCache {
    pub pages: Vec<QuantPage>,
    pub buffer: DecodeBuffer,
    bits: Bits,
    d_head: usize,
    block: usize,
}

impl StreamCache {
    fn new(d_head: usize, block: usize, n_b: usize, bits: Bits) -> StreamCache {
        StreamCache {
            pages: Vec::new(),
            buffer: DecodeBuffer::new(d_head, n_b),
            bits,
            d_head,
            block,
        }
    }

    /// Tokens stored (pages + buffer).
    pub fn tokens(&self) -> usize {
        self.pages.iter().map(|p| p.tokens).sum::<usize>() + self.buffer.len()
    }

    /// Ingest a prefill q1 block (INT8 codes, one fp scale, `tokens`
    /// tokens). Full `block`-sized groups become pages immediately
    /// (Algorithm 1 write-back); a trailing partial group seeds the
    /// buffer with the block's scale as the universal scale.
    pub fn ingest_q1_block(&mut self, codes: &[i8], fp_scale: f32, tokens: usize) {
        assert_eq!(codes.len(), tokens * self.d_head);
        let mut t0 = 0;
        while t0 < tokens {
            let t1 = (t0 + self.block).min(tokens);
            let chunk = &codes[t0 * self.d_head..t1 * self.d_head];
            if t1 - t0 == self.block && self.buffer.is_empty() {
                self.pages.push(QuantPage::from_q1(
                    chunk,
                    self.block,
                    self.d_head,
                    fp_scale,
                    self.bits,
                ));
            } else {
                // Partial group (or buffer already seeded): go through the
                // buffer token by token to preserve flush semantics.
                for t in t0..t1 {
                    let row = &codes[t * self.d_head..(t + 1) * self.d_head];
                    let vals: Vec<f32> =
                        row.iter().map(|&c| c as f32 * fp_scale).collect();
                    self.push_token(&vals);
                }
                t0 = t1;
                continue;
            }
            t0 = t1;
        }
    }

    /// Append one decode token (float channel vector); flushes the buffer
    /// into a q2 page when it reaches capacity.
    pub fn push_token(&mut self, values: &[f32]) {
        let full = self.buffer.push(values);
        if full {
            let (codes, scale, tokens) = self.buffer.drain();
            self.pages.push(QuantPage::from_q1(
                &codes,
                tokens,
                self.d_head,
                scale,
                self.bits,
            ));
        }
    }

    /// Materialize the q1 view into caller buffers:
    /// `q1` is `[capacity_tokens, d_head]` (page-aligned capacity), and
    /// `scales` one entry per `block` tokens. Returns valid token count.
    pub fn read_q1_into(
        &self,
        scratch: &mut Vec<u8>,
        q1: &mut [i8],
        scales: &mut [f32],
    ) -> usize {
        let d = self.d_head;
        let mut t = 0usize;
        for (pi, page) in self.pages.iter().enumerate() {
            debug_assert_eq!(page.tokens, self.block, "non-final page must be full");
            page.dequant_q1_into(
                scratch,
                &mut q1[t * d..(t + page.tokens) * d],
            );
            scales[pi] = page.fp_scale;
            t += page.tokens;
        }
        let bl = self.buffer.len();
        if bl > 0 {
            debug_assert_eq!(t % self.block, 0);
            q1[t * d..(t + bl) * d].copy_from_slice(self.buffer.codes());
            scales[t / self.block] = self.buffer.scale();
            t += bl;
        }
        t
    }

    /// Storage bytes (packed pages + buffer codes).
    pub fn bytes(&self) -> usize {
        self.pages.iter().map(|p| p.bytes()).sum::<usize>()
            + self.buffer.len() * self.d_head
            + 4
    }
}

/// Aggregate memory statistics (drives the compression-ratio reporting).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheStats {
    pub tokens: usize,
    pub bytes: usize,
    pub fp16_equiv_bytes: usize,
}

impl CacheStats {
    pub fn compression_ratio(&self) -> f64 {
        self.fp16_equiv_bytes as f64 / self.bytes.max(1) as f64
    }
}

/// Full-model cache: `[n_layers][n_heads]` K and V streams.
pub struct KvCache {
    pub cfg: KvCacheConfig,
    k: Vec<StreamCache>,
    v: Vec<StreamCache>,
}

/// One (layer, head) pair of K/V stream views.
pub struct HeadCache<'a> {
    pub k: &'a StreamCache,
    pub v: &'a StreamCache,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        let mut k = Vec::new();
        let mut v = Vec::new();
        for layer in 0..cfg.n_layers {
            for head in 0..cfg.n_heads {
                let bits = cfg.precision.get(layer, head);
                k.push(StreamCache::new(cfg.d_head, cfg.block, cfg.n_b, bits));
                v.push(StreamCache::new(cfg.d_head, cfg.block, cfg.n_b, bits));
            }
        }
        KvCache { cfg, k, v }
    }

    fn idx(&self, layer: usize, head: usize) -> usize {
        layer * self.cfg.n_heads + head
    }

    pub fn head(&self, layer: usize, head: usize) -> HeadCache<'_> {
        let i = self.idx(layer, head);
        HeadCache { k: &self.k[i], v: &self.v[i] }
    }

    pub fn k_stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        let i = self.idx(layer, head);
        &mut self.k[i]
    }

    pub fn v_stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        let i = self.idx(layer, head);
        &mut self.v[i]
    }

    /// Token count of the (layer 0, head 0) K stream — by construction all
    /// streams hold the same count.
    pub fn tokens(&self) -> usize {
        self.k.first().map(|s| s.tokens()).unwrap_or(0)
    }

    pub fn stats(&self) -> CacheStats {
        let bytes: usize =
            self.k.iter().chain(&self.v).map(|s| s.bytes()).sum();
        let tokens = self.tokens();
        let fp16 = 2 * tokens
            * self.cfg.d_head
            * self.cfg.n_layers
            * self.cfg.n_heads
            * 2; // K and V, 2 bytes each
        CacheStats { tokens, bytes, fp16_equiv_bytes: fp16 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_sym_int8;
    use crate::testutil::{prop, Rng};

    fn cfg(block: usize) -> KvCacheConfig {
        KvCacheConfig::new(2, 2, 8, block, PrecisionMap::uniform(2, 2, Bits::Int4))
    }

    #[test]
    fn ingest_full_blocks_makes_pages() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(0);
        let x = rng.normal_vec(8 * 8, 1.0); // 8 tokens
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 8);
        let s = &cache.head(0, 0).k;
        assert_eq!(s.pages.len(), 2);
        assert_eq!(s.buffer.len(), 0);
        assert_eq!(s.tokens(), 8);
    }

    #[test]
    fn ingest_partial_block_seeds_buffer() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(6 * 8, 1.0); // 6 tokens: 1 page + 2 buffered
        let q1 = quant_sym_int8(&x);
        cache.k_stream_mut(0, 0).ingest_q1_block(&q1.codes, q1.scale, 6);
        let s = &cache.head(0, 0).k;
        assert_eq!(s.pages.len(), 1);
        assert_eq!(s.buffer.len(), 2);
        assert_eq!(s.tokens(), 6);
    }

    #[test]
    fn decode_pushes_flush_at_capacity() {
        let mut cache = KvCache::new(cfg(4));
        let mut rng = Rng::new(2);
        for i in 0..9 {
            let v = rng.normal_vec(8, 1.0);
            cache.k_stream_mut(1, 1).push_token(&v);
            assert_eq!(cache.head(1, 1).k.tokens(), i + 1);
        }
        let s = &cache.head(1, 1).k;
        assert_eq!(s.pages.len(), 2);
        assert_eq!(s.buffer.len(), 1);
    }

    #[test]
    fn read_q1_roundtrip_tracks_values() {
        prop::run("cache q1 read", 25, |g| {
            let block = 4;
            let mut cache = KvCache::new(cfg(block));
            let n = g.usize_in(1, 20);
            let mut originals: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n {
                let v = g.normal_vec(8, 1.0);
                cache.k_stream_mut(0, 1).push_token(&v);
                originals.push(v);
            }
            let cap = 24; // page-aligned capacity
            let mut q1 = vec![0i8; cap * 8];
            let mut scales = vec![0.0f32; cap / block];
            let mut scratch = Vec::new();
            let got =
                cache.head(0, 1).k.read_q1_into(&mut scratch, &mut q1, &mut scales);
            assert_eq!(got, n);
            // Every non-clamped token approximately recoverable:
            // q1 * block_scale (int8 round + int4 progressive error is a
            // bounded number of quantizer steps; values beyond the
            // universal scale's 127-code range are clamped by design).
            for (t, orig) in originals.iter().enumerate() {
                let s = scales[t / block];
                for c in 0..8 {
                    if orig[c].abs() > 126.0 * s {
                        continue; // clamped outlier (paper §3.3 semantics)
                    }
                    let approx = q1[t * 8 + c] as f32 * s;
                    assert!(
                        (approx - orig[c]).abs() <= 30.0 * s + 1e-4,
                        "t={t} c={c}: {approx} vs {}",
                        orig[c]
                    );
                }
            }
        });
    }

    #[test]
    fn stats_reflect_compression() {
        // Realistic geometry: page/parameter overhead amortizes over the
        // block and head dim (tiny 4x8 pages are overhead-dominated).
        let pm = PrecisionMap::uniform(2, 2, Bits::Int4);
        let cfg = KvCacheConfig::new(2, 2, 32, 16, pm);
        let mut cache = KvCache::new(cfg);
        let mut rng = Rng::new(3);
        for _ in 0..64 {
            for l in 0..2 {
                for h in 0..2 {
                    let kv = rng.normal_vec(32, 1.0);
                    cache.k_stream_mut(l, h).push_token(&kv);
                    cache.v_stream_mut(l, h).push_token(&kv);
                }
            }
        }
        let stats = cache.stats();
        assert_eq!(stats.tokens, 64);
        // INT4 pages + small buffer: better than 2.5x vs FP16.
        assert!(stats.compression_ratio() > 2.5, "{}", stats.compression_ratio());
    }

    #[test]
    fn mixed_precision_2bit_heads_smaller() {
        let mut pm = PrecisionMap::uniform(1, 2, Bits::Int4);
        pm.set(0, 1, Bits::Int2);
        let cfg = KvCacheConfig::new(1, 2, 8, 4, pm);
        let mut cache = KvCache::new(cfg);
        let mut rng = Rng::new(4);
        for _ in 0..8 {
            for h in 0..2 {
                let kv = rng.normal_vec(8, 1.0);
                cache.k_stream_mut(0, h).push_token(&kv);
            }
        }
        let b4 = cache.head(0, 0).k.bytes();
        let b2 = cache.head(0, 1).k.bytes();
        assert!(b2 < b4, "2-bit head {b2}B vs 4-bit head {b4}B");
    }
}
