//! Enhanced KV decode buffer (paper §3.3).
//!
//! Newly generated K/V tokens land here at INT8 with a **universal
//! clamped scale**: the scale is fixed when the buffer opens, and later
//! outliers are clamped rather than triggering a re-quantization of
//! already-buffered tokens. When `n_b` tokens accumulate the buffer is
//! flushed through progressive quantization into a q2 page.
//!
//! This contrasts with KIVI/GEAR's full-precision residual windows: the
//! buffer is itself INT8, so the attention over buffered tokens is still
//! integer inference.
//!
//! Invariant the incremental q1 view (`store::Q1View`) relies on: within
//! an epoch, `codes` is **append-only** — the universal scale is fixed at
//! the first push, so earlier tokens are never re-quantized; outliers are
//! clamped instead. Mutate streams only through `StreamCache` methods
//! (`push_token` / `ingest_q1_block`), or the mirrored view goes stale.

use crate::quant::sym::{quant_sym_int8_fixed_scale, INT8_QMAX};

/// INT8 token buffer for one (layer, head) K or V stream.
#[derive(Debug, Clone)]
pub struct DecodeBuffer {
    pub channels: usize,
    pub capacity: usize,
    /// INT8 codes, `len() / channels` tokens.
    codes: Vec<i8>,
    /// Universal scale; fixed at first append of an epoch, reset on flush.
    scale: f32,
    /// Count of clamped (outlier) elements since the last flush — a
    /// telemetry signal for scale quality.
    pub clamped: u64,
}

impl DecodeBuffer {
    pub fn new(channels: usize, capacity: usize) -> DecodeBuffer {
        assert!(capacity > 0);
        DecodeBuffer {
            channels,
            capacity,
            codes: Vec::with_capacity(capacity * channels),
            scale: 0.0,
            clamped: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.codes.len() / self.channels
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.len() >= self.capacity
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    pub fn codes(&self) -> &[i8] {
        &self.codes
    }

    /// Append one token's channel vector. Returns true if the buffer is
    /// now full (caller should flush into a page).
    ///
    /// The first token of an epoch sets the universal scale (with a 2x
    /// headroom factor so moderately larger later tokens don't clamp);
    /// subsequent outliers are clamped, per the paper.
    pub fn push(&mut self, values: &[f32]) -> bool {
        assert_eq!(values.len(), self.channels);
        assert!(!self.is_full(), "push into full buffer — flush first");
        if self.is_empty() {
            let amax = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            self.scale = (amax * 2.0 / INT8_QMAX).max(1e-8);
        }
        let before = self.codes.len();
        self.codes
            .extend(quant_sym_int8_fixed_scale(values, self.scale));
        // Count clamps for telemetry.
        for (&c, &v) in self.codes[before..].iter().zip(values) {
            if (c == 127 || c == -127) && (v / self.scale).abs() > 127.5 {
                self.clamped += 1;
            }
        }
        self.is_full()
    }

    /// Drain all buffered tokens as (q1 codes, universal scale, count),
    /// resetting the buffer for the next epoch.
    pub fn drain(&mut self) -> (Vec<i8>, f32, usize) {
        let tokens = self.len();
        let scale = self.scale;
        let codes = std::mem::take(&mut self.codes);
        self.scale = 0.0;
        self.clamped = 0;
        (codes, scale, tokens)
    }

    /// Dequantized float view of buffered tokens (tests/oracles only).
    pub fn to_f32(&self) -> Vec<f32> {
        self.codes.iter().map(|&c| c as f32 * self.scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn fills_at_capacity() {
        let mut b = DecodeBuffer::new(4, 3);
        assert!(!b.push(&[1.0, 2.0, 3.0, 4.0]));
        assert!(!b.push(&[1.0; 4]));
        assert!(b.push(&[0.5; 4]));
        assert!(b.is_full());
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn universal_scale_is_stable_across_pushes() {
        let mut b = DecodeBuffer::new(2, 8);
        b.push(&[1.0, -1.0]);
        let s0 = b.scale();
        b.push(&[100.0, 0.0]); // outlier: clamped, scale unchanged
        assert_eq!(b.scale(), s0);
        assert!(b.clamped > 0);
        // First token's codes unchanged by the outlier push.
        let f = b.to_f32();
        assert!((f[0] - 1.0).abs() < s0);
    }

    #[test]
    fn drain_resets_epoch() {
        let mut b = DecodeBuffer::new(2, 4);
        b.push(&[1.0, 2.0]);
        let (codes, scale, n) = b.drain();
        assert_eq!(n, 1);
        assert_eq!(codes.len(), 2);
        assert!(scale > 0.0);
        assert!(b.is_empty());
        assert_eq!(b.scale(), 0.0);
        // New epoch gets a fresh scale from its first token.
        b.push(&[10.0, 0.0]);
        assert!((b.scale() - 20.0 / INT8_QMAX).abs() < 1e-6);
    }

    #[test]
    fn token_count_conservation() {
        prop::run("buffer conserves tokens", 50, |g| {
            let ch = g.usize_in(1, 8);
            let cap = g.usize_in(1, 16);
            let mut b = DecodeBuffer::new(ch, cap);
            let mut pushed = 0usize;
            let mut drained = 0usize;
            for _ in 0..g.usize_in(0, 100) {
                if b.is_full() {
                    drained += b.drain().2;
                }
                let v = g.normal_vec(ch, 1.0);
                b.push(&v);
                pushed += 1;
            }
            drained += b.drain().2;
            assert_eq!(pushed, drained);
        });
    }

    #[test]
    fn roundtrip_error_within_scale_for_in_range_tokens() {
        prop::run("buffer quant error", 50, |g| {
            let ch = g.usize_in(1, 16);
            let mut b = DecodeBuffer::new(ch, 8);
            let first = g.normal_vec(ch, 1.0);
            b.push(&first);
            let s = b.scale();
            // Second token within 2x the first token's range: no clamping.
            let second: Vec<f32> =
                first.iter().map(|&x| x * g.f32_in(-1.5, 1.5)).collect();
            b.push(&second);
            let back = b.to_f32();
            for (i, &want) in first.iter().chain(&second).enumerate() {
                assert!(
                    (back[i] - want).abs() <= s * 0.5 + 1e-6,
                    "idx {i}: {} vs {want} (s={s})",
                    back[i]
                );
            }
        });
    }
}
