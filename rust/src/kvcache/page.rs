//! One q2-level cache page: `bc` tokens of K or V for one head, packed.
//!
//! Pages are **immutable after construction** — nothing rewrites codes or
//! parameters once `from_q1` returns. Two §Perf optimizations lean on
//! this: the per-channel dequant lookup table below, and the
//! dequantize-once incremental view in `store::Q1View`.

use crate::quant::{
    pack_codes, quant_asym_int, unpack_codes_into, Bits, PackedCodes,
};

/// A full page of `tokens x channels` codes at q2 precision, plus the
/// integer dequantization parameters and the q1-level FP scale.
#[derive(Debug, Clone)]
pub struct QuantPage {
    pub bits: Bits,
    pub tokens: usize,
    pub channels: usize,
    /// Packed q2 codes.
    pub packed: PackedCodes,
    /// Per-channel integer scale (INT8 range, held as i32).
    pub s_int: Vec<i32>,
    /// Per-channel integer zero point.
    pub z_int: Vec<i32>,
    /// The symmetric FP scale of the q1 level this page was built from.
    pub fp_scale: f32,
    /// Precomputed code -> q1 tables, `channels x (levels+1)` i8 entries.
    /// Pages are immutable, so the per-channel affine
    /// `clamp((code + z) * s)` is folded into a lookup at construction —
    /// the §Perf optimization of the decode hot path (derivable metadata,
    /// excluded from the storage accounting).
    deq_table: Vec<i8>,
}

impl QuantPage {
    /// Compress a q1 block (INT8 codes + scale) into a page.
    pub fn from_q1(
        q1: &[i8],
        tokens: usize,
        channels: usize,
        fp_scale: f32,
        bits: Bits,
    ) -> QuantPage {
        let blk = quant_asym_int(q1, tokens, channels, bits);
        let stride = bits.levels() as usize + 1;
        let mut deq_table = vec![0i8; channels * stride];
        for c in 0..channels {
            for code in 0..stride {
                let v = (code as i32 + blk.z_int[c]) * blk.s_int[c];
                deq_table[c * stride + code] = v.clamp(-127, 127) as i8;
            }
        }
        QuantPage {
            bits,
            tokens,
            channels,
            packed: pack_codes(&blk.codes, bits),
            s_int: blk.s_int,
            z_int: blk.z_int,
            fp_scale,
            deq_table,
        }
    }

    /// Decompress q2 -> q1 INT8 codes into `out` (len tokens*channels).
    ///
    /// Hot path: fused unpack + per-channel table lookup (no multiply,
    /// no clamp in the loop). INT4/INT2 get specialized byte-wise paths.
    pub fn dequant_q1_into(&self, scratch: &mut Vec<u8>, out: &mut [i8]) {
        let n = self.tokens * self.channels;
        assert_eq!(out.len(), n);
        let ch = self.channels;
        match self.bits {
            Bits::Int4 if ch % 2 == 0 => {
                // Two codes per byte; channel index advances by 2.
                let bytes_per_row = ch / 2;
                for t in 0..self.tokens {
                    let row = &self.packed.bytes
                        [t * bytes_per_row..(t + 1) * bytes_per_row];
                    let out_row = &mut out[t * ch..(t + 1) * ch];
                    for (i, &b) in row.iter().enumerate() {
                        let c = 2 * i;
                        out_row[c] =
                            self.deq_table[c * 16 + (b & 0xF) as usize];
                        out_row[c + 1] =
                            self.deq_table[(c + 1) * 16 + (b >> 4) as usize];
                    }
                }
            }
            Bits::Int2 if ch % 4 == 0 => {
                let bytes_per_row = ch / 4;
                for t in 0..self.tokens {
                    let row = &self.packed.bytes
                        [t * bytes_per_row..(t + 1) * bytes_per_row];
                    let out_row = &mut out[t * ch..(t + 1) * ch];
                    for (i, &b) in row.iter().enumerate() {
                        let c = 4 * i;
                        out_row[c] = self.deq_table[c * 4 + (b & 3) as usize];
                        out_row[c + 1] =
                            self.deq_table[(c + 1) * 4 + ((b >> 2) & 3) as usize];
                        out_row[c + 2] =
                            self.deq_table[(c + 2) * 4 + ((b >> 4) & 3) as usize];
                        out_row[c + 3] =
                            self.deq_table[(c + 3) * 4 + (b >> 6) as usize];
                    }
                }
            }
            _ => {
                // Generic path: unpack then table-lookup per element.
                let stride = self.bits.levels() as usize + 1;
                scratch.resize(n, 0);
                unpack_codes_into(&self.packed, &mut scratch[..n]);
                for t in 0..self.tokens {
                    let row_in = &scratch[t * ch..(t + 1) * ch];
                    let row_out = &mut out[t * ch..(t + 1) * ch];
                    for c in 0..ch {
                        row_out[c] =
                            self.deq_table[c * stride + row_in[c] as usize];
                    }
                }
            }
        }
    }

    /// Convenience allocating variant (tests / cold paths).
    #[deprecated(
        note = "allocates per call; use dequant_q1_into with a reused buffer"
    )]
    pub fn dequant_q1(&self) -> Vec<i8> {
        let mut out = vec![0i8; self.tokens * self.channels];
        let mut scratch = Vec::new();
        self.dequant_q1_into(&mut scratch, &mut out);
        out
    }

    /// Bytes of storage used by this page (codes + params).
    pub fn bytes(&self) -> usize {
        self.packed.bytes.len()
            + self.s_int.len()  // s_int fits i8 per paper; count 1B each
            + self.z_int.len()
            + 4 // fp_scale
    }
}

/// Cheap per-page statistics for the SparQ-style sparse decode path:
/// a per-channel min/max envelope over the page's q1 key codes (the
/// input to [`crate::kernels::page_score`]) and the per-channel column
/// mean of the q1 codes as f32 (the mean-value correction folded in for
/// skipped pages; for K pages the mean is computed but unused).
///
/// Summaries are **derivable state**, exactly like the pool's q1 memos:
/// recomputable from the page at any time, so evicting one never bumps
/// a cache epoch, and their bytes count against `pool_byte_cap` like
/// any other memo.
#[derive(Debug, Clone)]
pub struct PageSummary {
    /// Per-channel minimum q1 code (`channels` entries).
    pub min: Vec<i8>,
    /// Per-channel maximum q1 code (`channels` entries).
    pub max: Vec<i8>,
    /// Per-channel mean q1 code (`channels` f32 entries).
    pub mean: Vec<f32>,
}

impl PageSummary {
    /// Build a summary from q1 codes laid out `tokens x channels`
    /// row-major. `tokens` must be positive — empty pages never exist
    /// in the pool.
    pub fn from_q1(codes: &[i8], tokens: usize, channels: usize) -> PageSummary {
        assert!(tokens > 0, "a page holds at least one token");
        assert_eq!(codes.len(), tokens * channels);
        let mut min = vec![i8::MAX; channels];
        let mut max = vec![i8::MIN; channels];
        let mut sum = vec![0i64; channels];
        for t in 0..tokens {
            let row = &codes[t * channels..(t + 1) * channels];
            for c in 0..channels {
                let v = row[c];
                min[c] = min[c].min(v);
                max[c] = max[c].max(v);
                sum[c] += v as i64;
            }
        }
        let inv = 1.0 / tokens as f32;
        let mean = sum.iter().map(|&s| s as f32 * inv).collect();
        PageSummary { min, max, mean }
    }

    /// Bytes of memo storage this summary occupies (counted against the
    /// pool byte cap alongside the q1 memos).
    pub fn bytes(&self) -> usize {
        self.min.len() + self.max.len() + 4 * self.mean.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_sym_int8;
    use crate::testutil::prop;

    #[test]
    #[allow(deprecated)]
    fn page_roundtrip_matches_unpacked_pipeline() {
        prop::run("page == asym pipeline", 50, |g| {
            let tokens = g.usize_in(1, 64);
            let channels = g.usize_in(1, 32);
            let bits = *g.choose(&[Bits::Int2, Bits::Int4]);
            let x = g.normal_vec(tokens * channels, 2.0);
            let q1 = quant_sym_int8(&x);
            let page =
                QuantPage::from_q1(&q1.codes, tokens, channels, q1.scale, bits);
            let got = page.dequant_q1();
            let blk = crate::quant::quant_asym_int(&q1.codes, tokens, channels, bits);
            let want = crate::quant::dequant_asym_int(&blk);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn storage_is_actually_compressed() {
        let x: Vec<f32> = (0..64 * 32).map(|i| (i as f32).sin()).collect();
        let q1 = quant_sym_int8(&x);
        let p4 = QuantPage::from_q1(&q1.codes, 64, 32, q1.scale, Bits::Int4);
        let p2 = QuantPage::from_q1(&q1.codes, 64, 32, q1.scale, Bits::Int2);
        let fp16_bytes = 64 * 32 * 2;
        assert!(p4.bytes() * 3 < fp16_bytes, "int4 page {}B", p4.bytes());
        assert!(p2.bytes() < p4.bytes());
    }

    #[test]
    fn page_summary_envelopes_every_row_and_averages_columns() {
        prop::run("summary bounds q1 codes", 50, |g| {
            let tokens = g.usize_in(1, 48);
            let channels = g.usize_in(1, 24);
            let x = g.normal_vec(tokens * channels, 2.0);
            let q1 = quant_sym_int8(&x);
            let s = PageSummary::from_q1(&q1.codes, tokens, channels);
            for c in 0..channels {
                let col: Vec<i8> =
                    (0..tokens).map(|t| q1.codes[t * channels + c]).collect();
                assert_eq!(s.min[c], *col.iter().min().unwrap());
                assert_eq!(s.max[c], *col.iter().max().unwrap());
                let want: f32 = col.iter().map(|&v| v as i64).sum::<i64>()
                    as f32
                    / tokens as f32;
                assert_eq!(s.mean[c].to_bits(), want.to_bits(), "col {c}");
            }
            assert_eq!(s.bytes(), 6 * channels);
        });
    }

    #[test]
    fn dequant_into_avoids_reallocation() {
        let x: Vec<f32> = (0..16 * 8).map(|i| (i as f32).cos()).collect();
        let q1 = quant_sym_int8(&x);
        let page = QuantPage::from_q1(&q1.codes, 16, 8, q1.scale, Bits::Int4);
        let mut scratch = Vec::new();
        let mut out = vec![0i8; 16 * 8];
        page.dequant_q1_into(&mut scratch, &mut out);
        let cap = scratch.capacity();
        page.dequant_q1_into(&mut scratch, &mut out);
        assert_eq!(scratch.capacity(), cap);
    }
}
