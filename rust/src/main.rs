//! `turboattn` — leader entrypoint and CLI.
//!
//! Subcommands:
//!   serve       start the TCP serving loop (engine thread + listener)
//!   gen         one-shot generation from the CLI
//!   bench-serve sweep the serving stack with the load harness and
//!               write a BENCH_serve.json saturation report
//!   experiment  regenerate a paper table/figure (fig1..tab5, all)
//!   selftest    runtime smoke: load artifacts, run micro kernels
//!
//! Examples:
//!   turboattn gen --prompt "the router " --max-new 48 --mode turbo
//!   turboattn gen --path turbo-cpu --greedy          # no artifacts needed
//!   turboattn gen --path turbo-cpu --stream          # print tokens live
//!   turboattn gen --path turbo-cpu --batch 4 --seed-per-request
//!   turboattn serve --port 7100 --path turbo-cpu
//!   turboattn bench-serve --mode open --rates 2,4,8,16 --requests 64
//!   turboattn bench-serve --mode closed --concurrency 1,4 --check
//!   turboattn experiment fig6
//!
//! `--path` (alias `--mode`) selects the serving backend: `turbo`
//! (quantized execution in the AOT executables), `turbo-cpu` (the pure-
//! Rust integer-kernel substrate — runs with no artifacts and no PJRT
//! toolchain), or `flash` (exact FP32 baseline).
//!
//! Sampling is **per request** (`SamplingParams`): `--greedy` or
//! `--top-k N --temp T`, `--sample-seed S` (defaults to `--seed`),
//! `--stop <char>`. For `gen --batch N`, `--seed-per-request` gives
//! request i the seed S+i (otherwise all share S — identical requests
//! then produce identical outputs, regardless of batching). For
//! `serve`, the same flags set the *defaults* a `GEN` line can override
//! per request (see the wire protocol in `server/mod.rs`: `GEN
//! <max_new> [seed=N] [topk=K] [temp=T] [stop=BYTE] [greedy] <prompt>`
//! -> `ACK <id>`, streamed `TOK <id> <idx> <byte>` lines, then `DONE
//! <id> <reason> ...`; `CANCEL <id>` aborts; `STATS` snapshots
//! metrics).
//!
//! `gen --stream` prints tokens as the engine emits them (the CLI
//! analogue of the server's `TOK` stream) instead of waiting for
//! completion.
//!
//! Prompt-prefix KV sharing (`--share-prefixes` / `--no-share-prefixes`,
//! default on for `turbo-cpu`): batched requests with a common prompt
//! prefix share the same refcounted q2 pages instead of each storing a
//! copy; `gen --batch N` submits the prompt N times to exercise it.
//!
//! `--pool-bytes N` caps the shared KV page pool at N bytes (pages +
//! q1 memos). Under pressure the engine first evicts LRU q1 memos
//! (recomputed on demand), then preempts the cheapest-replay running
//! request — fewest generated tokens, youngest on ties — (pages
//! released, recompute-on-resume) — outputs stay bit-identical to an
//! uncapped run; pressure counters appear in `gen` output and `STATS`.
//!
//! Scheduling is token-budget continuous batching:
//! `--max-batch-total-tokens N` caps the sum of admitted KV
//! reservations (prompt + max_new per request; `--token-budget` is the
//! legacy alias), `--max-batch-prefill-tokens N` rations prompt tokens
//! prefilled per engine iteration, `--prefill-chunk N` splits long
//! prefills into N-token chunks interleaved with batch-mates' decode
//! steps (0 = monolithic; rounded up to the model block size), and
//! `--waiting-served-ratio R` batches admissions into waves once
//! waiting/running exceeds R (0 = admit greedily). All four knobs are
//! bitwise invisible: they change *when* work runs, never its result.
//!
//! `--kernel-backend scalar|avx2|neon|auto` pins the integer-kernel ISA
//! (default: auto-detect; the `TURBO_KERNEL` env var is the same knob
//! for processes without this flag). Every backend is bit-identical —
//! this selects speed, never results — and the arm actually dispatched
//! is reported in `gen` output and the server's `STATS` line.
//!
//! `--sparse-topk-pages K` (default 0 = dense) turns on SparQ-style
//! top-k page-sparse decode: each attention stream scores its full KV
//! pages against a per-page key envelope, attends only the K
//! best-scoring pages exactly, and folds every skipped page as a single
//! mean-value softmax term. Selection is deterministic (ties go to the
//! lower page index), so outputs stay reproducible at any thread count;
//! `K` large enough to cover the context is bit-identical to dense.
//! Page traffic saved is reported on the `sparse :` line of `gen`
//! output and in `STATS`.
//!
//! `bench-serve` drives the serving stack with the `loadgen` harness
//! and writes a saturation report (default `BENCH_serve.json`). Flags:
//! `--mode open|closed` picks the generator (open loop: seeded Poisson
//! arrivals at each of `--rates R1,R2,..` requests/s, offered load
//! never gated by completions; closed loop: fixed worker counts from
//! `--concurrency N1,N2,..`, next request on completion). The seeded
//! workload (`--seed`, `--requests N` per sweep point) is shaped by
//! `--mix short|longtail|heavy` (comma list sweeps mixes),
//! `--shared-prefix-ratio R` (+ `--shared-prefix-len L`, exercising
//! the prefix index), `--cancel-prob P` (client cancels after a random
//! k-th token — the disconnect-as-cancel path), and `--sparse-ratio R`
//! + `--sparse-topk-pages K` (sparse/dense traffic mix). Each sweep
//! point gets a fresh engine; `--pool-bytes-list B1,B2,..` sweeps pool
//! caps (0 = uncapped). `--transport tcp` (default) spawns an
//! in-process engine + listener and drives real loopback sockets
//! through the wire protocol; `--transport inproc` uses the
//! `EngineHandle` API directly (CI-friendly); `--connect HOST:PORT`
//! targets an already-running `turboattn serve` (engine counters are
//! then window deltas via `STATS JSON`). `--out FILE` sets the report
//! path and `--check` re-parses the written report, asserting nonzero
//! completions, zero transport errors, and p50 <= p99 per percentile
//! bundle. Sampling flags (`--greedy`, `--top-k`, `--temp`,
//! `--max-new`, …) set the workload's base `SamplingParams`; the
//! harness defaults `--max-new` to 32 so prefix + prompt + generation
//! fit the CPU substrate's 256-token context.

use std::net::TcpListener;
use std::sync::mpsc::channel;

use anyhow::{Context, Result};

use turboattention::coordinator::engine::Command;
use turboattention::coordinator::{
    Engine, EngineConfig, EngineHandle, GenRequest, PathMode, SamplingParams,
    TokenEvent,
};
use turboattention::model::{ByteTokenizer, ModelBundle, Sampler};
use turboattention::quant::Bits;
use turboattention::runtime::{HostTensor, Runtime};
use turboattention::util::cli::Args;
use turboattention::{info, server};

fn main() -> Result<()> {
    let args = Args::from_env();
    turboattention::util::set_log_level(if args.flag("quiet") {
        1
    } else if args.flag("verbose") {
        3
    } else {
        2
    });
    // Pin the kernel backend before anything can dispatch: the choice
    // is process-wide and sticky, so it must precede engine
    // construction (which stamps it into the metrics snapshot).
    if let Some(kb) = args.opt("kernel-backend") {
        let got = turboattention::kernels::force_kernel_backend(kb)
            .map_err(anyhow::Error::msg)
            .context("--kernel-backend")?;
        info!("main", "kernel backend pinned: {}", got.name());
    }
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("gen") => gen(&args),
        Some("bench-serve") => bench_serve(&args),
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .context("usage: turboattn experiment <figN|tabN|all>")?;
            turboattention::experiments::run(id, &args)
        }
        Some("selftest") => selftest(&args),
        other => {
            eprintln!(
                "usage: turboattn <serve|gen|bench-serve|experiment|selftest> \
                 [--options]\n(got {other:?})"
            );
            std::process::exit(2);
        }
    }
}

fn engine_config(args: &Args) -> EngineConfig {
    // `--path` is the canonical spelling; `--mode` stays as an alias.
    let path =
        args.opt("path").or_else(|| args.opt("mode")).unwrap_or("turbo");
    engine_config_for_path(args, path)
}

/// Engine config for an explicit backend path string. `bench-serve`
/// resolves `--path` itself — there `--mode` means open|closed, not a
/// backend — and defaults to the artifact-free `turbo-cpu` substrate.
fn engine_config_for_path(args: &Args, path: &str) -> EngineConfig {
    let mode = match path {
        "turbo" => PathMode::Turbo,
        "turbo-cpu" | "turbocpu" => PathMode::TurboCpu,
        "flash" => PathMode::Flash,
        other => panic!("--path must be turbo|turbo-cpu|flash, got {other}"),
    };
    let kv_bits = Bits::from_bits(args.opt_parse("kv-bits", 4u32))
        .expect("--kv-bits must be 2|3|4|8");
    // Prompt-prefix KV sharing: default ON for the artifact-free
    // turbo-cpu path (where every session shares one page pool), off
    // elsewhere unless forced; `--no-share-prefixes` always wins.
    let share_default = mode == PathMode::TurboCpu;
    let share_prefixes = if args.flag("no-share-prefixes") {
        false
    } else {
        share_default || args.flag("share-prefixes")
    };
    let mut cfg = EngineConfig {
        mode,
        kv_bits,
        n_2bit_heads: args.opt_parse("n-2bit-heads", 0usize),
        decode_threads: args.opt_parse(
            "decode-threads",
            turboattention::pool::default_threads(),
        ),
        share_prefixes,
        seed: args.opt_parse("seed", 0u64),
        ..Default::default()
    };
    cfg.batcher.max_running = args.opt_parse("max-running", 32usize);
    // `--token-budget` stays as the legacy alias for the total cap.
    cfg.batcher.max_batch_total_tokens = args.opt_parse(
        "max-batch-total-tokens",
        args.opt_parse("token-budget", 4096usize),
    );
    cfg.batcher.max_batch_prefill_tokens =
        args.opt_parse("max-batch-prefill-tokens", 512usize);
    cfg.batcher.prefill_chunk = args.opt_parse("prefill-chunk", 0usize);
    cfg.batcher.waiting_served_ratio =
        args.opt_parse("waiting-served-ratio", 0.0f32);
    cfg.pool_byte_cap = args.opt("pool-bytes").map(|s| {
        s.parse().unwrap_or_else(|_| {
            panic!("--pool-bytes: cannot parse {s:?} as bytes")
        })
    });
    cfg
}

/// Per-request sampling from the CLI flags. `--sample-seed` decouples
/// the sampling seed from `--seed` (which also seeds the CpuModel
/// weights); it defaults to the same value, preserving the old
/// one-seed behavior.
fn sampling_params(args: &Args) -> SamplingParams {
    let sampler = if args.flag("greedy") {
        Sampler::Greedy
    } else {
        Sampler::TopK {
            k: args.opt_parse("top-k", turboattention::model::DEFAULT_TOP_K),
            temp: args.opt_parse("temp", turboattention::model::DEFAULT_TEMP),
        }
    };
    SamplingParams {
        sampler,
        seed: args.opt_parse("sample-seed", args.opt_parse("seed", 0u64)),
        stop_byte: args.opt("stop").and_then(|s| s.bytes().next()),
        max_new_tokens: args.opt_parse("max-new", 48usize),
    }
}

/// Runtime for a config: the CPU-substrate path needs no artifacts (its
/// geometry is built in); everything else loads the artifact directory.
fn runtime_for(cfg: &EngineConfig, dir: &str) -> Result<Runtime> {
    if cfg.mode == PathMode::TurboCpu {
        return Ok(Runtime::cpu_substrate());
    }
    Runtime::load(dir)
}

fn load_engine(args: &Args) -> Result<Engine> {
    let cfg = engine_config(args);
    let rt = runtime_for(&cfg, args.opt_or("artifacts", "artifacts"))?;
    Ok(Engine::new(ModelBundle::new(rt), cfg))
}

fn gen(args: &Args) -> Result<()> {
    let mut engine = load_engine(args)?;
    let prompt = args.opt_or("prompt", "the router routes the tokens ");
    let params = sampling_params(args);
    // `--batch N` submits the prompt N times — with prefix sharing on,
    // requests 2..N fork from the first request's pages.
    let batch = args.opt_parse("batch", 1usize).max(1);
    let seed_per_request = args.flag("seed-per-request");
    // Top-k page-sparse decode (0 = dense). Per-request in the engine;
    // the CLI applies one value to the whole batch.
    let sparse_topk = args.opt_parse("sparse-topk-pages", 0usize);
    let tok = ByteTokenizer;
    for i in 0..batch as u64 {
        let mut p = params;
        if seed_per_request {
            p.seed = params.seed.wrapping_add(i);
        }
        engine.submit(
            GenRequest::with_params(i + 1, tok.encode(prompt), p)
                .with_sparse_topk(sparse_topk),
        );
    }
    let mut completions = if args.flag("stream") {
        // Print tokens as the engine emits them; batch > 1 interleaves,
        // so each token line carries its request id.
        use std::io::Write as _;
        let mut done = Vec::new();
        while !engine.idle() {
            for ev in engine.step()? {
                match ev.event {
                    TokenEvent::First { token, ttft } if batch == 1 => {
                        print!(
                            "[ttft {:.1}ms] {}",
                            ttft * 1e3,
                            tok.decode(&[token])
                        );
                        std::io::stdout().flush().ok();
                    }
                    TokenEvent::Token { token, .. } if batch == 1 => {
                        print!("{}", tok.decode(&[token]));
                        std::io::stdout().flush().ok();
                    }
                    TokenEvent::First { token, .. }
                    | TokenEvent::Token { token, .. } => {
                        println!("tok {} {}", ev.id, tok.decode(&[token]));
                    }
                    TokenEvent::Finished(c) => {
                        if batch == 1 {
                            println!();
                        }
                        done.push(c);
                    }
                }
            }
        }
        done
    } else {
        engine.run_to_completion()?
    };
    completions.sort_by_key(|c| c.id);
    for c in completions {
        println!("prompt : {prompt}");
        println!("output : {}", tok.decode(&c.generated));
        println!(
            "ttft {:.1}ms | total {:.1}ms | {:.1}ms/token | cache {:.2}x compressed",
            c.ttft * 1e3,
            c.total_latency * 1e3,
            c.tpot * 1e3,
            engine.metrics.cache_compression.max(1.0)
        );
    }
    println!("itl    : {}", engine.itl_hist.summary());
    println!(
        "sched  : waiting {} | fill {:.3} | prefill_chunks {} | \
         capacity waits {}",
        engine.waiting_hist.summary(),
        engine.metrics.batch_fill_ratio,
        engine.metrics.prefill_chunks,
        engine.metrics.batcher_capacity_waits
    );
    println!("kernel : {}", engine.metrics.kernel_backend);
    if sparse_topk > 0 {
        println!(
            "sparse : topk {} | sparse_pages_attended {} | \
             sparse_pages_skipped {} | sparse_bytes_saved {}",
            sparse_topk,
            engine.metrics.sparse_pages_attended,
            engine.metrics.sparse_pages_skipped,
            engine.metrics.sparse_bytes_saved
        );
    }
    if engine.metrics.requests_cancelled > 0 {
        println!("cancelled: {}", engine.metrics.requests_cancelled);
    }
    if engine.cfg.share_prefixes {
        println!(
            "prefix sharing: {} hits | {} shared tokens | dedup {:.3}",
            engine.metrics.prefix_hits,
            engine.metrics.prefix_shared_tokens,
            engine.metrics.page_dedup_ratio
        );
    }
    if let Some(cap) = engine.cfg.pool_byte_cap {
        println!(
            "pool   : cap {cap}B | preempt {} | replayed {} | \
             memo evict {} | memo recompute {}",
            engine.metrics.preemptions,
            engine.metrics.preempt_replayed_tokens,
            engine.metrics.pool_memo_evictions,
            engine.metrics.pool_memo_recomputes
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let port: u16 = args.opt_parse("port", 7100u16);
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    info!("main", "turboattn serving on 127.0.0.1:{port}");
    let (tx, rx) = channel::<Command>();
    // Defaults for requests that don't override sampling on the GEN line.
    let defaults = sampling_params(args);
    // The PJRT client is not Send (Rc internals): construct the engine
    // *inside* its thread — the leader owns the device for its lifetime.
    let cfg = engine_config(args);
    let dir = args.opt_or("artifacts", "artifacts").to_string();
    let engine_thread = std::thread::spawn(move || -> Result<()> {
        let rt = runtime_for(&cfg, &dir)?;
        let engine = Engine::new(ModelBundle::new(rt), cfg);
        engine.run_loop(rx)
    });
    server::serve(listener, EngineHandle::new(tx), defaults)?;
    engine_thread.join().expect("engine thread")?;
    Ok(())
}

/// `bench-serve`: sweep the serving stack with the load harness and
/// write a `BENCH_serve.json` saturation report (flags documented in
/// the module doc above).
fn bench_serve(args: &Args) -> Result<()> {
    use turboattention::loadgen::{self, LenMix, WorkloadConfig};

    let mode = args.opt_or("mode", "open").to_string();
    anyhow::ensure!(
        mode == "open" || mode == "closed",
        "--mode must be open|closed"
    );
    let rates = args.opt_list("rates", &[2.0f64, 4.0, 8.0, 16.0, 32.0]);
    let concs = args.opt_list("concurrency", &[1usize, 2, 4, 8]);
    let mixes: Vec<LenMix> = args
        .opt_or("mix", "longtail")
        .split(',')
        .map(|m| LenMix::parse(m.trim()).map_err(anyhow::Error::msg))
        .collect::<Result<_>>()?;
    let caps = args.opt_list("pool-bytes-list", &[0usize]);
    let transport = args.opt_or("transport", "tcp").to_string();
    anyhow::ensure!(
        transport == "tcp" || transport == "inproc",
        "--transport must be tcp|inproc"
    );
    let cancel_prob = args.opt_parse("cancel-prob", 0.0f64);
    let out_path = args.opt_or("out", "BENCH_serve.json").to_string();

    let mut base = sampling_params(args);
    if args.opt("max-new").is_none() {
        // Harness default: keep shared prefix + prompt + generation
        // inside the CPU substrate's 256-token context.
        base.max_new_tokens = 32;
    }

    let mut points = Vec::new();
    let mut kernel = String::new();
    for mix in &mixes {
        for &cap in &caps {
            let wl = WorkloadConfig {
                seed: args.opt_parse("seed", 0u64),
                n_requests: args.opt_parse("requests", 64usize),
                mix: *mix,
                shared_prefix_ratio: args
                    .opt_parse("shared-prefix-ratio", 0.5f64),
                shared_prefix_len: args.opt_parse("shared-prefix-len", 64usize),
                cancel_prob,
                sparse_ratio: args.opt_parse("sparse-ratio", 0.0f64),
                sparse_topk_pages: args.opt_parse("sparse-topk-pages", 4usize),
                base,
            };
            let axis: Vec<(Option<f64>, Option<usize>)> = if mode == "open" {
                rates.iter().map(|&r| (Some(r), None)).collect()
            } else {
                concs.iter().map(|&c| (None, Some(c))).collect()
            };
            for (rate, conc) in axis {
                let point =
                    run_sweep_point(args, &transport, cap, &wl, rate, conc, &mode)?;
                if let Some(k) = point.engine.get("kernel") {
                    if !k.is_empty() {
                        kernel = k.clone();
                    }
                }
                if !args.flag("quiet") {
                    println!("{}", loadgen::summary_line(&point));
                }
                points.push(point);
            }
        }
    }
    let doc = loadgen::render_report(&points, &kernel);
    std::fs::write(&out_path, &doc)
        .with_context(|| format!("write {out_path}"))?;
    println!(
        "bench-serve: wrote {out_path} ({} sweep points)",
        points.len()
    );
    if args.flag("check") {
        check_serve_report(&doc, cancel_prob)?;
        println!("bench-serve: report checks passed");
    }
    Ok(())
}

/// Run one sweep point: fresh engine (and, for the tcp transport, a
/// fresh loopback listener) unless `--connect` targets an external
/// server, an engine-stats scrape on each side of the run, and the
/// collector's aggregation of the outcomes.
fn run_sweep_point(
    args: &Args,
    transport: &str,
    cap: usize,
    wl: &turboattention::loadgen::WorkloadConfig,
    rate: Option<f64>,
    conc: Option<usize>,
    mode: &str,
) -> Result<turboattention::loadgen::SweepPoint> {
    use turboattention::loadgen::{self, Target};

    let (target, control, engine_thread) = if let Some(hostport) =
        args.opt("connect")
    {
        use std::net::ToSocketAddrs;
        let addr = hostport
            .to_socket_addrs()
            .with_context(|| format!("--connect {hostport}"))?
            .next()
            .context("--connect resolved to no address")?;
        (Target::Tcp(addr), None, None)
    } else {
        let mut cfg =
            engine_config_for_path(args, args.opt("path").unwrap_or("turbo-cpu"));
        if cap > 0 {
            cfg.pool_byte_cap = Some(cap);
        }
        let dir = args.opt_or("artifacts", "artifacts").to_string();
        let (tx, rx) = channel::<Command>();
        // Engine constructed inside its thread (the PJRT client is not
        // Send), same pattern as `serve`.
        let join = std::thread::spawn(move || -> Result<()> {
            let rt = runtime_for(&cfg, &dir)?;
            let engine = Engine::new(ModelBundle::new(rt), cfg);
            engine.run_loop(rx)
        });
        let handle = EngineHandle::new(tx);
        let target = if transport == "inproc" {
            Target::InProcess(handle.clone())
        } else {
            let listener = TcpListener::bind(("127.0.0.1", 0))?;
            let addr = listener.local_addr()?;
            let h = handle.clone();
            // The accept loop has no shutdown path; the thread parks in
            // accept() when the point ends — harmless in a benchmark
            // process that exits after the sweep.
            std::thread::spawn(move || {
                let _ = server::serve(listener, h, SamplingParams::default());
            });
            Target::Tcp(addr)
        };
        (target, Some(handle), Some(join))
    };

    let before = scrape_engine_stats(&target, control.as_ref())?;
    let run = match (rate, conc) {
        (Some(r), _) => loadgen::run_open_loop(&target, wl, r),
        (_, Some(c)) => loadgen::run_closed_loop(&target, wl, c),
        _ => unreachable!("sweep axis sets rate or concurrency"),
    };
    let after = scrape_engine_stats(&target, control.as_ref())?;
    let engine = loadgen::diff_engine_stats(&before, &after);
    if let Some(h) = control {
        h.shutdown();
    }
    if let Some(j) = engine_thread {
        j.join()
            .map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
    }
    let cfgp = loadgen::SweepPointConfig {
        mode: mode.to_string(),
        rate,
        concurrency: conc,
        mix: wl.mix.name().to_string(),
        pool_byte_cap: cap,
        n_requests: wl.n_requests,
        seed: wl.seed,
        shared_prefix_ratio: wl.shared_prefix_ratio,
        cancel_prob: wl.cancel_prob,
        sparse_ratio: wl.sparse_ratio,
        sparse_topk_pages: wl.sparse_topk_pages,
        max_new: wl.base.max_new_tokens,
    };
    Ok(loadgen::SweepPoint::build(cfgp, &run, engine))
}

/// Engine counters in the `stats_pairs` shape: through the control
/// handle when this process owns the engine, else over the wire via
/// `STATS JSON` (the `--connect` case).
fn scrape_engine_stats(
    target: &turboattention::loadgen::Target,
    control: Option<&EngineHandle>,
) -> Result<std::collections::BTreeMap<String, String>> {
    use turboattention::loadgen::{Target, TcpClient};
    let snap = match (control, target) {
        (Some(h), _) | (None, Target::InProcess(h)) => h.stats()?,
        (None, Target::Tcp(addr)) => {
            let mut c = TcpClient::connect(*addr)?;
            let stats = c.stats_json()?;
            let _ = c.quit();
            return Ok(stats);
        }
    };
    Ok(server::stats_pairs(&snap)
        .into_iter()
        .map(|(k, v)| (k.to_string(), v))
        .collect())
}

/// `--check`: the written report must parse, contain sweep points, and
/// carry sane aggregates (no transport errors, completions unless the
/// whole workload cancels, p50 <= p99 per latency bundle).
fn check_serve_report(doc: &str, cancel_prob: f64) -> Result<()> {
    use turboattention::util::json::Json;
    let j = Json::parse(doc).map_err(|e| anyhow::anyhow!("report: {e}"))?;
    let sweep = j
        .path("sweep")
        .and_then(|s| s.as_arr())
        .context("report missing sweep array")?;
    anyhow::ensure!(!sweep.is_empty(), "report has no sweep points");
    for pt in sweep {
        let label =
            pt.path("label").and_then(|l| l.as_str()).unwrap_or("?");
        let errors = pt
            .path("errors")
            .and_then(|e| e.as_usize())
            .context("point missing errors")?;
        anyhow::ensure!(errors == 0, "{label}: {errors} transport errors");
        let completed = pt
            .path("completed")
            .and_then(|c| c.as_usize())
            .context("point missing completed")?;
        if cancel_prob < 1.0 {
            anyhow::ensure!(completed > 0, "{label}: no completions");
        }
        for hist in ["ttft", "itl", "queue_wait", "e2e"] {
            let p50 = pt
                .path(&format!("{hist}/p50_ms"))
                .and_then(|x| x.as_f64())
                .with_context(|| format!("{label}: missing {hist} p50"))?;
            let p99 = pt
                .path(&format!("{hist}/p99_ms"))
                .and_then(|x| x.as_f64())
                .with_context(|| format!("{label}: missing {hist} p99"))?;
            anyhow::ensure!(
                p50 <= p99 + 1e-9,
                "{label}: {hist} p50 {p50} > p99 {p99}"
            );
        }
    }
    Ok(())
}

/// Runtime smoke test: run the micro artifacts and compare turbo vs flash.
fn selftest(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let mut rt = Runtime::load(dir)?;
    let micro = rt.manifest.micro.clone();
    let n = micro.heads * micro.seq * micro.d_head;
    let mut rng = turboattention::testutil::Rng::new(0);
    let shape = vec![micro.heads, micro.seq, micro.d_head];
    let mk = |rng: &mut turboattention::testutil::Rng| {
        HostTensor::F32(rng.normal_vec(n, 1.0), shape.clone())
    };
    let q = mk(&mut rng);
    let k = mk(&mut rng);
    let v = mk(&mut rng);
    let turbo = rt.run("attn_turbo_micro", &[q.clone(), k.clone(), v.clone()])?;
    let flash = rt.run("attn_flash_micro", &[q, k, v])?;
    let t = turbo[0].as_f32()?;
    let f = flash[0].as_f32()?;
    let rel = {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in t.iter().zip(f) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num / den).sqrt()
    };
    println!("attn_turbo_micro vs attn_flash_micro rel err: {rel:.4}");
    anyhow::ensure!(rel < 0.05, "quantized attention drifted: rel {rel}");

    let sas_in = HostTensor::F32(
        rng.normal_vec(micro.sas_rows * micro.sas_cols, 2.0),
        vec![micro.sas_rows, micro.sas_cols],
    );
    let sas_out = rt.run("sas_micro", &[sas_in])?;
    let probs = sas_out[0].as_f32()?;
    for r in 0..micro.sas_rows {
        let s: f32 =
            probs[r * micro.sas_cols..(r + 1) * micro.sas_cols].iter().sum();
        anyhow::ensure!((s - 1.0).abs() < 1e-4, "sas row {r} sums to {s}");
    }
    println!("sas_micro rows normalized OK");
    println!("selftest OK");
    Ok(())
}
