//! `turboattn` — leader entrypoint and CLI.
//!
//! Subcommands:
//!   serve       start the TCP serving loop (engine thread + listener)
//!   gen         one-shot generation from the CLI
//!   experiment  regenerate a paper table/figure (fig1..tab5, all)
//!   selftest    runtime smoke: load artifacts, run micro kernels
//!
//! Examples:
//!   turboattn gen --prompt "the router " --max-new 48 --mode turbo
//!   turboattn gen --path turbo-cpu --greedy     # no artifacts needed
//!   turboattn serve --port 7100 --mode turbo
//!   turboattn experiment fig6
//!
//! `--path` (alias `--mode`) selects the serving backend: `turbo`
//! (quantized execution in the AOT executables), `turbo-cpu` (the pure-
//! Rust integer-kernel substrate — runs with no artifacts and no PJRT
//! toolchain), or `flash` (exact FP32 baseline).
//!
//! Prompt-prefix KV sharing (`--share-prefixes` / `--no-share-prefixes`,
//! default on for `turbo-cpu`): batched requests with a common prompt
//! prefix share the same refcounted q2 pages instead of each storing a
//! copy; `gen --batch N` submits the prompt N times to exercise it.

use std::net::TcpListener;
use std::sync::mpsc::channel;

use anyhow::{Context, Result};

use turboattention::coordinator::engine::Command;
use turboattention::coordinator::{Engine, EngineConfig, GenRequest, PathMode};
use turboattention::model::{ByteTokenizer, ModelBundle, Sampler};
use turboattention::quant::Bits;
use turboattention::runtime::{HostTensor, Runtime};
use turboattention::util::cli::Args;
use turboattention::{info, server};

fn main() -> Result<()> {
    let args = Args::from_env();
    turboattention::util::set_log_level(if args.flag("quiet") {
        1
    } else if args.flag("verbose") {
        3
    } else {
        2
    });
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("gen") => gen(&args),
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .context("usage: turboattn experiment <figN|tabN|all>")?;
            turboattention::experiments::run(id, &args)
        }
        Some("selftest") => selftest(&args),
        other => {
            eprintln!(
                "usage: turboattn <serve|gen|experiment|selftest> [--options]\n\
                 (got {other:?})"
            );
            std::process::exit(2);
        }
    }
}

fn engine_config(args: &Args) -> EngineConfig {
    // `--path` is the canonical spelling; `--mode` stays as an alias.
    let path = args.opt("path").or_else(|| args.opt("mode"));
    let mode = match path.unwrap_or("turbo") {
        "turbo" => PathMode::Turbo,
        "turbo-cpu" | "turbocpu" => PathMode::TurboCpu,
        "flash" => PathMode::Flash,
        other => panic!("--path must be turbo|turbo-cpu|flash, got {other}"),
    };
    let kv_bits = Bits::from_bits(args.opt_parse("kv-bits", 4u32))
        .expect("--kv-bits must be 2|3|4|8");
    let sampler = if args.flag("greedy") {
        Sampler::Greedy
    } else {
        Sampler::TopK {
            k: args.opt_parse("top-k", 8usize),
            temp: args.opt_parse("temp", 0.8f32),
        }
    };
    // Prompt-prefix KV sharing: default ON for the artifact-free
    // turbo-cpu path (where every session shares one page pool), off
    // elsewhere unless forced; `--no-share-prefixes` always wins.
    let share_default = mode == PathMode::TurboCpu;
    let share_prefixes = if args.flag("no-share-prefixes") {
        false
    } else {
        share_default || args.flag("share-prefixes")
    };
    let mut cfg = EngineConfig {
        mode,
        kv_bits,
        sampler,
        n_2bit_heads: args.opt_parse("n-2bit-heads", 0usize),
        decode_threads: args.opt_parse(
            "decode-threads",
            turboattention::pool::default_threads(),
        ),
        share_prefixes,
        seed: args.opt_parse("seed", 0u64),
        ..Default::default()
    };
    cfg.batcher.max_running = args.opt_parse("max-running", 8usize);
    cfg.batcher.token_budget = args.opt_parse("token-budget", 4096usize);
    cfg
}

/// Runtime for a config: the CPU-substrate path needs no artifacts (its
/// geometry is built in); everything else loads the artifact directory.
fn runtime_for(cfg: &EngineConfig, dir: &str) -> Result<Runtime> {
    if cfg.mode == PathMode::TurboCpu {
        return Ok(Runtime::cpu_substrate());
    }
    Runtime::load(dir)
}

fn load_engine(args: &Args) -> Result<Engine> {
    let cfg = engine_config(args);
    let rt = runtime_for(&cfg, args.opt_or("artifacts", "artifacts"))?;
    Ok(Engine::new(ModelBundle::new(rt), cfg))
}

fn gen(args: &Args) -> Result<()> {
    let mut engine = load_engine(args)?;
    let prompt = args.opt_or("prompt", "the router routes the tokens ");
    let max_new = args.opt_parse("max-new", 48usize);
    // `--batch N` submits the prompt N times — with prefix sharing on,
    // requests 2..N fork from the first request's pages.
    let batch = args.opt_parse("batch", 1usize).max(1);
    let tok = ByteTokenizer;
    for id in 0..batch as u64 {
        engine.submit(GenRequest::new(id + 1, tok.encode(prompt), max_new));
    }
    let mut completions = engine.run_to_completion()?;
    completions.sort_by_key(|c| c.id);
    for c in completions {
        println!("prompt : {prompt}");
        println!("output : {}", tok.decode(&c.generated));
        println!(
            "ttft {:.1}ms | total {:.1}ms | {:.1}ms/token | cache {:.2}x compressed",
            c.ttft * 1e3,
            c.total_latency * 1e3,
            c.tpot * 1e3,
            engine.metrics.cache_compression.max(1.0)
        );
    }
    if engine.cfg.share_prefixes {
        println!(
            "prefix sharing: {} hits | {} shared tokens | dedup {:.3}",
            engine.metrics.prefix_hits,
            engine.metrics.prefix_shared_tokens,
            engine.metrics.page_dedup_ratio
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let port: u16 = args.opt_parse("port", 7100u16);
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    info!("main", "turboattn serving on 127.0.0.1:{port}");
    let (tx, rx) = channel::<Command>();
    // The PJRT client is not Send (Rc internals): construct the engine
    // *inside* its thread — the leader owns the device for its lifetime.
    let cfg = engine_config(args);
    let dir = args.opt_or("artifacts", "artifacts").to_string();
    let engine_thread = std::thread::spawn(move || -> Result<()> {
        let rt = runtime_for(&cfg, &dir)?;
        let engine = Engine::new(ModelBundle::new(rt), cfg);
        engine.run_loop(rx)
    });
    server::serve(listener, tx)?;
    engine_thread.join().expect("engine thread")?;
    Ok(())
}

/// Runtime smoke test: run the micro artifacts and compare turbo vs flash.
fn selftest(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let mut rt = Runtime::load(dir)?;
    let micro = rt.manifest.micro.clone();
    let n = micro.heads * micro.seq * micro.d_head;
    let mut rng = turboattention::testutil::Rng::new(0);
    let shape = vec![micro.heads, micro.seq, micro.d_head];
    let mk = |rng: &mut turboattention::testutil::Rng| {
        HostTensor::F32(rng.normal_vec(n, 1.0), shape.clone())
    };
    let q = mk(&mut rng);
    let k = mk(&mut rng);
    let v = mk(&mut rng);
    let turbo = rt.run("attn_turbo_micro", &[q.clone(), k.clone(), v.clone()])?;
    let flash = rt.run("attn_flash_micro", &[q, k, v])?;
    let t = turbo[0].as_f32()?;
    let f = flash[0].as_f32()?;
    let rel = {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in t.iter().zip(f) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num / den).sqrt()
    };
    println!("attn_turbo_micro vs attn_flash_micro rel err: {rel:.4}");
    anyhow::ensure!(rel < 0.05, "quantized attention drifted: rel {rel}");

    let sas_in = HostTensor::F32(
        rng.normal_vec(micro.sas_rows * micro.sas_cols, 2.0),
        vec![micro.sas_rows, micro.sas_cols],
    );
    let sas_out = rt.run("sas_micro", &[sas_in])?;
    let probs = sas_out[0].as_f32()?;
    for r in 0..micro.sas_rows {
        let s: f32 =
            probs[r * micro.sas_cols..(r + 1) * micro.sas_cols].iter().sum();
        anyhow::ensure!((s - 1.0).abs() < 1e-4, "sas row {r} sums to {s}");
    }
    println!("sas_micro rows normalized OK");
    println!("selftest OK");
    Ok(())
}
