//! Serving metrics: latency histograms and throughput windows.

use std::time::{Duration, Instant};

/// Fixed-bucket log-scale latency histogram (microseconds to minutes).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds in seconds (log spaced).
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        // 1us .. ~100s, 4 buckets per decade.
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 120.0 {
            bounds.push(b);
            b *= 10f64.powf(0.25);
        }
        let n = bounds.len();
        Histogram { bounds, counts: vec![0; n + 1], sum: 0.0, count: 0, max: 0.0 }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += seconds;
        self.count += 1;
        self.max = self.max.max(seconds);
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate from bucket interpolation (q in [0,1]).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold `other`'s samples into `self`. Both histograms share the
    /// fixed bucket layout from [`Histogram::new`], so merging is exact:
    /// the result is identical to recording every sample into one
    /// histogram (the load harness merges per-worker bundles this way).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds.len(),
            other.bounds.len(),
            "histogram bucket layouts differ"
        );
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.count += other.count;
        self.max = self.max.max(other.max);
    }

    /// One-line human summary (ms).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count,
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.p95() * 1e3,
            self.p99() * 1e3,
            self.max * 1e3
        )
    }
}

/// Sliding-window throughput counter (events/s over the last window).
#[derive(Debug)]
pub struct ThroughputWindow {
    window: Duration,
    events: std::collections::VecDeque<(Instant, u64)>,
    total: u64,
}

impl ThroughputWindow {
    pub fn new(window: Duration) -> ThroughputWindow {
        ThroughputWindow { window, events: Default::default(), total: 0 }
    }

    pub fn record(&mut self, n: u64) {
        self.record_at(Instant::now(), n);
    }

    fn record_at(&mut self, t: Instant, n: u64) {
        self.events.push_back((t, n));
        self.total += n;
        self.evict(t);
    }

    fn evict(&mut self, now: Instant) {
        while let Some(&(t, n)) = self.events.front() {
            if now.duration_since(t) > self.window {
                self.events.pop_front();
                self.total -= n;
            } else {
                break;
            }
        }
    }

    /// Events per second over the current window.
    pub fn rate(&mut self) -> f64 {
        self.evict(Instant::now());
        self.total as f64 / self.window.as_secs_f64()
    }

    pub fn total_in_window(&mut self) -> u64 {
        self.evict(Instant::now());
        self.total
    }
}

/// Aggregated engine metrics snapshot.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_completed: u64,
    /// Requests aborted before finishing (client cancel or disconnect);
    /// disjoint from `requests_completed`.
    pub requests_cancelled: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub batches_run: u64,
    /// Compressed cache storage, aggregated over all live sessions.
    pub cache_bytes: usize,
    /// Working memory of the materialized q1 views (decode read scratch),
    /// aggregated over all live sessions.
    pub cache_view_bytes: usize,
    /// Working-set bytes of per-session decode slabs (`TurboSlabs`),
    /// aggregated over all live sessions — the dominant decode memory
    /// term the compressed-cache numbers alone under-report.
    pub cache_slab_bytes: usize,
    pub cache_compression: f64,
    /// Physical page storage shared by more than one session (pool
    /// refcount > 1) — the prefix-sharing dedup win, next to
    /// `cache_view_bytes`/`cache_slab_bytes`.
    pub shared_page_bytes: usize,
    /// Physical page storage with a single owner.
    pub private_page_bytes: usize,
    /// `1 - physical/logical` over the shared page pool: the fraction
    /// of referenced page storage deduplicated away. For B sessions
    /// sharing one prompt prefix this is ≈ (B-1)/B of the prefix pages.
    pub page_dedup_ratio: f64,
    /// Working memory of the pool's per-page q1 memos (dequantized
    /// lazily on the first view sync that reads the page, shared by
    /// every owner afterwards) — the pool-level analogue of
    /// `cache_view_bytes`, and the price of cross-session
    /// dequantize-once. Excluded from `cache_bytes` like all derivable
    /// metadata, and evictable under `pool_byte_cap`.
    pub page_q1_memo_bytes: usize,
    /// Configured pool byte cap over pages + memos (0 = uncapped).
    pub pool_byte_cap: usize,
    /// Current physical page storage in the shared pool (the
    /// irreducible tier the cap's preemption path manages).
    pub pool_physical_bytes: usize,
    /// q1 memos dropped under memory pressure (monotone).
    pub pool_memo_evictions: u64,
    /// q1 memos rebuilt after an eviction (monotone) — the recompute
    /// price paid for staying under the cap.
    pub pool_memo_recomputes: u64,
    /// Running sessions preempted under memory pressure (pages
    /// released, request re-queued for recompute-on-resume; monotone).
    pub preemptions: u64,
    /// Decode steps replayed while resuming preempted requests — the
    /// recompute price of tier-2 pressure relief (monotone).
    pub preempt_replayed_tokens: u64,
    /// Admissions that forked from a shared prefix.
    pub prefix_hits: u64,
    /// Prompt tokens served from shared pages instead of re-quantized.
    pub prefix_shared_tokens: u64,
    /// Scheduler iterations that deferred admission for capacity
    /// (token budget or running-slot cap) — the starvation signal.
    pub batcher_capacity_waits: u64,
    /// Waiting-queue depth at the most recent capacity wait.
    pub batcher_wait_depth: u64,
    /// Current waiting-queue depth (gauge, sampled every step).
    pub queue_depth: u64,
    /// Admitted KV reservations over `max_batch_total_tokens` (gauge):
    /// how full the token-budget batch actually runs. Can exceed 1.0
    /// only via the oversized-solo-request escape hatch.
    pub batch_fill_ratio: f64,
    /// Chunk boundaries crossed by interleaved prefills: a prefill
    /// paused mid-prompt (to let batch-mates decode) and resumed on a
    /// later iteration. 0 means every prompt prefilled in one grant.
    pub prefill_chunks: u64,
    /// Wall-clock seconds spent in decode rounds (engine thread).
    pub decode_wall_s: f64,
    /// Seconds of per-(layer, head) work executed during those rounds,
    /// summed over every decode worker — with an `N`-thread pool this
    /// can exceed wall time by up to `N`x.
    pub decode_busy_s: f64,
    /// Kernel ISA the process dispatched to ("scalar" | "avx2" |
    /// "neon"), so serving numbers and bug reports are attributable to
    /// the code path that produced them. Every backend is bit-identical
    /// — this affects speed, never results. Empty on a default-built
    /// snapshot that never touched an engine.
    pub kernel_backend: &'static str,
    /// Full KV pages attended exactly by top-k page-sparse decode
    /// steps, summed over streams and layers (monotone). Dense steps
    /// count their pages here too — the knob-off contract is "attend
    /// everything" — so attended + skipped is total page traffic.
    pub sparse_pages_attended: u64,
    /// Full KV pages replaced by their mean-value summary term by
    /// sparse decode steps (monotone). 0 whenever the per-request
    /// `sparse_topk_pages` knob is off or covers the whole context.
    pub sparse_pages_skipped: u64,
    /// K+V slab bytes those skipped pages avoided reading
    /// (`2 * block * d_head` INT8 codes per skip; monotone).
    pub sparse_bytes_saved: u64,
}

impl EngineMetrics {
    /// Pooled-work seconds per decode-round wall second. The wall side
    /// spans the whole round (model execution, sampling, bookkeeping),
    /// not just the pooled region, so this is a *fraction-of-round*
    /// signal, not a thread count: it stays well below 1.0 when model
    /// execution dominates, and only approaches `decode_threads` in
    /// the limit where pooled shard work is the entire round. Compare
    /// runs at different `decode_threads` to see the fan-out's effect.
    /// 0.0 before any decode round has run.
    pub fn decode_parallelism(&self) -> f64 {
        if self.decode_wall_s <= 0.0 {
            0.0
        } else {
            self.decode_busy_s / self.decode_wall_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        assert!(h.p50() <= h.p95());
        assert!(h.p95() <= h.p99());
        assert!(h.p99() <= h.max() + 1e-9);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = Histogram::new();
        h.record(0.001);
        h.record(0.003);
        assert!((h.mean() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn quantile_of_uniform_batch() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(0.01);
        }
        // All mass in one bucket: p50 == p99 bucket bound >= 0.01.
        assert!(h.p50() >= 0.01);
        assert!(h.p50() < 0.02);
    }

    #[test]
    fn merge_matches_single_histogram() {
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=200 {
            let x = i as f64 * 3e-4;
            all.record(x);
            if i % 2 == 0 { a.record(x) } else { b.record(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.max(), all.max());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn p90_between_p50_and_p95() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4);
        }
        assert!(h.p50() <= h.p90());
        assert!(h.p90() <= h.p95());
    }

    #[test]
    fn throughput_window_counts() {
        let mut w = ThroughputWindow::new(Duration::from_secs(10));
        w.record(5);
        w.record(7);
        assert_eq!(w.total_in_window(), 12);
        assert!((w.rate() - 1.2).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn decode_parallelism_ratio() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.decode_parallelism(), 0.0, "no rounds yet");
        m.decode_wall_s = 2.0;
        m.decode_busy_s = 7.0;
        assert!((m.decode_parallelism() - 3.5).abs() < 1e-12);
    }
}
