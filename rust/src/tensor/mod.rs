//! Minimal dense tensor types for the CPU engines.
//!
//! The hot paths (quantized attention, dequantization) operate on plain
//! slices for speed; `Mat` is a row-major f32 matrix with just the
//! operations the attention/quant substrates need.

use crate::testutil::Rng;

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Standard-normal entries scaled by `scale` (deterministic from rng).
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, scale) }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Append every row of `other` (same column count) — how a
    /// resumable prefill grows its per-layer K/V prefix chunk by chunk.
    pub fn append_rows(&mut self, other: &Mat) {
        assert_eq!(self.cols, other.cols, "append_rows column mismatch");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Sub-matrix copy of rows [r0, r1).
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Mat {
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// self @ other ([m,k] x [k,n] -> [m,n]).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = out.row_mut(i);
            for (p, &a) in a_row.iter().enumerate().take(k) {
                let b_row = other.row(p);
                for j in 0..n {
                    o_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    /// self @ other^T ([m,k] x [n,k] -> [m,n]).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = out.row_mut(i);
            for j in 0..n {
                let b_row = other.row(j);
                o_row[j] = dot(a_row, b_row);
            }
        }
        out
    }

    /// Max |x| over the whole matrix.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean squared error against another matrix of the same shape.
    pub fn mse(&self, other: &Mat) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Relative Frobenius error ||self - other|| / ||other||.
    pub fn rel_err(&self, other: &Mat) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt()
    }
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

// The integer micro-kernels the hot paths actually run; re-exported here
// so old `tensor::idot` call sites migrate without a crate-wide rename.
pub use crate::kernels::{idot_mr, ipv_acc, qk_dot_block, ACC_MAX_ROWS, MR};

/// Integer dot product — the single-accumulator scalar *reference*.
///
/// Now a thin delegate to [`crate::kernels::scalar::idot`], where the
/// oracle lives with the rest of the scalar kernel arm; hot paths use
/// the dispatched multi-row kernels (`idot_mr` / `qk_dot_block`), which
/// compute the same exact integer result. New code (including oracles
/// in tests) should name `kernels::scalar::idot` directly.
#[deprecated(
    since = "0.1.0",
    note = "use kernels::scalar::idot for oracles; hot paths use \
            kernels::qk_dot_block / kernels::idot_mr"
)]
#[inline]
pub fn idot(a: &[i8], b: &[i8]) -> i32 {
    crate::kernels::scalar::idot(a, b)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // idot stays the reference oracle in tests

    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let eye = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_t_equals_matmul_of_transpose() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(&mut rng, 4, 6, 1.0);
        let b = Mat::randn(&mut rng, 5, 6, 1.0);
        let mut bt = Mat::zeros(6, 5);
        for i in 0..5 {
            for j in 0..6 {
                bt.set(j, i, b.get(i, j));
            }
        }
        let c1 = a.matmul_t(&b);
        let c2 = a.matmul(&bt);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn idot_matches_widening() {
        let a: Vec<i8> = vec![127, -128, 5, -7];
        let b: Vec<i8> = vec![127, 127, -3, 2];
        let want: i32 =
            a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(idot(&a, &b), want);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(&mut rng, 3, 3, 1.0);
        assert!(a.rel_err(&a) < 1e-12);
    }
}
