//! SAS — Sparse Activated Softmax (paper §4), Rust mirror.
//!
//! `e^{-t} = LUT(t_int) * POLY(t_dec)` with the cubic of Eq. 15 on [0,1)
//! and a sparsity threshold `n_r`: after max-subtraction, any score below
//! `n_r` contributes exactly zero. The LUT stays tiny (|n_r|+2 entries)
//! because the threshold bounds the integer part — the "sparse" in SAS.
//!
//! On the GPU the win is replacing FP32 CUDA-core `exp` with FP16
//! tensor/vector ops; on this CPU substrate the same structure replaces
//! `libm::expf` with a fused multiply-add chain, which the §Perf pass
//! benchmarks against the exact path.

/// Cubic coefficients for e^{-x} on [0,1) — paper Eq. 15 (c3,c2,c1,c0).
pub const SAS_POLY: [f32; 4] = [-0.1025, 0.4626, -0.9922, 0.9996];

/// Default sparsity threshold (paper §5.2 fixes n_r = -6).
pub const SAS_NR: f32 = -6.0;

/// Precomputed SAS evaluator for a given threshold.
#[derive(Debug, Clone)]
pub struct Sas {
    pub n_r: f32,
    /// LUT[i] = e^{-i} for i in 0..=depth, then one trailing 0 entry.
    lut: Vec<f32>,
    depth: usize,
}

impl Default for Sas {
    fn default() -> Self {
        Sas::new(SAS_NR)
    }
}

impl Sas {
    pub fn new(n_r: f32) -> Sas {
        assert!(n_r < 0.0, "n_r must be negative");
        let depth = (-n_r) as usize;
        let mut lut: Vec<f32> = (0..=depth).map(|i| (-(i as f32)).exp()).collect();
        lut.push(0.0);
        Sas { n_r, lut, depth }
    }

    /// The cubic POLY(t) ~= e^{-t} for t in [0,1), Horner form.
    #[inline]
    pub fn poly(t: f32) -> f32 {
        let [c3, c2, c1, c0] = SAS_POLY;
        ((c3 * t + c2) * t + c1) * t + c0
    }

    /// SAS approximation of e^{x} for x <= 0 (Eq. 13/14).
    #[inline]
    pub fn exp(&self, x: f32) -> f32 {
        if x < self.n_r {
            return 0.0;
        }
        let t = -x;
        let ti = t as i32; // t >= 0: trunc == floor
        let td = t - ti as f32;
        // x >= n_r ensures ti <= depth, but guard the x == n_r edge.
        let idx = (ti as usize).min(self.depth + 1);
        self.lut[idx] * Self::poly(td)
    }

    /// Batched SAS evaluation over one block of scores: `row[i] <-
    /// SAS_exp(row[i] - m)` for the whole slice, returning the sum of
    /// the results — the decode block loop's shift-exp-and-sum step in
    /// one pass.
    ///
    /// Bit-identical to calling [`Sas::exp`] per element (summing in
    /// slice order), but **branch-free**: the sparsity threshold becomes
    /// a 0/1 mask multiplied into the result, and the LUT index is
    /// clamped instead of tested, so the loop body is straight-line
    /// clamp + LUT gather + Horner cubic. The evaluator itself lives in
    /// [`crate::kernels`] and dispatches to the selected backend arm
    /// (scalar / AVX2 / NEON); every arm replicates the same f32 op
    /// sequence, so which ISA runs cannot change a bit of the output —
    /// [`Sas::exp_block_scalar`] pins the oracle arm for tests.
    #[inline]
    pub fn exp_block(&self, row: &mut [f32], m: f32) -> f32 {
        crate::kernels::sas_exp_block(&self.lut, self.depth, self.n_r, row, m)
    }

    /// [`Sas::exp_block`] pinned to the scalar oracle arm, bypassing
    /// kernel dispatch — the reference the SIMD arms are property-tested
    /// against, and the first thing to compare when a kernel result
    /// looks wrong.
    #[inline]
    pub fn exp_block_scalar(&self, row: &mut [f32], m: f32) -> f32 {
        crate::kernels::scalar::sas_exp_block(&self.lut, self.depth, self.n_r, row, m)
    }

    /// Raw evaluator tables `(lut, depth, n_r)` for the kernel backend
    /// tests, which call the arm functions directly.
    pub(crate) fn tables(&self) -> (&[f32], usize, f32) {
        (&self.lut, self.depth, self.n_r)
    }

    /// In-place SAS softmax over one row of scores.
    pub fn softmax_row(&self, row: &mut [f32]) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = self.exp(*v - m);
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-20);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }

    /// Max |SAS(x) - e^x| sampled on [lo, 0] (Figure 5 metric).
    pub fn max_abs_error(&self, lo: f32, samples: usize) -> f32 {
        let mut worst = 0.0f32;
        for i in 0..=samples {
            let x = lo * (i as f32) / (samples as f32);
            let err = (self.exp(x) - x.exp()).abs();
            worst = worst.max(err);
        }
        worst
    }
}

/// Exact softmax row (baseline for accuracy + the FP32-exp comparator in
/// benches).
pub fn softmax_row_exact(row: &mut [f32]) {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-20);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn poly_close_on_unit_interval() {
        let mut worst = 0.0f32;
        for i in 0..=1000 {
            let t = i as f32 / 1000.0;
            worst = worst.max((Sas::poly(t) - (-t).exp()).abs());
        }
        assert!(worst < 5e-4, "poly err {worst}");
    }

    #[test]
    fn exp_matches_above_threshold() {
        let sas = Sas::default();
        assert!(sas.max_abs_error(-6.0, 6000) < 1e-3);
    }

    #[test]
    fn zero_below_threshold() {
        let sas = Sas::default();
        assert_eq!(sas.exp(-6.0001), 0.0);
        assert_eq!(sas.exp(-100.0), 0.0);
        assert_eq!(sas.exp(f32::NEG_INFINITY), 0.0);
    }

    #[test]
    fn exp_at_zero() {
        assert!((Sas::default().exp(0.0) - 0.9996).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        prop::run("sas softmax normalization", 100, |g| {
            let n = g.usize_in(1, 64);
            let mut row = g.normal_vec(n, 3.0);
            Sas::default().softmax_row(&mut row);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "sum {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0001).contains(&p)));
        });
    }

    #[test]
    fn softmax_close_to_exact() {
        prop::run("sas vs exact softmax", 60, |g| {
            let n = g.usize_in(2, 64);
            let row = g.normal_vec(n, 2.5);
            let mut a = row.clone();
            let mut b = row;
            Sas::default().softmax_row(&mut a);
            softmax_row_exact(&mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 2e-2, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn exp_block_bit_identical_to_scalar_exp() {
        // The batched evaluator is a pure de-branching of `exp`: for any
        // shift and any score mix (deep below the threshold, at it,
        // above it) every element and the running sum must match the
        // scalar path to the bit.
        prop::run("exp_block == exp", 80, |g| {
            let sas = if g.bool() { Sas::default() } else { Sas::new(-3.5) };
            let n = g.usize_in(0, 64);
            let m = g.f32_in(-2.0, 8.0);
            let mut row: Vec<f32> = (0..n)
                .map(|_| match g.usize_in(0, 5) {
                    0 => m + sas.n_r, // exactly at the threshold
                    1 => m + sas.n_r - 1e-3, // just below: must be zero
                    2 => m - 20.0,    // deep in the sparse region
                    _ => m + g.f32_in(sas.n_r, 0.0),
                })
                .collect();
            let want: Vec<f32> = row.iter().map(|&x| sas.exp(x - m)).collect();
            let want_sum = want.iter().fold(0.0f32, |a, &b| a + b);
            let sum = sas.exp_block(&mut row, m);
            assert_eq!(sum.to_bits(), want_sum.to_bits(), "sum");
            for (i, (got, want)) in row.iter().zip(&want).enumerate() {
                assert_eq!(got.to_bits(), want.to_bits(), "elem {i}");
            }
        });
    }

    #[test]
    fn exp_block_dispatch_bit_identical_to_scalar_arm() {
        // Whichever backend arm this process dispatched to must agree
        // with the pinned scalar oracle arm to the bit, sum included.
        prop::run("exp_block dispatch == scalar arm", 80, |g| {
            let sas = if g.bool() { Sas::default() } else { Sas::new(-4.5) };
            let n = g.usize_in(0, 40);
            let m = g.f32_in(-2.0, 8.0);
            let row: Vec<f32> = (0..n)
                .map(|_| m + g.f32_in(2.0 * sas.n_r, 1.0))
                .collect();
            let mut a = row.clone();
            let mut b = row;
            let sa = sas.exp_block(&mut a, m);
            let sb = sas.exp_block_scalar(&mut b, m);
            assert_eq!(sa.to_bits(), sb.to_bits(), "sum (n={n})");
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "elem {i}");
            }
        });
    }

    #[test]
    fn exp_block_zeroes_below_threshold() {
        let sas = Sas::default();
        let mut row = vec![-6.0001f32, -100.0, f32::NEG_INFINITY, -0.5];
        let sum = sas.exp_block(&mut row, 0.0);
        assert_eq!(row[0], 0.0);
        assert_eq!(row[1], 0.0);
        assert_eq!(row[2], 0.0);
        assert!(row[3] > 0.0);
        assert_eq!(sum, row[3]);
    }

    #[test]
    fn custom_threshold() {
        let sas = Sas::new(-3.0);
        assert_eq!(sas.exp(-3.5), 0.0);
        assert!(sas.exp(-2.5) > 0.0);
    }

    #[test]
    fn monotone_nonincreasing_in_t() {
        let sas = Sas::default();
        let mut prev = f32::INFINITY;
        for i in 0..=800 {
            let x = -(i as f32) / 100.0; // 0 .. -8
            let v = sas.exp(x);
            assert!(v <= prev + 1e-6, "non-monotone at x={x}");
            prev = v;
        }
    }
}
