//! Threaded TCP serving front end, streaming tokens as they decode.
//!
//! Line-delimited protocol (one command per line). Request ids are
//! allocated by the *engine* at admission and returned in the `ACK`:
//!
//! ```text
//!   GEN <max_new> [key=value ...] <prompt...>\n
//!       -> ACK <id>\n                          (admission ack)
//!          TOK <id> <index> <byte>\n           (one per token, streamed;
//!                                               index 0 = first token,
//!                                               byte in decimal 0-255)
//!          DONE <id> <reason> <ttft_ms> <total_ms> <text>\n
//!                                              (reason: max_tokens |
//!                                               stop_byte | context_full |
//!                                               cancelled)
//!   CANCEL <id>\n     -> the request's stream ends with DONE .. cancelled
//!                        (only ids ACKed on this connection; others get
//!                         ERR unknown request id)
//!   STATS\n           -> STATS completed=.. cancelled=.. itl_p50_ms=.. ..\n
//!   QUIT\n            -> BYE\n, closes the socket — any of this
//!                        connection's still-running requests are
//!                        cancelled when their forwarders hit the
//!                        closed socket
//! ```
//!
//! Per-request sampling overrides ride on the `GEN` line between
//! `<max_new>` and the prompt: `seed=<u64>`, `topk=<k>`, `temp=<t>`,
//! `stop=<byte>`, and the bare word `greedy`. Anything else — including
//! an unknown `key=value` word — starts the prompt, so only a prompt
//! *beginning* with one of those five override tokens needs care (a
//! known key with a bad value is rejected with `ERR`). Unspecified
//! fields fall back to the server's default [`SamplingParams`] (the
//! `serve` CLI flags).
//!
//! Each client connection gets a reader thread and each in-flight
//! request a forwarder thread draining its [`ResponseHandle`]; writes
//! share one locked socket so `TOK`/`DONE`/`ACK` lines never interleave
//! mid-line. Commands reach the single engine thread through a cloned
//! [`EngineHandle`] (the `Sender` inside is `Clone` — no mutex around
//! the command channel). A client that disconnects mid-generation takes
//! its forwarder down on the next write, which drops the
//! `ResponseHandle` and cancels the request engine-side, releasing its
//! batcher slot and KV pages.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{
    EngineHandle, GenRequest, RequestId, ResponseHandle, SamplingParams,
    TokenEvent,
};
use crate::info;
use crate::model::Sampler;

/// Serve on `listener` until it errors; `handle` feeds the engine
/// thread and `defaults` fills whatever a `GEN` line doesn't override.
pub fn serve(
    listener: TcpListener,
    handle: EngineHandle,
    defaults: SamplingParams,
) -> Result<()> {
    let addr = listener.local_addr()?;
    info!("server", "listening on {addr}");
    for stream in listener.incoming() {
        let stream = stream.context("accept")?;
        let handle = handle.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_client(stream, handle, defaults) {
                crate::debug!("server", "client error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_client(
    stream: TcpStream,
    handle: EngineHandle,
    defaults: SamplingParams,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    // Ids ACKed on *this* connection — the only ones its CANCELs may
    // touch (ids are sequential, so without this check any client
    // could guess and kill another client's requests). Shared with the
    // forwarder threads, which prune their id once the request's
    // stream ends, so a long-lived connection doesn't accumulate ids.
    let mine: Arc<Mutex<HashSet<RequestId>>> =
        Arc::new(Mutex::new(HashSet::new()));
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_line(line) {
            ParsedLine::Gen { max_new, overrides, prompt } => {
                let params = params_for(defaults, max_new, &overrides);
                // The engine assigns the id; 0 here is a placeholder.
                let req = GenRequest::with_params(0, prompt, params);
                match handle.submit(req) {
                    Ok(resp) => {
                        lock(&mine).insert(resp.id());
                        write_line(&writer, &format!("ACK {}", resp.id()))?;
                        let w = Arc::clone(&writer);
                        let m = Arc::clone(&mine);
                        std::thread::spawn(move || {
                            stream_response(resp, w, m)
                        });
                    }
                    Err(_) => {
                        write_line(&writer, "ERR engine gone")?;
                    }
                }
            }
            ParsedLine::Cancel(id) => {
                // The DONE (reason `cancelled`) arrives on the original
                // request's stream. An id this connection never ACKed —
                // or already saw finish (forwarders prune on DONE) — is
                // rejected without touching the engine.
                if !lock(&mine).contains(&id) {
                    write_line(&writer, "ERR unknown request id")?;
                } else if handle.cancel(id).is_err() {
                    write_line(&writer, "ERR engine gone")?;
                }
            }
            ParsedLine::Stats => match handle.stats() {
                Ok(s) => write_line(&writer, &format_stats(&s))?,
                Err(_) => write_line(&writer, "ERR engine gone")?,
            },
            ParsedLine::Quit => {
                write_line(&writer, "BYE")?;
                // Close the socket for the forwarder clones too: their
                // next write fails, which drops each `ResponseHandle`
                // and cancels whatever this connection still had
                // decoding — QUIT really ends the connection instead
                // of letting forwarders stream into it for seconds.
                let _ = lock(&writer).shutdown(Shutdown::Both);
                break;
            }
            ParsedLine::Bad(msg) => {
                write_line(&writer, &format!("ERR {msg}"))?;
            }
        }
    }
    crate::debug!("server", "client {peer} disconnected");
    Ok(())
}

/// One whole line under the shared socket lock (keeps concurrent
/// request streams from interleaving mid-line).
fn write_line(writer: &Arc<Mutex<TcpStream>>, line: &str) -> Result<()> {
    let mut w = lock(writer);
    writeln!(w, "{line}")?;
    Ok(())
}

/// Poison-tolerant mutex lock (same policy as the engine's pool reads:
/// a panicked holder doesn't invalidate this plain data).
fn lock<T>(m: &Arc<Mutex<T>>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Forwarder: drain one request's event stream onto the shared socket.
/// A write failure means the client hung up — dropping `resp` lets the
/// engine cancel the request instead of decoding for nobody. On exit
/// the id is pruned from the connection's cancellable set.
fn stream_response(
    resp: ResponseHandle,
    writer: Arc<Mutex<TcpStream>>,
    mine: Arc<Mutex<HashSet<RequestId>>>,
) {
    let id = resp.id();
    for ev in resp {
        let line = match ev {
            TokenEvent::First { token, .. } => format!("TOK {id} 0 {token}"),
            TokenEvent::Token { token, index } => {
                format!("TOK {id} {index} {token}")
            }
            TokenEvent::Finished(c) => {
                let text = crate::model::ByteTokenizer.decode(&c.generated);
                format!(
                    "DONE {id} {} {:.1} {:.1} {}",
                    c.finish_reason.as_str(),
                    c.ttft * 1e3,
                    c.total_latency * 1e3,
                    text.replace('\n', " ")
                )
            }
        };
        if write_line(&writer, &line).is_err() {
            break;
        }
    }
    lock(&mine).remove(&id);
}

fn format_stats(s: &crate::coordinator::StatsSnapshot) -> String {
    format!(
        "STATS completed={} cancelled={} tokens={} prefill_tokens={} \
         ttft_p50_ms={:.2} latency_p50_ms={:.2} itl_p50_ms={:.3} \
         itl_p95_ms={:.3} itl_mean_ms={:.3} dedup={:.3} kernel={} \
         pool_cap={} pool_bytes={} preempt={} replayed={} memo_evict={} \
         memo_recompute={} queue_depth={} fill={:.3} prefill_chunks={} \
         waiting_p50_ms={:.3} sparse_attended={} sparse_skipped={} \
         sparse_bytes_saved={}",
        s.metrics.requests_completed,
        s.metrics.requests_cancelled,
        s.metrics.tokens_generated,
        s.metrics.prefill_tokens,
        s.ttft.p50() * 1e3,
        s.latency.p50() * 1e3,
        s.itl.p50() * 1e3,
        s.itl.p95() * 1e3,
        s.itl.mean() * 1e3,
        s.metrics.page_dedup_ratio,
        s.metrics.kernel_backend,
        s.metrics.pool_byte_cap,
        s.metrics.pool_physical_bytes,
        s.metrics.preemptions,
        s.metrics.preempt_replayed_tokens,
        s.metrics.pool_memo_evictions,
        s.metrics.pool_memo_recomputes,
        s.metrics.queue_depth,
        s.metrics.batch_fill_ratio,
        s.metrics.prefill_chunks,
        s.waiting.p50() * 1e3,
        s.metrics.sparse_pages_attended,
        s.metrics.sparse_pages_skipped,
        s.metrics.sparse_bytes_saved,
    )
}

/// Sampling fields a `GEN` line may override.
#[derive(Debug, Default, PartialEq)]
struct GenOverrides {
    seed: Option<u64>,
    top_k: Option<usize>,
    temp: Option<f32>,
    stop: Option<u8>,
    greedy: bool,
}

/// Merge `GEN`-line overrides onto the server defaults.
fn params_for(
    defaults: SamplingParams,
    max_new: usize,
    ov: &GenOverrides,
) -> SamplingParams {
    let mut p = defaults;
    p.max_new_tokens = max_new;
    if let Some(s) = ov.seed {
        p.seed = s;
    }
    if let Some(b) = ov.stop {
        p.stop_byte = Some(b);
    }
    if ov.greedy {
        p.sampler = Sampler::Greedy;
    } else if ov.top_k.is_some() || ov.temp.is_some() {
        let (dk, dt) = match defaults.sampler {
            Sampler::TopK { k, temp } => (k, temp),
            Sampler::Greedy => {
                (crate::model::DEFAULT_TOP_K, crate::model::DEFAULT_TEMP)
            }
        };
        p.sampler = Sampler::TopK {
            k: ov.top_k.unwrap_or(dk),
            temp: ov.temp.unwrap_or(dt),
        };
    }
    p
}

enum ParsedLine {
    Gen { max_new: usize, overrides: GenOverrides, prompt: Vec<u8> },
    Cancel(RequestId),
    Stats,
    Quit,
    Bad(&'static str),
}

/// First space-separated word and the remainder (empty if none).
fn split_word(s: &str) -> Option<(&str, &str)> {
    if s.is_empty() {
        return None;
    }
    match s.split_once(' ') {
        Some((w, rest)) => Some((w, rest)),
        None => Some((s, "")),
    }
}

fn parse_line(line: &str) -> ParsedLine {
    if line == "QUIT" {
        return ParsedLine::Quit;
    }
    if line == "STATS" {
        return ParsedLine::Stats;
    }
    if let Some(rest) = line.strip_prefix("CANCEL ") {
        return match rest.trim().parse::<RequestId>() {
            Ok(id) => ParsedLine::Cancel(id),
            Err(_) => ParsedLine::Bad("usage: CANCEL <id>"),
        };
    }
    if let Some(rest) = line.strip_prefix("GEN ") {
        return parse_gen(rest);
    }
    ParsedLine::Bad("unknown command")
}

/// Parse one `key=value` override into `dst`; false on a bad value.
fn set_override<T: std::str::FromStr>(dst: &mut Option<T>, v: &str) -> bool {
    match v.parse() {
        Ok(x) => {
            *dst = Some(x);
            true
        }
        Err(_) => false,
    }
}

fn parse_gen(rest: &str) -> ParsedLine {
    const USAGE: &str = "usage: GEN <max_new_tokens> [seed=N] [topk=K] \
                         [temp=T] [stop=BYTE] [greedy] <prompt>";
    let Some((first, mut rem)) = split_word(rest) else {
        return ParsedLine::Bad(USAGE);
    };
    let Ok(max_new) = first.parse::<usize>() else {
        return ParsedLine::Bad(USAGE);
    };
    let mut ov = GenOverrides::default();
    while let Some((word, after)) = split_word(rem) {
        if word == "greedy" {
            ov.greedy = true;
            rem = after;
            continue;
        }
        let Some((k, v)) = word.split_once('=') else { break };
        // An unknown key is not an override at all — it starts the
        // prompt (the doc promise: "anything else starts the prompt").
        // A *known* key with an unparsable value is a client error.
        let parsed = match k {
            "seed" => set_override(&mut ov.seed, v),
            "topk" => set_override(&mut ov.top_k, v),
            "temp" => set_override(&mut ov.temp, v),
            "stop" => set_override(&mut ov.stop, v),
            _ => break,
        };
        if !parsed {
            return ParsedLine::Bad(
                "bad GEN override value (seed=|topk=|temp=|stop=)",
            );
        }
        rem = after;
    }
    if rem.is_empty() {
        return ParsedLine::Bad("empty prompt");
    }
    ParsedLine::Gen {
        max_new: max_new.clamp(1, 256),
        overrides: ov,
        prompt: rem.as_bytes().to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen_plain() {
        match parse_line("GEN 32 the router routes") {
            ParsedLine::Gen { max_new, overrides, prompt } => {
                assert_eq!(max_new, 32);
                assert_eq!(overrides, GenOverrides::default());
                assert_eq!(prompt, b"the router routes");
            }
            _ => panic!("expected Gen"),
        }
    }

    #[test]
    fn parse_gen_with_overrides() {
        match parse_line("GEN 16 seed=9 topk=4 temp=0.5 stop=46 the prompt") {
            ParsedLine::Gen { max_new, overrides, prompt } => {
                assert_eq!(max_new, 16);
                assert_eq!(overrides.seed, Some(9));
                assert_eq!(overrides.top_k, Some(4));
                assert_eq!(overrides.temp, Some(0.5));
                assert_eq!(overrides.stop, Some(46));
                assert!(!overrides.greedy);
                assert_eq!(prompt, b"the prompt");
            }
            _ => panic!("expected Gen"),
        }
        match parse_line("GEN 8 greedy hi") {
            ParsedLine::Gen { overrides, prompt, .. } => {
                assert!(overrides.greedy);
                assert_eq!(prompt, b"hi");
            }
            _ => panic!("expected Gen"),
        }
        // An unknown key=value word is prompt text, not a bad override.
        match parse_line("GEN 8 x=1 plus y=2") {
            ParsedLine::Gen { overrides, prompt, .. } => {
                assert_eq!(overrides, GenOverrides::default());
                assert_eq!(prompt, b"x=1 plus y=2");
            }
            _ => panic!("expected Gen"),
        }
    }

    #[test]
    fn parse_cancel_and_stats() {
        assert!(matches!(parse_line("CANCEL 7"), ParsedLine::Cancel(7)));
        assert!(matches!(parse_line("CANCEL x"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("STATS"), ParsedLine::Stats));
    }

    #[test]
    fn parse_quit_and_garbage() {
        assert!(matches!(parse_line("QUIT"), ParsedLine::Quit));
        assert!(matches!(parse_line("NOPE"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN x y"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN 5"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN 5 seed=zzz hi"), ParsedLine::Bad(_)));
    }

    #[test]
    fn overrides_merge_onto_defaults() {
        let defaults = SamplingParams {
            sampler: Sampler::TopK { k: 8, temp: 0.8 },
            seed: 1,
            stop_byte: None,
            max_new_tokens: 48,
        };
        let ov = GenOverrides { seed: Some(5), temp: Some(0.5), ..Default::default() };
        let p = params_for(defaults, 16, &ov);
        assert_eq!(p.max_new_tokens, 16);
        assert_eq!(p.seed, 5);
        // temp override keeps the default k.
        assert_eq!(p.sampler, Sampler::TopK { k: 8, temp: 0.5 });

        let greedy = GenOverrides { greedy: true, ..Default::default() };
        assert_eq!(
            params_for(defaults, 4, &greedy).sampler,
            Sampler::Greedy
        );

        let none = GenOverrides::default();
        assert_eq!(params_for(defaults, 4, &none).sampler, defaults.sampler);
    }
}
