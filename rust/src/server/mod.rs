//! Threaded TCP serving front end, streaming tokens as they decode.
//!
//! Line-delimited protocol (one command per line). Request ids are
//! allocated by the *engine* at admission and returned in the `ACK`:
//!
//! ```text
//!   GEN <max_new> [key=value ...] <prompt...>\n
//!       -> ACK <id>\n                          (admission ack)
//!          TOK <id> <index> <byte>\n           (one per token, streamed;
//!                                               index 0 = first token,
//!                                               byte in decimal 0-255)
//!          DONE <id> <reason> <ttft_ms> <total_ms> <text>\n
//!                                              (reason: max_tokens |
//!                                               stop_byte | context_full |
//!                                               cancelled)
//!   CANCEL <id>\n     -> the request's stream ends with DONE .. cancelled
//!                        (only ids ACKed on this connection; others get
//!                         ERR unknown request id)
//!   STATS\n           -> STATS completed=.. cancelled=.. itl_p50_ms=.. ..\n
//!   STATS JSON\n      -> STATS {"completed":..,"kernel":"..",..}\n
//!                        (same fields as the key=value form, as a
//!                         one-line JSON object for machine scraping —
//!                         the key=value layout stays byte-stable for
//!                         text scrapers)
//!   QUIT\n            -> BYE\n, closes the socket — any of this
//!                        connection's still-running requests are
//!                        cancelled when their forwarders hit the
//!                        closed socket
//! ```
//!
//! Per-request overrides ride on the `GEN` line between `<max_new>`
//! and the prompt: `seed=<u64>`, `topk=<k>`, `temp=<t>`, `stop=<byte>`,
//! `sparse=<pages>` (top-k page-sparse decode; 0 = dense), and the
//! bare word `greedy`. Anything else — including an unknown
//! `key=value` word — starts the prompt, so only a prompt *beginning*
//! with one of those six override tokens needs care (a known key with
//! a bad value is rejected with `ERR`). Unspecified fields fall back
//! to the server's default [`SamplingParams`] (the `serve` CLI flags).
//!
//! Each client connection gets a reader thread and each in-flight
//! request a forwarder thread draining its [`ResponseHandle`]; writes
//! share one locked socket so `TOK`/`DONE`/`ACK` lines never interleave
//! mid-line. Commands reach the single engine thread through a cloned
//! [`EngineHandle`] (the `Sender` inside is `Clone` — no mutex around
//! the command channel). A client that disconnects mid-generation takes
//! its forwarder down on the next write, which drops the
//! `ResponseHandle` and cancels the request engine-side, releasing its
//! batcher slot and KV pages.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{
    EngineHandle, GenRequest, RequestId, ResponseHandle, SamplingParams,
    TokenEvent,
};
use crate::info;
use crate::model::Sampler;

/// Serve on `listener` until it errors; `handle` feeds the engine
/// thread and `defaults` fills whatever a `GEN` line doesn't override.
pub fn serve(
    listener: TcpListener,
    handle: EngineHandle,
    defaults: SamplingParams,
) -> Result<()> {
    let addr = listener.local_addr()?;
    info!("server", "listening on {addr}");
    for stream in listener.incoming() {
        let stream = stream.context("accept")?;
        let handle = handle.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_client(stream, handle, defaults) {
                crate::debug!("server", "client error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_client(
    stream: TcpStream,
    handle: EngineHandle,
    defaults: SamplingParams,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    // Ids ACKed on *this* connection — the only ones its CANCELs may
    // touch (ids are sequential, so without this check any client
    // could guess and kill another client's requests). Shared with the
    // forwarder threads, which prune their id once the request's
    // stream ends, so a long-lived connection doesn't accumulate ids.
    let mine: Arc<Mutex<HashSet<RequestId>>> =
        Arc::new(Mutex::new(HashSet::new()));
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_line(line) {
            ParsedLine::Gen { max_new, overrides, prompt } => {
                let params = params_for(defaults, max_new, &overrides);
                // The engine assigns the id; 0 here is a placeholder.
                let req = GenRequest::with_params(0, prompt, params)
                    .with_sparse_topk(overrides.sparse.unwrap_or(0));
                match handle.submit(req) {
                    Ok(resp) => {
                        lock(&mine).insert(resp.id());
                        write_line(&writer, &format!("ACK {}", resp.id()))?;
                        let w = Arc::clone(&writer);
                        let m = Arc::clone(&mine);
                        std::thread::spawn(move || {
                            stream_response(resp, w, m)
                        });
                    }
                    Err(_) => {
                        write_line(&writer, "ERR engine gone")?;
                    }
                }
            }
            ParsedLine::Cancel(id) => {
                // The DONE (reason `cancelled`) arrives on the original
                // request's stream. An id this connection never ACKed —
                // or already saw finish (forwarders prune on DONE) — is
                // rejected without touching the engine.
                if !lock(&mine).contains(&id) {
                    write_line(&writer, "ERR unknown request id")?;
                } else if handle.cancel(id).is_err() {
                    write_line(&writer, "ERR engine gone")?;
                }
            }
            ParsedLine::Stats => match handle.stats() {
                Ok(s) => write_line(&writer, &format_stats(&s))?,
                Err(_) => write_line(&writer, "ERR engine gone")?,
            },
            ParsedLine::StatsJson => match handle.stats() {
                Ok(s) => write_line(&writer, &format_stats_json(&s))?,
                Err(_) => write_line(&writer, "ERR engine gone")?,
            },
            ParsedLine::Quit => {
                write_line(&writer, "BYE")?;
                // Close the socket for the forwarder clones too: their
                // next write fails, which drops each `ResponseHandle`
                // and cancels whatever this connection still had
                // decoding — QUIT really ends the connection instead
                // of letting forwarders stream into it for seconds.
                let _ = lock(&writer).shutdown(Shutdown::Both);
                break;
            }
            ParsedLine::Bad(msg) => {
                write_line(&writer, &format!("ERR {msg}"))?;
            }
        }
    }
    crate::debug!("server", "client {peer} disconnected");
    Ok(())
}

/// One whole line under the shared socket lock (keeps concurrent
/// request streams from interleaving mid-line).
fn write_line(writer: &Arc<Mutex<TcpStream>>, line: &str) -> Result<()> {
    let mut w = lock(writer);
    writeln!(w, "{line}")?;
    Ok(())
}

/// Poison-tolerant mutex lock (same policy as the engine's pool reads:
/// a panicked holder doesn't invalidate this plain data).
fn lock<T>(m: &Arc<Mutex<T>>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Forwarder: drain one request's event stream onto the shared socket.
/// A write failure means the client hung up — dropping `resp` lets the
/// engine cancel the request instead of decoding for nobody. On exit
/// the id is pruned from the connection's cancellable set.
fn stream_response(
    resp: ResponseHandle,
    writer: Arc<Mutex<TcpStream>>,
    mine: Arc<Mutex<HashSet<RequestId>>>,
) {
    let id = resp.id();
    for ev in resp {
        let line = match ev {
            TokenEvent::First { token, .. } => format!("TOK {id} 0 {token}"),
            TokenEvent::Token { token, index } => {
                format!("TOK {id} {index} {token}")
            }
            TokenEvent::Finished(c) => {
                let text = crate::model::ByteTokenizer.decode(&c.generated);
                format!(
                    "DONE {id} {} {:.1} {:.1} {}",
                    c.finish_reason.as_str(),
                    c.ttft * 1e3,
                    c.total_latency * 1e3,
                    text.replace('\n', " ")
                )
            }
        };
        if write_line(&writer, &line).is_err() {
            break;
        }
    }
    lock(&mine).remove(&id);
}

/// The `STATS` fields in wire order, each value already rendered in
/// its canonical spelling. Single source for both reply forms — the
/// classic `key=value` line (whose byte layout external scrapers like
/// `scripts/stream_smoke.sh` depend on) and the `STATS JSON` object —
/// and for the in-process scrape the `bench-serve` harness does when
/// no socket is involved.
pub fn stats_pairs(
    s: &crate::coordinator::StatsSnapshot,
) -> Vec<(&'static str, String)> {
    vec![
        ("completed", s.metrics.requests_completed.to_string()),
        ("cancelled", s.metrics.requests_cancelled.to_string()),
        ("tokens", s.metrics.tokens_generated.to_string()),
        ("prefill_tokens", s.metrics.prefill_tokens.to_string()),
        ("ttft_p50_ms", format!("{:.2}", s.ttft.p50() * 1e3)),
        ("latency_p50_ms", format!("{:.2}", s.latency.p50() * 1e3)),
        ("itl_p50_ms", format!("{:.3}", s.itl.p50() * 1e3)),
        ("itl_p95_ms", format!("{:.3}", s.itl.p95() * 1e3)),
        ("itl_mean_ms", format!("{:.3}", s.itl.mean() * 1e3)),
        ("dedup", format!("{:.3}", s.metrics.page_dedup_ratio)),
        ("kernel", s.metrics.kernel_backend.to_string()),
        ("pool_cap", s.metrics.pool_byte_cap.to_string()),
        ("pool_bytes", s.metrics.pool_physical_bytes.to_string()),
        ("preempt", s.metrics.preemptions.to_string()),
        ("replayed", s.metrics.preempt_replayed_tokens.to_string()),
        ("memo_evict", s.metrics.pool_memo_evictions.to_string()),
        ("memo_recompute", s.metrics.pool_memo_recomputes.to_string()),
        ("queue_depth", s.metrics.queue_depth.to_string()),
        ("fill", format!("{:.3}", s.metrics.batch_fill_ratio)),
        ("prefill_chunks", s.metrics.prefill_chunks.to_string()),
        ("waiting_p50_ms", format!("{:.3}", s.waiting.p50() * 1e3)),
        ("sparse_attended", s.metrics.sparse_pages_attended.to_string()),
        ("sparse_skipped", s.metrics.sparse_pages_skipped.to_string()),
        ("sparse_bytes_saved", s.metrics.sparse_bytes_saved.to_string()),
    ]
}

fn format_stats(s: &crate::coordinator::StatsSnapshot) -> String {
    let body = stats_pairs(s)
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(" ");
    format!("STATS {body}")
}

/// `STATS JSON` reply: one-line object with the same fields as the
/// classic form. Numeric-looking values become JSON numbers (the
/// canonical renderings above are already valid JSON number literals);
/// everything else — the kernel name, a NaN ratio on an idle engine —
/// is a JSON string.
fn format_stats_json(s: &crate::coordinator::StatsSnapshot) -> String {
    let body = stats_pairs(s)
        .iter()
        .map(|(k, v)| {
            let val = match v.parse::<f64>() {
                Ok(x) if x.is_finite() => v.clone(),
                _ => crate::bench::json_str(v),
            };
            format!("{}:{val}", crate::bench::json_str(k))
        })
        .collect::<Vec<_>>()
        .join(",");
    format!("STATS {{{body}}}")
}

/// Sampling fields a `GEN` line may override.
#[derive(Debug, Default, PartialEq)]
struct GenOverrides {
    seed: Option<u64>,
    top_k: Option<usize>,
    temp: Option<f32>,
    stop: Option<u8>,
    greedy: bool,
    /// Top-k page-sparse decode pages (`sparse=K`; 0/absent = dense).
    sparse: Option<usize>,
}

/// Merge `GEN`-line overrides onto the server defaults.
fn params_for(
    defaults: SamplingParams,
    max_new: usize,
    ov: &GenOverrides,
) -> SamplingParams {
    let mut p = defaults;
    p.max_new_tokens = max_new;
    if let Some(s) = ov.seed {
        p.seed = s;
    }
    if let Some(b) = ov.stop {
        p.stop_byte = Some(b);
    }
    if ov.greedy {
        p.sampler = Sampler::Greedy;
    } else if ov.top_k.is_some() || ov.temp.is_some() {
        let (dk, dt) = match defaults.sampler {
            Sampler::TopK { k, temp } => (k, temp),
            Sampler::Greedy => {
                (crate::model::DEFAULT_TOP_K, crate::model::DEFAULT_TEMP)
            }
        };
        p.sampler = Sampler::TopK {
            k: ov.top_k.unwrap_or(dk),
            temp: ov.temp.unwrap_or(dt),
        };
    }
    p
}

enum ParsedLine {
    Gen { max_new: usize, overrides: GenOverrides, prompt: Vec<u8> },
    Cancel(RequestId),
    Stats,
    StatsJson,
    Quit,
    Bad(&'static str),
}

/// First space-separated word and the remainder (empty if none).
fn split_word(s: &str) -> Option<(&str, &str)> {
    if s.is_empty() {
        return None;
    }
    match s.split_once(' ') {
        Some((w, rest)) => Some((w, rest)),
        None => Some((s, "")),
    }
}

fn parse_line(line: &str) -> ParsedLine {
    if line == "QUIT" {
        return ParsedLine::Quit;
    }
    if line == "STATS" {
        return ParsedLine::Stats;
    }
    if line == "STATS JSON" || line == "STATS json" {
        return ParsedLine::StatsJson;
    }
    if let Some(rest) = line.strip_prefix("CANCEL ") {
        return match rest.trim().parse::<RequestId>() {
            Ok(id) => ParsedLine::Cancel(id),
            Err(_) => ParsedLine::Bad("usage: CANCEL <id>"),
        };
    }
    if let Some(rest) = line.strip_prefix("GEN ") {
        return parse_gen(rest);
    }
    ParsedLine::Bad("unknown command")
}

/// Parse one `key=value` override into `dst`; false on a bad value.
fn set_override<T: std::str::FromStr>(dst: &mut Option<T>, v: &str) -> bool {
    match v.parse() {
        Ok(x) => {
            *dst = Some(x);
            true
        }
        Err(_) => false,
    }
}

fn parse_gen(rest: &str) -> ParsedLine {
    const USAGE: &str = "usage: GEN <max_new_tokens> [seed=N] [topk=K] \
                         [temp=T] [stop=BYTE] [sparse=K] [greedy] <prompt>";
    let Some((first, mut rem)) = split_word(rest) else {
        return ParsedLine::Bad(USAGE);
    };
    let Ok(max_new) = first.parse::<usize>() else {
        return ParsedLine::Bad(USAGE);
    };
    let mut ov = GenOverrides::default();
    while let Some((word, after)) = split_word(rem) {
        if word == "greedy" {
            ov.greedy = true;
            rem = after;
            continue;
        }
        let Some((k, v)) = word.split_once('=') else { break };
        // An unknown key is not an override at all — it starts the
        // prompt (the doc promise: "anything else starts the prompt").
        // A *known* key with an unparsable value is a client error.
        let parsed = match k {
            "seed" => set_override(&mut ov.seed, v),
            "topk" => set_override(&mut ov.top_k, v),
            "temp" => set_override(&mut ov.temp, v),
            "stop" => set_override(&mut ov.stop, v),
            "sparse" => set_override(&mut ov.sparse, v),
            _ => break,
        };
        if !parsed {
            return ParsedLine::Bad(
                "bad GEN override value (seed=|topk=|temp=|stop=|sparse=)",
            );
        }
        rem = after;
    }
    if rem.is_empty() {
        return ParsedLine::Bad("empty prompt");
    }
    ParsedLine::Gen {
        max_new: max_new.clamp(1, 256),
        overrides: ov,
        prompt: rem.as_bytes().to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen_plain() {
        match parse_line("GEN 32 the router routes") {
            ParsedLine::Gen { max_new, overrides, prompt } => {
                assert_eq!(max_new, 32);
                assert_eq!(overrides, GenOverrides::default());
                assert_eq!(prompt, b"the router routes");
            }
            _ => panic!("expected Gen"),
        }
    }

    #[test]
    fn parse_gen_with_overrides() {
        match parse_line("GEN 16 seed=9 topk=4 temp=0.5 stop=46 the prompt") {
            ParsedLine::Gen { max_new, overrides, prompt } => {
                assert_eq!(max_new, 16);
                assert_eq!(overrides.seed, Some(9));
                assert_eq!(overrides.top_k, Some(4));
                assert_eq!(overrides.temp, Some(0.5));
                assert_eq!(overrides.stop, Some(46));
                assert!(!overrides.greedy);
                assert_eq!(prompt, b"the prompt");
            }
            _ => panic!("expected Gen"),
        }
        match parse_line("GEN 8 greedy hi") {
            ParsedLine::Gen { overrides, prompt, .. } => {
                assert!(overrides.greedy);
                assert_eq!(prompt, b"hi");
            }
            _ => panic!("expected Gen"),
        }
        // An unknown key=value word is prompt text, not a bad override.
        match parse_line("GEN 8 x=1 plus y=2") {
            ParsedLine::Gen { overrides, prompt, .. } => {
                assert_eq!(overrides, GenOverrides::default());
                assert_eq!(prompt, b"x=1 plus y=2");
            }
            _ => panic!("expected Gen"),
        }
    }

    #[test]
    fn parse_cancel_and_stats() {
        assert!(matches!(parse_line("CANCEL 7"), ParsedLine::Cancel(7)));
        assert!(matches!(parse_line("CANCEL x"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("STATS"), ParsedLine::Stats));
        assert!(matches!(parse_line("STATS JSON"), ParsedLine::StatsJson));
        assert!(matches!(parse_line("STATS json"), ParsedLine::StatsJson));
        assert!(matches!(parse_line("STATS xml"), ParsedLine::Bad(_)));
    }

    #[test]
    fn parse_gen_sparse_override() {
        match parse_line("GEN 16 sparse=4 the prompt") {
            ParsedLine::Gen { overrides, prompt, .. } => {
                assert_eq!(overrides.sparse, Some(4));
                assert_eq!(prompt, b"the prompt");
            }
            _ => panic!("expected Gen"),
        }
        assert!(matches!(
            parse_line("GEN 16 sparse=x hi"),
            ParsedLine::Bad(_)
        ));
    }

    #[test]
    fn stats_forms_agree_and_json_parses() {
        let mut snap = crate::coordinator::StatsSnapshot::default();
        snap.metrics.requests_completed = 3;
        snap.metrics.kernel_backend = "scalar";
        // key=value form renders stats_pairs verbatim, space-joined —
        // the byte-compatibility contract for text scrapers.
        let kv = format_stats(&snap);
        assert!(kv.starts_with("STATS completed=3 cancelled=0 "));
        assert!(kv.contains(" kernel=scalar "));
        assert!(kv.contains(" itl_p50_ms=0.000 "));
        // JSON form: same fields, parseable, numbers as numbers.
        let js = format_stats_json(&snap);
        let payload = js.strip_prefix("STATS ").unwrap();
        let j = crate::util::json::Json::parse(payload).unwrap();
        assert_eq!(j.path("completed").unwrap().as_usize(), Some(3));
        assert_eq!(j.path("kernel").unwrap().as_str(), Some("scalar"));
        let pairs = stats_pairs(&snap);
        assert_eq!(pairs.len(), j.as_obj().unwrap().len());
        for (k, _) in pairs {
            assert!(j.get(k).is_some(), "missing {k} in JSON form");
        }
    }

    #[test]
    fn parse_quit_and_garbage() {
        assert!(matches!(parse_line("QUIT"), ParsedLine::Quit));
        assert!(matches!(parse_line("NOPE"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN x y"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN 5"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN 5 seed=zzz hi"), ParsedLine::Bad(_)));
    }

    #[test]
    fn overrides_merge_onto_defaults() {
        let defaults = SamplingParams {
            sampler: Sampler::TopK { k: 8, temp: 0.8 },
            seed: 1,
            stop_byte: None,
            max_new_tokens: 48,
        };
        let ov = GenOverrides { seed: Some(5), temp: Some(0.5), ..Default::default() };
        let p = params_for(defaults, 16, &ov);
        assert_eq!(p.max_new_tokens, 16);
        assert_eq!(p.seed, 5);
        // temp override keeps the default k.
        assert_eq!(p.sampler, Sampler::TopK { k: 8, temp: 0.5 });

        let greedy = GenOverrides { greedy: true, ..Default::default() };
        assert_eq!(
            params_for(defaults, 4, &greedy).sampler,
            Sampler::Greedy
        );

        let none = GenOverrides::default();
        assert_eq!(params_for(defaults, 4, &none).sampler, defaults.sampler);
    }
}
