//! Threaded TCP serving front end.
//!
//! Line-delimited protocol (one request per line):
//!
//! ```text
//!   GEN <max_new_tokens> <prompt...>\n   ->  OK <id> <ttft_ms> <total_ms> <text>\n
//!   STATS\n                             ->  STATS <completed> <tokens> ...\n
//!   QUIT\n                              ->  closes the connection
//! ```
//!
//! Each client connection gets a thread; generation commands flow over an
//! mpsc channel to the single engine thread (the PJRT client is not
//! thread-safe), matching the leader/worker topology in DESIGN.md.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::engine::Command;
use crate::coordinator::GenRequest;
use crate::info;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

pub fn next_request_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Serve on `addr` until the listener errors; `engine_tx` feeds the
/// engine thread. Returns the bound address (port 0 supported for tests).
pub fn serve(
    listener: TcpListener,
    engine_tx: Sender<Command>,
) -> Result<()> {
    let addr = listener.local_addr()?;
    info!("server", "listening on {addr}");
    let engine_tx = Arc::new(Mutex::new(engine_tx));
    for stream in listener.incoming() {
        let stream = stream.context("accept")?;
        let tx = engine_tx.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_client(stream, tx) {
                crate::debug!("server", "client error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_client(
    stream: TcpStream,
    engine_tx: Arc<Mutex<Sender<Command>>>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_line(line) {
            ParsedLine::Gen { max_new, prompt } => {
                let id = next_request_id();
                let (tx, rx) = channel();
                let req = GenRequest::new(id, prompt, max_new);
                engine_tx
                    .lock()
                    .unwrap()
                    .send(Command::Submit(req, tx))
                    .context("engine gone")?;
                // Ask the engine to flush so the reply arrives promptly.
                let (ftx, _frx) = channel();
                let _ = engine_tx.lock().unwrap().send(Command::Flush(ftx));
                match rx.recv() {
                    Ok(c) => {
                        let text =
                            crate::model::ByteTokenizer.decode(&c.generated);
                        writeln!(
                            writer,
                            "OK {} {:.1} {:.1} {}",
                            c.id,
                            c.ttft * 1e3,
                            c.total_latency * 1e3,
                            text.replace('\n', " ")
                        )?;
                    }
                    Err(_) => writeln!(writer, "ERR engine dropped request")?,
                }
            }
            ParsedLine::Quit => {
                writeln!(writer, "BYE")?;
                break;
            }
            ParsedLine::Bad(msg) => {
                writeln!(writer, "ERR {msg}")?;
            }
        }
    }
    crate::debug!("server", "client {peer} disconnected");
    Ok(())
}

enum ParsedLine {
    Gen { max_new: usize, prompt: Vec<u8> },
    Quit,
    Bad(&'static str),
}

fn parse_line(line: &str) -> ParsedLine {
    if line == "QUIT" {
        return ParsedLine::Quit;
    }
    if let Some(rest) = line.strip_prefix("GEN ") {
        let mut parts = rest.splitn(2, ' ');
        let Some(n) = parts.next().and_then(|p| p.parse::<usize>().ok()) else {
            return ParsedLine::Bad("usage: GEN <max_new_tokens> <prompt>");
        };
        let Some(prompt) = parts.next().filter(|p| !p.is_empty()) else {
            return ParsedLine::Bad("empty prompt");
        };
        return ParsedLine::Gen { max_new: n.clamp(1, 256), prompt: prompt.as_bytes().to_vec() };
    }
    ParsedLine::Bad("unknown command")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen() {
        match parse_line("GEN 32 the router routes") {
            ParsedLine::Gen { max_new, prompt } => {
                assert_eq!(max_new, 32);
                assert_eq!(prompt, b"the router routes");
            }
            _ => panic!("expected Gen"),
        }
    }

    #[test]
    fn parse_quit_and_garbage() {
        assert!(matches!(parse_line("QUIT"), ParsedLine::Quit));
        assert!(matches!(parse_line("NOPE"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN x y"), ParsedLine::Bad(_)));
        assert!(matches!(parse_line("GEN 5"), ParsedLine::Bad(_)));
    }

    #[test]
    fn ids_are_unique() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
    }
}
