//! Build-time stand-in for the `xla` crate, used when the `pjrt` feature
//! is off (the default — xla-rs and its xla_extension native library are
//! not vendorable offline; see Cargo.toml).
//!
//! Mirrors exactly the API surface `runtime::mod` consumes. Every entry
//! point fails at [`PjRtClient::cpu`], i.e. at `Runtime::load` time, so
//! the pure-Rust layers (quant, kvcache, attention engines, coordinator
//! logic, benches, property tests) build and run with no PJRT toolchain,
//! while artifact-backed paths report a clear error instead of linking
//! against a missing library.

use std::fmt;

/// Error type standing in for `xla::Error` (convertible to `anyhow`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (see rust/Cargo.toml for how to enable real execution)"
            .to_string(),
    )
}

/// Mirrors `xla::ElementType` (only the dtypes the manifest uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
}

/// Mirrors `xla::Literal` — never actually constructed in stub builds.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::PjRtClient`; `cpu()` is the single gate where stub
/// builds fail.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_at_client_creation_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_literal_paths_error_not_panic() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16]
        )
        .is_err());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(Literal.to_tuple().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        let proto = HloModuleProto::from_text_file("nope");
        assert!(proto.is_err());
    }
}
