//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust request path.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`). The
//! interchange format is HLO **text** because xla_extension 0.5.1 rejects
//! jax>=0.5's 64-bit-instruction-id protos (see DESIGN.md / aot.py).
//!
//! The `xla` dependency is gated behind the `pjrt` feature; default
//! builds alias [`stub`] in its place so the crate compiles without the
//! native toolchain and fails gracefully at [`Runtime::load`].

pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(not(feature = "pjrt"))]
use self::stub as xla;

pub use manifest::{
    ArtifactSpec, Manifest, MicroInfo, ModelInfo, TensorSpec,
};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A typed host tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) | HostTensor::I8(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
            HostTensor::I8(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            HostTensor::I8(d, _) => Ok(d),
            _ => bail!("tensor is not i8"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        // One untyped-bytes path covers every dtype (i8 has no NativeType
        // impl in the xla crate, so Literal::vec1 is unavailable for it).
        fn as_bytes<T>(s: &[T]) -> &[u8] {
            unsafe {
                std::slice::from_raw_parts(
                    s.as_ptr() as *const u8,
                    std::mem::size_of_val(s),
                )
            }
        }
        let (ty, bytes) = match self {
            HostTensor::F32(d, _) => (xla::ElementType::F32, as_bytes(d.as_slice())),
            HostTensor::I32(d, _) => (xla::ElementType::S32, as_bytes(d.as_slice())),
            HostTensor::I8(d, _) => (xla::ElementType::S8, as_bytes(d.as_slice())),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty,
            self.shape(),
            bytes,
        )?)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let shape = spec.shape.clone();
        match spec.dtype.as_str() {
            "float32" => Ok(HostTensor::F32(lit.to_vec::<f32>()?, shape)),
            "int32" => Ok(HostTensor::I32(lit.to_vec::<i32>()?, shape)),
            "int8" => Ok(HostTensor::I8(lit.to_vec::<i8>()?, shape)),
            other => bail!("unsupported artifact dtype {other}"),
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns outputs per the manifest spec.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

/// PJRT client + compiled-executable registry for an artifact directory.
///
/// The client is `None` for a [`Runtime::cpu_substrate`] runtime: the
/// manifest (model geometry) is served from a built-in default and any
/// attempt to run a compiled artifact fails with a clear error — the
/// pure-Rust `TurboCpu` backend never calls one.
pub struct Runtime {
    client: Option<xla::PjRtClient>,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json` from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} — run `make artifacts`"))?;
        let client = xla::PjRtClient::cpu()?;
        crate::info!(
            "runtime",
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client: Some(client),
            manifest,
            dir,
            cache: HashMap::new(),
        })
    }

    /// Artifact-free runtime for the pure-Rust CPU substrate: built-in
    /// geometry ([`Manifest::cpu_substrate`]), no PJRT client, no
    /// executables. The `TurboCpu` serving path runs against this with
    /// no toolchain and no `make artifacts`.
    pub fn cpu_substrate() -> Runtime {
        Runtime {
            client: None,
            manifest: Manifest::cpu_substrate(),
            dir: PathBuf::new(),
            cache: HashMap::new(),
        }
    }

    /// Compile (or fetch cached) an executable by artifact name.
    pub fn executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let client = self.client.as_ref().with_context(|| {
                format!(
                    "artifact {name} requested on a CPU-substrate runtime \
                     (no PJRT client; use Runtime::load for artifact paths)"
                )
            })?;
            let spec = self
                .manifest
                .artifact(name)
                .with_context(|| format!("artifact {name} not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            crate::info!(
                "runtime",
                "compiled {name} in {:.2}s",
                t0.elapsed().as_secs_f64()
            );
            self.cache.insert(name.to_string(), Executable { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Convenience: compile + run in one call.
    pub fn run(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.executable(name)?.run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.shape(), &[2]);
        assert_eq!(t.len(), 2);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i8().is_err());
    }

    #[test]
    fn scalar_tensor() {
        let t = HostTensor::scalar_i32(7);
        assert!(t.shape().is_empty());
        assert_eq!(t.as_i32().unwrap(), &[7]);
    }

    #[test]
    fn cpu_substrate_serves_geometry_but_refuses_artifacts() {
        let mut rt = Runtime::cpu_substrate();
        let m = &rt.manifest.model;
        assert_eq!(m.vocab, 256, "byte LM");
        assert_eq!(m.d_model, m.n_heads * m.d_head);
        assert_eq!(m.max_ctx % m.block, 0, "page-aligned context");
        let err = rt.run("decode_turbo", &[]).unwrap_err();
        assert!(
            format!("{err:#}").contains("CPU-substrate"),
            "clear no-client error, got: {err:#}"
        );
    }
}
