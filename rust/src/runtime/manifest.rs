//! `artifacts/manifest.json` — typed view of what aot.py produced.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// dtype + shape of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .context("tensor spec missing dtype")?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor spec missing shape")?
            .iter()
            .map(|d| d.as_usize().context("non-integer dim"))
            .collect::<Result<_>>()?;
        Ok(TensorSpec { dtype, shape })
    }
}

/// One AOT artifact: file + I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model geometry recorded by aot.py (single source of truth for shapes).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_ctx: usize,
    pub block: usize,
    pub n_r: f32,
}

/// Microbench kernel shapes.
#[derive(Debug, Clone)]
pub struct MicroInfo {
    pub heads: usize,
    pub seq: usize,
    pub d_head: usize,
    pub block: usize,
    pub sas_rows: usize,
    pub sas_cols: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub micro: MicroInfo,
    pub artifacts: Vec<ArtifactSpec>,
}

fn req_usize(j: &Json, path: &str) -> Result<usize> {
    j.path(path)
        .and_then(Json::as_usize)
        .with_context(|| format!("manifest missing {path}"))
}

impl Manifest {
    /// Built-in manifest for the artifact-free CPU substrate: the model
    /// geometry the pure-Rust `TurboCpu` path serves (vocab 256 — a byte
    /// LM — with `d_model = n_heads * d_head` and a page-aligned
    /// context), no compiled artifacts. Shapes are deliberately small so
    /// the no-toolchain engine path stays fast in tests and benches.
    pub fn cpu_substrate() -> Manifest {
        Manifest {
            model: ModelInfo {
                vocab: 256,
                d_model: 64,
                n_layers: 2,
                n_heads: 4,
                d_head: 16,
                max_ctx: 256,
                block: 32,
                n_r: -6.0,
            },
            micro: MicroInfo {
                heads: 4,
                seq: 64,
                d_head: 16,
                block: 32,
                sas_rows: 64,
                sas_cols: 64,
            },
            artifacts: Vec::new(),
        }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        let model = ModelInfo {
            vocab: req_usize(&j, "model/vocab")?,
            d_model: req_usize(&j, "model/d_model")?,
            n_layers: req_usize(&j, "model/n_layers")?,
            n_heads: req_usize(&j, "model/n_heads")?,
            d_head: req_usize(&j, "model/d_head")?,
            max_ctx: req_usize(&j, "model/max_ctx")?,
            block: req_usize(&j, "model/block")?,
            n_r: j
                .path("model/n_r")
                .and_then(Json::as_f64)
                .context("manifest missing model/n_r")? as f32,
        };
        let micro = MicroInfo {
            heads: req_usize(&j, "micro/heads")?,
            seq: req_usize(&j, "micro/seq")?,
            d_head: req_usize(&j, "micro/d_head")?,
            block: req_usize(&j, "micro/block")?,
            sas_rows: req_usize(&j, "micro/sas_rows")?,
            sas_cols: req_usize(&j, "micro/sas_cols")?,
        };
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .context("artifact missing name")?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .context("artifact missing file")?
                        .to_string(),
                    inputs: a
                        .get("inputs")
                        .and_then(Json::as_arr)
                        .context("artifact missing inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .context("artifact missing outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { model, micro, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"vocab": 256, "d_model": 128, "n_layers": 2, "n_heads": 4,
                "d_head": 32, "d_ff": 256, "max_ctx": 288, "block": 32,
                "n_r": -6.0, "int8_qmax": 119.0, "sas_poly": [1,2,3,4]},
      "micro": {"heads": 4, "seq": 128, "d_head": 32, "block": 32,
                "sas_rows": 128, "sas_cols": 128},
      "artifacts": [
        {"name": "sas_micro", "file": "sas_micro.hlo.txt",
         "inputs": [{"shape": [128, 128], "dtype": "float32"}],
         "outputs": [{"shape": [128, 128], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model.d_model, 128);
        assert_eq!(m.model.n_r, -6.0);
        assert_eq!(m.micro.seq, 128);
        let a = m.artifact("sas_micro").unwrap();
        assert_eq!(a.inputs[0].shape, vec![128, 128]);
        assert_eq!(a.inputs[0].numel(), 16384);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn rejects_incomplete() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
