//! Analytical GPU cost model — the testbed substitute for the paper's
//! A100 measurements (DESIGN.md §2).
//!
//! Figures 1, 6 and 7a are latency/throughput *shape* claims driven by
//! three hardware facts the model captures explicitly:
//!
//! 1. precision-dependent peak rates (FP16 TC 312 TFLOPS, INT8 TC 624
//!    TOPS, FP32 CUDA ~19.5 TFLOPS ~= 3% of FP16 TC per the paper §2.2),
//! 2. the HBM roofline (decode attention is bandwidth-bound; KV bytes
//!    scale with precision),
//! 3. where each method pays dequantization: KIVI/GEAR decompress to
//!    FP16 *before* attention (extra elementwise work + extra traffic),
//!    TurboAttention dequantizes INT4->INT8 inside the kernel (integer
//!    ops, no extra HBM traffic).
//!
//! Absolute numbers are estimates; the reproduced content is who wins,
//! by what factor, and where OOM lands — validated against the paper's
//! reported speedup ranges in `experiments/` and `benches/`.

pub mod attention;
pub mod e2e;
pub mod gpu;

pub use attention::{attention_decode_cost, attention_prefill_cost, AttnWorkload, LatencyBreakdown, Method};
pub use e2e::{e2e_step_cost, max_batch, ModelShape};
pub use gpu::GpuSpec;
