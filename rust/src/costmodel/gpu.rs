//! Hardware specification for the analytical model.

/// Peak rates and capacities of the modeled accelerator.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// FP16 tensor-core peak, FLOP/s.
    pub fp16_tc: f64,
    /// INT8 tensor-core peak, OP/s.
    pub int8_tc: f64,
    /// FP32 CUDA-core peak, FLOP/s (the exp path in FlashAttention —
    /// the paper calls out ~3% of FP16 TC).
    pub fp32_cuda: f64,
    /// FP16 CUDA/vector peak, FLOP/s (SAS polynomial path).
    pub fp16_cuda: f64,
    /// HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_cap: f64,
    /// Fixed kernel-launch + scheduling overhead per kernel, seconds.
    pub kernel_overhead: f64,
}

impl GpuSpec {
    /// NVIDIA A100-SXM-80GB (the paper's testbed).
    pub fn a100_80gb() -> GpuSpec {
        GpuSpec {
            name: "A100-SXM4-80GB",
            fp16_tc: 312e12,
            int8_tc: 624e12,
            fp32_cuda: 19.5e12,
            fp16_cuda: 78e12,
            hbm_bw: 2.039e12,
            hbm_cap: 80e9,
            kernel_overhead: 5e-6,
        }
    }

    /// Roofline time for a kernel phase: max(compute, memory) + overhead.
    pub fn roofline(&self, flops: f64, rate: f64, bytes: f64) -> f64 {
        (flops / rate).max(bytes / self.hbm_bw) + self.kernel_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_exp_rate_is_3pct_of_tc() {
        let g = GpuSpec::a100_80gb();
        let ratio = g.fp32_cuda / g.fp16_tc;
        assert!((0.05..0.07).contains(&(ratio / 1.0)) || ratio < 0.07);
        assert!(ratio < 0.07, "paper: FP32 CUDA ~3-6% of FP16 TC");
    }

    #[test]
    fn roofline_picks_max() {
        let g = GpuSpec::a100_80gb();
        // Compute-bound case.
        let t1 = g.roofline(1e12, 312e12, 1e3);
        assert!((t1 - (1e12 / 312e12 + g.kernel_overhead)).abs() < 1e-9);
        // Memory-bound case.
        let t2 = g.roofline(1e6, 312e12, 1e9);
        assert!((t2 - (1e9 / g.hbm_bw + g.kernel_overhead)).abs() < 1e-9);
    }
}
