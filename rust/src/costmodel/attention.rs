//! Per-method attention kernel latency models (Figures 1b, 6).

use super::GpuSpec;

/// Attention method being modeled.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// FlashAttention, FP16 matmuls + FP32 exp, FP16 KV cache.
    FlashFp16,
    /// KIVI-style: 4-bit KV cache, decompress to FP16 *before* attention.
    Kivi { bits: u32 },
    /// GEAR-L: KIVI + low-rank reconstruction work at read time.
    GearL { bits: u32, rank: usize },
    /// TurboAttention: INT8 execution + SAS + in-kernel INT4/2 dequant.
    Turbo { avg_bits: f64 },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::FlashFp16 => "Flash-FP16".into(),
            Method::Kivi { bits } => format!("KIVI-{bits}bit"),
            Method::GearL { bits, rank } => format!("GEAR-L-{bits}bit-r{rank}"),
            Method::Turbo { avg_bits } => format!("Turbo-{avg_bits}bit"),
        }
    }

    /// Bytes per cached KV element (K or V, one scalar).
    pub fn kv_bytes_per_elem(&self) -> f64 {
        match self {
            Method::FlashFp16 => 2.0,
            Method::Kivi { bits } | Method::GearL { bits, .. } => {
                *bits as f64 / 8.0 + 0.06 // + group scale/zero overhead
            }
            Method::Turbo { avg_bits } => avg_bits / 8.0 + 0.06,
        }
    }
}

/// Attention workload shape (per layer; all heads, one batch element).
#[derive(Debug, Clone, Copy)]
pub struct AttnWorkload {
    pub batch: usize,
    pub heads: usize,
    pub d_head: usize,
    /// Query tokens this pass (prefill: context; decode: 1).
    pub nq: usize,
    /// Key/value tokens attended.
    pub nk: usize,
}

impl AttnWorkload {
    fn bhd(&self) -> f64 {
        (self.batch * self.heads) as f64
    }

    /// FLOPs in the two matmuls (QK^T and PV).
    fn matmul_flops(&self) -> f64 {
        self.bhd() * 2.0 * 2.0 * (self.nq * self.nk * self.d_head) as f64
    }

    /// Score-matrix elements (exp evaluations).
    fn softmax_elems(&self) -> f64 {
        self.bhd() * (self.nq * self.nk) as f64
    }

    /// KV elements read (K and V).
    fn kv_elems(&self) -> f64 {
        self.bhd() * 2.0 * (self.nk * self.d_head) as f64
    }

    /// Q read + O write elements.
    fn qo_elems(&self) -> f64 {
        self.bhd() * 2.0 * (self.nq * self.d_head) as f64
    }
}

/// Phase-level latency decomposition (drives Figure 1b/1c stacking).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// Matmul + KV-cache load (roofline of the fused kernel).
    pub matmul_kv: f64,
    /// Softmax / exponentiation.
    pub softmax: f64,
    /// Dequantization outside the attention kernel (KIVI/GEAR only).
    pub dequant: f64,
    /// Cache write-back (prefill compression).
    pub writeback: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.matmul_kv + self.softmax + self.dequant + self.writeback
    }
}

/// Exponentiation cost for a method: FP32 CUDA cores for exact exp
/// (~6 flops/elem transcendental), FP16 vector path for SAS (~5 fma).
fn softmax_cost(gpu: &GpuSpec, elems: f64, turbo: bool) -> f64 {
    if turbo {
        // SAS: LUT gather + 3rd-degree Horner in FP16 (paper §4).
        gpu.roofline(elems * 5.0, gpu.fp16_cuda, 0.0)
    } else {
        // Exact exp on FP32 CUDA cores: the transcendental itself plus
        // FP16<->FP32 conversion and the online-softmax rescale chain
        // (~15 FP32 ops per score element in the fused kernel).
        gpu.roofline(elems * 15.0, gpu.fp32_cuda, 0.0)
    }
}

/// Out-of-kernel dequantization cost for decompress-first baselines:
/// read packed cache, write FP16 copy, elementwise affine per element —
/// the overhead Figure 1b attributes to KIVI/GEAR.
fn dequant_cost(gpu: &GpuSpec, method: &Method, kv_elems: f64) -> f64 {
    match method {
        Method::FlashFp16 | Method::Turbo { .. } => 0.0,
        Method::Kivi { .. } => {
            let bytes = kv_elems * (method.kv_bytes_per_elem() + 2.0);
            gpu.roofline(kv_elems * 2.0, gpu.fp16_cuda, bytes)
        }
        Method::GearL { rank, .. } => {
            // KIVI-style pass + rank-r reconstruction GEMV per element.
            let bytes = kv_elems * (method.kv_bytes_per_elem() + 2.0);
            let lr_flops = kv_elems * (2.0 * *rank as f64);
            gpu.roofline(kv_elems * 2.0 + lr_flops, gpu.fp16_cuda, bytes)
        }
    }
}

/// Prefill attention latency for one full pass over the workload.
pub fn attention_prefill_cost(
    gpu: &GpuSpec,
    method: &Method,
    w: &AttnWorkload,
) -> LatencyBreakdown {
    let matmul_rate = match method {
        Method::Turbo { .. } => gpu.int8_tc,
        _ => gpu.fp16_tc,
    };
    // Fused-kernel traffic: Q/O + KV at the precision attention *reads*
    // (baselines read the decompressed FP16 copy).
    let kv_read_bytes = match method {
        Method::Turbo { .. } => w.kv_elems() * 1.0, // INT8 tiles in-kernel
        _ => w.kv_elems() * 2.0,
    };
    let bytes = w.qo_elems() * 2.0 + kv_read_bytes;
    let matmul_kv = gpu.roofline(w.matmul_flops(), matmul_rate, bytes);
    let softmax = softmax_cost(
        gpu,
        w.softmax_elems(),
        matches!(method, Method::Turbo { .. }),
    );
    // Prefill writes the compressed cache (all methods write something;
    // quantizing methods also compute scales — negligible vs traffic).
    let writeback = gpu.roofline(
        0.0,
        gpu.fp16_tc,
        w.kv_elems() * method.kv_bytes_per_elem(),
    );
    // Baselines do not decompress during prefill (cache is fresh).
    LatencyBreakdown { matmul_kv, softmax, dequant: 0.0, writeback }
}

/// Decode attention latency for one token step (nq = 1 per sequence).
pub fn attention_decode_cost(
    gpu: &GpuSpec,
    method: &Method,
    w: &AttnWorkload,
) -> LatencyBreakdown {
    assert_eq!(w.nq, 1, "decode models one query token");
    let matmul_rate = match method {
        Method::Turbo { .. } => gpu.int8_tc,
        _ => gpu.fp16_tc,
    };
    // Decode is bandwidth-bound: the kernel streams the whole cache.
    let kv_read_bytes = match method {
        Method::FlashFp16 => w.kv_elems() * 2.0,
        // Turbo reads the packed q2 cache directly (integer dequant
        // fused — no extra traffic).
        Method::Turbo { .. } => w.kv_elems() * method.kv_bytes_per_elem(),
        // KIVI/GEAR attention reads the FP16 copy produced by dequant.
        _ => w.kv_elems() * 2.0,
    };
    let bytes = w.qo_elems() * 2.0 + kv_read_bytes;
    let matmul_kv = gpu.roofline(w.matmul_flops(), matmul_rate, bytes);
    let softmax = softmax_cost(
        gpu,
        w.softmax_elems(),
        matches!(method, Method::Turbo { .. }),
    );
    let dequant = dequant_cost(gpu, method, w.kv_elems());
    LatencyBreakdown { matmul_kv, softmax, dequant, writeback: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(nq: usize, nk: usize, batch: usize) -> AttnWorkload {
        AttnWorkload { batch, heads: 32, d_head: 128, nq, nk }
    }

    #[test]
    fn turbo_beats_flash_prefill() {
        let g = GpuSpec::a100_80gb();
        let w = wl(4096, 4096, 4);
        let t = attention_prefill_cost(&g, &Method::Turbo { avg_bits: 3.0 }, &w);
        let f = attention_prefill_cost(&g, &Method::FlashFp16, &w);
        let speedup = f.total() / t.total();
        // Paper Figure 6: up to 1.8x prefill speedup.
        assert!(speedup > 1.2 && speedup < 3.0, "speedup {speedup}");
    }

    #[test]
    fn turbo_beats_flash_decode() {
        let g = GpuSpec::a100_80gb();
        let w = wl(1, 16384, 4);
        let t = attention_decode_cost(&g, &Method::Turbo { avg_bits: 3.0 }, &w);
        let f = attention_decode_cost(&g, &Method::FlashFp16, &w);
        let speedup = f.total() / t.total();
        assert!(speedup > 1.2, "speedup {speedup}");
    }

    #[test]
    fn kivi_decode_slower_than_flash() {
        // Paper Figure 6: dequantization makes KIVI *worse* than FP16.
        let g = GpuSpec::a100_80gb();
        let w = wl(1, 16384, 4);
        let k = attention_decode_cost(&g, &Method::Kivi { bits: 4 }, &w);
        let f = attention_decode_cost(&g, &Method::FlashFp16, &w);
        assert!(k.total() > f.total());
        assert!(k.dequant > 0.0);
    }

    #[test]
    fn costs_monotone_in_context() {
        let g = GpuSpec::a100_80gb();
        let mut prev = 0.0;
        for nk in [1024, 2048, 4096, 8192, 16384] {
            let c = attention_decode_cost(
                &g,
                &Method::Turbo { avg_bits: 3.0 },
                &wl(1, nk, 1),
            )
            .total();
            assert!(c > prev);
            prev = c;
        }
    }

    #[test]
    fn softmax_share_significant_for_flash() {
        // Paper §4: softmax is 30%+ of attention time in flash workflows.
        let g = GpuSpec::a100_80gb();
        let w = wl(2048, 2048, 8);
        let f = attention_prefill_cost(&g, &Method::FlashFp16, &w);
        let share = f.softmax / f.total();
        assert!(share > 0.25, "softmax share {share}");
    }

    #[test]
    fn gear_dequant_exceeds_kivi() {
        let g = GpuSpec::a100_80gb();
        let w = wl(1, 8192, 4);
        let k = attention_decode_cost(&g, &Method::Kivi { bits: 4 }, &w);
        let r = attention_decode_cost(&g, &Method::GearL { bits: 4, rank: 4 }, &w);
        assert!(r.dequant >= k.dequant);
    }
}
