//! End-to-end inference step model: linear layers + attention + KV cache
//! (Figures 1a, 1c, 7a).

use super::{
    attention_decode_cost, attention_prefill_cost, AttnWorkload, GpuSpec,
    LatencyBreakdown, Method,
};

/// Transformer shape for the end-to-end model (Phi3-medium-like default).
#[derive(Debug, Clone, Copy)]
pub struct ModelShape {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

impl ModelShape {
    /// Phi3-medium (14B): 40 layers, d=5120, 40 heads, ff=17920.
    pub fn phi3_medium() -> ModelShape {
        ModelShape {
            n_layers: 40,
            d_model: 5120,
            n_heads: 40,
            d_ff: 17920,
            vocab: 32064,
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Weight parameter count (QKVO + FFN + embeddings).
    pub fn params(&self) -> f64 {
        let per_layer =
            4.0 * (self.d_model * self.d_model) as f64
                + 3.0 * (self.d_model * self.d_ff) as f64;
        self.n_layers as f64 * per_layer
            + 2.0 * (self.vocab * self.d_model) as f64
    }

    /// Linear-layer FLOPs for `tokens` tokens in one full forward pass.
    pub fn linear_flops(&self, tokens: usize) -> f64 {
        2.0 * self.params() * tokens as f64
    }
}

/// One inference step (prefill pass or a single decode step) end to end.
///
/// Returns (attention breakdown summed over layers, linear time, total).
pub fn e2e_step_cost(
    gpu: &GpuSpec,
    shape: &ModelShape,
    method: &Method,
    batch: usize,
    context: usize,
    prefill: bool,
) -> (LatencyBreakdown, f64, f64) {
    let w = AttnWorkload {
        batch,
        heads: shape.n_heads,
        d_head: shape.d_head(),
        nq: if prefill { context } else { 1 },
        nk: context,
    };
    let per_layer = if prefill {
        attention_prefill_cost(gpu, method, &w)
    } else {
        attention_decode_cost(gpu, method, &w)
    };
    let attn = LatencyBreakdown {
        matmul_kv: per_layer.matmul_kv * shape.n_layers as f64,
        softmax: per_layer.softmax * shape.n_layers as f64,
        dequant: per_layer.dequant * shape.n_layers as f64,
        writeback: per_layer.writeback * shape.n_layers as f64,
    };
    let tokens = batch * if prefill { context } else { 1 };
    // Linear layers: FP16 tensor-core, plus weight traffic (dominant at
    // small batch: every step streams all weights).
    let linear = gpu.roofline(
        shape.linear_flops(tokens),
        gpu.fp16_tc,
        shape.params() * 2.0,
    ) + shape.n_layers as f64 * gpu.kernel_overhead * 3.0;
    let total = attn.total() + linear;
    (attn, linear, total)
}

/// Max batch size before KV cache + weights exceed HBM (Figure 6 "OOM"
/// markers, Figure 7a saturation).
pub fn max_batch(
    gpu: &GpuSpec,
    shape: &ModelShape,
    method: &Method,
    context: usize,
) -> usize {
    let weight_bytes = shape.params() * 2.0;
    let per_seq = 2.0
        * (context * shape.n_layers * shape.n_heads * shape.d_head()) as f64
        * method.kv_bytes_per_elem();
    // ~10% activation/workspace reserve.
    let budget = gpu.hbm_cap * 0.9 - weight_bytes;
    if budget <= 0.0 {
        return 0;
    }
    (budget / per_seq).floor() as usize
}

/// Sustained decode throughput (tokens/s) at a given batch and context:
/// batch tokens emitted per decode step.
pub fn decode_throughput(
    gpu: &GpuSpec,
    shape: &ModelShape,
    method: &Method,
    batch: usize,
    context: usize,
) -> f64 {
    let (_, _, step) = e2e_step_cost(gpu, shape, method, batch, context, false);
    batch as f64 / step
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attention_dominates_at_long_context() {
        // Figure 1a: attention share reaches ~80% at >80k context.
        let g = GpuSpec::a100_80gb();
        let s = ModelShape::phi3_medium();
        let m = Method::FlashFp16;
        let (attn, linear, total) = e2e_step_cost(&g, &s, &m, 1, 80_000, true);
        let share = attn.total() / total;
        assert!(share > 0.6, "share {share} (attn {} lin {linear})", attn.total());
        let (attn_s, _, total_s) = e2e_step_cost(&g, &s, &m, 1, 1_000, true);
        assert!(attn_s.total() / total_s < share, "share must grow with ctx");
    }

    #[test]
    fn turbo_extends_max_batch() {
        let g = GpuSpec::a100_80gb();
        let s = ModelShape::phi3_medium();
        let fp = max_batch(&g, &s, &Method::FlashFp16, 32_000);
        let tb = max_batch(&g, &s, &Method::Turbo { avg_bits: 3.0 }, 32_000);
        assert!(tb as f64 >= fp as f64 * 3.0, "fp {fp} turbo {tb}");
    }

    #[test]
    fn throughput_improves_with_turbo() {
        // Figure 7a: up to 2.37x max throughput.
        let g = GpuSpec::a100_80gb();
        let s = ModelShape::phi3_medium();
        let ctx = 1_000;
        let b_fp = max_batch(&g, &s, &Method::FlashFp16, ctx + 125);
        let b_tb = max_batch(&g, &s, &Method::Turbo { avg_bits: 3.0 }, ctx + 125);
        let tp_fp = decode_throughput(&g, &s, &Method::FlashFp16, b_fp, ctx);
        let tp_tb =
            decode_throughput(&g, &s, &Method::Turbo { avg_bits: 3.0 }, b_tb, ctx);
        let gain = tp_tb / tp_fp;
        // Paper reports 2.37x; the analytical model omits framework
        // overheads at large batch so it lands somewhat higher.
        assert!(gain > 1.3 && gain < 6.0, "gain {gain}");
    }

    #[test]
    fn params_order_of_magnitude() {
        let s = ModelShape::phi3_medium();
        let p = s.params();
        assert!((10e9..20e9).contains(&p), "params {p}");
    }

    #[test]
    fn max_batch_monotone_decreasing_in_context() {
        let g = GpuSpec::a100_80gb();
        let s = ModelShape::phi3_medium();
        let m = Method::FlashFp16;
        let mut prev = usize::MAX;
        for ctx in [4_000, 8_000, 16_000, 32_000] {
            let b = max_batch(&g, &s, &m, ctx);
            assert!(b <= prev);
            prev = b;
        }
    }
}
