//! The serving engine: continuous batcher + PJRT model + quantized KV
//! cache + sampling, with a threaded command loop for the server.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::request::{Completion, FinishReason, GenRequest, RequestId};
use crate::info;
use crate::kvcache::{KvCache, KvCacheConfig, PrecisionMap};
use crate::metrics::{EngineMetrics, Histogram};
use crate::model::{ModelBundle, Sampler};
use crate::quant::Bits;
use crate::testutil::Rng;

/// Which attention path serves requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathMode {
    /// TurboAttention: quantized execution + paged q2 cache.
    Turbo,
    /// Exact FlashAttention baseline with an FP32 cache.
    Flash,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub mode: PathMode,
    pub batcher: BatcherConfig,
    pub sampler: Sampler,
    /// q2 storage width for uniform precision (Turbo mode).
    pub kv_bits: Bits,
    /// Number of 2-bit heads per layer (0 = uniform `kv_bits`).
    pub n_2bit_heads: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: PathMode::Turbo,
            batcher: BatcherConfig::default(),
            sampler: Sampler::Greedy,
            kv_bits: Bits::Int4,
            n_2bit_heads: 0,
            seed: 0,
        }
    }
}

/// Per-request generation state.
struct Session {
    req: GenRequest,
    /// Turbo path: paged quantized cache.
    cache: Option<KvCache>,
    /// Flash path: float K/V slabs `[L*H*C*dh]`.
    flash_kv: Option<(Vec<f32>, Vec<f32>)>,
    generated: Vec<u8>,
    /// Next token to feed (sampled but not yet decoded).
    pending_token: u8,
    /// Its absolute position.
    pos: usize,
    prefill_done_at: Instant,
}

/// Commands accepted by the engine thread.
pub enum Command {
    Submit(GenRequest, Sender<Completion>),
    /// Drain all work then reply on the channel.
    Flush(Sender<()>),
    Shutdown,
}

/// The engine. Owns the PJRT runtime; single-threaded step loop.
pub struct Engine {
    pub cfg: EngineConfig,
    bundle: ModelBundle,
    batcher: Batcher,
    sessions: HashMap<RequestId, Session>,
    rng: Rng,
    pub metrics: EngineMetrics,
    pub ttft_hist: Histogram,
    pub latency_hist: Histogram,
}

impl Engine {
    pub fn new(bundle: ModelBundle, cfg: EngineConfig) -> Engine {
        Engine {
            batcher: Batcher::new(cfg.batcher.clone()),
            sessions: HashMap::new(),
            rng: Rng::new(cfg.seed),
            metrics: EngineMetrics::default(),
            ttft_hist: Histogram::new(),
            latency_hist: Histogram::new(),
            bundle,
            cfg,
        }
    }

    pub fn bundle(&mut self) -> &mut ModelBundle {
        &mut self.bundle
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.batcher.submit(req);
    }

    pub fn idle(&self) -> bool {
        self.batcher.idle()
    }

    fn new_cache(&self) -> KvCache {
        let m = &self.bundle.rt.manifest.model;
        let precision = if self.cfg.n_2bit_heads == 0 {
            PrecisionMap::uniform(m.n_layers, m.n_heads, self.cfg.kv_bits)
        } else {
            // Static head split until calibration runs (experiments use
            // `PrecisionMap::mixed_from_stats` with real stats).
            let mut pm = PrecisionMap::uniform(m.n_layers, m.n_heads, Bits::Int4);
            for l in 0..m.n_layers {
                for h in 0..self.cfg.n_2bit_heads.min(m.n_heads) {
                    pm.set(l, h, Bits::Int2);
                }
            }
            pm
        };
        KvCache::new(KvCacheConfig::new(
            m.n_layers, m.n_heads, m.d_head, m.block, precision,
        ))
    }

    /// Run one scheduler iteration: admit + prefill, then one decode round.
    /// Returns completions finished this step.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let decision = self.batcher.schedule();
        let mut done = Vec::new();

        // Prefill admitted requests.
        for id in decision.prefill {
            let req = self
                .batcher
                .request(id)
                .expect("scheduled request must exist")
                .clone();
            let turbo = self.cfg.mode == PathMode::Turbo;
            let out = self.bundle.prefill(&req.prompt, turbo)?;
            let n = req.prompt.len();
            let logits = self.bundle.logits_at(&out.logits, n - 1);
            let first = self.cfg.sampler.sample(logits, &mut self.rng);
            let mut session = Session {
                cache: None,
                flash_kv: None,
                generated: vec![first],
                pending_token: first,
                pos: n,
                prefill_done_at: Instant::now(),
                req,
            };
            match self.cfg.mode {
                PathMode::Turbo => {
                    let (k8, v8, sk, sv) =
                        out.turbo_cache.expect("turbo prefill returns cache");
                    let mut cache = self.new_cache();
                    self.bundle.ingest_prefill(&mut cache, &k8, &v8, &sk, &sv, n);
                    session.cache = Some(cache);
                }
                PathMode::Flash => {
                    session.flash_kv = Some(out.flash_cache.expect("flash cache"));
                }
            }
            self.metrics.prefill_tokens += n as u64;
            self.metrics.tokens_generated += 1;
            self.batcher.on_token(id);
            let ttft = session.req.submitted_at.elapsed().as_secs_f64();
            self.ttft_hist.record(ttft);
            self.sessions.insert(id, session);
        }

        // Decode round: one step per running request.
        for id in decision.decode {
            let Some(session) = self.sessions.get_mut(&id) else { continue };
            if let Some(reason) = finished(session, self.bundle.max_ctx()) {
                let c = Self::complete(session, reason);
                self.latency_hist.record(c.total_latency);
                self.metrics.requests_completed += 1;
                self.batcher.finish(id);
                self.sessions.remove(&id);
                done.push(c);
                continue;
            }
            let token = session.pending_token;
            let pos = session.pos;
            let out = match self.cfg.mode {
                PathMode::Turbo => {
                    let cache = session.cache.as_ref().expect("turbo cache");
                    self.bundle.decode_turbo(cache, token, pos)?
                }
                PathMode::Flash => {
                    let (kf, vf) = session.flash_kv.as_ref().expect("flash kv");
                    let nk = pos;
                    self.bundle.decode_flash(kf, vf, token, pos, nk)?
                }
            };
            // Fold the new token's K/V into the cache.
            let m_info = self.bundle.rt.manifest.model.clone();
            match self.cfg.mode {
                PathMode::Turbo => {
                    let cache = session.cache.as_mut().unwrap();
                    let dh = m_info.d_head;
                    for l in 0..m_info.n_layers {
                        for h in 0..m_info.n_heads {
                            let o = (l * m_info.n_heads + h) * dh;
                            cache
                                .k_stream_mut(l, h)
                                .push_token(&out.k_new[o..o + dh]);
                            cache
                                .v_stream_mut(l, h)
                                .push_token(&out.v_new[o..o + dh]);
                        }
                    }
                }
                PathMode::Flash => {
                    let (kf, vf) = session.flash_kv.as_mut().unwrap();
                    let dh = m_info.d_head;
                    let c = m_info.max_ctx;
                    for l in 0..m_info.n_layers {
                        for h in 0..m_info.n_heads {
                            let src = (l * m_info.n_heads + h) * dh;
                            let dst = ((l * m_info.n_heads + h) * c + pos) * dh;
                            kf[dst..dst + dh]
                                .copy_from_slice(&out.k_new[src..src + dh]);
                            vf[dst..dst + dh]
                                .copy_from_slice(&out.v_new[src..src + dh]);
                        }
                    }
                }
            }
            let next = self.cfg.sampler.sample(&out.logits, &mut self.rng);
            session.generated.push(next);
            session.pending_token = next;
            session.pos += 1;
            self.metrics.tokens_generated += 1;
            self.batcher.on_token(id);
        }
        self.metrics.batches_run += 1;
        if let Some(s) = self.sessions.values().next() {
            if let Some(cache) = &s.cache {
                let stats = cache.stats();
                self.metrics.cache_bytes = stats.bytes;
                self.metrics.cache_compression = stats.compression_ratio();
            }
        }
        Ok(done)
    }

    fn complete(session: &Session, reason: FinishReason) -> Completion {
        let total = session.req.submitted_at.elapsed().as_secs_f64();
        let decode_time = session.prefill_done_at.elapsed().as_secs_f64();
        let n_gen = session.generated.len().max(1);
        Completion {
            id: session.req.id,
            prompt_len: session.req.prompt.len(),
            generated: session.generated.clone(),
            total_latency: total,
            ttft: total - decode_time,
            tpot: decode_time / n_gen as f64,
            finish_reason: reason,
        }
    }

    /// Drive the engine until all submitted requests complete.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    /// Threaded serving loop: consume commands until Shutdown.
    pub fn run_loop(mut self, rx: Receiver<Command>) -> Result<()> {
        let mut reply_to: HashMap<RequestId, Sender<Completion>> = HashMap::new();
        loop {
            // Drain pending commands (non-blocking while busy; blocking
            // when idle so we don't spin).
            loop {
                let cmd = if self.idle() {
                    match rx.recv() {
                        Ok(c) => c,
                        Err(_) => return Ok(()),
                    }
                } else {
                    match rx.try_recv() {
                        Ok(c) => c,
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            return Ok(())
                        }
                    }
                };
                match cmd {
                    Command::Submit(req, tx) => {
                        reply_to.insert(req.id, tx);
                        self.submit(req);
                    }
                    Command::Flush(tx) => {
                        while !self.idle() {
                            for c in self.step()? {
                                if let Some(tx) = reply_to.remove(&c.id) {
                                    let _ = tx.send(c);
                                }
                            }
                        }
                        let _ = tx.send(());
                    }
                    Command::Shutdown => {
                        info!("engine", "shutdown: {} completed", self.metrics.requests_completed);
                        return Ok(());
                    }
                }
            }
            for c in self.step()? {
                if let Some(tx) = reply_to.remove(&c.id) {
                    let _ = tx.send(c);
                }
            }
        }
    }
}

/// Completion check: token budget, stop byte, or context exhaustion.
fn finished(s: &Session, max_ctx: usize) -> Option<FinishReason> {
    if s.generated.len() >= s.req.max_new_tokens {
        return Some(FinishReason::MaxTokens);
    }
    if let Some(stop) = s.req.stop_byte {
        if s.generated.last() == Some(&stop) {
            return Some(FinishReason::StopByte);
        }
    }
    if s.pos + 1 >= max_ctx {
        return Some(FinishReason::ContextFull);
    }
    None
}
