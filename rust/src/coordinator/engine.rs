//! The serving engine: continuous batcher + PJRT model + pluggable
//! attention backend + sampling, with a threaded command loop for the
//! server.
//!
//! All path-specific logic (turbo vs flash caches, decode reads, K/V
//! folds) lives behind [`DynBackend`] — `step` drives prefill/decode/fold
//! through the trait and never matches on the path.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::prefix::PrefixIndex;
use super::request::{Completion, FinishReason, GenRequest, RequestId};
use crate::attention::backend::{backend_for, BackendState, DynBackend};
use crate::info;
use crate::metrics::{EngineMetrics, Histogram};
use crate::model::{ModelBundle, Sampler};
use crate::pool::{default_threads, WorkerPool};
use crate::quant::Bits;
use crate::testutil::Rng;

pub use crate::attention::backend::PathMode;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub mode: PathMode,
    pub batcher: BatcherConfig,
    pub sampler: Sampler,
    /// q2 storage width for uniform precision (Turbo mode).
    pub kv_bits: Bits,
    /// Number of 2-bit heads per layer (0 = uniform `kv_bits`).
    pub n_2bit_heads: usize,
    /// Worker threads for per-(layer, head) decode work. On the
    /// `Turbo` path this parallelizes the slab sync
    /// (`TurboSession::sync_slabs`; attention runs in the decode
    /// executable); on the `TurboCpu` path it additionally fans out
    /// per-stream attention itself (`turbo_decode_streams` over the
    /// integer kernels) and prefill's per-head tiles. Default = the
    /// machine's available parallelism; `1` (or `0`) = the exact
    /// serial path. Decode output is thread-count-invariant — the
    /// determinism contract the parallel-parity suite enforces — so
    /// this is purely a throughput knob.
    pub decode_threads: usize,
    /// Prompt-prefix KV sharing: at admission, match the new request's
    /// prompt against the prefix index of previously prefilled prompts
    /// and fork the session from the shared pool pages at the
    /// page-aligned split point — N requests with a common prefix then
    /// store those q2 pages once. Decode output is bit-identical with
    /// sharing on or off (shared pages hold exactly the codes a private
    /// prefill would produce; the mutable decode buffer is never
    /// shared), so this is purely a memory/ingest-work knob. Only the
    /// turbo-family backends have a page pool; the flash baseline
    /// ignores it.
    pub share_prefixes: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: PathMode::Turbo,
            batcher: BatcherConfig::default(),
            sampler: Sampler::Greedy,
            kv_bits: Bits::Int4,
            n_2bit_heads: 0,
            decode_threads: default_threads(),
            share_prefixes: false,
            seed: 0,
        }
    }
}

/// Per-request generation state. The cache lives inside `state`, owned
/// by whichever backend created it.
struct Session {
    req: GenRequest,
    /// Backend-owned cache/slab state (paged q2 cache + decode slabs for
    /// turbo, float slabs for flash).
    state: BackendState,
    generated: Vec<u8>,
    /// Next token to feed (sampled but not yet decoded).
    pending_token: u8,
    /// Its absolute position.
    pos: usize,
    prefill_done_at: Instant,
}

/// Commands accepted by the engine thread.
pub enum Command {
    Submit(GenRequest, Sender<Completion>),
    /// Drain all work then reply on the channel.
    Flush(Sender<()>),
    Shutdown,
}

/// The engine. Owns the PJRT runtime; single-threaded step loop.
pub struct Engine {
    pub cfg: EngineConfig,
    bundle: ModelBundle,
    batcher: Batcher,
    backend: Box<dyn DynBackend>,
    /// Decode worker pool, shared with the backend's sessions; the
    /// engine keeps its own handle for the wall/busy decode metrics.
    pool: Arc<WorkerPool>,
    sessions: HashMap<RequestId, Session>,
    /// Admission-time prompt-prefix index (Some iff
    /// `cfg.share_prefixes`); the page handles it holds are weak — the
    /// backend's pool refcounts own the memory.
    prefix_index: Option<PrefixIndex>,
    rng: Rng,
    pub metrics: EngineMetrics,
    pub ttft_hist: Histogram,
    pub latency_hist: Histogram,
}

/// Registered prompts kept by the prefix index before stalest eviction.
const PREFIX_INDEX_CAP: usize = 64;

impl Engine {
    pub fn new(bundle: ModelBundle, cfg: EngineConfig) -> Engine {
        // Only the turbo-family paths fork decode work; a flash engine
        // gets a serial (thread-free) pool instead of parked workers.
        let pool_threads = match cfg.mode {
            PathMode::Turbo | PathMode::TurboCpu => cfg.decode_threads,
            PathMode::Flash => 1,
        };
        let pool = Arc::new(WorkerPool::new(pool_threads));
        let prefix_index = cfg
            .share_prefixes
            .then(|| PrefixIndex::new(PREFIX_INDEX_CAP));
        Engine {
            batcher: Batcher::new(cfg.batcher.clone()),
            backend: backend_for(
                cfg.mode,
                cfg.kv_bits,
                cfg.n_2bit_heads,
                cfg.seed,
                &bundle.rt.manifest.model,
                Arc::clone(&pool),
            ),
            pool,
            sessions: HashMap::new(),
            prefix_index,
            rng: Rng::new(cfg.seed),
            metrics: EngineMetrics::default(),
            ttft_hist: Histogram::new(),
            latency_hist: Histogram::new(),
            bundle,
            cfg,
        }
    }

    pub fn bundle(&mut self) -> &mut ModelBundle {
        &mut self.bundle
    }

    /// The decode worker pool (1-thread = serial path).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.batcher.submit(req);
    }

    pub fn idle(&self) -> bool {
        self.batcher.idle()
    }

    /// Run one scheduler iteration: admit + prefill, then one decode round.
    /// Returns completions finished this step.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let decision = self.batcher.schedule();
        let mut done = Vec::new();

        // Prefill admitted requests, with admission-time prefix
        // detection: match the prompt against the index of live
        // registered prefixes and fork from the shared pages on a hit.
        for id in decision.prefill {
            let req = self
                .batcher
                .request(id)
                .expect("scheduled request must exist")
                .clone();
            let n = req.prompt.len();
            let shared = match (&mut self.prefix_index, self.backend.page_pool())
            {
                (Some(ix), Some(pool)) => {
                    let pool = pool.read().unwrap_or_else(|e| e.into_inner());
                    ix.lookup(&req.prompt, self.bundle.block(), &pool)
                }
                _ => None,
            };
            if let Some(sp) = &shared {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_shared_tokens += sp.tokens as u64;
            }
            let (logits, state, reg) = self.backend.prefill(
                &mut self.bundle,
                &req.prompt,
                shared.as_ref(),
            )?;
            if let (Some(ix), Some(reg)) = (&mut self.prefix_index, reg) {
                ix.insert(req.prompt.clone(), reg);
            }
            let first = self
                .cfg
                .sampler
                .sample(self.bundle.logits_at(&logits, n - 1), &mut self.rng);
            let session = Session {
                state,
                generated: vec![first],
                pending_token: first,
                pos: n,
                prefill_done_at: Instant::now(),
                req,
            };
            self.metrics.prefill_tokens += n as u64;
            self.metrics.tokens_generated += 1;
            self.batcher.on_token(id);
            let ttft = session.req.submitted_at.elapsed().as_secs_f64();
            self.ttft_hist.record(ttft);
            self.sessions.insert(id, session);
        }

        // Decode round: one step per running request. Wall time vs the
        // pool's busy time over the round is the parallel-efficiency
        // signal (`EngineMetrics::decode_parallelism`).
        let decode_round = (!decision.decode.is_empty())
            .then(|| (Instant::now(), self.pool.busy()));
        for id in decision.decode {
            let Some(session) = self.sessions.get_mut(&id) else { continue };
            if let Some(reason) = finished(session, self.bundle.max_ctx()) {
                let c = Self::complete(session, reason);
                self.latency_hist.record(c.total_latency);
                self.metrics.requests_completed += 1;
                self.batcher.finish(id);
                self.sessions.remove(&id);
                done.push(c);
                continue;
            }
            let token = session.pending_token;
            let pos = session.pos;
            let out = self.backend.decode_step(
                &mut self.bundle,
                &mut session.state,
                token,
                pos,
            )?;
            self.backend.fold_new_token(
                &self.bundle,
                &mut session.state,
                &out.k_new,
                &out.v_new,
                pos,
            );
            let next = self.cfg.sampler.sample(&out.logits, &mut self.rng);
            session.generated.push(next);
            session.pending_token = next;
            session.pos += 1;
            self.metrics.tokens_generated += 1;
            self.batcher.on_token(id);
        }
        if let Some((wall0, busy0)) = decode_round {
            self.metrics.decode_wall_s += wall0.elapsed().as_secs_f64();
            self.metrics.decode_busy_s +=
                (self.pool.busy() - busy0).as_secs_f64();
        }
        self.metrics.batches_run += 1;
        self.update_cache_metrics();
        Ok(done)
    }

    /// Aggregate cache memory across *all* live sessions (a multi-request
    /// engine's true footprint — previously this sampled an arbitrary
    /// single session). When no session holds a compressed cache the last
    /// observed values are kept, so a completion snapshot still reports
    /// the memory the request used.
    ///
    /// `cache_bytes` sums per-session (logical) footprints, so a page
    /// shared by N sessions counts N times there; the pool-level
    /// shared/private/dedup numbers below are the physical truth.
    fn update_cache_metrics(&mut self) {
        let (mut bytes, mut fp16, mut view, mut slab) =
            (0usize, 0usize, 0usize, 0usize);
        for s in self.sessions.values() {
            if let Some(stats) = self.backend.cache_stats(&s.state) {
                bytes += stats.bytes;
                fp16 += stats.fp16_equiv_bytes;
                view += stats.view_bytes;
                slab += stats.slab_bytes;
            }
        }
        if bytes > 0 {
            self.metrics.cache_bytes = bytes;
            self.metrics.cache_view_bytes = view;
            self.metrics.cache_slab_bytes = slab;
            self.metrics.cache_compression = fp16 as f64 / bytes as f64;
        }
        if let Some(pool) = self.backend.page_pool() {
            let stats =
                pool.read().unwrap_or_else(|e| e.into_inner()).stats();
            // Same keep-last rule as the cache bytes above: when the
            // last session drains, its pages are freed and a fresh
            // snapshot would read all-zero — keep the last live values
            // so completion-time reporting (e.g. `gen --batch`) still
            // shows the dedup the batch actually achieved.
            if stats.physical_bytes > 0 {
                self.metrics.shared_page_bytes = stats.shared_bytes;
                self.metrics.private_page_bytes = stats.private_bytes;
                self.metrics.page_dedup_ratio = stats.dedup_ratio();
                self.metrics.page_q1_memo_bytes = stats.q1_memo_bytes;
            }
        }
        self.metrics.batcher_capacity_waits =
            self.batcher.metrics.capacity_waits;
        self.metrics.batcher_wait_depth =
            self.batcher.metrics.last_wait_depth as u64;
    }

    fn complete(session: &Session, reason: FinishReason) -> Completion {
        let total = session.req.submitted_at.elapsed().as_secs_f64();
        let decode_time = session.prefill_done_at.elapsed().as_secs_f64();
        let n_gen = session.generated.len().max(1);
        Completion {
            id: session.req.id,
            prompt_len: session.req.prompt.len(),
            generated: session.generated.clone(),
            total_latency: total,
            ttft: total - decode_time,
            tpot: decode_time / n_gen as f64,
            finish_reason: reason,
        }
    }

    /// Drive the engine until all submitted requests complete.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    /// Threaded serving loop: consume commands until Shutdown.
    pub fn run_loop(mut self, rx: Receiver<Command>) -> Result<()> {
        let mut reply_to: HashMap<RequestId, Sender<Completion>> = HashMap::new();
        loop {
            // Drain pending commands (non-blocking while busy; blocking
            // when idle so we don't spin).
            loop {
                let cmd = if self.idle() {
                    match rx.recv() {
                        Ok(c) => c,
                        Err(_) => return Ok(()),
                    }
                } else {
                    match rx.try_recv() {
                        Ok(c) => c,
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            return Ok(())
                        }
                    }
                };
                match cmd {
                    Command::Submit(req, tx) => {
                        reply_to.insert(req.id, tx);
                        self.submit(req);
                    }
                    Command::Flush(tx) => {
                        while !self.idle() {
                            for c in self.step()? {
                                if let Some(tx) = reply_to.remove(&c.id) {
                                    let _ = tx.send(c);
                                }
                            }
                        }
                        let _ = tx.send(());
                    }
                    Command::Shutdown => {
                        info!("engine", "shutdown: {} completed", self.metrics.requests_completed);
                        return Ok(());
                    }
                }
            }
            for c in self.step()? {
                if let Some(tx) = reply_to.remove(&c.id) {
                    let _ = tx.send(c);
                }
            }
        }
    }
}

/// Completion check: token budget, stop byte, or context exhaustion.
fn finished(s: &Session, max_ctx: usize) -> Option<FinishReason> {
    if s.generated.len() >= s.req.max_new_tokens {
        return Some(FinishReason::MaxTokens);
    }
    if let Some(stop) = s.req.stop_byte {
        if s.generated.last() == Some(&stop) {
            return Some(FinishReason::StopByte);
        }
    }
    if s.pos + 1 >= max_ctx {
        return Some(FinishReason::ContextFull);
    }
    None
}
