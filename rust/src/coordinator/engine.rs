//! The serving engine: continuous batcher + PJRT model + pluggable
//! attention backend, with a threaded command loop for the server.
//!
//! All path-specific logic (turbo vs flash caches, decode reads, K/V
//! folds) lives behind [`DynBackend`] — `step` drives prefill/decode/fold
//! through the trait and never matches on the path.
//!
//! Request lifecycle (streaming): `step` emits [`StepEvent`]s — a
//! `First` token when prefill completes, one `Token` per decode step,
//! and a terminal `Finished(Completion)` — which [`Engine::run_loop`]
//! forwards to each request's event channel. Sampling is per-request
//! ([`SamplingParams`] on [`GenRequest`], private RNG seeded from
//! `params.seed`), so a request's output is a pure function of
//! `(prompt, params)`: batch composition, other traffic, and
//! `decode_threads` cannot change it. [`Engine::cancel`] aborts an
//! in-flight request immediately — the batcher slot and the session's
//! PagePool refs are released before the call returns.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::prefix::PrefixIndex;
use super::request::{
    Completion, FinishReason, GenRequest, RequestId, StepEvent, TokenEvent,
};
use crate::attention::backend::{
    backend_for, BackendState, DynBackend, PrefillChunkOut,
};
use crate::info;
use crate::kvcache::SharedPagePool;
use crate::metrics::{EngineMetrics, Histogram};
use crate::model::ModelBundle;
use crate::pool::{default_threads, WorkerPool};
use crate::quant::Bits;
use crate::testutil::Rng;

pub use crate::attention::backend::PathMode;

/// Engine configuration. Sampling is *not* configured here — it rides
/// on every request as [`crate::coordinator::SamplingParams`].
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub mode: PathMode,
    pub batcher: BatcherConfig,
    /// q2 storage width for uniform precision (Turbo mode).
    pub kv_bits: Bits,
    /// Number of 2-bit heads per layer (0 = uniform `kv_bits`).
    pub n_2bit_heads: usize,
    /// Worker threads for per-(layer, head) decode work. On the
    /// `Turbo` path this parallelizes the slab sync
    /// (`TurboSession::sync_slabs`; attention runs in the decode
    /// executable); on the `TurboCpu` path it additionally fans out
    /// per-stream attention itself (`turbo_decode_streams` over the
    /// integer kernels) and prefill's per-head tiles. Default = the
    /// machine's available parallelism; `1` (or `0`) = the exact
    /// serial path. Decode output is thread-count-invariant — the
    /// determinism contract the parallel-parity suite enforces — so
    /// this is purely a throughput knob.
    pub decode_threads: usize,
    /// Prompt-prefix KV sharing: at admission, match the new request's
    /// prompt against the prefix index of previously prefilled prompts
    /// and fork the session from the shared pool pages at the
    /// page-aligned split point — N requests with a common prefix then
    /// store those q2 pages once. Decode output is bit-identical with
    /// sharing on or off (shared pages hold exactly the codes a private
    /// prefill would produce; the mutable decode buffer is never
    /// shared), so this is purely a memory/ingest-work knob. Only the
    /// turbo-family backends have a page pool; the flash baseline
    /// ignores it.
    pub share_prefixes: bool,
    /// Seeds the deterministic `CpuModel` weights (TurboCpu path).
    /// Sampling seeds live on each request's `SamplingParams`.
    pub seed: u64,
    /// Byte cap over the shared page pool's footprint (pages + q1
    /// memos; `None` = unbounded). Under pressure the engine first
    /// drops LRU q1 memos (derivable state — recomputed on demand),
    /// then preempts the running session with the cheapest replay
    /// (fewest generated tokens; ties fall to the youngest): its pages
    /// are released through the strict pool rules and the request
    /// rejoins the front of the waiting queue, to be re-prefilled and
    /// replayed on resume. Output stays bit-identical to an uncapped run (the
    /// PR-5 purity invariant); only latency and recompute work change.
    /// Turbo-family paths only; the flash baseline has no pool.
    pub pool_byte_cap: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: PathMode::Turbo,
            batcher: BatcherConfig::default(),
            kv_bits: Bits::Int4,
            n_2bit_heads: 0,
            decode_threads: default_threads(),
            share_prefixes: false,
            seed: 0,
            pool_byte_cap: None,
        }
    }
}

/// Per-request generation state. The cache lives inside `state`, owned
/// by whichever backend created it.
struct Session {
    req: GenRequest,
    /// Backend-owned cache/slab state (paged q2 cache + decode slabs for
    /// turbo, float slabs for flash).
    state: BackendState,
    generated: Vec<u8>,
    /// Next token to feed (sampled but not yet decoded).
    pending_token: u8,
    /// Its absolute position.
    pos: usize,
    /// Private sampling RNG, seeded from `req.params.seed` — the reason
    /// output is invariant to batch composition.
    rng: Rng,
    prefill_done_at: Instant,
    /// When the previous token was emitted (feeds the ITL histogram).
    last_token_at: Instant,
}

/// Resume snapshot of a preempted session: everything needed to rebuild
/// the request bit-identically *except* the KV cache, which is
/// recomputed on resume (re-prefill the prompt, then replay the
/// already-emitted tokens through ordinary decode steps). The session's
/// `BackendState` is dropped at preemption — that is the whole point:
/// its page refs release through the strict pool rules.
struct PreemptedState {
    generated: Vec<u8>,
    pending_token: u8,
    rng: Rng,
    prefill_done_at: Instant,
    last_token_at: Instant,
}

/// Commands accepted by the engine thread (see [`Engine::run_loop`]).
pub enum Command {
    /// Submit a request. The engine assigns the id (overwriting
    /// `req.id`), acks it on `ack`, and streams the request's
    /// [`TokenEvent`]s — ending with `Finished` — on `events`.
    Submit {
        req: GenRequest,
        events: Sender<TokenEvent>,
        ack: Sender<RequestId>,
    },
    /// Abort an in-flight request: its stream receives
    /// `Finished(Completion { finish_reason: Cancelled, .. })` and its
    /// batcher slot + KV pages are released immediately. Unknown ids
    /// are ignored (the request may have finished while the command was
    /// in flight).
    Cancel(RequestId),
    /// Reply on the channel once the engine has drained to idle. The
    /// reply is sent from the main loop when idleness is next observed,
    /// not by draining inline — commands (Cancel in particular) keep
    /// being serviced between steps while a flush is outstanding.
    Flush(Sender<()>),
    /// Reply with a metrics + histogram snapshot.
    Stats(Sender<StatsSnapshot>),
    Shutdown,
}

/// Point-in-time engine telemetry (the server's `STATS` reply).
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub metrics: EngineMetrics,
    pub ttft: Histogram,
    pub latency: Histogram,
    /// Inter-token latency (decode-step cadence) across all requests.
    pub itl: Histogram,
    /// Queue waiting time: submission to first prefill grant.
    pub waiting: Histogram,
}

/// The engine. Owns the PJRT runtime; single-threaded step loop.
pub struct Engine {
    pub cfg: EngineConfig,
    bundle: ModelBundle,
    batcher: Batcher,
    backend: Box<dyn DynBackend>,
    /// Decode worker pool, shared with the backend's sessions; the
    /// engine keeps its own handle for the wall/busy decode metrics.
    pool: Arc<WorkerPool>,
    sessions: HashMap<RequestId, Session>,
    /// In-flight chunked prefills: the backend's resume cursor per
    /// request, held between scheduler iterations while a long prompt
    /// streams in. Dropping an entry (cancel, preemption) releases its
    /// page refs through the strict pool rules.
    prefills: HashMap<RequestId, BackendState>,
    /// Sessions preempted under memory pressure, keyed by request id;
    /// the request itself waits at the front of the batcher queue and
    /// resumes through the ordinary prefill path.
    preempted: HashMap<RequestId, PreemptedState>,
    /// Admission-time prompt-prefix index (Some iff
    /// `cfg.share_prefixes`); the page handles it holds are weak — the
    /// backend's pool refcounts own the memory.
    prefix_index: Option<PrefixIndex>,
    /// Next id handed out to `Command::Submit` requests.
    next_id: RequestId,
    pub metrics: EngineMetrics,
    pub ttft_hist: Histogram,
    pub latency_hist: Histogram,
    /// Inter-token latency: seconds between consecutive emitted tokens
    /// of a request (first sample spans prefill-done to first decode).
    pub itl_hist: Histogram,
    /// Queue waiting time: submission (or preemption) to the request's
    /// first prefill grant.
    pub waiting_hist: Histogram,
}

/// Registered prompts kept by the prefix index before stalest eviction.
const PREFIX_INDEX_CAP: usize = 64;

impl Engine {
    pub fn new(bundle: ModelBundle, cfg: EngineConfig) -> Engine {
        // Only the turbo-family paths fork decode work; a flash engine
        // gets a serial (thread-free) pool instead of parked workers.
        let pool_threads = match cfg.mode {
            PathMode::Turbo | PathMode::TurboCpu => cfg.decode_threads,
            PathMode::Flash => 1,
        };
        let pool = Arc::new(WorkerPool::new(pool_threads));
        let prefix_index = cfg
            .share_prefixes
            .then(|| PrefixIndex::new(PREFIX_INDEX_CAP));
        let backend = backend_for(
            cfg.mode,
            cfg.kv_bits,
            cfg.n_2bit_heads,
            cfg.seed,
            &bundle.rt.manifest.model,
            Arc::clone(&pool),
        );
        // Chunk boundaries must stay block-aligned (the quantized cache
        // flushes whole blocks, and bitwise-invisible chunking depends
        // on it), and a backend that cannot pause a prefill gets
        // whole-prompt grants regardless of the requested chunk.
        let mut bcfg = cfg.batcher.clone();
        let block = bundle.block();
        bcfg.chunk_align = block;
        if !backend.supports_chunked_prefill() {
            bcfg.prefill_chunk = 0;
        } else if bcfg.prefill_chunk > 0 {
            bcfg.prefill_chunk = bcfg.prefill_chunk.div_ceil(block) * block;
        }
        let engine = Engine {
            batcher: Batcher::new(bcfg),
            backend,
            pool,
            sessions: HashMap::new(),
            prefills: HashMap::new(),
            preempted: HashMap::new(),
            prefix_index,
            next_id: 1,
            metrics: EngineMetrics {
                // Pin + report the kernel ISA this engine will run; the
                // backend choice is process-wide and sticky, so one
                // engine cannot mix arms across decode steps.
                kernel_backend: crate::kernels::kernel_backend().name(),
                pool_byte_cap: cfg.pool_byte_cap.unwrap_or(0),
                ..EngineMetrics::default()
            },
            ttft_hist: Histogram::new(),
            latency_hist: Histogram::new(),
            itl_hist: Histogram::new(),
            waiting_hist: Histogram::new(),
            bundle,
            cfg,
        };
        if let (Some(cap), Some(pool)) =
            (engine.cfg.pool_byte_cap, engine.backend.page_pool())
        {
            pool.write()
                .unwrap_or_else(|e| e.into_inner())
                .set_byte_cap(Some(cap));
        }
        engine
    }

    pub fn bundle(&mut self) -> &mut ModelBundle {
        &mut self.bundle
    }

    /// The decode worker pool (1-thread = serial path).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The backend's shared page pool, if the path has one (turbo
    /// family). Tests use it to assert refcount/epoch invariants across
    /// cancellation; metrics read it every step.
    pub fn page_pool(&self) -> Option<&SharedPagePool> {
        self.backend.page_pool()
    }

    /// Allocate the next engine-owned request id (what `run_loop`
    /// stamps on `Command::Submit` requests).
    pub fn allocate_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    pub fn submit(&mut self, req: GenRequest) {
        // Direct submitters pick their own ids; keep the allocator
        // ahead of them so handle-submitted ids never collide.
        self.next_id = self.next_id.max(req.id.saturating_add(1));
        self.batcher.submit(req);
    }

    pub fn idle(&self) -> bool {
        self.batcher.idle()
    }

    /// Abort a request wherever it is in its lifecycle. Returns the
    /// `Cancelled` completion if the id was live (waiting or decoding),
    /// `None` for unknown/finished ids. Effects are immediate — before
    /// this returns, the batcher slot and token-budget share are freed
    /// and the session (with its PagePool refs and slabs) is dropped,
    /// so the pool epoch/refcount rules see an ordinary release.
    pub fn cancel(&mut self, id: RequestId) -> Option<Completion> {
        let session = self.sessions.remove(&id);
        // A mid-prefill request only has a cursor; dropping it releases
        // the partial prefill's page refs strictly.
        self.prefills.remove(&id);
        // A preempted request has no session (its state was dropped at
        // preemption) but already streamed tokens — report them.
        let preempted = self.preempted.remove(&id);
        // Waiting and mid-prefill requests have no session yet; read
        // what the completion needs off the borrowed request before
        // evicting it (no reason to clone a potentially long prompt to
        // destroy it).
        let queued = if session.is_none() {
            self.batcher.request(id).map(|r| (r.prompt.len(), r.submitted_at))
        } else {
            None
        };
        let tracked = self.batcher.cancel(id);
        if session.is_none() && !tracked {
            return None;
        }
        self.metrics.requests_cancelled += 1;
        let c = match session {
            Some(s) => Self::complete(&s, FinishReason::Cancelled),
            None => {
                let (prompt_len, submitted_at) = queued
                    .expect("tracked but sessionless => waiting/mid-prefill");
                Completion {
                    id,
                    prompt_len,
                    generated: preempted.map(|p| p.generated).unwrap_or_default(),
                    total_latency: submitted_at.elapsed().as_secs_f64(),
                    ttft: 0.0,
                    tpot: 0.0,
                    finish_reason: FinishReason::Cancelled,
                }
            }
        };
        self.update_cache_metrics();
        Some(c)
    }

    /// Run one scheduler iteration: prefill grants (continuations of
    /// in-flight chunked prefills, then new admissions), then one
    /// decode round. Returns the lifecycle events this step produced —
    /// `First` per completed prefill, `Token` per decode step,
    /// `Finished` per completed request. A request whose final chunk
    /// lands this step joins the same step's decode round, so chunking
    /// never adds a step of first-token latency.
    pub fn step(&mut self) -> Result<Vec<StepEvent>> {
        let admit = self.relieve_memory_pressure();
        let decision = self.batcher.schedule_gated(admit);
        let mut decode = decision.decode;
        let mut events = Vec::new();

        // Serve prefill grants, with admission-time prefix detection:
        // when a prefill *opens* (no cursor yet), match the prompt
        // against the index of live registered prefixes and fork from
        // the shared pages on a hit. Continuations carry their cursor.
        for grant in decision.prefill {
            let id = grant.id;
            let req = self
                .batcher
                .request(id)
                .expect("scheduled request must exist")
                .clone();
            let n = req.prompt.len();
            if grant.admitted {
                self.waiting_hist
                    .record(req.submitted_at.elapsed().as_secs_f64());
            }
            let mut cursor = self.prefills.remove(&id);
            let shared = if cursor.is_none() {
                match (&mut self.prefix_index, self.backend.page_pool()) {
                    (Some(ix), Some(pool)) => {
                        let pool =
                            pool.read().unwrap_or_else(|e| e.into_inner());
                        ix.lookup(&req.prompt, self.bundle.block(), &pool)
                    }
                    _ => None,
                }
            } else {
                None
            };
            if let Some(sp) = &shared {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_shared_tokens += sp.tokens as u64;
            }
            let out = self.backend.prefill_chunk(
                &mut self.bundle,
                &req.prompt,
                shared.as_ref(),
                &mut cursor,
                grant.tokens,
            )?;
            let (last_logits, state, reg) = match out {
                PrefillChunkOut::Pending { processed } => {
                    self.batcher.prefill_progress(id, processed);
                    self.metrics.prefill_chunks += 1;
                    let cur = cursor.expect("pending prefill keeps a cursor");
                    self.prefills.insert(id, cur);
                    continue; // more chunks to come; nothing to sample
                }
                PrefillChunkOut::Done { last_logits, session, reg } => {
                    (last_logits, session, reg)
                }
            };
            self.batcher.prefill_done(id);
            if let (Some(ix), Some(reg)) = (&mut self.prefix_index, reg) {
                if let Some(pool) = self.backend.page_pool() {
                    let pool = pool.read().unwrap_or_else(|e| e.into_inner());
                    ix.insert(req.prompt.clone(), reg, &pool);
                }
            }
            // Resume of a preempted request: the prefill above rebuilt
            // the prompt's KV state bit-identically (forking from the
            // prefix index is itself bit-identical); now replay the
            // tokens already emitted before preemption through ordinary
            // decode steps — decode determinism makes the rebuilt cache
            // exactly the one the session would have had uninterrupted.
            // No events are emitted and nothing is re-sampled: the
            // client saw these tokens already.
            if let Some(ps) = self.preempted.remove(&id) {
                let mut state = state;
                let n_replay = ps.generated.len().saturating_sub(1);
                for (i, &tok) in ps.generated[..n_replay].iter().enumerate() {
                    let out = self.backend.decode_step(
                        &mut self.bundle,
                        &mut state,
                        tok,
                        n + i,
                        req.sparse_topk_pages,
                    )?;
                    self.note_sparse(&out);
                    self.backend.fold_new_token(
                        &self.bundle,
                        &mut state,
                        &out.k_new,
                        &out.v_new,
                        n + i,
                    );
                    self.metrics.preempt_replayed_tokens += 1;
                }
                let session = Session {
                    state,
                    generated: ps.generated,
                    pending_token: ps.pending_token,
                    pos: n + n_replay,
                    rng: ps.rng,
                    prefill_done_at: ps.prefill_done_at,
                    last_token_at: ps.last_token_at,
                    req,
                };
                self.sessions.insert(id, session);
                decode.push(id);
                continue;
            }
            let mut rng = Rng::new(req.params.seed);
            let first = req.params.sampler.sample(&last_logits, &mut rng);
            let now = Instant::now();
            let session = Session {
                state,
                generated: vec![first],
                pending_token: first,
                pos: n,
                rng,
                prefill_done_at: now,
                last_token_at: now,
                req,
            };
            self.metrics.prefill_tokens += n as u64;
            self.metrics.tokens_generated += 1;
            self.batcher.on_token(id);
            let ttft = session.req.submitted_at.elapsed().as_secs_f64();
            self.ttft_hist.record(ttft);
            self.sessions.insert(id, session);
            events.push(StepEvent {
                id,
                event: TokenEvent::First { token: first, ttft },
            });
            decode.push(id);
        }

        // Decode round: one step per fully-prefilled running request.
        // Wall time vs the pool's busy time over the round is the
        // parallel-efficiency signal
        // (`EngineMetrics::decode_parallelism`).
        let decode_round = (!decode.is_empty())
            .then(|| (Instant::now(), self.pool.busy()));
        for id in decode {
            let Some(session) = self.sessions.get_mut(&id) else { continue };
            if let Some(reason) = finished(session, self.bundle.max_ctx()) {
                let c = Self::complete(session, reason);
                self.latency_hist.record(c.total_latency);
                self.metrics.requests_completed += 1;
                self.batcher.finish(id);
                self.sessions.remove(&id);
                events.push(StepEvent {
                    id,
                    event: TokenEvent::Finished(c),
                });
                continue;
            }
            let token = session.pending_token;
            let pos = session.pos;
            let out = self.backend.decode_step(
                &mut self.bundle,
                &mut session.state,
                token,
                pos,
                session.req.sparse_topk_pages,
            )?;
            self.note_sparse(&out);
            self.backend.fold_new_token(
                &self.bundle,
                &mut session.state,
                &out.k_new,
                &out.v_new,
                pos,
            );
            let next =
                session.req.params.sampler.sample(&out.logits, &mut session.rng);
            session.generated.push(next);
            session.pending_token = next;
            session.pos += 1;
            let now = Instant::now();
            self.itl_hist
                .record(now.duration_since(session.last_token_at).as_secs_f64());
            session.last_token_at = now;
            events.push(StepEvent {
                id,
                event: TokenEvent::Token {
                    token: next,
                    index: session.generated.len() - 1,
                },
            });
            self.metrics.tokens_generated += 1;
            self.batcher.on_token(id);
        }
        if let Some((wall0, busy0)) = decode_round {
            self.metrics.decode_wall_s += wall0.elapsed().as_secs_f64();
            self.metrics.decode_busy_s +=
                (self.pool.busy() - busy0).as_secs_f64();
        }
        self.metrics.batches_run += 1;
        self.update_cache_metrics();
        Ok(events)
    }

    /// Two-tier relief against `cfg.pool_byte_cap`, run before every
    /// scheduling decision. Tier 1 drops least-recently-used q1 memos
    /// (derivable state: no epoch bump, recomputed on the next read).
    /// Tier 2 — capped storage itself still over budget — preempts the
    /// cheapest-replay running session at a time: its `BackendState` drops,
    /// releasing every page ref through the strict pool rules (frees
    /// bump the epoch; shared pages survive while other owners remain),
    /// and the request rejoins the waiting queue for recompute-on-
    /// resume. The last running session is never preempted (the
    /// batcher's never-deadlock rule: an oversized workload finishes
    /// solo rather than thrash). Returns the admission verdict for this
    /// iteration: admit only when pages + memos fit under the cap, or
    /// the engine is empty.
    fn relieve_memory_pressure(&mut self) -> bool {
        let Some(cap) = self.cfg.pool_byte_cap else { return true };
        let Some(pool) = self.backend.page_pool() else { return true };
        let pool = Arc::clone(pool);
        pool.write().unwrap_or_else(|e| e.into_inner()).enforce_cap();
        loop {
            let physical = pool
                .read()
                .unwrap_or_else(|e| e.into_inner())
                .physical_bytes();
            if physical <= cap || self.batcher.running_len() <= 1 {
                break;
            }
            let Some(victim) = self.batcher.preemption_victim() else { break };
            self.preempt_session(victim);
            // Freed pages may strand memos over the cap line; re-check.
            pool.write().unwrap_or_else(|e| e.into_inner()).enforce_cap();
        }
        let (physical, memo) = {
            let p = pool.read().unwrap_or_else(|e| e.into_inner());
            (p.physical_bytes(), p.memo_bytes())
        };
        physical + memo <= cap || self.batcher.running_len() == 0
    }

    /// Preempt one running session: snapshot its resume state, drop its
    /// backend state (every page ref releases strictly, bumping the
    /// epoch on final frees), and push the request back to the *front*
    /// of the waiting queue. Resume happens through the ordinary
    /// prefill path in [`Self::step`], which replays the generated
    /// tokens bit-identically. Preemption never mutates pages in place.
    fn preempt_session(&mut self, id: RequestId) {
        // A mid-prefill victim has no session yet: drop its cursor (the
        // partial prefill's page refs release strictly) and send it
        // back to the queue — no emitted tokens to snapshot, resume is
        // a plain re-prefill.
        if self.prefills.remove(&id).is_some() {
            self.batcher.preempt(id);
            self.metrics.preemptions += 1;
            return;
        }
        let Some(s) = self.sessions.remove(&id) else { return };
        let Session {
            state,
            generated,
            pending_token,
            rng,
            prefill_done_at,
            last_token_at,
            ..
        } = s;
        drop(state);
        self.preempted.insert(
            id,
            PreemptedState {
                generated,
                pending_token,
                rng,
                prefill_done_at,
                last_token_at,
            },
        );
        self.batcher.preempt(id);
        self.metrics.preemptions += 1;
    }

    /// Aggregate cache memory across *all* live sessions (a multi-request
    /// engine's true footprint — previously this sampled an arbitrary
    /// single session). When no session holds a compressed cache the last
    /// observed values are kept, so a completion snapshot still reports
    /// the memory the request used.
    ///
    /// `cache_bytes` sums per-session (logical) footprints, so a page
    /// shared by N sessions counts N times there; the pool-level
    /// shared/private/dedup numbers below are the physical truth.
    fn update_cache_metrics(&mut self) {
        let (mut bytes, mut fp16, mut view, mut slab) =
            (0usize, 0usize, 0usize, 0usize);
        for s in self.sessions.values() {
            if let Some(stats) = self.backend.cache_stats(&s.state) {
                bytes += stats.bytes;
                fp16 += stats.fp16_equiv_bytes;
                view += stats.view_bytes;
                slab += stats.slab_bytes;
            }
        }
        if bytes > 0 {
            self.metrics.cache_bytes = bytes;
            self.metrics.cache_view_bytes = view;
            self.metrics.cache_slab_bytes = slab;
            self.metrics.cache_compression = fp16 as f64 / bytes as f64;
        }
        if let Some(pool) = self.backend.page_pool() {
            let stats =
                pool.read().unwrap_or_else(|e| e.into_inner()).stats();
            // Same keep-last rule as the cache bytes above: when the
            // last session drains, its pages are freed and a fresh
            // snapshot would read all-zero — keep the last live values
            // so completion-time reporting (e.g. `gen --batch`) still
            // shows the dedup the batch actually achieved.
            if stats.physical_bytes > 0 {
                self.metrics.shared_page_bytes = stats.shared_bytes;
                self.metrics.private_page_bytes = stats.private_bytes;
                self.metrics.page_dedup_ratio = stats.dedup_ratio();
                self.metrics.page_q1_memo_bytes = stats.q1_memo_bytes;
            }
            // Pressure telemetry: the counters are monotone (no
            // keep-last dance needed) and the physical gauge is honest
            // current state — zero after drain is the truth.
            self.metrics.pool_physical_bytes = stats.physical_bytes;
            self.metrics.pool_memo_evictions = stats.memo_evictions;
            self.metrics.pool_memo_recomputes = stats.memo_recomputes;
        }
        self.metrics.batcher_capacity_waits =
            self.batcher.metrics.capacity_waits;
        self.metrics.batcher_wait_depth =
            self.batcher.metrics.last_wait_depth as u64;
        self.metrics.queue_depth = self.batcher.waiting_len() as u64;
        let budget = self.batcher.cfg.max_batch_total_tokens;
        self.metrics.batch_fill_ratio = if budget > 0 {
            self.batcher.reserved_tokens() as f64 / budget as f64
        } else {
            0.0
        };
    }

    /// Fold one decode step's sparse-attention counters into the
    /// engine totals (no-ops for dense steps — the backend reports
    /// zeros when the knob is off).
    fn note_sparse(&mut self, out: &crate::model::DecodeOut) {
        self.metrics.sparse_pages_attended += out.sparse_pages_attended;
        self.metrics.sparse_pages_skipped += out.sparse_pages_skipped;
        self.metrics.sparse_bytes_saved += out.sparse_bytes_saved;
    }

    fn complete(session: &Session, reason: FinishReason) -> Completion {
        let total = session.req.submitted_at.elapsed().as_secs_f64();
        let decode_time = session.prefill_done_at.elapsed().as_secs_f64();
        let n_gen = session.generated.len().max(1);
        Completion {
            id: session.req.id,
            prompt_len: session.req.prompt.len(),
            generated: session.generated.clone(),
            total_latency: total,
            ttft: total - decode_time,
            tpot: decode_time / n_gen as f64,
            finish_reason: reason,
        }
    }

    /// Point-in-time telemetry snapshot (`Command::Stats`).
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            metrics: self.metrics.clone(),
            ttft: self.ttft_hist.clone(),
            latency: self.latency_hist.clone(),
            itl: self.itl_hist.clone(),
            waiting: self.waiting_hist.clone(),
        }
    }

    /// Drive the engine until all submitted requests complete; token
    /// events are discarded, completions collected (the old blocking
    /// contract — `EngineHandle`/`ResponseHandle` stream instead).
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.idle() {
            for ev in self.step()? {
                if let TokenEvent::Finished(c) = ev.event {
                    all.push(c);
                }
            }
        }
        Ok(all)
    }

    /// Threaded serving loop: consume commands until Shutdown,
    /// streaming each request's events to its submit-time channel. A
    /// request whose event receiver hung up (client disconnected) is
    /// cancelled so it stops holding its batcher slot and KV pages.
    pub fn run_loop(mut self, rx: Receiver<Command>) -> Result<()> {
        let mut streams: HashMap<RequestId, Sender<TokenEvent>> =
            HashMap::new();
        // Flush acks waiting for the engine to go idle (see
        // `Command::Flush` — replied below, never drained inline, so a
        // flush can't starve Cancel/Submit while a long request runs).
        let mut pending_flushes: Vec<Sender<()>> = Vec::new();
        loop {
            // Drain pending commands (non-blocking while busy; blocking
            // when idle so we don't spin).
            loop {
                let cmd = if self.idle() {
                    match rx.recv() {
                        Ok(c) => c,
                        Err(_) => {
                            Self::drain_streams(&mut streams, "senders gone");
                            return Ok(());
                        }
                    }
                } else {
                    match rx.try_recv() {
                        Ok(c) => c,
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            Self::drain_streams(&mut streams, "senders gone");
                            return Ok(());
                        }
                    }
                };
                match cmd {
                    Command::Submit { mut req, events, ack } => {
                        req.id = self.allocate_id();
                        // The submitter blocks on this ack; a dropped
                        // ack receiver just means it stopped caring.
                        let _ = ack.send(req.id);
                        streams.insert(req.id, events);
                        self.submit(req);
                    }
                    Command::Cancel(id) => {
                        if let Some(c) = self.cancel(id) {
                            let ev = StepEvent {
                                id,
                                event: TokenEvent::Finished(c),
                            };
                            self.route_events(&mut streams, vec![ev]);
                        }
                    }
                    Command::Flush(tx) => {
                        pending_flushes.push(tx);
                    }
                    Command::Stats(tx) => {
                        let _ = tx.send(self.stats_snapshot());
                    }
                    Command::Shutdown => {
                        info!(
                            "engine",
                            "shutdown: {} completed, {} cancelled",
                            self.metrics.requests_completed,
                            self.metrics.requests_cancelled
                        );
                        Self::drain_streams(&mut streams, "shutdown");
                        return Ok(());
                    }
                }
                // A command can itself reach idleness (Flush when
                // already drained, Cancel of the last request) — ack
                // outstanding flushes before possibly blocking on recv.
                if self.idle() {
                    for tx in pending_flushes.drain(..) {
                        let _ = tx.send(());
                    }
                }
            }
            let evs = self.step()?;
            self.route_events(&mut streams, evs);
            if self.idle() {
                for tx in pending_flushes.drain(..) {
                    let _ = tx.send(());
                }
            }
        }
    }

    /// Forward step events to their per-request channels. Terminal
    /// events retire the channel entry (whether or not a sender was
    /// ever registered — direct `Engine::submit` requests have none,
    /// and previously their reply entries leaked). A send failure means
    /// the client hung up: cancel the request so it releases its slot
    /// and pages instead of decoding to `max_new_tokens` for nobody.
    fn route_events(
        &mut self,
        streams: &mut HashMap<RequestId, Sender<TokenEvent>>,
        events: Vec<StepEvent>,
    ) {
        let mut disconnected = Vec::new();
        for ev in events {
            let done = matches!(ev.event, TokenEvent::Finished(_));
            if let Some(tx) = streams.get(&ev.id) {
                if tx.send(ev.event).is_err() && !done {
                    disconnected.push(ev.id);
                }
            }
            if done {
                streams.remove(&ev.id);
            }
        }
        for id in disconnected {
            streams.remove(&id);
            if self.cancel(id).is_some() {
                crate::debug!(
                    "engine",
                    "request {id}: client disconnected, cancelled"
                );
            }
        }
    }

    /// Explicitly drop any event channels still registered when the
    /// loop exits — the old `reply_to` map silently leaked these.
    fn drain_streams(
        streams: &mut HashMap<RequestId, Sender<TokenEvent>>,
        why: &str,
    ) {
        if !streams.is_empty() {
            info!(
                "engine",
                "{why}: dropping {} undelivered event stream(s)",
                streams.len()
            );
            streams.clear();
        }
    }
}

/// Completion check: token budget, stop byte, or context exhaustion.
fn finished(s: &Session, max_ctx: usize) -> Option<FinishReason> {
    if s.generated.len() >= s.req.params.max_new_tokens {
        return Some(FinishReason::MaxTokens);
    }
    if let Some(stop) = s.req.params.stop_byte {
        if s.generated.last() == Some(&stop) {
            return Some(FinishReason::StopByte);
        }
    }
    if s.pos + 1 >= max_ctx {
        return Some(FinishReason::ContextFull);
    }
    None
}
