//! Client-side request-lifecycle API: [`EngineHandle`] submits work to
//! a running [`Engine::run_loop`](super::Engine::run_loop) thread and
//! hands back a [`ResponseHandle`] that *streams* the request's
//! [`TokenEvent`]s — first token, every decode token, then the terminal
//! [`Completion`].
//!
//! ```text
//!   let (tx, rx) = std::sync::mpsc::channel();
//!   std::thread::spawn(move || engine.run_loop(rx));   // engine thread
//!   let handle = EngineHandle::new(tx);                // any thread
//!   let mut resp = handle.submit(req)?;                // ack carries the id
//!   while let Some(ev) = resp.recv() { ... }           // or resp.wait()
//! ```
//!
//! `EngineHandle` is `Clone` — one per client thread, no locking (the
//! underlying `Sender<Command>` is itself cloneable; the server used to
//! wrap one in `Arc<Mutex<..>>` for no reason). Cancellation
//! ([`ResponseHandle::cancel`] or [`EngineHandle::cancel`]) aborts the
//! request engine-side: its batcher slot and PagePool refs are released
//! immediately and the stream ends with a `Cancelled` completion.

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use super::engine::{Command, StatsSnapshot};
use super::request::{Completion, GenRequest, RequestId, TokenEvent};

/// Cloneable client handle onto a running engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Command>,
}

impl EngineHandle {
    /// Wrap the command channel feeding an `Engine::run_loop` thread.
    pub fn new(tx: Sender<Command>) -> EngineHandle {
        EngineHandle { tx }
    }

    /// Submit a request and block (briefly) for the engine's admission
    /// ack, which carries the engine-allocated request id. `req.id` is
    /// ignored — the engine owns id allocation on this path.
    pub fn submit(&self, req: GenRequest) -> Result<ResponseHandle> {
        let (events_tx, events_rx) = channel();
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(Command::Submit { req, events: events_tx, ack: ack_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        let id = ack_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request before ack"))?;
        Ok(ResponseHandle {
            id,
            events: events_rx,
            tx: self.tx.clone(),
            finished: false,
        })
    }

    /// Abort a request by id (unknown/finished ids are ignored).
    pub fn cancel(&self, id: RequestId) -> Result<()> {
        self.tx
            .send(Command::Cancel(id))
            .map_err(|_| anyhow!("engine thread gone"))
    }

    /// Block until the engine has drained all submitted work.
    pub fn flush(&self) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Command::Flush(tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))
    }

    /// Fetch a metrics + histogram snapshot.
    pub fn stats(&self) -> Result<StatsSnapshot> {
        let (tx, rx) = channel();
        self.tx
            .send(Command::Stats(tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))
    }

    /// Ask the engine thread to exit its loop. Best-effort: a dead
    /// engine is already shut down.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }
}

/// The streaming side of one submitted request.
///
/// Dropping the handle without draining it is a *disconnect*: the
/// engine notices the dead channel on its next event and cancels the
/// request, releasing its batcher slot and KV pages.
pub struct ResponseHandle {
    id: RequestId,
    events: Receiver<TokenEvent>,
    tx: Sender<Command>,
    finished: bool,
}

impl ResponseHandle {
    /// The engine-allocated request id (from the submit ack).
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Next event, blocking. `None` after the terminal `Finished` event
    /// (or if the engine died mid-request).
    pub fn recv(&mut self) -> Option<TokenEvent> {
        if self.finished {
            return None;
        }
        match self.events.recv() {
            Ok(ev) => {
                if matches!(ev, TokenEvent::Finished(_)) {
                    self.finished = true;
                }
                Some(ev)
            }
            Err(_) => {
                self.finished = true;
                None
            }
        }
    }

    /// Request cancellation. The stream still ends with a `Finished`
    /// completion (reason `Cancelled`) — keep draining to observe it.
    pub fn cancel(&self) -> Result<()> {
        self.tx
            .send(Command::Cancel(self.id))
            .map_err(|_| anyhow!("engine thread gone"))
    }

    /// Block until the request finishes, discarding token events — the
    /// old one-shot `Submit(req, Sender<Completion>)` behavior. `None`
    /// if the engine died before completing the request.
    pub fn wait(mut self) -> Option<Completion> {
        while let Some(ev) = self.recv() {
            if let TokenEvent::Finished(c) = ev {
                return Some(c);
            }
        }
        None
    }
}

impl Iterator for ResponseHandle {
    type Item = TokenEvent;

    fn next(&mut self) -> Option<TokenEvent> {
        self.recv()
    }
}
