//! Admission-time prompt-prefix detection for KV page sharing.
//!
//! When a request is admitted, the engine asks this index whether any
//! previously prefilled prompt shares a page-aligned prefix with it. On
//! a hit, the new session *forks* from the registered pages (retaining
//! them in the [`PagePool`]) and prefill quantizes/stores only the tail
//! — N batched requests with a common prompt prefix then hold one
//! physical copy of those q2 pages instead of N.
//!
//! The index is a **sorted map** over full prompts. Longest-common-
//! prefix lookup uses the classic property of byte-sorted keys: the key
//! maximizing the LCP with a probe is one of the probe's two neighbors
//! in sort order, so a lookup is two `BTreeMap::range` probes, not a
//! scan.
//!
//! Entries are **weak**: the index holds page handles without retaining
//! them, so it pins no memory — a prefix is shareable for exactly as
//! long as some live session still owns its pages (donor or any fork
//! that adopted them; adoption chains keep hot prefixes alive across
//! donor completions). Dead entries are pruned lazily when a lookup
//! touches them, and a small capacity bound evicts the stalest entries
//! so the map cannot grow with request history.

use std::collections::BTreeMap;
use std::ops::Bound;

use crate::kvcache::{PageHandle, PagePool};

/// Page handles covering the page-aligned prefix of one prompt, for
/// every (layer, head) K and V stream in layer-major order — the unit a
/// forking session adopts and a prefilled session registers.
#[derive(Debug, Clone)]
pub struct SharedPrefix {
    /// Tokens covered (= `n_pages * block`).
    pub tokens: usize,
    /// Pages per stream.
    pub n_pages: usize,
    /// Stream count (`n_layers * n_heads`).
    pub n_streams: usize,
    /// K handles, `[n_streams * n_pages]`, stream-major.
    pub k: Vec<PageHandle>,
    /// V handles, same layout.
    pub v: Vec<PageHandle>,
}

impl SharedPrefix {
    /// K handles of one stream (layer-major stream index).
    pub fn k_pages(&self, stream: usize) -> &[PageHandle] {
        &self.k[stream * self.n_pages..(stream + 1) * self.n_pages]
    }

    /// V handles of one stream.
    pub fn v_pages(&self, stream: usize) -> &[PageHandle] {
        &self.v[stream * self.n_pages..(stream + 1) * self.n_pages]
    }

    /// Longest page-aligned head of this prefix whose handles are all
    /// still live (pages die from the tail: shorter-prompt forks retain
    /// only the head, so when a donor completes the tail pages free
    /// first). 0 means nothing shareable survives.
    fn live_pages(&self, pool: &PagePool) -> usize {
        for p in 0..self.n_pages {
            for s in 0..self.n_streams {
                let i = s * self.n_pages + p;
                if !pool.is_live(self.k[i]) || !pool.is_live(self.v[i]) {
                    return p;
                }
            }
        }
        self.n_pages
    }

    /// The first `n_pages` pages of every stream — the shareable overlap
    /// with a new prompt.
    fn clipped(&self, n_pages: usize, block: usize) -> SharedPrefix {
        debug_assert!(n_pages <= self.n_pages);
        let mut k = Vec::with_capacity(self.n_streams * n_pages);
        let mut v = Vec::with_capacity(self.n_streams * n_pages);
        for s in 0..self.n_streams {
            let o = s * self.n_pages;
            k.extend_from_slice(&self.k[o..o + n_pages]);
            v.extend_from_slice(&self.v[o..o + n_pages]);
        }
        SharedPrefix {
            tokens: n_pages * block,
            n_pages,
            n_streams: self.n_streams,
            k,
            v,
        }
    }
}

struct Entry {
    prefix: SharedPrefix,
    /// Insertion stamp for stalest-first eviction.
    stamp: u64,
}

/// Sorted-map index of live/registered prompt prefixes.
pub struct PrefixIndex {
    entries: BTreeMap<Vec<u8>, Entry>,
    cap: usize,
    clock: u64,
    /// Lookup counters (engine telemetry / tests).
    pub hits: u64,
    pub misses: u64,
}

impl PrefixIndex {
    /// Index bounded to `cap` registered prompts (stalest evicted).
    pub fn new(cap: usize) -> PrefixIndex {
        PrefixIndex {
            entries: BTreeMap::new(),
            cap: cap.max(1),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Nearest key at or below `prompt` in sort order whose entry still
    /// holds live pages. Fully dead entries met on the way are pruned;
    /// a partially dead entry (its tail pages freed because only
    /// shorter-prefix forks survive) is **clipped** to its live head
    /// rather than discarded — the live pages stay shareable.
    fn live_neighbor(
        &mut self,
        prompt: &[u8],
        below: bool,
        pool: &PagePool,
    ) -> Option<Vec<u8>> {
        loop {
            let key = if below {
                self.entries
                    .range::<[u8], _>((Bound::Unbounded, Bound::Included(prompt)))
                    .next_back()
                    .map(|(k, _)| k.clone())?
            } else {
                self.entries
                    .range::<[u8], _>((Bound::Excluded(prompt), Bound::Unbounded))
                    .next()
                    .map(|(k, _)| k.clone())?
            };
            let live = self
                .entries
                .get(&key)
                .map(|e| e.prefix.live_pages(pool))
                .unwrap_or(0);
            if live == 0 {
                self.entries.remove(&key);
                continue;
            }
            let entry = self.entries.get_mut(&key).expect("checked above");
            if live < entry.prefix.n_pages {
                let block = entry.prefix.tokens / entry.prefix.n_pages;
                let clipped = entry.prefix.clipped(live, block);
                entry.prefix = clipped;
            }
            return Some(key);
        }
    }

    /// Longest page-aligned shared prefix between `prompt` and any live
    /// registered prompt, clipped to whole pages of `block` tokens.
    /// Returns handles the caller must adopt (retain) before the owning
    /// sessions can go away.
    pub fn lookup(
        &mut self,
        prompt: &[u8],
        block: usize,
        pool: &PagePool,
    ) -> Option<SharedPrefix> {
        let mut best: Option<(usize, Vec<u8>)> = None;
        for below in [true, false] {
            let Some(key) = self.live_neighbor(prompt, below, pool) else {
                continue;
            };
            let lcp = lcp_len(prompt, &key);
            let entry = self.entries.get(&key).expect("neighbor exists");
            let pages = (lcp / block).min(entry.prefix.n_pages);
            if pages == 0 {
                continue;
            }
            if best.as_ref().map(|&(p, _)| pages > p).unwrap_or(true) {
                best = Some((pages, key));
            }
        }
        match best {
            Some((pages, key)) => {
                self.hits += 1;
                let entry = self.entries.get(&key).expect("best exists");
                Some(entry.prefix.clipped(pages, block))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Register a freshly prefilled prompt's page-aligned prefix. A
    /// re-registered prompt replaces its entry (newer handles win).
    /// When the capacity bound trips, fully dead entries (pages freed —
    /// otherwise only pruned lazily by lookups that meet them) are
    /// dropped *first*, so ghosts never push a live, shareable entry
    /// out of the index.
    pub fn insert(&mut self, prompt: Vec<u8>, prefix: SharedPrefix, pool: &PagePool) {
        if prefix.n_pages == 0 {
            return;
        }
        self.clock += 1;
        let stamp = self.clock;
        self.entries.insert(prompt, Entry { prefix, stamp });
        if self.entries.len() > self.cap {
            self.entries.retain(|_, e| e.prefix.live_pages(pool) > 0);
        }
        while self.entries.len() > self.cap {
            if let Some(key) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&key);
            } else {
                break;
            }
        }
    }
}

/// Length of the byte-wise longest common prefix.
fn lcp_len(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::QuantPage;
    use crate::quant::{quant_sym_int8, Bits};
    use crate::testutil::Rng;

    const BLOCK: usize = 4;
    const D: usize = 8;

    fn page(rng: &mut Rng, pool: &mut PagePool) -> PageHandle {
        let x = rng.normal_vec(BLOCK * D, 1.0);
        let q1 = quant_sym_int8(&x);
        pool.insert(QuantPage::from_q1(&q1.codes, BLOCK, D, q1.scale, Bits::Int4))
    }

    /// A 1-stream prefix of `n_pages` pages backed by real pooled pages.
    fn prefix(rng: &mut Rng, pool: &mut PagePool, n_pages: usize) -> SharedPrefix {
        let k = (0..n_pages).map(|_| page(rng, pool)).collect();
        let v = (0..n_pages).map(|_| page(rng, pool)).collect();
        SharedPrefix {
            tokens: n_pages * BLOCK,
            n_pages,
            n_streams: 1,
            k,
            v,
        }
    }

    #[test]
    fn exact_prompt_match_shares_all_pages() {
        let mut rng = Rng::new(1);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(8);
        let p = prefix(&mut rng, &mut pool, 2);
        ix.insert(b"abcdefgh".to_vec(), p.clone(), &pool);
        let got = ix.lookup(b"abcdefgh", BLOCK, &pool).expect("hit");
        assert_eq!(got.tokens, 8);
        assert_eq!(got.n_pages, 2);
        assert_eq!(got.k, p.k);
        assert_eq!(got.v, p.v);
        assert_eq!(ix.hits, 1);
    }

    #[test]
    fn partial_overlap_clips_to_page_boundary() {
        let mut rng = Rng::new(2);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(8);
        ix.insert(b"abcdefgh".to_vec(), prefix(&mut rng, &mut pool, 2), &pool);
        // 6 common bytes -> 1 whole page of 4.
        let got = ix.lookup(b"abcdefZZZZ", BLOCK, &pool).expect("hit");
        assert_eq!(got.n_pages, 1);
        assert_eq!(got.tokens, 4);
        // < 1 page of overlap -> miss.
        assert!(ix.lookup(b"abZZZZZZ", BLOCK, &pool).is_none());
        assert_eq!(ix.misses, 1);
    }

    #[test]
    fn picks_longest_of_multiple_candidates() {
        let mut rng = Rng::new(3);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(8);
        ix.insert(b"aaaabbbb".to_vec(), prefix(&mut rng, &mut pool, 2), &pool);
        ix.insert(b"aaaacccc".to_vec(), prefix(&mut rng, &mut pool, 2), &pool);
        ix.insert(b"zzzz".to_vec(), prefix(&mut rng, &mut pool, 1), &pool);
        let got = ix.lookup(b"aaaabbbbXXXX", BLOCK, &pool).expect("hit");
        assert_eq!(got.n_pages, 2, "full 8-byte overlap beats the 4-byte one");
    }

    #[test]
    fn dead_entries_are_pruned_on_lookup() {
        let mut rng = Rng::new(4);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(8);
        let p = prefix(&mut rng, &mut pool, 1);
        let handles = p.k.clone();
        ix.insert(b"aaaa".to_vec(), p, &pool);
        // The owning session goes away; entries are weak, so the pages die.
        for h in handles {
            pool.release(h);
        }
        // (v pages still live, but any dead handle kills the entry.)
        assert!(ix.lookup(b"aaaa", BLOCK, &pool).is_none());
        assert!(ix.is_empty(), "dead entry pruned");
    }

    /// A partially dead entry (tail pages freed, head still owned by a
    /// shorter-prefix fork) is clipped to its live head, not discarded:
    /// the surviving pages stay shareable.
    #[test]
    fn partially_dead_entry_is_clipped_not_dropped() {
        let mut rng = Rng::new(7);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(8);
        let p = prefix(&mut rng, &mut pool, 2);
        let (head_k, tail_k) = (p.k[0], p.k[1]);
        let tail_v = p.v[1];
        ix.insert(b"abcdefgh".to_vec(), p, &pool);
        // Donor dies; a fork retained only page 1, so page 2 frees.
        pool.release(tail_k);
        pool.release(tail_v);
        let got = ix.lookup(b"abcdefgh", BLOCK, &pool).expect("clipped hit");
        assert_eq!(got.n_pages, 1, "live head survives");
        assert_eq!(got.tokens, BLOCK);
        assert_eq!(got.k, vec![head_k]);
        assert_eq!(ix.len(), 1, "entry kept, clipped in place");
    }

    #[test]
    fn capacity_evicts_stalest() {
        let mut rng = Rng::new(5);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(2);
        ix.insert(b"aaaa".to_vec(), prefix(&mut rng, &mut pool, 1), &pool);
        ix.insert(b"bbbb".to_vec(), prefix(&mut rng, &mut pool, 1), &pool);
        ix.insert(b"cccc".to_vec(), prefix(&mut rng, &mut pool, 1), &pool);
        assert_eq!(ix.len(), 2);
        assert!(ix.lookup(b"aaaa", BLOCK, &pool).is_none(), "stalest evicted");
        assert!(ix.lookup(b"cccc", BLOCK, &pool).is_some());
    }

    /// Capacity hygiene (ISSUE 7 satellite): dead entries — only pruned
    /// lazily when a lookup happens to meet them — must not count
    /// against `cap` and push a *live* entry out at insert time.
    #[test]
    fn capacity_prunes_dead_before_evicting_live() {
        let mut rng = Rng::new(8);
        let mut pool = PagePool::new();
        let mut ix = PrefixIndex::new(2);
        // Stalest entry is live and shareable...
        let a = prefix(&mut rng, &mut pool, 1);
        ix.insert(b"aaaa".to_vec(), a, &pool);
        // ...the newer one's pages die (owner completed, no forks).
        let b = prefix(&mut rng, &mut pool, 1);
        let dead: Vec<PageHandle> =
            b.k.iter().chain(b.v.iter()).copied().collect();
        ix.insert(b"bbbb".to_vec(), b, &pool);
        for h in dead {
            pool.release(h);
        }
        // The third insert trips the cap: the dead ghost must go, not
        // the stalest-but-live "aaaa".
        ix.insert(b"cccc".to_vec(), prefix(&mut rng, &mut pool, 1), &pool);
        assert_eq!(ix.len(), 2);
        assert!(ix.lookup(b"aaaa", BLOCK, &pool).is_some(), "live kept");
        assert!(ix.lookup(b"cccc", BLOCK, &pool).is_some());
        assert!(ix.lookup(b"bbbb", BLOCK, &pool).is_none(), "ghost gone");
    }

    #[test]
    fn clipped_prefix_respects_stream_layout() {
        let mut rng = Rng::new(6);
        let mut pool = PagePool::new();
        // 2 streams x 3 pages.
        let mut k = Vec::new();
        let mut v = Vec::new();
        for _ in 0..2 * 3 {
            k.push(page(&mut rng, &mut pool));
            v.push(page(&mut rng, &mut pool));
        }
        let p = SharedPrefix {
            tokens: 3 * BLOCK,
            n_pages: 3,
            n_streams: 2,
            k: k.clone(),
            v: v.clone(),
        };
        let c = p.clipped(2, BLOCK);
        assert_eq!(c.n_pages, 2);
        assert_eq!(c.tokens, 2 * BLOCK);
        assert_eq!(c.k_pages(0), &k[0..2]);
        assert_eq!(c.k_pages(1), &k[3..5]);
        assert_eq!(c.v_pages(1), &v[3..5]);
    }
}
