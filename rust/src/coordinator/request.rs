//! Request, sampling, and lifecycle-event types flowing through the
//! coordinator.
//!
//! Sampling is a *request* property, not an engine property: every
//! [`GenRequest`] carries its own [`SamplingParams`] (policy + seed +
//! stop condition + token budget), and the engine derives a per-session
//! RNG from the seed, so a request's output is a pure function of
//! `(prompt, params)` — independent of what else happens to be batched
//! with it and of `decode_threads`.
//!
//! The engine reports progress as a stream of [`TokenEvent`]s per
//! request (first token, each decode token, then a terminal
//! [`Completion`]), which is what the `EngineHandle` /
//! `ResponseHandle` client API and the server's `TOK`/`DONE` wire
//! protocol forward.

use std::time::Instant;

use crate::model::Sampler;

pub type RequestId = u64;

/// Per-request sampling policy: everything that determines which token
/// is emitted next, and when generation stops. Two requests with equal
/// `(prompt, SamplingParams)` produce identical token streams.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    pub sampler: Sampler,
    /// Seeds the request's private RNG (ignored by `Sampler::Greedy`).
    pub seed: u64,
    /// Stop generation after emitting this byte (e.g. `b'.'`), if set.
    pub stop_byte: Option<u8>,
    pub max_new_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            sampler: Sampler::Greedy,
            seed: 0,
            stop_byte: None,
            max_new_tokens: 48,
        }
    }
}

impl SamplingParams {
    /// Greedy decoding with a token budget — the common test shape.
    pub fn greedy(max_new_tokens: usize) -> SamplingParams {
        SamplingParams { max_new_tokens, ..SamplingParams::default() }
    }
}

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Assigned by the engine at admission when submitted through
    /// `EngineHandle`; direct `Engine::submit` callers pick their own.
    pub id: RequestId,
    pub prompt: Vec<u8>,
    pub params: SamplingParams,
    /// Top-k page-sparse decode knob: attend only this many full KV
    /// pages per stream per step (envelope-scored, SparQ-style), folding
    /// the rest as mean-value terms. `0` = dense (the default); any
    /// value covering the whole context is bit-identical to dense.
    /// Per-request, so batch-mates mix sparse and dense freely.
    pub sparse_topk_pages: usize,
    pub submitted_at: Instant,
}

impl GenRequest {
    /// Greedy request with default sampling — the historical signature.
    pub fn new(id: RequestId, prompt: Vec<u8>, max_new_tokens: usize) -> GenRequest {
        GenRequest::with_params(id, prompt, SamplingParams::greedy(max_new_tokens))
    }

    pub fn with_params(
        id: RequestId,
        prompt: Vec<u8>,
        params: SamplingParams,
    ) -> GenRequest {
        GenRequest {
            id,
            prompt,
            params,
            sparse_topk_pages: 0,
            submitted_at: Instant::now(),
        }
    }

    /// Builder-style setter for [`GenRequest::sparse_topk_pages`].
    pub fn with_sparse_topk(mut self, k: usize) -> GenRequest {
        self.sparse_topk_pages = k;
        self
    }
}

/// Lifecycle state tracked by the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Queued, prefill not yet run.
    Waiting,
    /// Prefill done; decoding.
    Running,
    /// Finished (all tokens emitted or stop condition hit).
    Done,
}

/// One streamed lifecycle event for a request.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// Prefill finished and the first token was sampled; `ttft` is the
    /// observed queue + prefill time in seconds.
    First { token: u8, ttft: f64 },
    /// One decode-sampled token; `index` is its position in the
    /// generated sequence (the first decode token has index 1).
    Token { token: u8, index: usize },
    /// Terminal event — the channel carries nothing after this.
    Finished(Completion),
}

/// A [`TokenEvent`] tagged with the request it belongs to, as returned
/// by `Engine::step`.
#[derive(Debug, Clone)]
pub struct StepEvent {
    pub id: RequestId,
    pub event: TokenEvent,
}

/// Completed request with serving telemetry.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt_len: usize,
    pub generated: Vec<u8>,
    /// Queue + prefill + decode wall time.
    pub total_latency: f64,
    /// Time to first token (queue + prefill).
    pub ttft: f64,
    /// Decode seconds per generated token.
    pub tpot: f64,
    pub finish_reason: FinishReason,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopByte,
    ContextFull,
    /// Client-initiated abort: the session's batcher slot and KV pages
    /// were released before the token budget was reached.
    Cancelled,
}

impl FinishReason {
    /// Wire-protocol spelling (the server's `DONE` line).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopByte => "stop_byte",
            FinishReason::ContextFull => "context_full",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = GenRequest::new(7, b"hello".to_vec(), 32);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, b"hello");
        assert_eq!(r.params.max_new_tokens, 32);
        assert!(r.params.stop_byte.is_none());
        assert_eq!(r.params.sampler, Sampler::Greedy);
    }

    #[test]
    fn params_equality_is_total_over_fields() {
        let a = SamplingParams {
            sampler: Sampler::TopK { k: 4, temp: 0.7 },
            seed: 9,
            stop_byte: Some(b'.'),
            max_new_tokens: 16,
        };
        assert_eq!(a, a);
        assert_ne!(a, SamplingParams { seed: 10, ..a });
        assert_ne!(a, SamplingParams { sampler: Sampler::Greedy, ..a });
    }

    #[test]
    fn finish_reason_wire_names() {
        assert_eq!(FinishReason::MaxTokens.as_str(), "max_tokens");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
    }
}
