//! Request and completion types flowing through the coordinator.

use std::time::Instant;

pub type RequestId = u64;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: RequestId,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// Stop generation at this byte (e.g. b'.') if set.
    pub stop_byte: Option<u8>,
    pub submitted_at: Instant,
}

impl GenRequest {
    pub fn new(id: RequestId, prompt: Vec<u8>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            stop_byte: None,
            submitted_at: Instant::now(),
        }
    }
}

/// Lifecycle state tracked by the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Queued, prefill not yet run.
    Waiting,
    /// Prefill done; decoding.
    Running,
    /// Finished (all tokens emitted or stop condition hit).
    Done,
}

/// Completed request with serving telemetry.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt_len: usize,
    pub generated: Vec<u8>,
    /// Queue + prefill + decode wall time.
    pub total_latency: f64,
    /// Time to first token (queue + prefill).
    pub ttft: f64,
    /// Decode seconds per generated token.
    pub tpot: f64,
    pub finish_reason: FinishReason,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopByte,
    ContextFull,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = GenRequest::new(7, b"hello".to_vec(), 32);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, b"hello");
        assert_eq!(r.max_new_tokens, 32);
        assert!(r.stop_byte.is_none());
    }
}
