//! The serving coordinator — Layer 3's contribution: request routing,
//! iteration-level continuous batching, and the engine that ties the PJRT
//! runtime to the quantized KV cache.
//!
//! Topology (vLLM-router-like, scaled to this testbed):
//!
//! ```text
//!   clients -> server (TCP threads) -> EngineHandle::submit -> Engine thread
//!                                        ^      |                | step():
//!                                        |      | Cancel(id)     |  admit prefills
//!                                        |      v                |  decode round
//!                                        |   command queue       v
//!                  ResponseHandle <- per-request TokenEvent streams
//!                  (First, Token*, Finished(Completion))
//! ```
//!
//! The PJRT CPU client executes one computation at a time, so "batching"
//! here is Orca-style *iteration-level scheduling*: the batcher multiplexes
//! prefill admission and per-request decode steps under a token budget,
//! which is exactly the coordination layer the paper's throughput numbers
//! assume (the kernel-level batch dimension lives in the cost model).
//!
//! Request lifecycle: sampling rides on the request
//! ([`SamplingParams`]), ids are allocated by the engine at admission,
//! tokens stream back as [`TokenEvent`]s, and [`EngineHandle`] /
//! [`ResponseHandle`] give clients submit / stream / cancel / wait.

pub mod batcher;
pub mod engine;
pub mod handle;
pub mod prefix;
pub mod request;

pub use batcher::{
    Batcher, BatcherConfig, BatcherMetrics, PrefillGrant, SchedDecision,
};
pub use engine::{Command, Engine, EngineConfig, PathMode, StatsSnapshot};
pub use handle::{EngineHandle, ResponseHandle};
pub use prefix::{PrefixIndex, SharedPrefix};
pub use request::{
    Completion, FinishReason, GenRequest, RequestId, RequestState,
    SamplingParams, StepEvent, TokenEvent,
};
