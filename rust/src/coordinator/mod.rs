//! The serving coordinator — Layer 3's contribution: request routing,
//! iteration-level continuous batching, and the engine that ties the PJRT
//! runtime to the quantized KV cache.
//!
//! Topology (vLLM-router-like, scaled to this testbed):
//!
//! ```text
//!   clients -> server (TCP threads) -> submit queue -> Engine thread
//!                                                        | step():
//!                                                        |  admit prefills
//!                                                        |  decode round
//!                                                        v
//!                                  completions -> per-request channels
//! ```
//!
//! The PJRT CPU client executes one computation at a time, so "batching"
//! here is Orca-style *iteration-level scheduling*: the batcher multiplexes
//! prefill admission and per-request decode steps under a token budget,
//! which is exactly the coordination layer the paper's throughput numbers
//! assume (the kernel-level batch dimension lives in the cost model).

pub mod batcher;
pub mod engine;
pub mod prefix;
pub mod request;

pub use batcher::{Batcher, BatcherConfig, BatcherMetrics, SchedDecision};
pub use engine::{Engine, EngineConfig, PathMode};
pub use prefix::{PrefixIndex, SharedPrefix};
pub use request::{Completion, GenRequest, RequestId, RequestState};
