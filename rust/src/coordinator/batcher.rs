//! Iteration-level continuous batcher (Orca/TGI-style).
//!
//! Each scheduler iteration produces a [`SchedDecision`]: a list of
//! prefill *grants* — token-rationed, possibly partial chunks of a long
//! prompt — plus the decode round. Admission charges each request's
//! full KV reservation (`prompt + max_new_tokens`) against
//! `max_batch_total_tokens`; prefill work is rationed per iteration by
//! `max_batch_prefill_tokens`; and long prompts stream in as
//! block-aligned chunks interleaved with batch-mates' decode steps, so
//! a single long prompt can no longer monopolize an iteration while
//! late arrivals wait for a *slot* instead of *capacity*. FIFO within
//! each class; in-flight prefills outrank new admissions for the
//! per-iteration prefill budget (finish what you started), and the
//! head-of-line prefill always progresses at least one aligned chunk so
//! the batch cannot stall.

use std::collections::VecDeque;

use super::request::{GenRequest, RequestId};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests in the running set (slot cap — a coarse backstop;
    /// the token budgets below are the real admission control).
    pub max_running: usize,
    /// Max total token-budget reservation (`prompt + max_new_tokens`)
    /// across running requests — the KV-capacity admission gate, the
    /// CPU analogue of the HBM budget in `costmodel::max_batch`.
    pub max_batch_total_tokens: usize,
    /// Max prompt tokens granted to prefill per scheduler iteration,
    /// shared by in-flight chunked prefills and new admissions — this
    /// is what keeps batch-mates' inter-token latency flat while a long
    /// prompt streams in.
    pub max_batch_prefill_tokens: usize,
    /// Chunk size for splitting long prefills across iterations.
    /// 0 = whole prompt per grant (the engine clamps to 0 when the
    /// backend cannot pause and resume a prefill).
    pub prefill_chunk: usize,
    /// Admission-wave threshold: when > 0 and the batch is non-empty,
    /// defer admission until `waiting >= ratio * running`, so new
    /// requests join in batches instead of trickling in one per
    /// iteration (TGI's `waiting_served_ratio`). 0 admits eagerly.
    /// Waiting requests are never starved forever: the wave opens at
    /// the latest when the running batch drains.
    pub waiting_served_ratio: f32,
    /// Alignment for budget-clipped partial grants — the engine sets
    /// this to the model block size so every chunk boundary stays
    /// block-aligned (a hard requirement for bitwise-invisible
    /// chunking on the quantized cache).
    pub chunk_align: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_running: 32,
            max_batch_total_tokens: 4096,
            max_batch_prefill_tokens: 512,
            prefill_chunk: 0,
            waiting_served_ratio: 0.0,
            chunk_align: 1,
        }
    }
}

/// Internal per-request accounting.
#[derive(Debug, Clone)]
struct Tracked {
    req: GenRequest,
    /// Current context tokens (prompt + generated so far).
    context: usize,
    /// Token-budget reservation: `prompt + max_new_tokens`. Constant
    /// over the request's life (context grows by exactly one as the
    /// remaining allowance shrinks by one), so summing it never
    /// re-grants headroom already promised to a running request — the
    /// fix for the double-allocation bug where `schedule` recomputed
    /// usage from *current* context mid-decode.
    reserved: usize,
    /// Prompt tokens whose prefill has completed. A request joins the
    /// decode round only once `prefilled == prompt.len()`; preemption
    /// resets this to 0 (resume re-prefills from scratch).
    prefilled: usize,
}

/// One prefill grant: run up to `tokens` further prompt tokens of `id`
/// this iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillGrant {
    pub id: RequestId,
    /// Token allowance for this iteration (never more than the
    /// request's remaining prompt).
    pub tokens: usize,
    /// True when this grant moved the request out of the waiting queue
    /// (its first grant since submission or resume) — what the engine's
    /// waiting-time histogram records on.
    pub admitted: bool,
}

/// One scheduling decision.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct SchedDecision {
    /// Prefill grants this iteration, in execution order: in-flight
    /// continuations first (running order), then new admissions (FIFO).
    pub prefill: Vec<PrefillGrant>,
    /// Requests receiving one decode step this iteration — every fully
    /// prefilled running request. A request whose final chunk lands
    /// this iteration is appended by the engine once the grant
    /// completes, so admission-to-first-token stays a single step.
    pub decode: Vec<RequestId>,
}

/// Starvation observability: how often (and how deep) admission had to
/// wait for capacity. A silently deep waiting queue was previously
/// invisible — these counters make the capacity-wait branch a metric.
#[derive(Debug, Default, Clone)]
pub struct BatcherMetrics {
    /// Scheduler iterations that deferred admission because a token
    /// budget or the running-slot cap was exhausted (with work
    /// waiting). Intentional `waiting_served_ratio` waves don't count.
    pub capacity_waits: u64,
    /// Waiting-queue depth at the most recent capacity wait.
    pub last_wait_depth: usize,
    /// Deepest waiting queue seen at any capacity wait.
    pub max_wait_depth: usize,
}

/// Clip a prefill grant to the iteration's remaining budget. `want` is
/// `remaining` (whole-prompt mode) or `min(remaining, chunk)`; a grant
/// that exceeds `cap` is rounded down to an `align`-multiple so the
/// chunk boundary stays block-aligned (possibly 0 = no grant).
fn clip_grant(remaining: usize, chunk: usize, cap: usize, align: usize) -> usize {
    let want = if chunk == 0 { remaining } else { remaining.min(chunk) };
    if want <= cap {
        want
    } else {
        (cap / align) * align
    }
}

/// The continuous batcher: waiting queue + running set.
pub struct Batcher {
    pub cfg: BatcherConfig,
    pub metrics: BatcherMetrics,
    waiting: VecDeque<Tracked>,
    running: Vec<Tracked>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            metrics: BatcherMetrics::default(),
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        let context = req.prompt.len();
        let reserved = context + req.params.max_new_tokens;
        self.waiting.push_back(Tracked { req, context, reserved, prefilled: 0 });
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Total context tokens held by running requests.
    pub fn running_tokens(&self) -> usize {
        self.running.iter().map(|t| t.context).sum()
    }

    /// Total token-budget reservation held by running requests
    /// (`prompt + max_new_tokens` each) — what admission charges
    /// against, not the smaller current context.
    pub fn reserved_tokens(&self) -> usize {
        self.running.iter().map(|t| t.reserved).sum()
    }

    /// Prompt tokens prefilled so far for a running request (tests and
    /// observability; the engine learns progress from the backend).
    pub fn prefilled(&self, id: RequestId) -> Option<usize> {
        self.running.iter().find(|t| t.req.id == id).map(|t| t.prefilled)
    }

    /// Pick the preemption victim: the running request that costs the
    /// fewest replay tokens to resume. Generated tokens must be
    /// replayed one-by-one through the decode path on resume, while the
    /// prompt re-prefills in parallel chunks — so the victim is the
    /// request with the fewest *generated* tokens, and ties fall back
    /// to the youngest (pure LIFO on a fresh batch, where every
    /// candidate is equally cheap). Replaces the old youngest-first
    /// rule, which after a resume could evict a request with a long
    /// generated tail while a nearly-fresh one sat cheaper.
    pub fn preemption_victim(&self) -> Option<RequestId> {
        self.running
            .iter()
            .enumerate()
            .min_by_key(|(i, t)| {
                let replay = t.context.saturating_sub(t.req.prompt.len());
                (replay, std::cmp::Reverse(*i))
            })
            .map(|(_, t)| t.req.id)
    }

    /// Move a running request back to the *front* of the waiting queue
    /// (it keeps its FIFO seniority over later arrivals). The engine
    /// owns the session-state side: it must release the request's pages
    /// and re-prefill on resume — so the prefill progress resets here.
    /// Returns whether the id was running.
    pub fn preempt(&mut self, id: RequestId) -> bool {
        let Some(i) = self.running.iter().position(|t| t.req.id == id) else {
            return false;
        };
        let mut t = self.running.remove(i);
        t.prefilled = 0;
        self.waiting.push_front(t);
        true
    }

    /// Record chunked-prefill progress: `processed` prompt tokens are
    /// done (cumulative, as reported by the backend).
    pub fn prefill_progress(&mut self, id: RequestId, processed: usize) {
        if let Some(t) = self.running.iter_mut().find(|t| t.req.id == id) {
            debug_assert!(processed <= t.req.prompt.len());
            t.prefilled = processed;
        }
    }

    /// Mark a request's prefill complete: it joins every decode round
    /// from the next iteration (the engine appends it to the current
    /// round itself).
    pub fn prefill_done(&mut self, id: RequestId) {
        if let Some(t) = self.running.iter_mut().find(|t| t.req.id == id) {
            t.prefilled = t.req.prompt.len();
        }
    }

    /// Record one capacity-wait observation (see [`BatcherMetrics`]).
    fn note_capacity_wait(&mut self) {
        let depth = self.waiting.len();
        self.metrics.capacity_waits += 1;
        self.metrics.last_wait_depth = depth;
        self.metrics.max_wait_depth = self.metrics.max_wait_depth.max(depth);
    }

    /// Whether the `waiting_served_ratio` admission wave is open.
    /// Evaluated once per iteration so a wave, once open, admits every
    /// request capacity allows instead of shrinking as it admits.
    fn wave_open(&self) -> bool {
        let ratio = self.cfg.waiting_served_ratio;
        ratio <= 0.0
            || self.running.is_empty()
            || self.waiting.len() as f32 >= ratio * self.running.len() as f32
    }

    /// Compute the next scheduling decision. In-flight chunked prefills
    /// continue first (head-of-line never stalls), then FIFO waiting
    /// requests are admitted while slots and both token budgets allow;
    /// a deferred admission is recorded in [`BatcherMetrics`] so
    /// starvation is observable. The KV charge is each running
    /// request's full *reservation* (`prompt + max_new_tokens`), never
    /// its current context — headroom promised to a running request is
    /// promised once.
    pub fn schedule(&mut self) -> SchedDecision {
        self.schedule_gated(true)
    }

    /// [`Self::schedule`] with an external admission gate: when `admit`
    /// is false (the engine is under memory pressure), no waiting
    /// request is admitted this iteration — running requests still get
    /// their prefill grants and decode step, and the deferred admission
    /// is recorded as a capacity wait.
    pub fn schedule_gated(&mut self, admit: bool) -> SchedDecision {
        let mut d = SchedDecision::default();
        let chunk = self.cfg.prefill_chunk;
        let align = self.cfg.chunk_align.max(1);
        let mut budget = self.cfg.max_batch_prefill_tokens;

        // 1. Continue in-flight chunked prefills in running order. The
        //    first one is the head of the line: it always progresses at
        //    least one aligned chunk even when the per-iteration
        //    prefill budget is smaller — a stalled head would wedge the
        //    whole batch.
        for t in &self.running {
            let remaining = t.req.prompt.len().saturating_sub(t.prefilled);
            if remaining == 0 {
                continue;
            }
            let cap = if d.prefill.is_empty() { budget.max(align) } else { budget };
            let tokens = clip_grant(remaining, chunk, cap, align);
            if tokens == 0 {
                continue;
            }
            budget = budget.saturating_sub(tokens);
            d.prefill.push(PrefillGrant {
                id: t.req.id,
                tokens,
                admitted: false,
            });
        }

        // 2. Admit waiting requests into whatever capacity remains.
        if !self.waiting.is_empty() {
            if !admit {
                self.note_capacity_wait(); // memory-pressure wait
            } else if self.wave_open() {
                self.admit_waiting(&mut d, chunk, align, &mut budget);
            }
            // else: intentional waiting_served_ratio wave — not a
            // capacity wait.
        }

        // 3. Decode round: every fully prefilled running request.
        d.decode = self
            .running
            .iter()
            .filter(|t| t.prefilled >= t.req.prompt.len())
            .map(|t| t.req.id)
            .collect();
        d
    }

    /// Admission loop of [`Self::schedule_gated`] — FIFO while the slot
    /// cap, the KV reservation budget, and the per-iteration prefill
    /// budget all allow. An empty engine always admits its head request
    /// whatever the budgets say: an oversized request must degrade to
    /// solo execution, never deadlock.
    fn admit_waiting(
        &mut self,
        d: &mut SchedDecision,
        chunk: usize,
        align: usize,
        budget: &mut usize,
    ) {
        while !self.waiting.is_empty() {
            if self.running.len() >= self.cfg.max_running {
                self.note_capacity_wait(); // slot-cap wait
                break;
            }
            let (head_reserved, head_prompt) = {
                let h = self.waiting.front().expect("checked non-empty");
                (h.reserved, h.req.prompt.len())
            };
            if self.reserved_tokens() + head_reserved
                > self.cfg.max_batch_total_tokens
                && !self.running.is_empty()
            {
                self.note_capacity_wait(); // KV-budget wait
                break;
            }
            let engine_empty = self.running.is_empty() && d.prefill.is_empty();
            let tokens = if chunk == 0 {
                // Whole-prompt grants (non-resumable prefill): admit
                // only if the entire prompt fits this iteration's
                // prefill budget.
                if head_prompt <= *budget || engine_empty {
                    head_prompt
                } else {
                    0
                }
            } else {
                let cap = if engine_empty { (*budget).max(align) } else { *budget };
                clip_grant(head_prompt, chunk, cap, align)
            };
            if tokens == 0 {
                self.note_capacity_wait(); // prefill-budget wait
                break;
            }
            let Some(t) = self.waiting.pop_front() else { break };
            *budget = budget.saturating_sub(tokens);
            d.prefill.push(PrefillGrant { id: t.req.id, tokens, admitted: true });
            self.running.push(t);
        }
    }

    /// Record one generated token for a running request.
    pub fn on_token(&mut self, id: RequestId) {
        if let Some(t) = self.running.iter_mut().find(|t| t.req.id == id) {
            t.context += 1;
        }
    }

    /// Remove a finished request from the running set.
    pub fn finish(&mut self, id: RequestId) {
        self.running.retain(|t| t.req.id != id);
    }

    /// Remove a request wherever it lives — still waiting for admission
    /// or mid-decode in the running set. Returns whether it was tracked
    /// (the cancellation path uses this to distinguish "freed a slot"
    /// from "unknown id, nothing to do"). Frees the running slot and
    /// its token-budget share immediately: the next `schedule` can
    /// admit into the vacated capacity.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        let before = self.waiting.len() + self.running.len();
        self.waiting.retain(|t| t.req.id != id);
        self.finish(id);
        self.waiting.len() + self.running.len() < before
    }

    pub fn request(&self, id: RequestId) -> Option<&GenRequest> {
        self.running
            .iter()
            .find(|t| t.req.id == id)
            .map(|t| &t.req)
            .or_else(|| {
                self.waiting.iter().find(|t| t.req.id == id).map(|t| &t.req)
            })
    }

    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> GenRequest {
        GenRequest::new(id, vec![b'a'; prompt_len], max_new)
    }

    fn cfg(max_running: usize, total: usize) -> BatcherConfig {
        BatcherConfig {
            max_running,
            max_batch_total_tokens: total,
            max_batch_prefill_tokens: 100_000,
            prefill_chunk: 0,
            waiting_served_ratio: 0.0,
            chunk_align: 1,
        }
    }

    fn batcher(max_running: usize, total: usize) -> Batcher {
        Batcher::new(cfg(max_running, total))
    }

    fn ids(d: &SchedDecision) -> Vec<RequestId> {
        d.prefill.iter().map(|g| g.id).collect()
    }

    #[test]
    fn fifo_admission_merges_continuously() {
        let mut b = batcher(4, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        let d1 = b.schedule();
        assert_eq!(ids(&d1), vec![1, 2], "capacity admits both in one wave");
        assert!(d1.prefill.iter().all(|g| g.admitted && g.tokens == 10));
        assert!(d1.decode.is_empty(), "nothing fully prefilled yet");
        b.prefill_done(1);
        b.prefill_done(2);
        let d2 = b.schedule();
        assert!(d2.prefill.is_empty());
        assert_eq!(d2.decode, vec![1, 2]);
    }

    #[test]
    fn respects_running_cap() {
        let mut b = batcher(1, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        let d = b.schedule();
        assert_eq!(ids(&d), vec![1]);
        b.prefill_done(1);
        let d = b.schedule();
        assert!(d.prefill.is_empty());
        assert_eq!(b.waiting_len(), 1);
        b.finish(1);
        assert_eq!(ids(&b.schedule()), vec![2]);
    }

    #[test]
    fn respects_token_budget() {
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 20)); // reserves 70
        b.submit(req(2, 40, 20)); // reserves 60 -> exceeds with #1 running
        let d = b.schedule();
        assert_eq!(ids(&d), vec![1], "budget must defer #2");
        b.prefill_done(1);
        assert!(b.schedule().prefill.is_empty(), "still deferred");
        b.finish(1);
        assert_eq!(ids(&b.schedule()), vec![2]);
    }

    #[test]
    fn capacity_waits_are_observable() {
        // Budget wait: #2 deferred while #1 holds the budget.
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 20));
        b.submit(req(2, 40, 20));
        b.schedule(); // admits #1, defers #2 in the same iteration
        assert_eq!(b.metrics.capacity_waits, 1);
        assert_eq!(b.metrics.last_wait_depth, 1);
        b.prefill_done(1);
        b.schedule();
        assert_eq!(b.metrics.capacity_waits, 2, "every deferred iteration counts");
        assert_eq!(b.metrics.max_wait_depth, 1);
        b.finish(1);
        b.schedule();
        assert_eq!(b.metrics.capacity_waits, 2, "admission clears the wait");

        // Slot-cap wait with a deeper queue.
        let mut b = batcher(1, 10_000);
        for id in 0..4 {
            b.submit(req(id, 10, 5));
        }
        b.schedule(); // admits #0; slot cap defers the other 3
        assert_eq!(b.metrics.capacity_waits, 1);
        assert_eq!(b.metrics.last_wait_depth, 3);
        assert_eq!(b.metrics.max_wait_depth, 3);
    }

    #[test]
    fn cancel_frees_slot_and_waiting_entry() {
        let mut b = batcher(1, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        b.schedule(); // #1 running, #2 waiting
        assert!(b.cancel(2), "waiting request is tracked");
        assert_eq!(b.waiting_len(), 0);
        assert!(b.cancel(1), "running request is tracked");
        assert_eq!(b.running_len(), 0);
        assert!(b.idle());
        assert!(!b.cancel(1), "already gone");
        assert!(!b.cancel(99), "unknown id");
    }

    #[test]
    fn cancel_releases_capacity_for_admission() {
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 20)); // holds 70 of the 100 budget
        b.submit(req(2, 40, 20)); // needs 60 -> deferred
        b.schedule();
        assert!(b.schedule().prefill.is_empty(), "budget must defer #2");
        b.cancel(1);
        assert_eq!(ids(&b.schedule()), vec![2], "cancel freed the budget");
    }

    #[test]
    fn budget_reserves_decode_headroom_of_running_requests() {
        // Regression: admission used to recompute usage from *current*
        // context, handing out generation headroom already promised to
        // a running request and overshooting the budget mid-decode.
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 30)); // reserves 80
        b.schedule();
        b.prefill_done(1);
        // 10 decode steps: context grows 50 -> 60, but the reservation
        // stays 80 (context + remaining allowance is constant).
        for _ in 0..10 {
            b.on_token(1);
        }
        assert_eq!(b.reserved_tokens(), 80);
        b.submit(req(2, 10, 15)); // needs 25; 80 + 25 > 100
        let d = b.schedule();
        assert!(d.prefill.is_empty(), "headroom promised to #1 stays his");
        b.finish(1);
        assert_eq!(ids(&b.schedule()), vec![2]);
    }

    #[test]
    fn preempt_returns_running_to_waiting_front_and_resets_prefill() {
        let mut b = batcher(4, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        let d = b.schedule();
        for g in &d.prefill {
            b.prefill_done(g.id);
        }
        b.submit(req(3, 10, 5));
        assert!(!b.preempt(99), "unknown id");
        assert!(!b.preempt(3), "waiting request cannot be preempted");
        assert!(b.preempt(2));
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.waiting_len(), 2);
        // The preempted request resumes before later arrivals, and
        // resumes by re-prefilling its whole prompt.
        let d = b.schedule();
        assert_eq!(ids(&d), vec![2, 3]);
        assert_eq!(d.prefill[0].tokens, 10, "resume re-prefills from scratch");
        assert!(!d.decode.contains(&2), "not decodable until re-prefilled");
    }

    #[test]
    fn preemption_victim_prefers_cheapest_replay() {
        let mut b = batcher(4, 1000);
        b.submit(req(1, 10, 8));
        b.submit(req(2, 10, 8));
        let d = b.schedule();
        for g in &d.prefill {
            b.prefill_done(g.id);
        }
        // #1 is older but has generated less: 1 token vs #2's 5. LIFO
        // would evict #2 and throw away five replayable tokens; the
        // cost rule picks #1.
        b.on_token(1);
        for _ in 0..5 {
            b.on_token(2);
        }
        assert_eq!(b.preemption_victim(), Some(1));
        // Ties fall back to LIFO: equalize the replay cost and the
        // youngest goes, as before.
        for _ in 0..4 {
            b.on_token(1);
        }
        assert_eq!(b.preemption_victim(), Some(2));
    }

    #[test]
    fn long_prefill_streams_in_chunks_while_batchmates_decode() {
        let mut b = Batcher::new(BatcherConfig {
            max_running: 4,
            max_batch_total_tokens: 10_000,
            max_batch_prefill_tokens: 8,
            prefill_chunk: 4,
            waiting_served_ratio: 0.0,
            chunk_align: 4,
        });
        b.submit(req(1, 4, 4));
        let d = b.schedule();
        assert_eq!(ids(&d), vec![1]);
        b.prefill_done(1);
        b.submit(req(2, 10, 4)); // long prompt: chunks of 4
        let d = b.schedule();
        assert_eq!(ids(&d), vec![2]);
        assert_eq!(d.prefill[0].tokens, 4);
        assert_eq!(d.decode, vec![1], "mate decodes while the prompt streams");
        b.prefill_progress(2, 4);
        let d = b.schedule();
        assert_eq!(ids(&d), vec![2]);
        assert!(!d.prefill[0].admitted, "continuation, not admission");
        assert_eq!(d.prefill[0].tokens, 4);
        assert_eq!(d.decode, vec![1]);
        b.prefill_progress(2, 8);
        let d = b.schedule();
        assert_eq!(d.prefill[0].tokens, 2, "final partial chunk");
        assert_eq!(d.decode, vec![1]);
        b.prefill_done(2);
        let d = b.schedule();
        assert!(d.prefill.is_empty());
        assert_eq!(d.decode, vec![1, 2]);
    }

    #[test]
    fn prefill_budget_rations_grants_per_iteration() {
        let mut b = Batcher::new(BatcherConfig {
            max_running: 4,
            max_batch_total_tokens: 10_000,
            max_batch_prefill_tokens: 8,
            prefill_chunk: 8,
            waiting_served_ratio: 0.0,
            chunk_align: 4,
        });
        b.submit(req(1, 8, 4));
        b.submit(req(2, 8, 4));
        let d = b.schedule();
        assert_eq!(ids(&d), vec![1], "8-token budget fits one 8-token grant");
        assert_eq!(b.metrics.capacity_waits, 1, "deferred grant is observable");
        b.prefill_done(1);
        let d = b.schedule();
        assert_eq!(ids(&d), vec![2]);
    }

    #[test]
    fn waiting_served_ratio_batches_admission_waves() {
        let mut b = Batcher::new(BatcherConfig {
            waiting_served_ratio: 2.0,
            ..cfg(8, 10_000)
        });
        b.submit(req(1, 10, 5));
        let d = b.schedule();
        assert_eq!(ids(&d), vec![1], "empty batch admits immediately");
        b.prefill_done(1);
        b.submit(req(2, 10, 5));
        let d = b.schedule();
        assert!(d.prefill.is_empty(), "1 waiting < ratio 2.0 x 1 running");
        assert_eq!(d.decode, vec![1], "the wave delay is policy, decode runs");
        assert_eq!(b.metrics.capacity_waits, 0, "a wave is not a capacity wait");
        b.submit(req(3, 10, 5));
        let d = b.schedule();
        assert_eq!(ids(&d), vec![2, 3], "wave threshold reached, both join");
    }

    #[test]
    fn oversized_request_admitted_when_engine_empty() {
        // A request larger than the budget must not deadlock forever.
        let mut b = batcher(8, 100);
        b.submit(req(1, 500, 10));
        let d = b.schedule();
        assert_eq!(ids(&d), vec![1]);
        assert_eq!(d.prefill[0].tokens, 500, "whole-prompt grant");
    }

    #[test]
    fn no_starvation_and_budget_invariant() {
        prop::run("batcher invariants", 40, |g| {
            let budget = g.usize_in(64, 512);
            let max_running = g.usize_in(1, 8);
            let chunk =
                if g.rng.bool(0.5) { 0 } else { g.usize_in(1, 6) * 4 };
            let mut b = Batcher::new(BatcherConfig {
                max_running,
                max_batch_total_tokens: budget,
                max_batch_prefill_tokens: g.usize_in(4, 64),
                prefill_chunk: chunk,
                waiting_served_ratio: 0.0,
                chunk_align: 4,
            });
            let n = g.usize_in(1, 30);
            for id in 0..n as u64 {
                b.submit(req(id, g.usize_in(1, 64), g.usize_in(1, 32)));
            }
            let mut progress = std::collections::HashMap::new();
            let mut completed = std::collections::HashSet::new();
            let mut iterations = 0;
            while !b.idle() {
                iterations += 1;
                assert!(iterations < 10_000, "livelock");
                let d = b.schedule();
                assert!(b.running_len() <= max_running);
                // Reservation invariant: beyond the single oversized-
                // request escape hatch, admitted reservations never
                // exceed the budget (the double-allocation regression).
                if b.running_len() >= 2 {
                    assert!(
                        b.reserved_tokens() <= budget,
                        "reserved {} > budget {budget}",
                        b.reserved_tokens()
                    );
                }
                // Drive each grant the way the engine does: accumulate
                // progress, complete when the prompt is covered.
                for grant in &d.prefill {
                    assert!(grant.tokens > 0, "empty grant");
                    let len = b.request(grant.id).unwrap().prompt.len();
                    let done = progress.entry(grant.id).or_insert(0usize);
                    *done += grant.tokens;
                    assert!(*done <= len, "grant overshoots the prompt");
                }
                for grant in &d.prefill {
                    let len = b.request(grant.id).unwrap().prompt.len();
                    let done = progress[&grant.id];
                    if done == len {
                        b.prefill_done(grant.id);
                    } else {
                        b.prefill_progress(grant.id, done);
                    }
                }
                // Every decode round makes progress: finish each
                // running request with probability ~1/4.
                for id in d.decode {
                    b.on_token(id);
                    if g.rng.bool(0.25) {
                        b.finish(id);
                        completed.insert(id);
                        progress.remove(&id);
                    }
                }
            }
            assert_eq!(completed.len(), n, "all requests complete");
        });
    }
}
