//! Iteration-level continuous batcher (Orca-style).
//!
//! Each scheduler iteration produces a [`SchedDecision`]: which waiting
//! request to prefill (admission control under a token budget and a
//! running-slot cap) and which running requests get a decode step.
//! FIFO within each class; prefills are admitted before the decode round
//! so a new request's first token is not starved by a long decode queue
//! (the paper's latency numbers assume prefill priority at low load).

use std::collections::VecDeque;

use super::request::{GenRequest, RequestId};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests in the decode round (running slots).
    pub max_running: usize,
    /// Max total context tokens across running requests (KV memory cap —
    /// the CPU analogue of the HBM budget in `costmodel::max_batch`).
    pub token_budget: usize,
    /// Max prefills admitted per iteration.
    pub prefill_per_step: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_running: 8, token_budget: 4096, prefill_per_step: 1 }
    }
}

/// Internal per-request accounting.
#[derive(Debug, Clone)]
struct Tracked {
    req: GenRequest,
    /// Current context tokens (prompt + generated so far).
    context: usize,
    /// Token-budget reservation: `prompt + max_new_tokens`. Constant
    /// over the request's life (context grows by exactly one as the
    /// remaining allowance shrinks by one), so summing it never
    /// re-grants headroom already promised to a running request — the
    /// fix for the double-allocation bug where `schedule` recomputed
    /// usage from *current* context mid-decode.
    reserved: usize,
}

/// One scheduling decision.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct SchedDecision {
    /// Requests to prefill this iteration (moved to running on success).
    pub prefill: Vec<RequestId>,
    /// Requests receiving one decode step this iteration.
    pub decode: Vec<RequestId>,
}

/// Starvation observability: how often (and how deep) admission had to
/// wait for capacity. A silently deep waiting queue was previously
/// invisible — these counters make the capacity-wait branch a metric.
#[derive(Debug, Default, Clone)]
pub struct BatcherMetrics {
    /// Scheduler iterations that deferred admission because the token
    /// budget or running-slot cap was exhausted (with work waiting).
    pub capacity_waits: u64,
    /// Waiting-queue depth at the most recent capacity wait.
    pub last_wait_depth: usize,
    /// Deepest waiting queue seen at any capacity wait.
    pub max_wait_depth: usize,
}

/// The continuous batcher: waiting queue + running set.
pub struct Batcher {
    pub cfg: BatcherConfig,
    pub metrics: BatcherMetrics,
    waiting: VecDeque<Tracked>,
    running: Vec<Tracked>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            metrics: BatcherMetrics::default(),
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        let context = req.prompt.len();
        let reserved = context + req.params.max_new_tokens;
        self.waiting.push_back(Tracked { req, context, reserved });
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Total context tokens held by running requests.
    pub fn running_tokens(&self) -> usize {
        self.running.iter().map(|t| t.context).sum()
    }

    /// Total token-budget reservation held by running requests
    /// (`prompt + max_new_tokens` each) — what admission charges
    /// against, not the smaller current context.
    pub fn reserved_tokens(&self) -> usize {
        self.running.iter().map(|t| t.reserved).sum()
    }

    /// The most recently admitted running request — the preemption
    /// victim (LIFO: preempting the youngest wastes the least completed
    /// work and cannot starve the head of the line).
    pub fn youngest_running(&self) -> Option<RequestId> {
        self.running.last().map(|t| t.req.id)
    }

    /// Move a running request back to the *front* of the waiting queue
    /// (it keeps its FIFO seniority over later arrivals). The engine
    /// owns the session-state side: it must release the request's pages
    /// and re-prefill on resume. Returns whether the id was running.
    pub fn preempt(&mut self, id: RequestId) -> bool {
        let Some(i) = self.running.iter().position(|t| t.req.id == id) else {
            return false;
        };
        let t = self.running.remove(i);
        self.waiting.push_front(t);
        true
    }

    /// Record one capacity-wait observation (see [`BatcherMetrics`]).
    fn note_capacity_wait(&mut self) {
        let depth = self.waiting.len();
        self.metrics.capacity_waits += 1;
        self.metrics.last_wait_depth = depth;
        self.metrics.max_wait_depth = self.metrics.max_wait_depth.max(depth);
    }

    /// Compute the next scheduling decision. Admission: FIFO waiting
    /// requests move to running while slots and token budget allow; a
    /// deferred admission is recorded in [`BatcherMetrics`] so
    /// starvation is observable. The budget charge is each running
    /// request's full *reservation* (`prompt + max_new_tokens`), never
    /// its current context — headroom promised to a running request is
    /// promised once.
    pub fn schedule(&mut self) -> SchedDecision {
        self.schedule_gated(true)
    }

    /// [`Self::schedule`] with an external admission gate: when `admit`
    /// is false (the engine is under memory pressure), no waiting
    /// request is admitted this iteration — running requests still get
    /// their decode step, and the deferred admission is recorded as a
    /// capacity wait.
    pub fn schedule_gated(&mut self, admit: bool) -> SchedDecision {
        let mut d = SchedDecision::default();
        if !admit {
            if !self.waiting.is_empty() {
                self.note_capacity_wait(); // memory-pressure wait
            }
            d.decode = self.running.iter().map(|t| t.req.id).collect();
            return d;
        }
        let mut budget_used = self.reserved_tokens();
        let mut admitted = 0;
        while admitted < self.cfg.prefill_per_step {
            if self.running.len() >= self.cfg.max_running {
                if !self.waiting.is_empty() {
                    self.note_capacity_wait(); // slot-cap wait
                }
                break;
            }
            let Some(head) = self.waiting.front() else { break };
            let need = head.reserved;
            if budget_used + need > self.cfg.token_budget && !self.running.is_empty()
            {
                // Wait for capacity (never deadlock an empty engine) —
                // and make the wait observable instead of silent.
                self.note_capacity_wait();
                break;
            }
            // Checked pop: the head we just inspected must still be
            // there, but a silent `.unwrap()` on that assumption was the
            // one panic path in the scheduler — fail soft instead.
            let Some(t) = self.waiting.pop_front() else { break };
            budget_used += need;
            d.prefill.push(t.req.id);
            self.running.push(t);
            admitted += 1;
        }
        d.decode = self.running.iter().map(|t| t.req.id).collect();
        d
    }

    /// Record one generated token for a running request.
    pub fn on_token(&mut self, id: RequestId) {
        if let Some(t) = self.running.iter_mut().find(|t| t.req.id == id) {
            t.context += 1;
        }
    }

    /// Remove a finished request from the running set.
    pub fn finish(&mut self, id: RequestId) {
        self.running.retain(|t| t.req.id != id);
    }

    /// Remove a request wherever it lives — still waiting for admission
    /// or mid-decode in the running set. Returns whether it was tracked
    /// (the cancellation path uses this to distinguish "freed a slot"
    /// from "unknown id, nothing to do"). Frees the running slot and
    /// its token-budget share immediately: the next `schedule` can
    /// admit into the vacated capacity.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        let before = self.waiting.len() + self.running.len();
        self.waiting.retain(|t| t.req.id != id);
        self.finish(id);
        self.waiting.len() + self.running.len() < before
    }

    pub fn request(&self, id: RequestId) -> Option<&GenRequest> {
        self.running
            .iter()
            .find(|t| t.req.id == id)
            .map(|t| &t.req)
            .or_else(|| {
                self.waiting.iter().find(|t| t.req.id == id).map(|t| &t.req)
            })
    }

    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> GenRequest {
        GenRequest::new(id, vec![b'a'; prompt_len], max_new)
    }

    fn batcher(max_running: usize, budget: usize) -> Batcher {
        Batcher::new(BatcherConfig {
            max_running,
            token_budget: budget,
            prefill_per_step: 1,
        })
    }

    #[test]
    fn fifo_admission() {
        let mut b = batcher(4, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        let d1 = b.schedule();
        assert_eq!(d1.prefill, vec![1]);
        assert_eq!(d1.decode, vec![1]);
        let d2 = b.schedule();
        assert_eq!(d2.prefill, vec![2]);
        assert_eq!(d2.decode, vec![1, 2]);
    }

    #[test]
    fn respects_running_cap() {
        let mut b = batcher(1, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        b.schedule();
        let d = b.schedule();
        assert!(d.prefill.is_empty());
        assert_eq!(b.waiting_len(), 1);
        b.finish(1);
        let d = b.schedule();
        assert_eq!(d.prefill, vec![2]);
    }

    #[test]
    fn respects_token_budget() {
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 20)); // needs 70
        b.submit(req(2, 40, 20)); // needs 60 -> exceeds with #1 running
        b.schedule();
        let d = b.schedule();
        assert!(d.prefill.is_empty(), "budget must defer #2");
        b.finish(1);
        assert_eq!(b.schedule().prefill, vec![2]);
    }

    #[test]
    fn capacity_waits_are_observable() {
        // Budget wait: #2 deferred while #1 holds the budget.
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 20));
        b.submit(req(2, 40, 20));
        b.schedule();
        assert_eq!(b.metrics.capacity_waits, 0, "no wait while admitting");
        b.schedule();
        assert_eq!(b.metrics.capacity_waits, 1);
        assert_eq!(b.metrics.last_wait_depth, 1);
        b.schedule();
        assert_eq!(b.metrics.capacity_waits, 2, "every deferred iteration counts");
        assert_eq!(b.metrics.max_wait_depth, 1);
        b.finish(1);
        b.schedule();
        assert_eq!(b.metrics.capacity_waits, 2, "admission clears the wait");

        // Slot-cap wait with a deeper queue.
        let mut b = batcher(1, 10_000);
        for id in 0..4 {
            b.submit(req(id, 10, 5));
        }
        b.schedule(); // admits #0
        b.schedule(); // slots full, 3 waiting
        assert_eq!(b.metrics.capacity_waits, 1);
        assert_eq!(b.metrics.last_wait_depth, 3);
        assert_eq!(b.metrics.max_wait_depth, 3);
    }

    #[test]
    fn cancel_frees_slot_and_waiting_entry() {
        let mut b = batcher(1, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        b.schedule(); // #1 running, #2 waiting
        assert!(b.cancel(2), "waiting request is tracked");
        assert_eq!(b.waiting_len(), 0);
        assert!(b.cancel(1), "running request is tracked");
        assert_eq!(b.running_len(), 0);
        assert!(b.idle());
        assert!(!b.cancel(1), "already gone");
        assert!(!b.cancel(99), "unknown id");
    }

    #[test]
    fn cancel_releases_capacity_for_admission() {
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 20)); // holds 70 of the 100 budget
        b.submit(req(2, 40, 20)); // needs 60 -> deferred
        b.schedule();
        assert!(b.schedule().prefill.is_empty(), "budget must defer #2");
        b.cancel(1);
        assert_eq!(b.schedule().prefill, vec![2], "cancel freed the budget");
    }

    #[test]
    fn budget_reserves_decode_headroom_of_running_requests() {
        // Regression: admission used to recompute usage from *current*
        // context, handing out generation headroom already promised to
        // a running request and overshooting the budget mid-decode.
        let mut b = batcher(8, 100);
        b.submit(req(1, 50, 30)); // reserves 80
        b.schedule();
        // 10 decode steps: context grows 50 -> 60, but the reservation
        // stays 80 (context + remaining allowance is constant).
        for _ in 0..10 {
            b.on_token(1);
        }
        assert_eq!(b.reserved_tokens(), 80);
        b.submit(req(2, 10, 15)); // needs 25; 80 + 25 > 100
        let d = b.schedule();
        assert!(d.prefill.is_empty(), "headroom promised to #1 stays his");
        b.finish(1);
        assert_eq!(b.schedule().prefill, vec![2]);
    }

    #[test]
    fn preempt_returns_running_to_waiting_front() {
        let mut b = batcher(4, 1000);
        b.submit(req(1, 10, 5));
        b.submit(req(2, 10, 5));
        b.schedule();
        b.schedule(); // both running
        b.submit(req(3, 10, 5));
        assert_eq!(b.youngest_running(), Some(2));
        assert!(b.preempt(2));
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.waiting_len(), 2);
        // The preempted request resumes before later arrivals.
        let d = b.schedule();
        assert_eq!(d.prefill, vec![2]);
        assert!(!b.preempt(99), "unknown id");
        assert!(!b.preempt(3), "waiting request cannot be preempted");
    }

    #[test]
    fn gated_schedule_defers_admission_under_pressure() {
        let mut b = batcher(4, 1000);
        b.submit(req(1, 10, 5));
        b.schedule(); // #1 running
        b.submit(req(2, 10, 5));
        let d = b.schedule_gated(false);
        assert!(d.prefill.is_empty(), "gate closed");
        assert_eq!(d.decode, vec![1], "decode continues under pressure");
        assert_eq!(b.metrics.capacity_waits, 1, "gated wait is observable");
        assert_eq!(b.schedule_gated(true).prefill, vec![2]);
    }

    #[test]
    fn oversized_request_admitted_when_engine_empty() {
        // A request larger than the budget must not deadlock forever.
        let mut b = batcher(8, 100);
        b.submit(req(1, 500, 10));
        let d = b.schedule();
        assert_eq!(d.prefill, vec![1]);
    }

    #[test]
    fn no_starvation_and_budget_invariant() {
        prop::run("batcher invariants", 40, |g| {
            let budget = g.usize_in(64, 512);
            let max_running = g.usize_in(1, 8);
            let mut b = Batcher::new(BatcherConfig {
                max_running,
                token_budget: budget,
                prefill_per_step: g.usize_in(1, 3),
            });
            let n = g.usize_in(1, 30);
            for id in 0..n as u64 {
                b.submit(req(id, g.usize_in(1, 64), g.usize_in(1, 32)));
            }
            let mut completed = std::collections::HashSet::new();
            let mut iterations = 0;
            while !b.idle() {
                iterations += 1;
                assert!(iterations < 10_000, "livelock");
                let d = b.schedule();
                assert!(b.running_len() <= max_running);
                // Reservation invariant: beyond the single oversized-
                // request escape hatch, admitted reservations never
                // exceed the budget (the double-allocation regression).
                if b.running_len() >= 2 {
                    assert!(
                        b.reserved_tokens() <= budget,
                        "reserved {} > budget {budget}",
                        b.reserved_tokens()
                    );
                }
                // Every decode round makes progress: finish each running
                // request with probability ~1/4.
                for id in d.decode {
                    b.on_token(id);
                    if g.rng.bool(0.25) {
                        b.finish(id);
                        completed.insert(id);
                    }
                }
            }
            assert_eq!(completed.len(), n, "all requests complete");
        });
    }
}
