//! Bit packing for q2 codes: 2x INT4 or 4x INT2 per byte.
//!
//! The unpacked `AsymBlock.codes` (one code per byte) is convenient for
//! compute; the KV cache stores this packed form so the claimed memory
//! savings (4.4x+ over FP16) are real, not simulated. Unpacking is on the
//! decode hot path and is optimized in the perf pass (see kvcache::page).

use super::Bits;

/// Bit-packed code storage.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    pub bits: Bits,
    pub n: usize,
    pub bytes: Vec<u8>,
}

/// Pack codes (each in [0, 2^bits-1]) into bytes, little-end first.
pub fn pack_codes(codes: &[u8], bits: Bits) -> PackedCodes {
    let n = codes.len();
    let mut bytes = vec![0u8; bits.packed_bytes(n)];
    match bits {
        Bits::Int8 => bytes.copy_from_slice(codes),
        Bits::Int4 => {
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c <= 15);
                bytes[i / 2] |= (c & 0xF) << ((i % 2) * 4);
            }
        }
        Bits::Int2 => {
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c <= 3);
                bytes[i / 4] |= (c & 0x3) << ((i % 4) * 2);
            }
        }
        Bits::Int3 => {
            // 3-bit codes packed contiguously (used only by the 3-bit
            // baseline comparison; not on the hot path).
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c <= 7);
                let bit = i * 3;
                let (byte, off) = (bit / 8, bit % 8);
                bytes[byte] |= (c & 0x7) << off;
                if off > 5 {
                    bytes[byte + 1] |= (c & 0x7) >> (8 - off);
                }
            }
        }
    }
    PackedCodes { bits, n, bytes }
}

/// Unpack back to one-code-per-byte.
pub fn unpack_codes(p: &PackedCodes) -> Vec<u8> {
    let mut out = vec![0u8; p.n];
    unpack_codes_into(p, &mut out);
    out
}

/// Unpack into a caller-provided buffer (hot path: avoids allocation).
pub fn unpack_codes_into(p: &PackedCodes, out: &mut [u8]) {
    assert_eq!(out.len(), p.n);
    match p.bits {
        Bits::Int8 => out.copy_from_slice(&p.bytes),
        Bits::Int4 => {
            // SWAR-ish: two codes per byte.
            let mut i = 0;
            for &b in &p.bytes {
                if i < p.n {
                    out[i] = b & 0xF;
                    i += 1;
                }
                if i < p.n {
                    out[i] = b >> 4;
                    i += 1;
                }
            }
        }
        Bits::Int2 => {
            let mut i = 0;
            for &b in &p.bytes {
                for k in 0..4 {
                    if i < p.n {
                        out[i] = (b >> (k * 2)) & 0x3;
                        i += 1;
                    }
                }
            }
        }
        Bits::Int3 => {
            for (i, o) in out.iter_mut().enumerate() {
                let bit = i * 3;
                let (byte, off) = (bit / 8, bit % 8);
                let mut v = (p.bytes[byte] >> off) as u16;
                if off > 5 {
                    v |= (p.bytes[byte + 1] as u16) << (8 - off);
                }
                *o = (v & 0x7) as u8;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn roundtrip_all_widths() {
        prop::run("pack roundtrip", 100, |g| {
            let bits = *g.choose(&[Bits::Int2, Bits::Int3, Bits::Int4, Bits::Int8]);
            let n = g.usize_in(0, 300);
            let max = bits.levels() as u8;
            let codes: Vec<u8> =
                (0..n).map(|_| (g.rng.next_u64() % (max as u64 + 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            assert_eq!(packed.bytes.len(), bits.packed_bytes(n));
            assert_eq!(unpack_codes(&packed), codes);
        });
    }

    #[test]
    fn int4_known_layout() {
        let p = pack_codes(&[0x1, 0x2, 0x3], Bits::Int4);
        assert_eq!(p.bytes, vec![0x21, 0x03]);
    }

    #[test]
    fn int2_known_layout() {
        let p = pack_codes(&[0b01, 0b10, 0b11, 0b00, 0b01], Bits::Int2);
        assert_eq!(p.bytes, vec![0b00111001, 0b01]);
    }

    #[test]
    fn compression_ratio() {
        let codes = vec![1u8; 128];
        assert_eq!(pack_codes(&codes, Bits::Int4).bytes.len(), 64);
        assert_eq!(pack_codes(&codes, Bits::Int2).bytes.len(), 32);
        assert_eq!(pack_codes(&codes, Bits::Int3).bytes.len(), 48);
    }
}
