//! Progressive step: asymmetric INT4/INT2 channelwise compression of an
//! INT8 block (paper Eq. 7/8/10; mirrors `ref.quant_asym_int` bit-exact).
//!
//! The q1 block is `[tokens, channels]` row-major; each *channel* gets an
//! integer scale `s_int >= 1` and zero point `z_int`, both fitting INT8.
//! Compression and decompression are pure integer arithmetic — this is
//! what lets the paper's decode path skip floating-point dequantization.

use super::Bits;

/// An asymmetrically-compressed block at q2 level.
#[derive(Debug, Clone, PartialEq)]
pub struct AsymBlock {
    pub bits: Bits,
    pub tokens: usize,
    pub channels: usize,
    /// Codes in [0, 2^bits - 1], one per (token, channel), row-major.
    /// Held unpacked (one code per byte) here; [`super::pack`] handles the
    /// bit-packed storage representation.
    pub codes: Vec<u8>,
    /// Per-channel integer scale (>= 1).
    pub s_int: Vec<i32>,
    /// Per-channel integer zero point (floor(min / s_int)).
    pub z_int: Vec<i32>,
}

/// Compress an INT8 block channelwise to `bits` (q1 -> q2).
///
/// `q1` is `[tokens, channels]` row-major.
pub fn quant_asym_int(q1: &[i8], tokens: usize, channels: usize, bits: Bits) -> AsymBlock {
    assert_eq!(q1.len(), tokens * channels);
    let levels = bits.levels();
    let mut s_int = vec![1i32; channels];
    let mut z_int = vec![0i32; channels];
    for c in 0..channels {
        let mut cmin = i32::MAX;
        let mut cmax = i32::MIN;
        for t in 0..tokens {
            let v = q1[t * channels + c] as i32;
            cmin = cmin.min(v);
            cmax = cmax.max(v);
        }
        if tokens == 0 {
            cmin = 0;
            cmax = 0;
        }
        let s = ((cmax - cmin + levels - 1).div_euclid(levels)).max(1);
        s_int[c] = s;
        z_int[c] = cmin.div_euclid(s);
    }
    let mut codes = vec![0u8; tokens * channels];
    for t in 0..tokens {
        for c in 0..channels {
            let v = q1[t * channels + c] as i32;
            let s = s_int[c];
            // Round-to-nearest: floor((2v + s) / (2s)), valid for signed v
            // (matches the jnp oracle's floor_divide form).
            let rounded = (2 * v + s).div_euclid(2 * s);
            codes[t * channels + c] =
                (rounded - z_int[c]).clamp(0, levels) as u8;
        }
    }
    AsymBlock { bits, tokens, channels, codes, s_int, z_int }
}

/// Integer q2 -> q1 decompression (paper Algorithm 2 Step 2 — the decode
/// hot path; see also the optimized batched form in `kvcache`).
pub fn dequant_asym_int(b: &AsymBlock) -> Vec<i8> {
    let mut q1 = vec![0i8; b.tokens * b.channels];
    for t in 0..b.tokens {
        let row = &b.codes[t * b.channels..(t + 1) * b.channels];
        let out = &mut q1[t * b.channels..(t + 1) * b.channels];
        for c in 0..b.channels {
            let v = (row[c] as i32 + b.z_int[c]) * b.s_int[c];
            out[c] = v.clamp(-127, 127) as i8;
        }
    }
    q1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sym::quant_sym_int8;
    use crate::testutil::prop;

    fn rand_q1(g: &mut crate::testutil::prop::Gen, t: usize, c: usize) -> Vec<i8> {
        let x = g.normal_vec(t * c, 2.0);
        quant_sym_int8(&x).codes
    }

    #[test]
    fn codes_in_range() {
        prop::run("asym codes in range", 80, |g| {
            let t = g.usize_in(1, 40);
            let c = g.usize_in(1, 24);
            let bits = *g.choose(&[Bits::Int2, Bits::Int3, Bits::Int4]);
            let q1 = rand_q1(g, t, c);
            let b = quant_asym_int(&q1, t, c, bits);
            assert!(b.codes.iter().all(|&v| (v as i32) <= bits.levels()));
            assert!(b.s_int.iter().all(|&s| (1..=255).contains(&s)));
        });
    }

    #[test]
    fn roundtrip_error_bounded_by_scale() {
        prop::run("asym roundtrip bound", 80, |g| {
            let t = g.usize_in(2, 40);
            let c = g.usize_in(1, 24);
            let bits = *g.choose(&[Bits::Int2, Bits::Int3, Bits::Int4]);
            let q1 = rand_q1(g, t, c);
            let b = quant_asym_int(&q1, t, c, bits);
            let back = dequant_asym_int(&b);
            for tt in 0..t {
                for cc in 0..c {
                    let e = (back[tt * c + cc] as i32
                        - q1[tt * c + cc] as i32)
                        .abs();
                    let bound = (3 * b.s_int[cc]) / 2 + 1;
                    assert!(e <= bound, "err {e} > bound {bound}");
                }
            }
        });
    }

    #[test]
    fn constant_channel_is_exact() {
        // A channel with a single repeated value must round-trip exactly.
        let q1 = vec![42i8; 8]; // 8 tokens x 1 channel
        let b = quant_asym_int(&q1, 8, 1, Bits::Int2);
        let back = dequant_asym_int(&b);
        assert!(back.iter().all(|&v| v == 42));
    }

    #[test]
    fn int4_never_worse_than_int2() {
        prop::run("int4 <= int2 error", 40, |g| {
            let t = g.usize_in(4, 40);
            let c = g.usize_in(1, 16);
            let q1 = rand_q1(g, t, c);
            let mse = |bits| {
                let b = quant_asym_int(&q1, t, c, bits);
                let back = dequant_asym_int(&b);
                q1.iter()
                    .zip(&back)
                    .map(|(&a, &b)| ((a as i32 - b as i32) as f64).powi(2))
                    .sum::<f64>()
            };
            assert!(mse(Bits::Int4) <= mse(Bits::Int2) + 1e-9);
        });
    }

    #[test]
    fn matches_known_example() {
        // Hand-checked against the jnp oracle.
        let q1: Vec<i8> = vec![-100, -50, 0, 50, 100, 119, -119, 7];
        let b = quant_asym_int(&q1, 8, 1, Bits::Int4);
        // range = 238 -> s = ceil(238/15) = 16, z = floor(-119/16) = -8
        assert_eq!(b.s_int[0], 16);
        assert_eq!(b.z_int[0], -8);
        let back = dequant_asym_int(&b);
        for (a, r) in q1.iter().zip(&back) {
            assert!((*a as i32 - *r as i32).abs() <= 8 + 1);
        }
    }

    #[test]
    fn empty_tokens_ok() {
        let b = quant_asym_int(&[], 0, 4, Bits::Int4);
        assert_eq!(b.codes.len(), 0);
        assert_eq!(dequant_asym_int(&b).len(), 0);
    }
}
