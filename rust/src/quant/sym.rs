//! Symmetric blockwise INT8 quantization (paper Eq. 9, Algorithm 1).
//!
//! Scale is `max|x| / 119` — the paper reserves headroom below 127 so the
//! online-softmax rescale can never overflow int8. Matches the jnp oracle
//! (`ref.quant_sym_int8`) bit-for-bit on the same input.

/// Symmetric quantization maps max|x| to this code (paper constant).
pub const INT8_QMAX: f32 = 119.0;

/// One symmetrically-quantized block: INT8 codes + one f32 scale.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantBlock {
    pub codes: Vec<i8>,
    pub scale: f32,
}

/// Quantize a block of floats to INT8 with a single symmetric scale.
pub fn quant_sym_int8(x: &[f32]) -> QuantBlock {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = (amax / INT8_QMAX).max(1e-8);
    let codes = x
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    QuantBlock { codes, scale }
}

/// Dequantize back to f32 (oracle/tests; the hot path never does this —
/// it multiplies the INT32 dot product by the scale product instead).
pub fn dequant_sym_int8(q: &QuantBlock) -> Vec<f32> {
    q.codes.iter().map(|&c| c as f32 * q.scale).collect()
}

/// Quantize into a caller-owned buffer, returning the scale — §Perf: the
/// decode hot path quantizes a score tile per cache block per head per
/// token, and this variant makes that allocation-free once the buffer is
/// warm (`clear` + `extend` reuses capacity).
pub fn quant_sym_int8_into(x: &[f32], codes: &mut Vec<i8>) -> f32 {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = (amax / INT8_QMAX).max(1e-8);
    codes.clear();
    codes.extend(
        x.iter().map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8),
    );
    scale
}

/// Quantize with a caller-fixed scale, clamping outliers — the enhanced
/// KV-buffer path (paper §3.3): a universal scale avoids re-quantizing
/// buffered tokens when a new outlier arrives.
pub fn quant_sym_int8_fixed_scale(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        prop::run("sym int8 roundtrip", 100, |g| {
            let n = g.usize_in(1, 256);
            let scale = g.f32_in(0.01, 100.0);
            let x = g.normal_vec(n, scale);
            let q = quant_sym_int8(&x);
            let back = dequant_sym_int8(&q);
            for (a, b) in x.iter().zip(&back) {
                assert!(
                    (a - b).abs() <= q.scale * 0.5 + 1e-6,
                    "err {} scale {}",
                    (a - b).abs(),
                    q.scale
                );
            }
        });
    }

    #[test]
    fn scale_is_amax_over_qmax() {
        let x = vec![-3.0, 1.0, 2.38];
        let q = quant_sym_int8(&x);
        assert!((q.scale - 3.0 / INT8_QMAX).abs() < 1e-7);
        assert_eq!(q.codes[0], -119);
    }

    #[test]
    fn zero_block_is_stable() {
        let q = quant_sym_int8(&[0.0; 16]);
        assert!(q.codes.iter().all(|&c| c == 0));
        assert!(q.scale > 0.0);
    }

    #[test]
    fn codes_never_exceed_127() {
        prop::run("codes in range", 100, |g| {
            let n = g.usize_in(1, 64);
            let x = g.normal_vec(n, 10.0);
            let q = quant_sym_int8(&x);
            assert!(q.codes.iter().all(|&c| (-127..=127).contains(&(c as i32))));
        });
    }

    #[test]
    fn into_variant_matches_allocating_and_reuses_capacity() {
        prop::run("quant into == alloc", 50, |g| {
            let n = g.usize_in(1, 128);
            let x = g.normal_vec(n, 2.0);
            let q = quant_sym_int8(&x);
            let mut codes = Vec::new();
            let scale = quant_sym_int8_into(&x, &mut codes);
            assert_eq!(codes, q.codes);
            assert!((scale - q.scale).abs() <= f32::EPSILON * q.scale);
            let cap = codes.capacity();
            let scale2 = quant_sym_int8_into(&x, &mut codes);
            assert_eq!(scale2, scale);
            assert_eq!(codes.capacity(), cap, "no reallocation on reuse");
        });
    }

    #[test]
    fn fixed_scale_clamps_outliers() {
        let codes = quant_sym_int8_fixed_scale(&[1000.0, -1000.0, 0.5], 0.01);
        assert_eq!(codes[0], 127);
        assert_eq!(codes[1], -127);
        assert_eq!(codes[2], 50);
    }
}
