//! FlashQ quantization substrate (paper §3), mirrored in Rust.
//!
//! This is the Rust-side twin of `python/compile/kernels/quant.py` /
//! `ref.py`: the Rust coordinator owns the q2-level (INT4/INT2 packed)
//! KV cache, so it needs bit-exact implementations of:
//!
//! * symmetric blockwise INT8 quantization (q1, scale = max|x|/119),
//! * asymmetric channelwise INT4/2 compression with integer scale and
//!   zero point (q2, paper Eq. 7/8/10),
//! * the pure-integer q2 -> q1 decompression on the decode hot path,
//! * bit packing (2x INT4 or 4x INT2 per byte) for real memory savings,
//! * head-wise mixed-precision priority metrics and selection (§3.2).

pub mod asym;
pub mod headwise;
pub mod pack;
pub mod sym;

pub use asym::{dequant_asym_int, quant_asym_int, AsymBlock};
pub use headwise::{
    head_priority, head_score, select_2bit_heads, HeadStats, SelectionRule,
};
pub use pack::{pack_codes, unpack_codes, unpack_codes_into, PackedCodes};
pub use sym::{
    dequant_sym_int8, quant_sym_int8, quant_sym_int8_into, QuantBlock,
    INT8_QMAX,
};

/// Bit width for the q2 (storage) level of progressive quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Bits {
    Int2,
    Int3,
    Int4,
    /// q1-only: keep INT8, skip the progressive step (used for the query
    /// and for ablations).
    Int8,
}

impl Bits {
    pub fn levels(self) -> i32 {
        match self {
            Bits::Int2 => 3,
            Bits::Int3 => 7,
            Bits::Int4 => 15,
            Bits::Int8 => 255,
        }
    }

    pub fn bits(self) -> u32 {
        match self {
            Bits::Int2 => 2,
            Bits::Int3 => 3,
            Bits::Int4 => 4,
            Bits::Int8 => 8,
        }
    }

    /// Bytes needed to store `n` codes at this width (packed).
    pub fn packed_bytes(self, n: usize) -> usize {
        (n * self.bits() as usize).div_ceil(8)
    }

    pub fn from_bits(b: u32) -> Option<Bits> {
        match b {
            2 => Some(Bits::Int2),
            3 => Some(Bits::Int3),
            4 => Some(Bits::Int4),
            8 => Some(Bits::Int8),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_levels() {
        assert_eq!(Bits::Int2.levels(), 3);
        assert_eq!(Bits::Int4.levels(), 15);
        assert_eq!(Bits::Int8.levels(), 255);
    }

    #[test]
    fn packed_bytes() {
        assert_eq!(Bits::Int4.packed_bytes(64), 32);
        assert_eq!(Bits::Int2.packed_bytes(64), 16);
        assert_eq!(Bits::Int2.packed_bytes(3), 1);
        assert_eq!(Bits::Int4.packed_bytes(3), 2);
    }
}
