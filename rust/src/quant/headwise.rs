//! Head-wise mixed precision (paper §3.2) plus the ablation baselines of
//! Figure 7b (entropy, min-max, variation selection rules).
//!
//! `priority^(h) = gap^(h) x std^(h)` where gap is the global max-min
//! range of the head's values and std is the standard deviation of the
//! per-channel gaps. The `n_h` lowest-priority heads per layer are stored
//! at 2-bit; the rest at 4-bit.

/// Per-head statistics computed from a calibration pass over K (or V).
#[derive(Debug, Clone)]
pub struct HeadStats {
    /// Per-channel min over tokens.
    pub cmin: Vec<f32>,
    /// Per-channel max over tokens.
    pub cmax: Vec<f32>,
}

impl HeadStats {
    /// Accumulate stats from a `[tokens, channels]` row-major slab.
    pub fn from_slab(data: &[f32], tokens: usize, channels: usize) -> HeadStats {
        assert_eq!(data.len(), tokens * channels);
        let mut cmin = vec![f32::INFINITY; channels];
        let mut cmax = vec![f32::NEG_INFINITY; channels];
        for t in 0..tokens {
            for c in 0..channels {
                let v = data[t * channels + c];
                cmin[c] = cmin[c].min(v);
                cmax[c] = cmax[c].max(v);
            }
        }
        if tokens == 0 {
            cmin.iter_mut().for_each(|v| *v = 0.0);
            cmax.iter_mut().for_each(|v| *v = 0.0);
        }
        HeadStats { cmin, cmax }
    }

    /// Per-channel gaps (max - min).
    pub fn channel_gaps(&self) -> Vec<f32> {
        self.cmax.iter().zip(&self.cmin).map(|(a, b)| a - b).collect()
    }

    /// Head-level gap: range across ALL channels.
    pub fn head_gap(&self) -> f32 {
        let hi = self.cmax.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lo = self.cmin.iter().fold(f32::INFINITY, |m, &v| m.min(v));
        hi - lo
    }

    /// Std-dev of per-channel gaps.
    pub fn gap_std(&self) -> f32 {
        let gaps = self.channel_gaps();
        let mean = gaps.iter().sum::<f32>() / gaps.len() as f32;
        (gaps.iter().map(|g| (g - mean).powi(2)).sum::<f32>()
            / gaps.len() as f32)
            .sqrt()
    }

    /// Shannon entropy of the (normalized absolute) channel-gap
    /// distribution — the "Entropy" ablation baseline.
    pub fn gap_entropy(&self) -> f32 {
        let gaps = self.channel_gaps();
        let total: f32 = gaps.iter().map(|g| g.abs()).sum();
        if total <= 0.0 {
            return 0.0;
        }
        -gaps
            .iter()
            .map(|g| {
                let p = g.abs() / total;
                if p > 0.0 {
                    p * p.ln()
                } else {
                    0.0
                }
            })
            .sum::<f32>()
    }
}

/// Selection rules compared in the paper's Figure 7b ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionRule {
    /// The paper's metric: gap x std (default).
    Priority,
    /// Entropy of the channel-gap distribution.
    Entropy,
    /// Head-level min-max range only.
    MinMax,
    /// Variation (std of channel gaps) only.
    Variation,
}

/// Paper priority: gap x std (Eq. 11).
pub fn head_priority(stats: &HeadStats) -> f32 {
    stats.head_gap() * stats.gap_std()
}

/// Score a head under the given rule (higher = keep at 4-bit).
pub fn head_score(stats: &HeadStats, rule: SelectionRule) -> f32 {
    match rule {
        SelectionRule::Priority => head_priority(stats),
        SelectionRule::Entropy => stats.gap_entropy(),
        SelectionRule::MinMax => stats.head_gap(),
        SelectionRule::Variation => stats.gap_std(),
    }
}

/// Pick the `n_h` lowest-scoring heads for 2-bit storage (Eq. 12).
/// Returns a boolean mask, true = 2-bit.
pub fn select_2bit_heads(scores: &[f32], n_h: usize) -> Vec<bool> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut mask = vec![false; scores.len()];
    for &h in order.iter().take(n_h.min(scores.len())) {
        mask[h] = true;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    fn slab_with_outlier_channel(
        rng: &mut Rng,
        tokens: usize,
        channels: usize,
        outlier_c: Option<usize>,
        boost: f32,
    ) -> Vec<f32> {
        let mut d = rng.normal_vec(tokens * channels, 1.0);
        if let Some(c) = outlier_c {
            for t in 0..tokens {
                d[t * channels + c] *= boost;
            }
        }
        d
    }

    #[test]
    fn outlier_head_gets_higher_priority() {
        let mut rng = Rng::new(7);
        let plain = HeadStats::from_slab(
            &slab_with_outlier_channel(&mut rng, 64, 16, None, 1.0),
            64,
            16,
        );
        let outlier = HeadStats::from_slab(
            &slab_with_outlier_channel(&mut rng, 64, 16, Some(3), 15.0),
            64,
            16,
        );
        assert!(head_priority(&outlier) > head_priority(&plain) * 5.0);
    }

    #[test]
    fn select_lowest() {
        let scores = [3.0, 1.0, 2.0, 10.0];
        assert_eq!(select_2bit_heads(&scores, 2), vec![false, true, true, false]);
    }

    #[test]
    fn select_count_invariant() {
        prop::run("2bit head count", 100, |g| {
            let h = g.usize_in(1, 16);
            let n_h = g.usize_in(0, h + 3); // may exceed head count
            let scores: Vec<f32> = (0..h).map(|_| g.f32_in(0.0, 10.0)).collect();
            let mask = select_2bit_heads(&scores, n_h);
            assert_eq!(mask.iter().filter(|&&b| b).count(), n_h.min(h));
        });
    }

    #[test]
    fn stats_known_values() {
        // 2 tokens x 2 channels: ch0 in [1, 3], ch1 in [-2, 0].
        let s = HeadStats::from_slab(&[1.0, -2.0, 3.0, 0.0], 2, 2);
        assert_eq!(s.channel_gaps(), vec![2.0, 2.0]);
        assert_eq!(s.head_gap(), 5.0); // 3 - (-2)
        assert_eq!(s.gap_std(), 0.0);
        assert_eq!(head_priority(&s), 0.0); // uniform gaps -> std 0
    }

    #[test]
    fn entropy_uniform_gaps_maximal() {
        let uniform = HeadStats { cmin: vec![0.0; 4], cmax: vec![1.0; 4] };
        let skewed = HeadStats {
            cmin: vec![0.0; 4],
            cmax: vec![10.0, 0.1, 0.1, 0.1],
        };
        assert!(uniform.gap_entropy() > skewed.gap_entropy());
    }

    #[test]
    fn all_rules_produce_finite_scores() {
        prop::run("finite scores", 50, |g| {
            let t = g.usize_in(1, 32);
            let c = g.usize_in(1, 16);
            let data = g.normal_vec(t * c, 2.0);
            let s = HeadStats::from_slab(&data, t, c);
            for rule in [
                SelectionRule::Priority,
                SelectionRule::Entropy,
                SelectionRule::MinMax,
                SelectionRule::Variation,
            ] {
                assert!(head_score(&s, rule).is_finite());
            }
        });
    }
}
