//! TurboAttention CPU engine — paper Algorithms 1 (prefill) and 2 (decode).
//!
//! Bit-faithful mirror of the Pallas kernel / jnp oracle: INT8 symmetric
//! tile quantization, INT8xINT8->INT32 matmuls, SAS online softmax, INT8
//! quantization of the probability tile before the PV matmul, and an
//! optional progressive (INT4/2) round trip of K/V tiles to measure the
//! q2-cache effect end to end.

use crate::kernels::{ipv_acc, page_score, qk_dot_block};
use crate::pool::{balanced_chunk_sizes, ScopeError, WorkerPool};
use crate::quant::{
    dequant_asym_int, quant_asym_int, quant_sym_int8, quant_sym_int8_into,
    Bits,
};
use crate::sas::Sas;
use crate::tensor::Mat;

/// Engine configuration (paper defaults: 64/64 tiles, n_r = -6).
#[derive(Debug, Clone)]
pub struct TurboConfig {
    pub br: usize,
    pub bc: usize,
    pub n_r: f32,
    pub causal: bool,
    /// If set, round-trip K/V tiles through progressive quantization at
    /// this storage width before use (models reading the q2 cache).
    pub kv_bits: Option<Bits>,
    /// Table 4 ablation: use exact exp instead of SAS (FlashQ-only mode).
    pub exact_exp: bool,
}

impl Default for TurboConfig {
    fn default() -> Self {
        TurboConfig {
            br: 64,
            bc: 64,
            n_r: -6.0,
            causal: false,
            kv_bits: None,
            exact_exp: false,
        }
    }
}

/// TurboAttention prefill over a single head (Algorithm 1).
///
/// §Perf: both block loops run on the integer micro-kernels — the score
/// tile through [`qk_dot_block`] (4 key rows per pass, no per-index
/// bounds checks) and the P·V update through [`ipv_acc`] (exact `i32`
/// block accumulation, one `p_scale * v_scale` multiply per output
/// element per block instead of one per INT8 product).
pub fn turbo_attention(q: &Mat, k: &Mat, v: &Mat, cfg: &TurboConfig) -> Mat {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let (nq, d) = (q.rows, q.cols);
    let nk = k.rows;
    let scale = 1.0 / (d as f32).sqrt();
    let sas = Sas::new(cfg.n_r);
    let ex = |x: f32| if cfg.exact_exp { x.exp() } else { sas.exp(x) };
    let mut out = Mat::zeros(nq, d);
    // Reused integer tiles (scores for one row / P·V lanes for one row).
    let mut s32 = vec![0i32; cfg.bc];
    let mut pv = vec![0i32; d];

    let mut i0 = 0;
    while i0 < nq {
        let i1 = (i0 + cfg.br).min(nq);
        let rb = i1 - i0;
        let q_blk = q.rows_slice(i0, i1);
        let q8 = quant_sym_int8(&q_blk.data);
        let mut m = vec![f32::NEG_INFINITY; rb];
        let mut l = vec![0.0f32; rb];
        let mut acc = Mat::zeros(rb, d);

        // Causal early exit: the last row of this tile sees keys up to
        // absolute index `i0 + rb - 1 + nk - nq`, so every later column
        // tile is fully masked. Skipping them is not only a ~2x prefill
        // tile-count win — it is a correctness anchor for chunked
        // prefill: SAS `exp(0)` is `poly(0)` = 0.9996, not exactly 1.0,
        // so a fully-masked tile would still rescale `acc`/`l` by
        // `ex(0)` (cancelled by the final `acc/l` division only in
        // exact arithmetic, visible in f32 low bits). Bounding the
        // column walk by the row tile's own visibility makes the tile
        // sequence for rows [i0, i1) a function of their absolute
        // positions alone, which is what makes `CpuModel::prefill_chunk`
        // bitwise identical to a monolithic prefill.
        let j_end = if cfg.causal { (i0 + rb + nk - nq).min(nk) } else { nk };
        let mut j0 = 0;
        while j0 < j_end {
            let j1 = (j0 + cfg.bc).min(nk);
            let cb = j1 - j0;
            let mut k_blk = k.rows_slice(j0, j1);
            let mut v_blk = v.rows_slice(j0, j1);
            if let Some(bits) = cfg.kv_bits {
                roundtrip_q2(&mut k_blk, bits);
                roundtrip_q2(&mut v_blk, bits);
            }
            let k8 = quant_sym_int8(&k_blk.data);
            let v8 = quant_sym_int8(&v_blk.data);
            let sf = q8.scale * k8.scale * scale;

            // INT8 score tile: per query row, one multi-row integer
            // QK^T over the row's *visible* prefix of the key block
            // (causality truncates contiguously — key c is visible iff
            // j0 + c <= limit), then a single scale to f32.
            let mut s = vec![f32::NEG_INFINITY; rb * cb];
            for r in 0..rb {
                let vis = if cfg.causal {
                    let limit = i0 + r + nk - nq;
                    if limit < j0 { 0 } else { (limit - j0 + 1).min(cb) }
                } else {
                    cb
                };
                if vis == 0 {
                    continue;
                }
                let q_row = &q8.codes[r * d..(r + 1) * d];
                qk_dot_block(q_row, &k8.codes[..vis * d], d, &mut s32[..vis]);
                let s_row = &mut s[r * cb..r * cb + vis];
                for (sv, &si) in s_row.iter_mut().zip(&s32[..vis]) {
                    *sv = si as f32 * sf;
                }
            }

            // SAS online softmax + P quantization + INT8 PV.
            let mut p = vec![0.0f32; rb * cb];
            let mut m_new = vec![f32::NEG_INFINITY; rb];
            for r in 0..rb {
                let row = &s[r * cb..(r + 1) * cb];
                m_new[r] =
                    row.iter().fold(m[r], |a, &b| a.max(b));
                if m_new[r] == f32::NEG_INFINITY {
                    continue;
                }
                let p_row = &mut p[r * cb..(r + 1) * cb];
                for (pp, &sv) in p_row.iter_mut().zip(row) {
                    *pp = if sv.is_finite() { ex(sv - m_new[r]) } else { 0.0 };
                }
            }
            let p8 = quant_sym_int8(&p);
            let pv_sf = p8.scale * v8.scale;
            for r in 0..rb {
                if m_new[r] == f32::NEG_INFINITY {
                    continue;
                }
                let alpha = if m[r] == f32::NEG_INFINITY {
                    0.0
                } else {
                    ex(m[r] - m_new[r])
                };
                let p_row = &p[r * cb..(r + 1) * cb];
                l[r] = alpha * l[r] + p_row.iter().sum::<f32>();
                // Exact integer P·V for this row, folded into the f32
                // accumulator with one fused scale per element.
                let p8_row = &p8.codes[r * cb..(r + 1) * cb];
                ipv_acc(p8_row, &v8.codes, d, &mut pv);
                let acc_row = acc.row_mut(r);
                for (a, &pvi) in acc_row.iter_mut().zip(&pv) {
                    *a = *a * alpha + pvi as f32 * pv_sf;
                }
                m[r] = m_new[r];
            }
            j0 = j1;
        }
        for r in 0..rb {
            let inv = 1.0 / l[r].max(1e-20);
            for (o, &a) in out.row_mut(i0 + r).iter_mut().zip(acc.row(r)) {
                *o = a * inv;
            }
        }
        i0 = i1;
    }
    out
}

/// Round-trip a float tile through progressive quantization at `bits`
/// (write to q2 cache, read back) — models the decode-visible error.
fn roundtrip_q2(blk: &mut Mat, bits: Bits) {
    let q1 = quant_sym_int8(&blk.data);
    let b = quant_asym_int(&q1.codes, blk.rows, blk.cols, bits);
    let back = dequant_asym_int(&b);
    for (x, &c) in blk.data.iter_mut().zip(&back) {
        *x = c as f32 * q1.scale;
    }
}

/// Reusable buffers for [`turbo_decode_into`] — §Perf: once warm, the
/// decode inner loop allocates nothing per head per step. One instance
/// per decode thread (or per backend session) is enough; it adapts to
/// whatever `d`/`bc` each call uses.
#[derive(Debug, Default, Clone)]
pub struct DecodeScratch {
    /// Score, then probability, tile for one cache block (`bc` entries).
    s: Vec<f32>,
    /// INT32 QK^T scores for one block (before the single f32 scale).
    s32: Vec<i32>,
    /// INT8 codes of the probability tile.
    p8: Vec<i8>,
    /// Exact INT32 P·V accumulator for one block (`d` entries).
    pv: Vec<i32>,
    /// Output accumulator (`d` entries).
    acc: Vec<f32>,
    /// INT8 codes of the query.
    q8: Vec<i8>,
    /// Sparse-path page selection buffer: (envelope score, page index)
    /// per full page, sorted/truncated in place per step.
    sel: Vec<(f32, u32)>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// One TurboAttention decode step (Algorithm 2) over a q1-level cache.
///
/// `k8`/`v8` are `[nk, d]` INT8 codes grouped in blocks of `bc` rows with
/// per-block scales `sk`/`sv` (`ceil(nk/bc)` entries). Writes the
/// attention output into `out` (`[d]`) and returns (running max m,
/// denominator l) so the caller can merge not-yet-cached tokens (the
/// model's current token). All intermediates live in `scratch`.
///
/// §Perf: the block loop is built on the integer micro-kernels —
/// [`qk_dot_block`] computes the whole block's QK^T in `i32` (4 key rows
/// per pass) with one scale-to-f32 per score, [`Sas::exp_block`] runs
/// the shifted SAS exp branch-free over the block, and [`ipv_acc`] keeps
/// P·V accumulation **exactly** in `i32` so `p_scale * v_scale` is
/// applied once per output element per block (the paper's "one
/// dequantization per tile"), not once per INT8 product. Exact integer
/// accumulation is order-independent, which strengthens the decode
/// determinism contract. [`turbo_decode_into_scalar`] preserves the old
/// single-accumulator loop as the reference the kernels are benchmarked
/// and property-tested against.
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode_into(
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    nk: usize,
    bc: usize,
    n_r: f32,
    scratch: &mut DecodeScratch,
    out: &mut [f32],
) -> (f32, f32) {
    let d = q.len();
    assert_eq!(out.len(), d);
    assert!(k8.len() >= nk * d && v8.len() >= nk * d);
    let scale = 1.0 / (d as f32).sqrt();
    let sas = Sas::new(n_r);
    let q_scale = quant_sym_int8_into(q, &mut scratch.q8);
    scratch.acc.clear();
    scratch.acc.resize(d, 0.0);
    scratch.s.clear();
    scratch.s.resize(bc, 0.0);
    scratch.s32.clear();
    scratch.s32.resize(bc, 0);
    scratch.pv.clear();
    scratch.pv.resize(d, 0);

    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut j0 = 0;
    let mut blk = 0;
    while j0 < nk {
        let j1 = (j0 + bc).min(nk);
        let cb = j1 - j0;
        let sf = q_scale * sk[blk] * scale;
        // Integer QK^T for the whole block, then one scale per score.
        qk_dot_block(
            &scratch.q8,
            &k8[j0 * d..j1 * d],
            d,
            &mut scratch.s32[..cb],
        );
        let mut m_new = m;
        for (sc, &si) in scratch.s[..cb].iter_mut().zip(&scratch.s32[..cb]) {
            let v = si as f32 * sf;
            *sc = v;
            m_new = m_new.max(v);
        }
        let alpha = if m == f32::NEG_INFINITY { 0.0 } else { sas.exp(m - m_new) };
        let row_sum = sas.exp_block(&mut scratch.s[..cb], m_new);
        l = alpha * l + row_sum;
        let p_scale = quant_sym_int8_into(&scratch.s[..cb], &mut scratch.p8);
        let pv_sf = p_scale * sv[blk];
        // Exact i32 P·V for the block; fold with one fused scale.
        ipv_acc(&scratch.p8, &v8[j0 * d..j1 * d], d, &mut scratch.pv);
        for (a, &pvi) in scratch.acc.iter_mut().zip(&scratch.pv) {
            *a = *a * alpha + pvi as f32 * pv_sf;
        }
        m = m_new;
        j0 = j1;
        blk += 1;
    }
    let inv = 1.0 / l.max(1e-20);
    for (o, &a) in out.iter_mut().zip(&scratch.acc) {
        *o = a * inv;
    }
    (m, l)
}

/// The seed scalar decode loop — single-accumulator [`idot`] per key
/// row, per-element float conversion and scale in the P·V update. Kept
/// verbatim as the reference implementation the kernelized
/// [`turbo_decode_into`] is property-tested and benchmarked against
/// (`decode_bench --json` records the speedup); not for hot-path use.
/// Built on the scalar kernel arm directly (never dispatched), so it
/// stays a fixed baseline whatever ISA the process selected.
///
/// [`idot`]: crate::kernels::scalar::idot
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode_into_scalar(
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    nk: usize,
    bc: usize,
    n_r: f32,
    scratch: &mut DecodeScratch,
    out: &mut [f32],
) -> (f32, f32) {
    use crate::kernels::scalar::idot;
    let d = q.len();
    assert_eq!(out.len(), d);
    assert!(k8.len() >= nk * d && v8.len() >= nk * d);
    let scale = 1.0 / (d as f32).sqrt();
    let sas = Sas::new(n_r);
    let q_scale = quant_sym_int8_into(q, &mut scratch.q8);
    scratch.acc.clear();
    scratch.acc.resize(d, 0.0);
    scratch.s.clear();
    scratch.s.resize(bc, 0.0);

    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut j0 = 0;
    let mut blk = 0;
    while j0 < nk {
        let j1 = (j0 + bc).min(nk);
        let cb = j1 - j0;
        let sf = q_scale * sk[blk] * scale;
        let mut m_new = m;
        for c in 0..cb {
            let k_row = &k8[(j0 + c) * d..(j0 + c + 1) * d];
            let sc = idot(&scratch.q8, k_row) as f32 * sf;
            scratch.s[c] = sc;
            m_new = m_new.max(sc);
        }
        let alpha = if m == f32::NEG_INFINITY { 0.0 } else { sas.exp(m - m_new) };
        let mut row_sum = 0.0;
        for item in scratch.s.iter_mut().take(cb) {
            *item = sas.exp(*item - m_new);
            row_sum += *item;
        }
        l = alpha * l + row_sum;
        let p_scale = quant_sym_int8_into(&scratch.s[..cb], &mut scratch.p8);
        let pv_sf = p_scale * sv[blk];
        for a in scratch.acc.iter_mut() {
            *a *= alpha;
        }
        for (c, &pc) in scratch.p8.iter().enumerate() {
            if pc != 0 {
                let v_row = &v8[(j0 + c) * d..(j0 + c + 1) * d];
                let w = pc as i32;
                for (a, &vv) in scratch.acc.iter_mut().zip(v_row) {
                    *a += (w * vv as i32) as f32 * pv_sf;
                }
            }
        }
        m = m_new;
        j0 = j1;
        blk += 1;
    }
    let inv = 1.0 / l.max(1e-20);
    for (o, &a) in out.iter_mut().zip(&scratch.acc) {
        *o = a * inv;
    }
    (m, l)
}

/// Allocating convenience wrapper around [`turbo_decode_into`] (tests,
/// experiments, cold paths). Returns (output `[d]`, m, l).
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode(
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    nk: usize,
    bc: usize,
    n_r: f32,
) -> (Vec<f32>, f32, f32) {
    let mut scratch = DecodeScratch::new();
    let mut out = vec![0.0f32; q.len()];
    let (m, l) =
        turbo_decode_into(q, k8, v8, sk, sv, nk, bc, n_r, &mut scratch, &mut out);
    (out, m, l)
}

/// Deterministic top-k page selection over `(score, page index)` pairs:
/// keep the `topk` highest-scoring entries, break score ties toward the
/// **lower page index** (so selection is a pure function of the scores —
/// thread-count and chunking invariant), then reorder the survivors by
/// ascending page index so the caller's block walk folds selected pages
/// in the same order the dense loop would.
pub fn select_topk_pages(sel: &mut Vec<(f32, u32)>, topk: usize) {
    sel.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    sel.truncate(topk);
    sel.sort_unstable_by_key(|e| e.1);
}

/// The exact dense block fold of [`turbo_decode_into`], factored out so
/// the sparse path attends its selected pages (and the ragged buffer
/// tail) with the identical instruction sequence.
#[allow(clippy::too_many_arguments)]
#[inline]
fn dense_block_fold(
    k8: &[i8],
    v8: &[i8],
    j0: usize,
    j1: usize,
    d: usize,
    sf: f32,
    sv_blk: f32,
    sas: &Sas,
    scratch: &mut DecodeScratch,
    m: &mut f32,
    l: &mut f32,
) {
    let cb = j1 - j0;
    qk_dot_block(&scratch.q8, &k8[j0 * d..j1 * d], d, &mut scratch.s32[..cb]);
    let mut m_new = *m;
    for (sc, &si) in scratch.s[..cb].iter_mut().zip(&scratch.s32[..cb]) {
        let v = si as f32 * sf;
        *sc = v;
        m_new = m_new.max(v);
    }
    let alpha =
        if *m == f32::NEG_INFINITY { 0.0 } else { sas.exp(*m - m_new) };
    let row_sum = sas.exp_block(&mut scratch.s[..cb], m_new);
    *l = alpha * *l + row_sum;
    let p_scale = quant_sym_int8_into(&scratch.s[..cb], &mut scratch.p8);
    let pv_sf = p_scale * sv_blk;
    ipv_acc(&scratch.p8, &v8[j0 * d..j1 * d], d, &mut scratch.pv);
    for (a, &pvi) in scratch.acc.iter_mut().zip(&scratch.pv) {
        *a = *a * alpha + pvi as f32 * pv_sf;
    }
    *m = m_new;
}

/// SparQ-style top-k page-sparse decode step over a q1-level cache.
///
/// Same cache layout as [`turbo_decode_into`], plus per-page summaries
/// for the `nk / bc` **full** pages: `kmin`/`kmax` (`[n_pages * d]` INT8
/// key envelope) and `vmean` (`[n_pages * d]` f32 V column means in q1
/// code space). Each full page is scored with the exact-integer
/// [`page_score`] envelope bound (an upper bound on every key row's dot
/// with the query), the top `topk` pages are chosen by
/// [`select_topk_pages`], and the block walk then runs in ascending page
/// order: selected pages get the dense fold, each skipped page collapses
/// to **one** mean-value online-softmax term — its envelope-midpoint
/// score with multiplicity `bc`, weighting the page's V column mean. The
/// ragged buffer tail past the last full page is always attended
/// exactly.
///
/// Returns `(m, l, pages_attended, pages_skipped)`. `topk == 0` (knob
/// off) and `topk >= n_pages` delegate to [`turbo_decode_into`] and are
/// **bit-identical** to the dense path by construction.
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode_into_sparse(
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    kmin: &[i8],
    kmax: &[i8],
    vmean: &[f32],
    nk: usize,
    bc: usize,
    n_r: f32,
    topk: usize,
    scratch: &mut DecodeScratch,
    out: &mut [f32],
) -> (f32, f32, usize, usize) {
    let d = q.len();
    let n_pages = nk / bc;
    if topk == 0 || topk >= n_pages {
        let (m, l) =
            turbo_decode_into(q, k8, v8, sk, sv, nk, bc, n_r, scratch, out);
        return (m, l, n_pages, 0);
    }
    assert_eq!(out.len(), d);
    assert!(k8.len() >= nk * d && v8.len() >= nk * d);
    assert!(kmin.len() >= n_pages * d && kmax.len() >= n_pages * d);
    assert!(vmean.len() >= n_pages * d);
    let scale = 1.0 / (d as f32).sqrt();
    let sas = Sas::new(n_r);
    let q_scale = quant_sym_int8_into(q, &mut scratch.q8);
    scratch.acc.clear();
    scratch.acc.resize(d, 0.0);
    scratch.s.clear();
    scratch.s.resize(bc, 0.0);
    scratch.s32.clear();
    scratch.s32.resize(bc, 0);
    scratch.pv.clear();
    scratch.pv.resize(d, 0);

    // Score every full page against its key envelope. The integer bound
    // is exact and identical across kernel arms; one f32 multiply maps
    // it into score space, so selection is deterministic everywhere.
    let mut sel = std::mem::take(&mut scratch.sel);
    sel.clear();
    for blk in 0..n_pages {
        let ub = page_score(
            &scratch.q8,
            &kmin[blk * d..(blk + 1) * d],
            &kmax[blk * d..(blk + 1) * d],
        );
        sel.push((ub as f32 * (q_scale * sk[blk] * scale), blk as u32));
    }
    select_topk_pages(&mut sel, topk);

    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut next = 0usize;
    for blk in 0..n_pages {
        let j0 = blk * bc;
        let sf = q_scale * sk[blk] * scale;
        if next < sel.len() && sel[next].1 as usize == blk {
            next += 1;
            dense_block_fold(
                k8,
                v8,
                j0,
                j0 + bc,
                d,
                sf,
                sv[blk],
                &sas,
                scratch,
                &mut m,
                &mut l,
            );
        } else {
            // Skipped page: envelope-midpoint score stands in for all
            // bc rows, weighting the page's V column mean once.
            let mut mid = 0i32;
            for (j, &qc) in scratch.q8.iter().enumerate() {
                let lo = kmin[blk * d + j] as i32;
                let hi = kmax[blk * d + j] as i32;
                mid += qc as i32 * ((lo + hi) / 2);
            }
            let s_mid = mid as f32 * sf;
            let m_new = m.max(s_mid);
            let alpha =
                if m == f32::NEG_INFINITY { 0.0 } else { sas.exp(m - m_new) };
            let p = sas.exp(s_mid - m_new) * bc as f32;
            l = alpha * l + p;
            let w = p * sv[blk];
            for (a, &vm) in
                scratch.acc.iter_mut().zip(&vmean[blk * d..(blk + 1) * d])
            {
                *a = *a * alpha + w * vm;
            }
            m = m_new;
        }
    }
    // The ragged buffer tail (tokens past the last full page) holds the
    // most recent context and is always attended exactly.
    let j0 = n_pages * bc;
    if j0 < nk {
        let sf = q_scale * sk[n_pages] * scale;
        dense_block_fold(
            k8,
            v8,
            j0,
            nk,
            d,
            sf,
            sv[n_pages],
            &sas,
            scratch,
            &mut m,
            &mut l,
        );
    }
    scratch.sel = sel;
    let inv = 1.0 / l.max(1e-20);
    for (o, &a) in out.iter_mut().zip(&scratch.acc) {
        *o = a * inv;
    }
    (m, l, topk, n_pages - topk)
}

/// One decode step's attention for **every** (layer, head) stream over
/// shared q1 slabs, fanned out on a worker pool — the parallel form of
/// the per-head [`turbo_decode_into`] loop (headwise quantization makes
/// the streams fully independent, paper §3).
///
/// Layout (matching `TurboSlabs` / `KvCache::streams_mut` stream order):
/// `q` and `out` are `[n_streams * d]`; `k8`/`v8` are
/// `[n_streams * C * d]` codes with per-block scales `sk`/`sv`
/// (`[n_streams * C/bc]`); `ml` (`[n_streams]`) receives each stream's
/// (running max, denominator) for the caller's uncached-token merge.
/// `n_streams` is taken from `ml.len()`.
///
/// Streams are dealt into `min(scratches.len(), n_streams)` contiguous
/// chunks whose sizes differ by at most one (so no worker idles when
/// `n_streams` is not a multiple of the job count), one job per chunk,
/// each reusing exactly one [`DecodeScratch`] — pass one scratch per
/// pool thread for full parallelism with zero steady-state allocation.
/// Every stream's math runs serially inside its job with the same
/// instruction order as the serial loop, and jobs write disjoint
/// `out`/`ml` chunks, so the result is **bit-identical for every
/// thread count and chunking** (the parallel-parity suite enforces it).
///
/// Returns `Err` if a worker panicked (the pool stays usable).
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode_streams(
    pool: &WorkerPool,
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    d: usize,
    nk: usize,
    bc: usize,
    n_r: f32,
    scratches: &mut [DecodeScratch],
    ml: &mut [(f32, f32)],
    out: &mut [f32],
) -> Result<(), ScopeError> {
    turbo_decode_streams_with(
        pool,
        q,
        k8,
        v8,
        sk,
        sv,
        d,
        nk,
        bc,
        n_r,
        scratches,
        ml,
        out,
        turbo_decode_into,
    )
}

/// [`turbo_decode_streams`] with the scalar reference body
/// ([`turbo_decode_into_scalar`]) in place of the kernels — the
/// like-for-like baseline `decode_bench` pits the kernelized fan-out
/// against at every (ctx, threads) point.
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode_streams_scalar(
    pool: &WorkerPool,
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    d: usize,
    nk: usize,
    bc: usize,
    n_r: f32,
    scratches: &mut [DecodeScratch],
    ml: &mut [(f32, f32)],
    out: &mut [f32],
) -> Result<(), ScopeError> {
    turbo_decode_streams_with(
        pool,
        q,
        k8,
        v8,
        sk,
        sv,
        d,
        nk,
        bc,
        n_r,
        scratches,
        ml,
        out,
        turbo_decode_into_scalar,
    )
}

/// Per-stream decode body a stream fan-out runs — the kernelized
/// [`turbo_decode_into`] or the scalar [`turbo_decode_into_scalar`].
type DecodeStreamFn = fn(
    &[f32],
    &[i8],
    &[i8],
    &[f32],
    &[f32],
    usize,
    usize,
    f32,
    &mut DecodeScratch,
    &mut [f32],
) -> (f32, f32);

/// Shared fan-out driver behind both stream entry points; the scheduling
/// (dealing, chunk sizes, write disjointness) is identical, so the
/// bit-determinism argument covers the kernelized and scalar paths the
/// same way.
#[allow(clippy::too_many_arguments)]
fn turbo_decode_streams_with(
    pool: &WorkerPool,
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    d: usize,
    nk: usize,
    bc: usize,
    n_r: f32,
    scratches: &mut [DecodeScratch],
    ml: &mut [(f32, f32)],
    out: &mut [f32],
    decode: DecodeStreamFn,
) -> Result<(), ScopeError> {
    let n_streams = ml.len();
    if n_streams == 0 {
        return Ok(());
    }
    assert!(!scratches.is_empty(), "need at least one DecodeScratch");
    assert_eq!(q.len(), n_streams * d, "q is [n_streams * d]");
    assert_eq!(out.len(), n_streams * d, "out is [n_streams * d]");
    let c = k8.len() / (n_streams * d);
    let nb = sk.len() / n_streams;
    assert!(nk <= c, "nk {nk} exceeds per-stream capacity {c}");
    assert!(v8.len() >= n_streams * c * d && sv.len() >= n_streams * nb);
    let n_jobs_cap = scratches.len();
    pool.scope(move |scope| {
        let mut out_rest = out;
        let mut ml_rest = ml;
        let mut first = 0usize;
        let mut scratch_it = scratches.iter_mut();
        for len in balanced_chunk_sizes(n_streams, n_jobs_cap) {
            let scratch =
                scratch_it.next().expect("one scratch per dealt group");
            let (out_c, tail) =
                std::mem::take(&mut out_rest).split_at_mut(len * d);
            out_rest = tail;
            let (ml_c, tail) =
                std::mem::take(&mut ml_rest).split_at_mut(len);
            ml_rest = tail;
            let start = first;
            first += len;
            scope.execute(move || {
                for (j, (o, ml_slot)) in
                    out_c.chunks_mut(d).zip(ml_c.iter_mut()).enumerate()
                {
                    let i = start + j;
                    let base = i * c * d;
                    let sbase = i * nb;
                    *ml_slot = decode(
                        &q[i * d..(i + 1) * d],
                        &k8[base..base + c * d],
                        &v8[base..base + c * d],
                        &sk[sbase..sbase + nb],
                        &sv[sbase..sbase + nb],
                        nk,
                        bc,
                        n_r,
                        scratch,
                        o,
                    );
                }
            });
        }
    })?;
    Ok(())
}

/// Top-k page-sparse form of [`turbo_decode_streams`]: every stream runs
/// [`turbo_decode_into_sparse`] with its own slice of the per-page
/// summary slabs `kmin`/`kmax` (`[n_streams * (C/bc) * d]` INT8) and
/// `vmean` (same shape, f32). Scheduling (dealing, chunk sizes, write
/// disjointness) is identical to the dense driver, and each stream's
/// page selection is a pure function of its own data, so the result is
/// bit-identical for every thread count and chunking.
///
/// Per-stream attended/skipped page counts are written to disjoint
/// chunks inside the scope and summed after it — no atomics on the hot
/// path. Returns `(pages_attended, pages_skipped)` totals across all
/// streams, or `Err` if a worker panicked.
#[allow(clippy::too_many_arguments)]
pub fn turbo_decode_streams_sparse(
    pool: &WorkerPool,
    q: &[f32],
    k8: &[i8],
    v8: &[i8],
    sk: &[f32],
    sv: &[f32],
    kmin: &[i8],
    kmax: &[i8],
    vmean: &[f32],
    d: usize,
    nk: usize,
    bc: usize,
    n_r: f32,
    topk: usize,
    scratches: &mut [DecodeScratch],
    ml: &mut [(f32, f32)],
    out: &mut [f32],
) -> Result<(u64, u64), ScopeError> {
    let n_streams = ml.len();
    if n_streams == 0 {
        return Ok((0, 0));
    }
    assert!(!scratches.is_empty(), "need at least one DecodeScratch");
    assert_eq!(q.len(), n_streams * d, "q is [n_streams * d]");
    assert_eq!(out.len(), n_streams * d, "out is [n_streams * d]");
    let c = k8.len() / (n_streams * d);
    let nb = sk.len() / n_streams;
    assert!(nk <= c, "nk {nk} exceeds per-stream capacity {c}");
    assert!(v8.len() >= n_streams * c * d && sv.len() >= n_streams * nb);
    let sums = (c / bc) * d;
    assert!(
        kmin.len() >= n_streams * sums
            && kmax.len() >= n_streams * sums
            && vmean.len() >= n_streams * sums,
        "summary slabs are [n_streams * (C/bc) * d]"
    );
    let n_jobs_cap = scratches.len();
    let mut counts = vec![(0usize, 0usize); n_streams];
    {
        let counts = &mut counts[..];
        pool.scope(move |scope| {
            let mut out_rest = out;
            let mut ml_rest = ml;
            let mut cnt_rest = counts;
            let mut first = 0usize;
            let mut scratch_it = scratches.iter_mut();
            for len in balanced_chunk_sizes(n_streams, n_jobs_cap) {
                let scratch =
                    scratch_it.next().expect("one scratch per dealt group");
                let (out_c, tail) =
                    std::mem::take(&mut out_rest).split_at_mut(len * d);
                out_rest = tail;
                let (ml_c, tail) =
                    std::mem::take(&mut ml_rest).split_at_mut(len);
                ml_rest = tail;
                let (cnt_c, tail) =
                    std::mem::take(&mut cnt_rest).split_at_mut(len);
                cnt_rest = tail;
                let start = first;
                first += len;
                scope.execute(move || {
                    for (j, ((o, ml_slot), cnt)) in out_c
                        .chunks_mut(d)
                        .zip(ml_c.iter_mut())
                        .zip(cnt_c.iter_mut())
                        .enumerate()
                    {
                        let i = start + j;
                        let base = i * c * d;
                        let sbase = i * nb;
                        let mbase = i * sums;
                        let (m, l, att, skip) = turbo_decode_into_sparse(
                            &q[i * d..(i + 1) * d],
                            &k8[base..base + c * d],
                            &v8[base..base + c * d],
                            &sk[sbase..sbase + nb],
                            &sv[sbase..sbase + nb],
                            &kmin[mbase..mbase + sums],
                            &kmax[mbase..mbase + sums],
                            &vmean[mbase..mbase + sums],
                            nk,
                            bc,
                            n_r,
                            topk,
                            scratch,
                            o,
                        );
                        *ml_slot = (m, l);
                        *cnt = (att, skip);
                    }
                });
            }
        })?;
    }
    let mut attended = 0u64;
    let mut skipped = 0u64;
    for &(a, s) in &counts {
        attended += a as u64;
        skipped += s as u64;
    }
    Ok((attended, skipped))
}

/// Merge one extra (uncached) token into a decode result via SAS online
/// softmax — the model-side float merge (model.py `_sas_merge_token`),
/// **in place** over `out` so the decode hot loop allocates nothing.
/// Element order and arithmetic match [`sas_merge_token`] exactly.
pub fn sas_merge_token_into(
    out: &mut [f32],
    m: f32,
    l: f32,
    s_new: f32,
    v_new: &[f32],
    n_r: f32,
) {
    let sas = Sas::new(n_r);
    let m_tot = m.max(s_new);
    let alpha = if m == f32::NEG_INFINITY { 0.0 } else { sas.exp(m - m_tot) };
    let p_new = sas.exp(s_new - m_tot);
    let l_tot = (alpha * l + p_new).max(1e-20);
    for (o, &v) in out.iter_mut().zip(v_new) {
        *o = (alpha * l * *o + p_new * v) / l_tot;
    }
}

/// Allocating convenience form of [`sas_merge_token_into`] (tests and
/// cold paths).
pub fn sas_merge_token(
    out: &[f32],
    m: f32,
    l: f32,
    s_new: f32,
    v_new: &[f32],
    n_r: f32,
) -> Vec<f32> {
    let mut merged = out.to_vec();
    sas_merge_token_into(&mut merged, m, l, s_new, v_new, n_r);
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_exact;
    use crate::quant::quant_sym_int8;
    use crate::testutil::{prop, Rng};

    #[test]
    fn close_to_exact_attention() {
        prop::run("turbo ~ exact", 40, |g| {
            let nq = g.usize_in(1, 40);
            let nk = g.usize_in(nq, 48);
            let d = g.usize_in(4, 24);
            let causal = g.bool();
            let q = Mat::from_vec(nq, d, g.normal_vec(nq * d, 1.0));
            let k = Mat::from_vec(nk, d, g.normal_vec(nk * d, 1.0));
            let v = Mat::from_vec(nk, d, g.normal_vec(nk * d, 1.0));
            let cfg = TurboConfig { br: 16, bc: 16, causal, ..Default::default() };
            let a = turbo_attention(&q, &k, &v, &cfg);
            let b = attention_exact(&q, &k, &v, causal);
            let rel = a.rel_err(&b);
            assert!(rel < 0.08, "rel err {rel}");
        });
    }

    #[test]
    fn tiling_invariance_up_to_quant_noise() {
        prop::run("turbo tiling", 30, |g| {
            let n = g.usize_in(4, 32);
            let d = g.usize_in(4, 16);
            let q = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let k = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let v = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let c1 = TurboConfig { br: 8, bc: 8, causal: true, ..Default::default() };
            let c2 = TurboConfig { br: 16, bc: 4, causal: true, ..Default::default() };
            let a = turbo_attention(&q, &k, &v, &c1);
            let b = turbo_attention(&q, &k, &v, &c2);
            assert!(a.rel_err(&b) < 0.06);
        });
    }

    #[test]
    fn causal_tail_query_rows_match_monolithic_bitwise() {
        // The chunked-prefill contract: a causal call with q = rows
        // [s, e) and k/v = rows [0, e) (tail-query semantics, nq < nk)
        // must reproduce the monolithic call's rows [s, e) to the bit,
        // for any block-aligned chunk start s. This is exact — not
        // tolerance — because the early exit makes both calls process
        // identical tile sequences with identical quantization groups.
        prop::run("chunked rows == monolithic", 20, |g| {
            let b = 8usize; // br == bc tile, chunk alignment
            let n = b * g.usize_in(2, 5) - g.usize_in(0, b - 1);
            let d = g.usize_in(4, 16);
            let q = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let k = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let v = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let cfg =
                TurboConfig { br: b, bc: b, causal: true, ..Default::default() };
            let mono = turbo_attention(&q, &k, &v, &cfg);
            let mut s = 0;
            while s < n {
                let e = (s + b * g.usize_in(1, 2)).min(n);
                let out = turbo_attention(
                    &q.rows_slice(s, e),
                    &k.rows_slice(0, e),
                    &v.rows_slice(0, e),
                    &cfg,
                );
                for r in 0..(e - s) {
                    let got: Vec<u32> =
                        out.row(r).iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        mono.row(s + r).iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "row {} of chunk [{s},{e})", s + r);
                }
                s = e;
            }
        });
    }

    #[test]
    fn kv_bits_4_better_than_2() {
        prop::run("q2 width ordering", 20, |g| {
            let n = 32;
            let d = 16;
            let q = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let k = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let v = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let exact = attention_exact(&q, &k, &v, true);
            let err = |bits| {
                let cfg = TurboConfig {
                    br: 16,
                    bc: 16,
                    causal: true,
                    kv_bits: Some(bits),
                    ..Default::default()
                };
                turbo_attention(&q, &k, &v, &cfg).rel_err(&exact)
            };
            assert!(err(Bits::Int4) <= err(Bits::Int2) + 0.02);
        });
    }

    #[test]
    fn decode_matches_prefill_last_row() {
        prop::run("decode == prefill tail", 30, |g| {
            let nk = g.usize_in(1, 40);
            let d = g.usize_in(4, 16);
            let bc = 8;
            let q = g.normal_vec(d, 1.0);
            let kf = g.normal_vec(nk * d, 1.0);
            let vf = g.normal_vec(nk * d, 1.0);
            // Build the q1 cache per block (as the kvcache would).
            let nb = nk.div_ceil(bc);
            let mut k8 = vec![0i8; nk * d];
            let mut v8 = vec![0i8; nk * d];
            let mut sk = vec![0.0f32; nb];
            let mut sv = vec![0.0f32; nb];
            for b in 0..nb {
                let lo = b * bc;
                let hi = ((b + 1) * bc).min(nk);
                let qk = quant_sym_int8(&kf[lo * d..hi * d]);
                k8[lo * d..hi * d].copy_from_slice(&qk.codes);
                sk[b] = qk.scale;
                let qv = quant_sym_int8(&vf[lo * d..hi * d]);
                v8[lo * d..hi * d].copy_from_slice(&qv.codes);
                sv[b] = qv.scale;
            }
            let (out, _m, l) = turbo_decode(&q, &k8, &v8, &sk, &sv, nk, bc, -6.0);
            assert!(l > 0.0);
            // Compare against exact attention over the dequantized cache.
            let kd: Vec<f32> = (0..nk * d)
                .map(|i| k8[i] as f32 * sk[i / (bc * d)])
                .collect();
            let vd: Vec<f32> = (0..nk * d)
                .map(|i| v8[i] as f32 * sv[i / (bc * d)])
                .collect();
            let qm = Mat::from_vec(1, d, q.clone());
            let km = Mat::from_vec(nk, d, kd);
            let vm = Mat::from_vec(nk, d, vd);
            let want = attention_exact(&qm, &km, &vm, false);
            let got = Mat::from_vec(1, d, out);
            let rel = got.rel_err(&want);
            assert!(rel < 0.08, "rel {rel}");
        });
    }

    #[test]
    fn decode_scratch_reuse_is_bit_identical() {
        prop::run("decode scratch reuse", 30, |g| {
            let nk = g.usize_in(1, 40);
            let d = g.usize_in(4, 16);
            let bc = 8;
            let nb = nk.div_ceil(bc);
            let q = g.normal_vec(d, 1.0);
            let mut k8 = vec![0i8; nk * d];
            let mut v8 = vec![0i8; nk * d];
            for c in k8.iter_mut().chain(v8.iter_mut()) {
                *c = (g.usize_in(0, 255) as i32 - 127) as i8;
            }
            let sk: Vec<f32> = (0..nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let sv: Vec<f32> = (0..nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let (want, wm, wl) =
                turbo_decode(&q, &k8, &v8, &sk, &sv, nk, bc, -6.0);
            // A warm scratch (dirtied by an unrelated call) must not
            // change results.
            let mut scratch = DecodeScratch::new();
            let mut out = vec![0.0f32; d];
            turbo_decode_into(
                &q, &v8, &k8, &sv, &sk, nk, bc, -6.0, &mut scratch, &mut out,
            );
            let (m, l) = turbo_decode_into(
                &q, &k8, &v8, &sk, &sv, nk, bc, -6.0, &mut scratch, &mut out,
            );
            assert_eq!(out, want);
            assert_eq!(m, wm);
            assert_eq!(l, wl);
        });
    }

    #[test]
    fn decode_streams_bit_identical_to_serial_loop() {
        // The parallel fan-out is a pure scheduler: for any pool width
        // and scratch count it must reproduce the serial per-stream
        // loop to the bit.
        prop::run("decode streams == serial", 15, |g| {
            let n_streams = g.usize_in(1, 9);
            let d = g.usize_in(4, 12);
            let bc = 4;
            let c = 16;
            let nb = c / bc;
            let nk = g.usize_in(1, c);
            let q = g.normal_vec(n_streams * d, 1.0);
            let mut k8 = vec![0i8; n_streams * c * d];
            let mut v8 = vec![0i8; n_streams * c * d];
            for x in k8.iter_mut().chain(v8.iter_mut()) {
                *x = (g.usize_in(0, 255) as i32 - 127) as i8;
            }
            let sk: Vec<f32> =
                (0..n_streams * nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let sv: Vec<f32> =
                (0..n_streams * nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            // Serial oracle: the old per-head loop.
            let mut want = vec![0.0f32; n_streams * d];
            let mut want_ml = vec![(0.0f32, 0.0f32); n_streams];
            let mut scratch = DecodeScratch::new();
            for i in 0..n_streams {
                let base = i * c * d;
                let sbase = i * nb;
                want_ml[i] = turbo_decode_into(
                    &q[i * d..(i + 1) * d],
                    &k8[base..base + c * d],
                    &v8[base..base + c * d],
                    &sk[sbase..sbase + nb],
                    &sv[sbase..sbase + nb],
                    nk,
                    bc,
                    -6.0,
                    &mut scratch,
                    &mut want[i * d..(i + 1) * d],
                );
            }
            for threads in [1usize, 2, 4] {
                let pool = WorkerPool::new(threads);
                let n_scratch = g.usize_in(1, threads + 2);
                let mut scratches = vec![DecodeScratch::new(); n_scratch];
                let mut out = vec![0.0f32; n_streams * d];
                let mut ml = vec![(0.0f32, 0.0f32); n_streams];
                turbo_decode_streams(
                    &pool, &q, &k8, &v8, &sk, &sv, d, nk, bc, -6.0,
                    &mut scratches, &mut ml, &mut out,
                )
                .expect("no panics");
                assert_eq!(out, want, "outputs (threads={threads})");
                assert_eq!(ml, want_ml, "(m, l) (threads={threads})");
            }
        });
    }

    #[test]
    fn kernelized_decode_tracks_scalar_reference() {
        // The kernels change only *where* rounding happens in the P·V
        // fold (exact i32 sum + one scale vs per-product f32 scale), so
        // against the scalar reference: scores, the running max and the
        // denominator are **bit-identical**, and the output agrees to
        // f32 rounding.
        prop::run("kernel decode ~ scalar decode", 40, |g| {
            let nk = g.usize_in(1, 64);
            let d = g.usize_in(1, 24);
            let bc = *g.choose(&[3usize, 4, 8, 16]);
            let nb = nk.div_ceil(bc);
            let q = g.normal_vec(d, 1.0);
            let mut k8 = vec![0i8; nk * d];
            let mut v8 = vec![0i8; nk * d];
            for x in k8.iter_mut().chain(v8.iter_mut()) {
                *x = match g.usize_in(0, 9) {
                    0 => 127,
                    1 => -127,
                    2 => -128,
                    _ => (g.usize_in(0, 255) as i32 - 127) as i8,
                };
            }
            let sk: Vec<f32> = (0..nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let sv: Vec<f32> = (0..nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let mut scratch = DecodeScratch::new();
            let mut want = vec![0.0f32; d];
            let (wm, wl) = turbo_decode_into_scalar(
                &q, &k8, &v8, &sk, &sv, nk, bc, -6.0, &mut scratch, &mut want,
            );
            let mut got = vec![0.0f32; d];
            let (m, l) = turbo_decode_into(
                &q, &k8, &v8, &sk, &sv, nk, bc, -6.0, &mut scratch, &mut got,
            );
            assert_eq!(m.to_bits(), wm.to_bits(), "running max");
            assert_eq!(l.to_bits(), wl.to_bits(), "denominator");
            let a = Mat::from_vec(1, d, got);
            let b = Mat::from_vec(1, d, want);
            let rel = a.rel_err(&b);
            assert!(rel < 1e-4, "rel {rel} (nk={nk} d={d} bc={bc})");
        });
    }

    #[test]
    fn scalar_streams_fanout_matches_scalar_serial_loop() {
        // The shared fan-out driver must be a pure scheduler for the
        // scalar body too (decode_bench relies on it as the baseline).
        let (n_streams, d, bc, c) = (5usize, 8usize, 4usize, 16usize);
        let nb = c / bc;
        let nk = 13;
        let mut rng = Rng::new(0x5CA1A);
        let q = rng.normal_vec(n_streams * d, 1.0);
        let mut k8 = vec![0i8; n_streams * c * d];
        let mut v8 = vec![0i8; n_streams * c * d];
        for x in k8.iter_mut().chain(v8.iter_mut()) {
            *x = (rng.range(0, 255) as i32 - 127) as i8;
        }
        let sk: Vec<f32> =
            (0..n_streams * nb).map(|_| rng.f32() + 0.01).collect();
        let sv: Vec<f32> =
            (0..n_streams * nb).map(|_| rng.f32() + 0.01).collect();
        let mut scratch = DecodeScratch::new();
        let mut want = vec![0.0f32; n_streams * d];
        let mut want_ml = vec![(0.0f32, 0.0f32); n_streams];
        for i in 0..n_streams {
            let base = i * c * d;
            let sbase = i * nb;
            want_ml[i] = turbo_decode_into_scalar(
                &q[i * d..(i + 1) * d],
                &k8[base..base + c * d],
                &v8[base..base + c * d],
                &sk[sbase..sbase + nb],
                &sv[sbase..sbase + nb],
                nk,
                bc,
                -6.0,
                &mut scratch,
                &mut want[i * d..(i + 1) * d],
            );
        }
        for threads in [1usize, 3] {
            let pool = WorkerPool::new(threads);
            let mut scratches = vec![DecodeScratch::new(); threads];
            let mut ml = vec![(0.0f32, 0.0f32); n_streams];
            let mut out = vec![0.0f32; n_streams * d];
            turbo_decode_streams_scalar(
                &pool, &q, &k8, &v8, &sk, &sv, d, nk, bc, -6.0,
                &mut scratches, &mut ml, &mut out,
            )
            .expect("no panics");
            assert_eq!(out, want, "threads={threads}");
            assert_eq!(ml, want_ml, "threads={threads}");
        }
    }

    #[test]
    fn merge_token_dominant_new_token() {
        // If the new token's score dwarfs the cache, output -> v_new.
        let out = vec![1.0, 2.0];
        let merged =
            sas_merge_token(&out, -3.0, 2.0, 50.0, &[9.0, -9.0], -6.0);
        assert!((merged[0] - 9.0).abs() < 1e-3);
        assert!((merged[1] + 9.0).abs() < 1e-3);
    }

    /// Per-page key envelope + V column mean over `[rows * d]` q1 codes
    /// (capacity pages: every full page of the slab, used or not) — the
    /// same reduction the pool's `PageSummary` memo performs.
    fn page_summaries(
        k8: &[i8],
        v8: &[i8],
        rows: usize,
        d: usize,
        bc: usize,
    ) -> (Vec<i8>, Vec<i8>, Vec<f32>) {
        let n_pages = rows / bc;
        let mut kmin = vec![i8::MAX; n_pages * d];
        let mut kmax = vec![i8::MIN; n_pages * d];
        let mut vmean = vec![0.0f32; n_pages * d];
        for b in 0..n_pages {
            for t in 0..bc {
                for j in 0..d {
                    let kc = k8[(b * bc + t) * d + j];
                    kmin[b * d + j] = kmin[b * d + j].min(kc);
                    kmax[b * d + j] = kmax[b * d + j].max(kc);
                    vmean[b * d + j] += v8[(b * bc + t) * d + j] as f32;
                }
            }
            for j in 0..d {
                vmean[b * d + j] /= bc as f32;
            }
        }
        (kmin, kmax, vmean)
    }

    #[test]
    fn select_topk_breaks_ties_toward_lower_page_index() {
        let mut sel = vec![(1.0f32, 3u32), (2.0, 1), (1.0, 0), (2.0, 4)];
        select_topk_pages(&mut sel, 3);
        // Scores 2.0 (pages 1, 4) survive; the 1.0 tie goes to page 0,
        // not page 3; survivors come back in ascending page order.
        assert_eq!(sel, vec![(1.0, 0), (2.0, 1), (2.0, 4)]);
        let mut sel = vec![(5.0f32, 2u32), (5.0, 1), (5.0, 0)];
        select_topk_pages(&mut sel, 2);
        assert_eq!(sel, vec![(5.0, 0), (5.0, 1)]);
    }

    #[test]
    fn sparse_knob_off_or_k_covering_matches_dense_bitwise() {
        // topk == 0 (knob off) and topk >= n_pages must be the dense
        // path to the bit — the engine's "sparse off" contract.
        prop::run("sparse covering == dense", 25, |g| {
            let d = g.usize_in(4, 16);
            let bc = 8;
            let nk = g.usize_in(1, 5 * bc);
            let n_pages = nk / bc;
            let nb = nk.div_ceil(bc);
            let q = g.normal_vec(d, 1.0);
            let mut k8 = vec![0i8; nk * d];
            let mut v8 = vec![0i8; nk * d];
            for x in k8.iter_mut().chain(v8.iter_mut()) {
                *x = (g.usize_in(0, 255) as i32 - 127) as i8;
            }
            let sk: Vec<f32> = (0..nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let sv: Vec<f32> = (0..nb).map(|_| g.f32_in(0.01, 1.0)).collect();
            let (kmin, kmax, vmean) = page_summaries(&k8, &v8, nk, d, bc);
            let mut scratch = DecodeScratch::new();
            let mut want = vec![0.0f32; d];
            let (wm, wl) = turbo_decode_into(
                &q, &k8, &v8, &sk, &sv, nk, bc, -6.0, &mut scratch, &mut want,
            );
            for topk in [0usize, n_pages, n_pages + 3] {
                let mut out = vec![0.0f32; d];
                let (m, l, att, skip) = turbo_decode_into_sparse(
                    &q, &k8, &v8, &sk, &sv, &kmin, &kmax, &vmean, nk, bc,
                    -6.0, topk, &mut scratch, &mut out,
                );
                assert_eq!(m.to_bits(), wm.to_bits(), "m (topk={topk})");
                assert_eq!(l.to_bits(), wl.to_bits(), "l (topk={topk})");
                let got: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                let dense: Vec<u32> =
                    want.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, dense, "out (topk={topk})");
                assert_eq!((att, skip), (n_pages, 0), "counters");
            }
        });
    }

    #[test]
    fn sparse_skips_pages_attends_tail_and_stays_close_to_dense() {
        // Aggressive k on a multi-page cache with a ragged tail: the
        // counters account every full page exactly once, the tail is
        // always attended, and the mean-value fold keeps the output in
        // the dense output's neighborhood.
        let mut rng = Rng::new(0x70D4);
        let (d, bc) = (8usize, 8usize);
        let n_pages = 5;
        let tail = 3;
        let nk = n_pages * bc + tail;
        let nb = nk.div_ceil(bc);
        let q = rng.normal_vec(d, 1.0);
        let mut k8 = vec![0i8; nk * d];
        let mut v8 = vec![0i8; nk * d];
        for x in k8.iter_mut().chain(v8.iter_mut()) {
            *x = (rng.range(0, 255) as i32 - 127) as i8;
        }
        let sk: Vec<f32> = (0..nb).map(|_| rng.f32() * 0.5 + 0.01).collect();
        let sv: Vec<f32> = (0..nb).map(|_| rng.f32() * 0.5 + 0.01).collect();
        let (kmin, kmax, vmean) = page_summaries(&k8, &v8, nk, d, bc);
        let mut scratch = DecodeScratch::new();
        let mut dense = vec![0.0f32; d];
        turbo_decode_into(
            &q, &k8, &v8, &sk, &sv, nk, bc, -6.0, &mut scratch, &mut dense,
        );
        for topk in [1usize, 2, 4] {
            let mut out = vec![0.0f32; d];
            let (m, l, att, skip) = turbo_decode_into_sparse(
                &q, &k8, &v8, &sk, &sv, &kmin, &kmax, &vmean, nk, bc, -6.0,
                topk, &mut scratch, &mut out,
            );
            assert_eq!(att, topk, "attended (topk={topk})");
            assert_eq!(skip, n_pages - topk, "skipped (topk={topk})");
            assert!(m.is_finite() && l > 0.0, "softmax state (topk={topk})");
            let a = Mat::from_vec(1, d, out);
            let b = Mat::from_vec(1, d, dense.clone());
            let rel = a.rel_err(&b);
            assert!(rel < 0.6, "rel {rel} (topk={topk})");
        }
        // Single full page, k = 1: covering — dense to the bit.
        let nk1 = bc;
        let (kmin1, kmax1, vmean1) = page_summaries(&k8, &v8, nk1, d, bc);
        let mut want = vec![0.0f32; d];
        let (wm, wl) = turbo_decode_into(
            &q, &k8, &v8, &sk, &sv, nk1, bc, -6.0, &mut scratch, &mut want,
        );
        let mut out = vec![0.0f32; d];
        let (m, l, att, skip) = turbo_decode_into_sparse(
            &q, &k8, &v8, &sk, &sv, &kmin1, &kmax1, &vmean1, nk1, bc, -6.0,
            1, &mut scratch, &mut out,
        );
        assert_eq!((m.to_bits(), l.to_bits()), (wm.to_bits(), wl.to_bits()));
        assert_eq!(out, want);
        assert_eq!((att, skip), (1, 0));
        // Ragged-only cache (no full page): any k is covering.
        let nk2 = bc - 1;
        let mut out = vec![0.0f32; d];
        let (_, _, att, skip) = turbo_decode_into_sparse(
            &q, &k8, &v8, &sk, &sv, &[], &[], &[], nk2, bc, -6.0, 1,
            &mut scratch, &mut out,
        );
        assert_eq!((att, skip), (0, 0));
    }

    #[test]
    fn sparse_streams_fanout_bit_identical_across_threads() {
        // The sparse fan-out is a pure scheduler too: serial per-stream
        // sparse calls are the oracle for every thread count, and the
        // summed counters match the per-stream sum exactly.
        let (n_streams, d, bc, c) = (6usize, 8usize, 4usize, 24usize);
        let nb = c / bc;
        let nk = 19; // 4 full pages + ragged tail of 3
        let topk = 2;
        let mut rng = Rng::new(0x51AB5);
        let q = rng.normal_vec(n_streams * d, 1.0);
        let mut k8 = vec![0i8; n_streams * c * d];
        let mut v8 = vec![0i8; n_streams * c * d];
        for x in k8.iter_mut().chain(v8.iter_mut()) {
            *x = (rng.range(0, 255) as i32 - 127) as i8;
        }
        let sk: Vec<f32> =
            (0..n_streams * nb).map(|_| rng.f32() + 0.01).collect();
        let sv: Vec<f32> =
            (0..n_streams * nb).map(|_| rng.f32() + 0.01).collect();
        // Capacity-shaped summary slabs, as TurboSlabs carries them.
        let sums = (c / bc) * d;
        let mut kmin = vec![0i8; n_streams * sums];
        let mut kmax = vec![0i8; n_streams * sums];
        let mut vmean = vec![0.0f32; n_streams * sums];
        for i in 0..n_streams {
            let base = i * c * d;
            let (lo, hi, mu) =
                page_summaries(&k8[base..base + c * d], &v8[base..base + c * d], c, d, bc);
            kmin[i * sums..(i + 1) * sums].copy_from_slice(&lo);
            kmax[i * sums..(i + 1) * sums].copy_from_slice(&hi);
            vmean[i * sums..(i + 1) * sums].copy_from_slice(&mu);
        }
        let mut scratch = DecodeScratch::new();
        let mut want = vec![0.0f32; n_streams * d];
        let mut want_ml = vec![(0.0f32, 0.0f32); n_streams];
        let mut want_att = 0u64;
        let mut want_skip = 0u64;
        for i in 0..n_streams {
            let base = i * c * d;
            let sbase = i * nb;
            let mbase = i * sums;
            let (m, l, att, skip) = turbo_decode_into_sparse(
                &q[i * d..(i + 1) * d],
                &k8[base..base + c * d],
                &v8[base..base + c * d],
                &sk[sbase..sbase + nb],
                &sv[sbase..sbase + nb],
                &kmin[mbase..mbase + sums],
                &kmax[mbase..mbase + sums],
                &vmean[mbase..mbase + sums],
                nk,
                bc,
                -6.0,
                topk,
                &mut scratch,
                &mut want[i * d..(i + 1) * d],
            );
            want_ml[i] = (m, l);
            want_att += att as u64;
            want_skip += skip as u64;
        }
        assert!(want_skip > 0, "fixture must actually skip pages");
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let mut scratches = vec![DecodeScratch::new(); threads];
            let mut ml = vec![(0.0f32, 0.0f32); n_streams];
            let mut out = vec![0.0f32; n_streams * d];
            let (att, skip) = turbo_decode_streams_sparse(
                &pool, &q, &k8, &v8, &sk, &sv, &kmin, &kmax, &vmean, d, nk,
                bc, -6.0, topk, &mut scratches, &mut ml, &mut out,
            )
            .expect("no panics");
            assert_eq!(out, want, "outputs (threads={threads})");
            assert_eq!(ml, want_ml, "(m, l) (threads={threads})");
            assert_eq!((att, skip), (want_att, want_skip), "counters");
        }
    }

    #[test]
    fn merge_token_empty_cache() {
        let merged = sas_merge_token(
            &[0.0, 0.0],
            f32::NEG_INFINITY,
            0.0,
            0.3,
            &[4.0, 5.0],
            -6.0,
        );
        assert!((merged[0] - 4.0).abs() < 1e-4);
        assert!((merged[1] - 5.0).abs() < 1e-4);
    }
}
