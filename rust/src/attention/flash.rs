//! FP32 tiled FlashAttention with exact exp — the paper's baseline.
//!
//! Same online-softmax dataflow as the turbo engine but without tile
//! quantization or SAS, so diffs between the two isolate exactly what
//! TurboAttention changes (used by Table 4's FlashQ-only/SAS-only
//! ablation).

use crate::tensor::{dot, Mat};

/// Tiled exact attention with running (m, l, acc) state.
pub fn flash_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    br: usize,
    bc: usize,
    causal: bool,
) -> Mat {
    let (nq, d) = (q.rows, q.cols);
    let nk = k.rows;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Mat::zeros(nq, d);

    let mut i0 = 0;
    while i0 < nq {
        let i1 = (i0 + br).min(nq);
        let rb = i1 - i0;
        let mut m = vec![f32::NEG_INFINITY; rb];
        let mut l = vec![0.0f32; rb];
        let mut acc = Mat::zeros(rb, d);

        let mut j0 = 0;
        while j0 < nk {
            let j1 = (j0 + bc).min(nk);
            let cb = j1 - j0;
            // Scores for this tile.
            let mut s = vec![f32::NEG_INFINITY; rb * cb];
            for r in 0..rb {
                let limit = if causal { i0 + r + nk - nq } else { usize::MAX };
                let q_row = q.row(i0 + r);
                for c in 0..cb {
                    if j0 + c <= limit {
                        s[r * cb + c] = dot(q_row, k.row(j0 + c)) * scale;
                    }
                }
            }
            for r in 0..rb {
                let row = &mut s[r * cb..(r + 1) * cb];
                let m_new = row
                    .iter()
                    .fold(m[r], |a, &b| a.max(b));
                if m_new == f32::NEG_INFINITY {
                    continue; // fully masked tile row
                }
                let alpha = if m[r] == f32::NEG_INFINITY {
                    0.0
                } else {
                    (m[r] - m_new).exp()
                };
                let mut row_sum = 0.0;
                for p in row.iter_mut() {
                    *p = if p.is_finite() { (*p - m_new).exp() } else { 0.0 };
                    row_sum += *p;
                }
                l[r] = alpha * l[r] + row_sum;
                let acc_row = acc.row_mut(r);
                for a in acc_row.iter_mut() {
                    *a *= alpha;
                }
                for (c, &p) in row.iter().enumerate() {
                    if p != 0.0 {
                        let v_row = v.row(j0 + c);
                        for (a, &vv) in acc_row.iter_mut().zip(v_row) {
                            *a += p * vv;
                        }
                    }
                }
                m[r] = m_new;
            }
            j0 = j1;
        }
        for r in 0..rb {
            let inv = 1.0 / l[r].max(1e-20);
            let acc_row = acc.row(r);
            let o_row = out.row_mut(i0 + r);
            for (o, &a) in o_row.iter_mut().zip(acc_row) {
                *o = a * inv;
            }
        }
        i0 = i1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_exact;
    use crate::testutil::prop;

    #[test]
    fn matches_exact_attention() {
        prop::run("flash == exact", 60, |g| {
            let nq = g.usize_in(1, 40);
            let nk = g.usize_in(nq, 48);
            let d = g.usize_in(1, 24);
            let br = *g.choose(&[4usize, 8, 16]);
            let bc = *g.choose(&[4usize, 8, 16]);
            let causal = g.bool();
            let q = Mat::from_vec(nq, d, g.normal_vec(nq * d, 1.0));
            let k = Mat::from_vec(nk, d, g.normal_vec(nk * d, 1.0));
            let v = Mat::from_vec(nk, d, g.normal_vec(nk * d, 1.0));
            let a = flash_attention(&q, &k, &v, br, bc, causal);
            let b = attention_exact(&q, &k, &v, causal);
            let rel = a.rel_err(&b);
            assert!(rel < 1e-5, "rel err {rel}");
        });
    }

    #[test]
    fn single_tile_equals_multi_tile() {
        prop::run("tiling invariance", 40, |g| {
            let n = g.usize_in(2, 32);
            let d = g.usize_in(1, 16);
            let q = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let k = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let v = Mat::from_vec(n, d, g.normal_vec(n * d, 1.0));
            let one = flash_attention(&q, &k, &v, n, n, true);
            let many = flash_attention(&q, &k, &v, 3, 5, true);
            assert!(one.rel_err(&many) < 1e-5);
        });
    }
}
