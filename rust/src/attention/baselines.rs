//! Baseline KV-cache compression comparators: KIVI and GEAR-L.
//!
//! Both compress K/V for storage but decompress to FLOAT before running
//! exact attention — the dequantization overhead TurboAttention removes
//! (paper Figure 1b/6). Implementations follow the cited papers at the
//! fidelity Table 2 needs:
//!
//! * KIVI (Liu et al. 2024): per-channel grouped asymmetric quantization
//!   for K, per-token grouped for V; the last `n_b` residual tokens stay
//!   in full precision.
//! * GEAR-L (Kang et al. 2024): group quantization plus a rank-r low-rank
//!   approximation of the residual error; full-precision residual tokens.

use crate::tensor::Mat;

/// Asymmetric float-scale group fake-quant along an axis.
///
/// `axis = 0`: groups of `group` consecutive *tokens* share a scale per
/// channel (KIVI key mode / "channelwise"). `axis = 1`: groups of
/// consecutive *channels* share a scale per token (KIVI value mode /
/// "tokenwise"). Returns the dequantized matrix.
pub fn fake_quant_grouped(x: &Mat, bits: u32, group: usize, axis: usize) -> Mat {
    let levels = ((1u32 << bits) - 1) as f32;
    let mut out = x.clone();
    match axis {
        0 => {
            let mut g0 = 0;
            while g0 < x.rows {
                let g1 = (g0 + group).min(x.rows);
                for c in 0..x.cols {
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for r in g0..g1 {
                        lo = lo.min(x.get(r, c));
                        hi = hi.max(x.get(r, c));
                    }
                    let scale = ((hi - lo) / levels).max(1e-8);
                    for r in g0..g1 {
                        let q = ((x.get(r, c) - lo) / scale).round().clamp(0.0, levels);
                        out.set(r, c, q * scale + lo);
                    }
                }
                g0 = g1;
            }
        }
        1 => {
            for r in 0..x.rows {
                let mut g0 = 0;
                while g0 < x.cols {
                    let g1 = (g0 + group).min(x.cols);
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for c in g0..g1 {
                        lo = lo.min(x.get(r, c));
                        hi = hi.max(x.get(r, c));
                    }
                    let scale = ((hi - lo) / levels).max(1e-8);
                    for c in g0..g1 {
                        let q = ((x.get(r, c) - lo) / scale).round().clamp(0.0, levels);
                        out.set(r, c, q * scale + lo);
                    }
                    g0 = g1;
                }
            }
        }
        _ => panic!("axis must be 0 or 1"),
    }
    out
}

/// KIVI-style cache compression of a `[tokens, d]` K or V slab.
///
/// The trailing `n_b` tokens (the residual buffer) stay full precision.
pub fn kivi_compress(x: &Mat, bits: u32, group: usize, n_b: usize, is_key: bool) -> Mat {
    let cut = x.rows.saturating_sub(n_b);
    if cut == 0 {
        return x.clone();
    }
    let head = x.rows_slice(0, cut);
    let axis = if is_key { 0 } else { 1 };
    let mut out = fake_quant_grouped(&head, bits, group, axis);
    // Re-attach the full-precision residual tokens.
    out.data.extend_from_slice(&x.data[cut * x.cols..]);
    out.rows = x.rows;
    out
}

/// Rank-r approximation of a matrix via subspace iteration (GEAR's
/// low-rank error-compensation term; r is small so this is cheap).
pub fn low_rank_approx(x: &Mat, r: usize, iters: usize) -> Mat {
    let (m, n) = (x.rows, x.cols);
    let r = r.min(m.min(n));
    if r == 0 {
        return Mat::zeros(m, n);
    }
    // Deterministic init: leading columns of x^T x power iteration.
    let mut rng = crate::testutil::Rng::new(0x6EA5);
    let mut basis = Mat::randn(&mut rng, n, r, 1.0); // [n, r]
    for _ in 0..iters.max(1) {
        // y = x @ basis [m, r]
        let y = x.matmul(&basis);
        // basis = x^T @ y, then orthonormalize (Gram-Schmidt).
        let mut xt_y = Mat::zeros(n, r);
        for i in 0..m {
            let x_row = x.row(i);
            let y_row = y.row(i);
            for c in 0..n {
                for j in 0..r {
                    xt_y.data[c * r + j] += x_row[c] * y_row[j];
                }
            }
        }
        gram_schmidt(&mut xt_y);
        basis = xt_y;
    }
    // Project: x ~= (x @ basis) @ basis^T.
    let coeff = x.matmul(&basis); // [m, r]
    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let c_row = coeff.row(i);
        let o_row = out.row_mut(i);
        for j in 0..r {
            let b_col = j;
            for c in 0..n {
                o_row[c] += c_row[j] * basis.data[c * r + b_col];
            }
        }
    }
    out
}

fn gram_schmidt(a: &mut Mat) {
    let (n, r) = (a.rows, a.cols);
    for j in 0..r {
        for prev in 0..j {
            let mut dot = 0.0f32;
            for i in 0..n {
                dot += a.data[i * r + j] * a.data[i * r + prev];
            }
            for i in 0..n {
                let sub = dot * a.data[i * r + prev];
                a.data[i * r + j] -= sub;
            }
        }
        let mut norm = 0.0f32;
        for i in 0..n {
            norm += a.data[i * r + j].powi(2);
        }
        let inv = 1.0 / norm.sqrt().max(1e-12);
        for i in 0..n {
            a.data[i * r + j] *= inv;
        }
    }
}

/// GEAR-L: group quantization + rank-r compensation of the residual.
pub fn gear_compress(x: &Mat, bits: u32, group: usize, n_b: usize, rank: usize) -> Mat {
    let cut = x.rows.saturating_sub(n_b);
    if cut == 0 {
        return x.clone();
    }
    let head = x.rows_slice(0, cut);
    let quantized = fake_quant_grouped(&head, bits, group, 0);
    // Residual error and its low-rank approximation.
    let mut resid = head.clone();
    for (r, &q) in resid.data.iter_mut().zip(&quantized.data) {
        *r -= q;
    }
    let lr = low_rank_approx(&resid, rank, 2);
    let mut out = quantized;
    for (o, &l) in out.data.iter_mut().zip(&lr.data) {
        *o += l;
    }
    out.data.extend_from_slice(&x.data[cut * x.cols..]);
    out.rows = x.rows;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    #[test]
    fn fake_quant_reduces_to_identity_at_high_bits() {
        let mut rng = Rng::new(0);
        let x = Mat::randn(&mut rng, 32, 8, 1.0);
        let q = fake_quant_grouped(&x, 16, 8, 0);
        assert!(x.rel_err(&q) < 1e-3);
    }

    #[test]
    fn channelwise_beats_tokenwise_with_channel_outliers() {
        // Figure 10's claim, reproduced as a unit test.
        let mut rng = Rng::new(1);
        let mut x = Mat::randn(&mut rng, 128, 32, 1.0);
        for r in 0..128 {
            x.data[r * 32 + 3] *= 12.0;
            x.data[r * 32 + 17] *= 8.0;
        }
        let chan = fake_quant_grouped(&x, 4, 32, 0);
        let tok = fake_quant_grouped(&x, 4, 32, 1);
        assert!(x.mse(&chan) < x.mse(&tok));
    }

    #[test]
    fn kivi_preserves_residual_tokens() {
        let mut rng = Rng::new(2);
        let x = Mat::randn(&mut rng, 40, 8, 1.0);
        let out = kivi_compress(&x, 2, 8, 16, true);
        // Last 16 tokens bit-identical.
        assert_eq!(&out.data[24 * 8..], &x.data[24 * 8..]);
        // Compressed head differs (2-bit is lossy).
        assert!(out.rows_slice(0, 24).mse(&x.rows_slice(0, 24)) > 0.0);
    }

    #[test]
    fn low_rank_exact_for_low_rank_input() {
        // Rank-1 matrix recovered exactly by rank-1 approximation.
        let u = [1.0f32, -2.0, 0.5];
        let v = [3.0f32, 1.0, -1.0, 2.0];
        let mut x = Mat::zeros(3, 4);
        for i in 0..3 {
            for j in 0..4 {
                x.set(i, j, u[i] * v[j]);
            }
        }
        let a = low_rank_approx(&x, 1, 4);
        assert!(x.rel_err(&a) < 1e-3, "rel {}", x.rel_err(&a));
    }

    #[test]
    fn gear_beats_plain_quant() {
        prop::run("gear <= kivi error", 15, |g| {
            let x = Mat::from_vec(48, 16, g.normal_vec(48 * 16, 1.0));
            let plain = fake_quant_grouped(&x, 2, 16, 0);
            let gear = gear_compress(&x, 2, 16, 0, 4);
            assert!(x.mse(&gear) <= x.mse(&plain) * 1.05);
        });
    }

    #[test]
    fn small_inputs_dont_panic() {
        let x = Mat::from_vec(1, 1, vec![3.0]);
        let _ = kivi_compress(&x, 2, 4, 0, true);
        let _ = gear_compress(&x, 2, 4, 0, 2);
        let _ = low_rank_approx(&x, 3, 2);
    }
}
