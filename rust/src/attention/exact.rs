//! Exact softmax attention — the single-head accuracy oracle.

use crate::tensor::Mat;

/// `softmax(Q K^T / sqrt(d)) V` over `[nq,d] x [nk,d] x [nk,d]`.
///
/// With `causal`, query row i sees key positions `<= i + nk - nq`
/// (the query block is the tail of the context).
pub fn attention_exact(q: &Mat, k: &Mat, v: &Mat, causal: bool) -> Mat {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let d = q.cols;
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores = q.matmul_t(k);
    for s in scores.data.iter_mut() {
        *s *= scale;
    }
    if causal {
        for i in 0..scores.rows {
            let limit = i + k.rows - q.rows;
            for j in 0..scores.cols {
                if j > limit {
                    scores.set(i, j, f32::NEG_INFINITY);
                }
            }
        }
    }
    for i in 0..scores.rows {
        crate::sas::softmax_row_exact(scores.row_mut(i));
    }
    scores.matmul(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    #[test]
    fn uniform_scores_average_values() {
        // q == 0 -> uniform attention -> output = mean of V rows.
        let q = Mat::zeros(1, 4);
        let mut rng = Rng::new(0);
        let k = Mat::randn(&mut rng, 3, 4, 1.0);
        let v = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let o = attention_exact(&q, &k, &v, false);
        assert!((o.get(0, 0) - 3.0).abs() < 1e-5);
        assert!((o.get(0, 1) - 4.0).abs() < 1e-5);
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(&mut rng, 4, 8, 1.0);
        let k = Mat::randn(&mut rng, 4, 8, 1.0);
        let v = Mat::randn(&mut rng, 4, 8, 1.0);
        let o = attention_exact(&q, &k, &v, true);
        for c in 0..8 {
            assert!((o.get(0, c) - v.get(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn rows_are_convex_combinations() {
        prop::run("attention output in V convex hull", 50, |g| {
            let nq = g.usize_in(1, 12);
            let nk = g.usize_in(nq, 16);
            let d = g.usize_in(1, 16);
            let q = Mat::from_vec(nq, d, g.normal_vec(nq * d, 1.0));
            let k = Mat::from_vec(nk, d, g.normal_vec(nk * d, 1.0));
            let v = Mat::from_vec(nk, d, g.normal_vec(nk * d, 1.0));
            let o = attention_exact(&q, &k, &v, false);
            for c in 0..d {
                let vmin = (0..nk).map(|r| v.get(r, c)).fold(f32::INFINITY, f32::min);
                let vmax = (0..nk).map(|r| v.get(r, c)).fold(f32::NEG_INFINITY, f32::max);
                for r in 0..nq {
                    let x = o.get(r, c);
                    assert!(x >= vmin - 1e-4 && x <= vmax + 1e-4);
                }
            }
        });
    }
}
