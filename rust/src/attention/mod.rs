//! Attention engines (CPU substrate).
//!
//! Four engines over identical `[N, d]` single-head inputs:
//!
//! * [`exact`]   — reference softmax attention (the accuracy oracle).
//! * [`flash`]   — FP32 tiled FlashAttention with exact exp (the paper's
//!   baseline; numerically equal to `exact` up to fp error).
//! * [`turbo`]   — TurboAttention (Algorithms 1/2): INT8 tile matmuls +
//!   SAS online softmax + progressive q2 cache. The paper's contribution.
//! * [`baselines`] — KIVI and GEAR-L KV-cache compression comparators
//!   (dequantize-to-float then exact attention), for Table 2 / Figure 6.
//!
//! These run the same math as the Pallas kernels (validated against the
//! same jnp oracles via golden vectors in `rust/tests/`), so accuracy
//! experiments can sweep configurations without a Python round trip.
//!
//! [`backend`] sits above the engines: the pluggable serving-path
//! interface ([`backend::AttentionBackend`]) the coordinator drives, with
//! three implementations — the executable-backed turbo path, the exact
//! flash baseline, and the artifact-free `TurboCpu` path that serves
//! through these CPU engines (integer kernels + `turbo_decode_streams`)
//! directly.

pub mod backend;
pub mod baselines;
pub mod exact;
pub mod flash;
pub mod turbo;

pub use backend::{
    backend_for, AttentionBackend, BackendState, DynBackend, FlashBackend,
    PathMode, TurboBackend, TurboCpuBackend,
};
pub use crate::kernels::{idot_mr, ipv_acc, qk_dot_block};
pub use exact::attention_exact;
pub use flash::flash_attention;
pub use turbo::{
    select_topk_pages, turbo_attention, turbo_decode, turbo_decode_into,
    turbo_decode_into_scalar, turbo_decode_into_sparse, turbo_decode_streams,
    turbo_decode_streams_scalar, turbo_decode_streams_sparse, DecodeScratch,
    TurboConfig,
};

/// Causal-mask helper: is key position `kpos` visible to query row `qrow`
/// when the query block is the tail of an `nk`-token context?
#[inline]
pub fn causal_visible(qrow: usize, kpos: usize, nq: usize, nk: usize) -> bool {
    kpos <= qrow + nk - nq
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_self_attention() {
        // nq == nk: strictly lower-triangular + diagonal.
        assert!(causal_visible(0, 0, 4, 4));
        assert!(!causal_visible(0, 1, 4, 4));
        assert!(causal_visible(3, 3, 4, 4));
    }

    #[test]
    fn causal_decode_tail() {
        // 1 query over 8 keys: sees everything.
        for k in 0..8 {
            assert!(causal_visible(0, k, 1, 8));
        }
    }
}
